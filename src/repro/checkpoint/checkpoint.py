"""Pytree checkpointing: .npz tensor store + JSON manifest.

Keeps FedPC state (global model + history + costs) restartable. Paths are
keyed by the flattened pytree path so restores are structure-checked.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.utils import PyTree


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, tree: PyTree, step: int,
                    metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    ckpt = os.path.join(directory, f"ckpt_{step:08d}")
    # npz cannot store bf16/fp8 — persist as a same-width uint view, the
    # manifest records the true dtype for restore.
    storable = {
        k: (v.view(np.uint16) if v.dtype == ml_dtypes.bfloat16 else v)
        for k, v in arrays.items()
    }
    np.savez(ckpt + ".npz", **storable)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "metadata": metadata or {},
    }
    with open(ckpt + ".json", "w") as f:
        json.dump(manifest, f, indent=1)
    return ckpt + ".npz"


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_"):-len(".npz")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, like: PyTree,
                    step: int | None = None) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (strict key/shape check)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    ckpt = os.path.join(directory, f"ckpt_{step:08d}")
    with open(ckpt + ".json") as f:
        manifest = json.load(f)
    data = np.load(ckpt + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, v in paths:
        key = _path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(v.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {v.shape}")
        leaves.append(jnp.asarray(arr, dtype=v.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
