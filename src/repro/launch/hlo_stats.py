"""Loop-aware HLO cost extraction from ``compiled.as_text()``.

XLA's built-in cost analysis counts a while-loop body ONCE regardless of
trip count, which under-reports scanned layer stacks by ~n_layers× and
recurrent time-scans by ~seq_len×. This module parses the post-partitioning
HLO, builds the computation call graph (entry → while bodies → nested
whiles / fusions), extracts each loop's trip count from its condition
computation, and accumulates:

  * FLOPs       — dot ops: 2 · prod(result dims) · contracted size, with
                  operand shapes resolved through a module-wide name→shape
                  table (optimized HLO prints operands by name only);
  * bytes       — per *top-level* op: operand + result bytes. Ops inside
                  fusion computations are skipped (they live in
                  registers/VMEM on TPU), so this approximates fused-TPU
                  HBM traffic rather than the CPU backend's op soup;
  * collectives — all-gather / all-reduce / reduce-scatter / all-to-all /
                  collective-permute result bytes × ring-model factors
                  (per participating device).

Everything is multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPL_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")

_COLLECTIVE_KINDS = {"all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute", "ragged-all-to-all"}

# ops whose operand/result traffic we do not count at top level
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota", "copy-start",
               "copy-done"}


def _parse_shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shapes_bytes(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    kind: str
    line: str
    result_shapes: list          # [(dtype, dims)]
    operand_names: list

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.result_shapes)

    def group_size(self) -> int:
        gm = _REPL_GROUPS_RE.search(self.line)
        if gm:
            return max(len([x for x in gm.group(1).split(",") if x.strip()]),
                       2)
        gm2 = _REPL_GROUPS_IOTA_RE.search(self.line)
        if gm2:
            return max(int(gm2.group(2)), 2)
        return 2


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    calls: list = field(default_factory=list)   # (kind, callee, cond_name)


def parse_module(text: str):
    """Returns (computations, name→result_shapes table, entry name)."""
    comps: dict[str, Computation] = {}
    shapes: dict[str, list] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        ms = _COMP_START_RE.match(line)
        if ms and "{" in line:
            cur = Computation(ms.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, res_part, kind = mo.groups()
        operand_part = line.split("(", 1)[1].split(")")[0] \
            if "(" in line else ""
        op = Op(name, kind, line.rstrip(), _parse_shapes(res_part),
                _OPERAND_RE.findall(operand_part))
        cur.ops.append(op)
        shapes[name] = op.result_shapes
        if kind == "while":
            body = cond = None
            for attr, val in re.findall(r"(body|condition)=%?([\w\.\-_]+)",
                                        line):
                if attr == "body":
                    body = val
                else:
                    cond = val
            if body:
                cur.calls.append(("while", body, cond))
        else:
            for val in re.findall(
                    r"(?:calls|to_apply)=%?([\w\.\-_]+)", line):
                cur.calls.append(("call", val, None))
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for callee in re.split(r"[,\s%]+", bm.group(1)):
                    if callee:
                        cur.calls.append(("call", callee, None))
    return comps, shapes, entry


def _trip_count(comps, cond_name: str | None) -> int:
    if not cond_name or cond_name not in comps:
        return 1
    best = 1
    for op in comps[cond_name].ops:
        for c in _CONST_RE.findall(op.line):
            best = max(best, int(c))
    return best


def multipliers(comps, entry: str) -> dict[str, float]:
    mult: dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        return mult
    mult[entry] = 1.0
    for _ in range(64):
        changed = False
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for kind, callee, cond in comp.calls:
                if callee not in comps:
                    continue
                inc = m * (_trip_count(comps, cond) if kind == "while" else 1)
                if mult[callee] < inc:
                    mult[callee] = inc
                    changed = True
        if not changed:
            break
    return mult


def _dot_flops(op: Op, shapes: dict) -> float:
    if not op.result_shapes:
        return 0.0
    res_elems = 1
    for d in op.result_shapes[0][1]:
        res_elems *= d
    if not op.operand_names:
        return 0.0
    lhs = shapes.get(op.operand_names[0])
    if not lhs:
        return 0.0
    lhs_dims = lhs[0][1]
    contract = 1
    cm = _DOT_LHS_C_RE.search(op.line)
    if cm:
        for idx in cm.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * res_elems * contract


def _operand_bytes(op: Op, shapes: dict) -> int:
    total = 0
    for nm in op.operand_names:
        s = shapes.get(nm)
        if s:
            total += _shapes_bytes(s)
    return total


# Operands at or below this size that are re-read every iteration of a loop
# stay resident in VMEM on TPU (v5e: 128 MiB/chip VMEM; we use a
# conservative 16 MiB) — count them once, not once per trip.
VMEM_RESIDENT_LIMIT = 16 * 1024 * 1024


def _amortized_operands(op: Op, shapes: dict, m: float) -> float:
    """Total operand read-bytes across m loop trips with VMEM residency."""
    total = 0.0
    for nm in op.operand_names:
        sh = shapes.get(nm)
        if not sh:
            continue
        b = _shapes_bytes(sh)
        total += b if (m > 1 and b <= VMEM_RESIDENT_LIMIT) else b * m
    return total


def _result_traffic(op: Op, m: float, is_carry: bool) -> float:
    """Result write-bytes across m trips. Small per-iteration intermediates
    fuse into VMEM on TPU (count once); values carried through the loop
    tuple round-trip HBM every iteration (count ×m)."""
    b = op.result_bytes
    if m > 1 and not is_carry and b <= VMEM_RESIDENT_LIMIT:
        return float(b)
    return float(b * m)


def _op_traffic(op: Op, shapes: dict, m: float = 1.0,
                is_carry: bool = False) -> float:
    """Approximate HBM bytes for one op across m loop trips.

    dynamic-slice / gather touch only the slice (≈ 2× result);
    dynamic-update-slice / scatter touch only the written region (≈ 2× the
    update operand; the full buffer aliases in place on TPU). Everything
    else: operands (VMEM-amortized) + result.
    """
    kind = op.kind
    if kind in ("dynamic-slice", "gather"):
        return 2.0 * op.result_bytes * m
    if kind == "dynamic-update-slice":
        upd = shapes.get(op.operand_names[1]) if len(op.operand_names) > 1 \
            else None
        return (2.0 * _shapes_bytes(upd) if upd
                else 2.0 * op.result_bytes) * m
    if kind == "scatter":
        upd = shapes.get(op.operand_names[2]) if len(op.operand_names) > 2 \
            else None
        idx = shapes.get(op.operand_names[1]) if len(op.operand_names) > 1 \
            else None
        b = 2.0 * _shapes_bytes(upd) if upd else 2.0 * op.result_bytes
        return (b + (_shapes_bytes(idx) if idx else 0.0)) * m
    return float(_result_traffic(op, m, is_carry)
                 + _amortized_operands(op, shapes, m))


@dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_device_bytes: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    collective_bytes_by_kind: dict = field(default_factory=dict)
    loop_trip_counts: dict = field(default_factory=dict)

    def to_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_device_bytes": self.collective_device_bytes,
            "collective_counts": self.collective_counts,
            "collective_bytes_by_kind": self.collective_bytes_by_kind,
        }


def _collective_moved(kind: str, result_bytes: int, g: int) -> float:
    kind = kind.replace("-start", "")
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes
    if kind in ("all-gather", "all-to-all", "ragged-all-to-all"):
        return (g - 1) / g * result_bytes
    if kind == "reduce-scatter":
        return (g - 1) * result_bytes          # operand = result × g
    return float(result_bytes)                  # collective-permute


def analyze(text: str) -> HloStats:
    comps, shapes, entry = parse_module(text)
    if entry is None:
        entry = next(iter(comps), None)
    mult = multipliers(comps, entry)

    fused: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                for val in re.findall(r"calls=%?([\w\.\-_]+)", op.line):
                    fused.add(val)

    # root op kind per computation — a fusion rooted in dynamic-update-slice
    # is an in-place cache write on TPU (buffer aliasing): its traffic is the
    # written slice, not the whole buffer. Same for dynamic-slice reads.
    root_kind: dict[str, str] = {}
    has_dus: set[str] = set()
    has_ds: set[str] = set()
    carry_names: dict[str, set] = {}
    while_bodies = set()
    for comp in comps.values():
        for kind, callee, cond in comp.calls:
            if kind == "while":
                while_bodies.add(callee)
    for cname, comp in comps.items():
        for op in comp.ops:
            if op.kind == "dynamic-update-slice":
                has_dus.add(cname)
            if op.kind == "dynamic-slice":
                has_ds.add(cname)
            if op.line.lstrip().startswith("ROOT"):
                root_kind[cname] = op.kind
                if cname in while_bodies and op.kind == "tuple":
                    carry_names[cname] = set(op.operand_names)

    def fusion_traffic(op: Op, m: float, is_carry: bool) -> float:
        callee = None
        mm = re.search(r"calls=%?([\w\.\-_]+)", op.line)
        if mm:
            callee = mm.group(1)
        rk = root_kind.get(callee, "")
        opnd = [(_shapes_bytes(shapes[nm]), nm) for nm in op.operand_names
                if nm in shapes]
        total_in = sum(b for b, _ in opnd)
        big_in = max((b for b, _ in opnd), default=0)
        # a fusion containing a dynamic-update-slice whose output matches
        # its largest input aliases in place on TPU: traffic ≈ the slice
        if callee in has_dus and opnd and \
                abs(big_in - op.result_bytes) <= 0.25 * op.result_bytes:
            return 2.0 * (total_in - big_in) * m
        # a fusion that internally dynamic-slices a large buffer reads only
        # the slice: charge 2× result + the small operands
        if callee in has_ds and opnd and op.result_bytes < 0.5 * big_in:
            small = total_in - big_in
            return (2.0 * op.result_bytes + small) * m
        if rk == "dynamic-slice":
            return 2.0 * op.result_bytes * m
        return float(_result_traffic(op, m, is_carry)
                     + _amortized_operands(op, shapes, m))

    stats = HloStats()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for op in comp.ops:
            if op.kind == "dot":
                stats.flops += m * _dot_flops(op, shapes)
            base_kind = op.kind.replace("-start", "").replace("-done", "")
            if base_kind in _COLLECTIVE_KINDS and not op.kind.endswith(
                    "-done"):
                rb = op.result_bytes
                g = op.group_size()
                moved = m * _collective_moved(op.kind, rb, g)
                stats.collective_device_bytes += moved
                stats.collective_counts[base_kind] = \
                    stats.collective_counts.get(base_kind, 0) + int(m)
                stats.collective_bytes_by_kind[base_kind] = \
                    stats.collective_bytes_by_kind.get(base_kind, 0.0) + moved
                continue
            if in_fusion or op.kind in _SKIP_BYTES:
                continue
            is_carry = op.name in carry_names.get(cname, ())
            if op.kind == "fusion":
                stats.bytes += fusion_traffic(op, m, is_carry)
            else:
                stats.bytes += _op_traffic(op, shapes, m, is_carry)
        for kind, callee, cond in comp.calls:
            if kind == "while":
                stats.loop_trip_counts[callee] = _trip_count(comps, cond)
    return stats
