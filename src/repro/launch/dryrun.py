import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod, all combos
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Results are appended to benchmarks/results/dryrun.json (one record per
combo) for benchmarks/roofline.py and EXPERIMENTS.md.

NOTE: the XLA_FLAGS line above must execute before ANY jax import — jax
locks the device count at first init. Do not set this flag globally:
smoke tests and benches should see 1 device.
"""
import argparse
import json
import time
import traceback

import jax  # noqa: E402  (must come after the XLA_FLAGS line)

from repro.configs import ASSIGNED, get_config
from repro.launch import analysis as an
from repro.launch import hlo_stats
from repro.launch.mesh import chips, make_production_mesh
from repro.launch.specs import SHAPES, input_specs, shape_supported

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            save_hlo: bool = False, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "skipped", "reason": why,
    }
    if not ok:
        if verbose:
            print(f"[dryrun] SKIP {arch} × {shape_name}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = chips(mesh)
    t0 = time.time()
    try:
        spec = input_specs(cfg, shape_name, mesh)
        with jax.set_mesh(mesh):
            lowered = jax.jit(spec.fn).lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        stats = hlo_stats.analyze(hlo)        # loop-aware FLOPs/bytes/colls
        info = SHAPES[shape_name]
        n_tokens = info["batch"] * (info["seq"] if info["kind"] != "decode"
                                    else 1)
        rl = an.roofline_from_stats(stats, n_chips, cfg, n_tokens,
                                    info["kind"])

        mem_rec = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_rec[k] = int(v)

        rec.update({
            "status": "ok",
            "chips": n_chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem_rec,
            "cost_raw": {k: float(v) for k, v in cost.items()
                         if isinstance(v, (int, float))
                         and k in ("flops", "bytes accessed",
                                   "transcendentals")},
            "collectives": {
                "counts": stats.collective_counts,
                "bytes_by_kind": {k: float(v) for k, v in
                                  stats.collective_bytes_by_kind.items()},
                "device_bytes": float(stats.collective_device_bytes),
            },
            "loop_trip_counts": stats.loop_trip_counts,
            "roofline": rl.to_dict(),
        })
        if save_hlo:
            os.makedirs(RESULTS, exist_ok=True)
            with open(os.path.join(
                    RESULTS, f"hlo_{arch}_{shape_name}_{rec['mesh']}.txt"),
                    "w") as f:
                f.write(hlo)
        if verbose:
            dom = rl.dominant
            print(f"[dryrun] OK   {arch} × {shape_name} × {rec['mesh']} "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s) "
                  f"compute {rl.compute_s*1e3:.2f}ms | "
                  f"memory {rl.memory_s*1e3:.2f}ms | "
                  f"collective {rl.collective_s*1e3:.2f}ms → {dom}-bound")
    except Exception as e:  # a failure here is a bug in the system
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[dryrun] FAIL {arch} × {shape_name}: "
                  f"{type(e).__name__}: {str(e)[:400]}")
    return rec


def run_fed(arch: str, strategy: str, multi_pod: bool = False,
            local_steps: int = 1, local_batch: int = 16, seq: int = 4096,
            save_hlo: bool = False, verbose: bool = True) -> dict:
    """Dry-run one federated round step (local train × sync strategy).

    Fed workers = the 'data'/'pod'-axis slices; this measures the paper's
    protocol as mesh collectives: fedavg (fp weights) vs fedpc (int8
    ternary) vs fedpc_packed (2-bit codes) — the Fig. 6 comparison in HLO
    bytes.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.fed.distributed import build_fed_step
    from repro.models.model import build_model
    from repro.optim.optimizers import momentum
    from repro.sharding.specs import param_specs

    cfg = get_config(arch).replace(param_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    fed_axis = "pod" if multi_pod else "data"
    F = mesh.shape[fed_axis]
    rec = {
        "arch": arch, "shape": f"fed_{strategy}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok", "fed_workers": F,
    }
    t0 = time.time()
    from repro.sharding import activations as _act
    try:
        _act.set_disabled(True)
        model = build_model(cfg, optimizer=momentum(accum_dtype=jnp.bfloat16))
        fed_step = build_fed_step(model, mesh, fed_axis, strategy, lr=0.01)

        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        raw_specs = param_specs(params_shape, mesh)

        def _drop(spec):
            # the fed axis is consumed by the worker dimension; within a
            # slice the model is sharded over 'model' only
            def drop_ax(s):
                if s == fed_axis:
                    return None
                if isinstance(s, tuple):
                    kept = tuple(a for a in s if a != fed_axis)
                    return kept if len(kept) > 1 else (kept[0] if kept
                                                       else None)
                return s
            return P(*[drop_ax(s) for s in spec])

        pspecs = jax.tree_util.tree_map(
            _drop, raw_specs,
            is_leaf=lambda x: isinstance(x, P))

        def sds(leaf, spec, lead=()):
            return jax.ShapeDtypeStruct(
                tuple(lead) + leaf.shape, leaf.dtype,
                sharding=NamedSharding(mesh, P(*( (fed_axis,) if lead else ())
                                               , *spec)))

        params = jax.tree_util.tree_map(
            lambda l, s: sds(l, s), params_shape, pspecs)
        opt_shape = jax.eval_shape(model.optimizer.init, params_shape)
        opt_specs = jax.tree_util.tree_map(
            _drop, param_specs(opt_shape, mesh),
            is_leaf=lambda x: isinstance(x, P))
        opt_F = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(
                (F,) + l.shape, l.dtype,
                sharding=NamedSharding(mesh, P(fed_axis, *s))),
            opt_shape, opt_specs)
        state = {
            "params": params,
            "params_prev": params,
            "prev_costs": jax.ShapeDtypeStruct((F,), jnp.float32),
            "round": jax.ShapeDtypeStruct((), jnp.int32),
        }
        batch_F = {"tokens": jax.ShapeDtypeStruct(
            (F, local_steps, local_batch, seq), jnp.int32,
            sharding=NamedSharding(mesh, P(fed_axis, None, None, None)))}
        sizes = jax.ShapeDtypeStruct((F,), jnp.float32)

        with jax.set_mesh(mesh):
            lowered = jax.jit(fed_step).lower(state, opt_F, batch_F, sizes)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        stats = hlo_stats.analyze(hlo)
        n_tokens = F * local_steps * local_batch * seq
        rl = an.roofline_from_stats(stats, chips(mesh), cfg, n_tokens,
                                    "train")
        mem = compiled.memory_analysis()
        rec.update({
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "collectives": {
                "counts": stats.collective_counts,
                "bytes_by_kind": {k: float(v) for k, v in
                                  stats.collective_bytes_by_kind.items()},
                "device_bytes": float(stats.collective_device_bytes),
            },
            "memory": {"temp_size_in_bytes":
                       int(getattr(mem, "temp_size_in_bytes", 0) or 0)},
            "roofline": rl.to_dict(),
        })
        if save_hlo:
            os.makedirs(RESULTS, exist_ok=True)
            with open(os.path.join(
                    RESULTS,
                    f"hlo_fed_{arch}_{strategy}_{rec['mesh']}.txt"), "w") as f:
                f.write(hlo)
        if verbose:
            print(f"[dryrun] OK   fed/{strategy} {arch} × {rec['mesh']} "
                  f"(compile {t_compile:.1f}s) "
                  f"collective {rl.collective_s*1e3:.2f}ms "
                  f"({stats.collective_device_bytes/1e9:.2f} GB/device)")
    except Exception as e:
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[dryrun] FAIL fed/{strategy} {arch}: "
                  f"{type(e).__name__}: {str(e)[:300]}")
    finally:
        _act.set_disabled(False)
    return rec


def append_result(rec: dict, path: str | None = None):
    path = path or os.path.join(RESULTS, "dryrun.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    records = []
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    # replace any prior record for the same combo
    records = [r for r in records
               if (r["arch"], r["shape"], r["mesh"])
               != (rec["arch"], rec["shape"], rec["mesh"])]
    records.append(rec)
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED),
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2×16×16 two-pod mesh")
    ap.add_argument("--all", action="store_true", help="run every combo")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--fed", default=None,
                    choices=["fedpc", "fedpc_packed", "fedpc_reduce", "fedavg"],
                    help="dry-run one federated round step instead of the "
                         "plain train/serve step")
    args = ap.parse_args()

    if args.fed:
        rec = run_fed(args.arch or "mistral-nemo-12b", args.fed,
                      multi_pod=args.multi_pod, save_hlo=args.save_hlo)
        append_result(rec, args.out)
        raise SystemExit(1 if rec["status"] == "fail" else 0)

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          save_hlo=args.save_hlo)
            append_result(rec, args.out)
            n_ok += rec["status"] == "ok"
            n_fail += rec["status"] == "fail"
            n_skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} fail, {n_skip} skipped")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
