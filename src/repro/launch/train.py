"""Training launcher.

Two modes:
  * ``simulate``   — the paper's testbed: N in-process workers, any arch
                     (reduced by default), FedPC/FedAvg/Phong, synthetic LM
                     data. Runs anywhere.
  * ``distributed``— the TPU-mesh runtime: fed workers = slices of the mesh
                     'data' axis, sync through shard_map collectives
                     (fed/distributed.py). On this CPU container pass
                     ``--devices 8`` to emulate with host devices.

Examples:
  PYTHONPATH=src python -m repro.launch.train simulate --arch qwen3-14b \
      --workers 4 --rounds 20
  PYTHONPATH=src python -m repro.launch.train distributed --devices 8 \
      --fed-axis data --strategy fedpc_packed --rounds 5
"""
import argparse
import os
import sys


def _simulate(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data.pipeline import BatchIterator
    from repro.data.synthetic import SyntheticLM, sequence_split
    from repro.fed.simulator import FedSimulator
    from repro.fed.worker import Worker, make_worker_configs
    from repro.models import build_model
    from repro.checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    m = build_model(cfg)
    toks = SyntheticLM(n_sequences=args.sequences, seq_len=args.seq_len,
                       vocab=cfg.vocab, seed=args.seed).generate()
    splits = sequence_split(len(toks), args.workers, seed=args.seed)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p, b: m.loss(p, {"tokens": jnp.asarray(b[0])}), has_aux=True))
    wcfgs = make_worker_configs(args.workers, [len(s) for s in splits],
                                seed=args.seed, batch_menu=(16, 8))
    workers = [Worker(cfg=wcfgs[k],
                      loader=BatchIterator((toks[splits[k]],),
                                           wcfgs[k].batch_size, seed=k),
                      loss_and_grad=loss_fn)
               for k in range(args.workers)]
    params = m.init(jax.random.PRNGKey(args.seed))
    sim = FedSimulator(workers, params, evade_streak=args.evade_streak)
    res = getattr(sim, f"run_{args.algo}")(args.rounds)
    print(f"[train] {args.algo} on {cfg.name}: cost {res.costs[0]:.4f} -> "
          f"{res.costs[-1]:.4f}, bytes {res.total_bytes/1e6:.2f} MB")
    if args.ckpt:
        print("[train] saved:", save_checkpoint(
            args.ckpt, res.params, step=args.rounds,
            metadata={"arch": cfg.name, "algo": args.algo}))
    return 0


def _distributed(args):
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.fed.distributed import build_fed_step, fed_state_init
    from repro.models import build_model

    n_model = max(args.devices // args.fed_workers, 1) if args.devices else 16
    mesh = jax.make_mesh((args.fed_workers, n_model), ("data", "model"))
    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(args.seed))
    F = args.fed_workers
    state = fed_state_init(params, F)
    opt_F = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * F), m.optimizer.init(params))
    sizes = jnp.asarray([100.0 + 25 * k for k in range(F)])
    fed_step = jax.jit(build_fed_step(m, mesh, args.fed_axis, args.strategy,
                                      lr=args.lr))
    key = jax.random.PRNGKey(args.seed)
    with jax.set_mesh(mesh):
        for r in range(args.rounds):
            key, k2 = jax.random.split(key)
            batch_F = {"tokens": jax.random.randint(
                k2, (F, args.local_steps, args.local_batch, args.seq_len),
                0, cfg.vocab)}
            state, opt_F, metrics = fed_step(state, opt_F, batch_F, sizes)
            print(f"[train] round {r + 1}: cost={float(metrics['cost_mean']):.4f} "
                  f"pilot={int(metrics['k_star'])}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    sim = sub.add_parser("simulate")
    sim.add_argument("--arch", default="fedpc-paper")
    sim.add_argument("--algo", default="fedpc",
                     choices=["fedpc", "fedavg", "phong"])
    sim.add_argument("--workers", type=int, default=4)
    sim.add_argument("--rounds", type=int, default=10)
    sim.add_argument("--seq-len", type=int, default=64)
    sim.add_argument("--sequences", type=int, default=192)
    sim.add_argument("--seed", type=int, default=0)
    sim.add_argument("--evade-streak", type=int, default=0)
    sim.add_argument("--full-size", action="store_true")
    sim.add_argument("--ckpt", default=None)

    dist = sub.add_parser("distributed")
    dist.add_argument("--arch", default="fedpc-paper")
    dist.add_argument("--strategy", default="fedpc_packed",
                      choices=["fedpc", "fedpc_packed", "fedpc_reduce", "fedavg"])
    dist.add_argument("--devices", type=int, default=8,
                      help="host devices to emulate (0 = real TPU topology)")
    dist.add_argument("--fed-workers", type=int, default=4)
    dist.add_argument("--fed-axis", default="data")
    dist.add_argument("--rounds", type=int, default=3)
    dist.add_argument("--local-steps", type=int, default=2)
    dist.add_argument("--local-batch", type=int, default=2)
    dist.add_argument("--seq-len", type=int, default=32)
    dist.add_argument("--lr", type=float, default=0.02)
    dist.add_argument("--seed", type=int, default=0)
    dist.add_argument("--full-size", action="store_true")

    args = ap.parse_args()
    sys.exit(_simulate(args) if args.mode == "simulate"
             else _distributed(args))


if __name__ == "__main__":
    main()
