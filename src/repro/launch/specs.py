"""Abstract input specs per (architecture × input shape) for the dry-run.

``input_specs`` returns ShapeDtypeStructs with NamedShardings attached —
weak-type-correct, shardable, zero allocation. ``build_step`` returns the
function to ``jit(...).lower(...)`` for each shape kind.

Input shapes (assigned):
  train_4k     seq 4096,   global_batch 256   (training)      -> train_step
  prefill_32k  seq 32768,  global_batch 32    (prefill)       -> prefill
  decode_32k   seq 32768 cache, global_batch 128 (decode)     -> decode_step
  long_500k    seq 524288 cache, global_batch 1  (long decode)-> decode_step
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.model import Model, build_model
from repro.optim.optimizers import momentum
from repro.sharding.specs import batch_spec, cache_specs, param_specs

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    info = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_decode:
        return False, ("full-attention architecture: 500k decode cache is "
                       "quadratic-history; skipped per DESIGN.md §4")
    if info["kind"] == "decode" and not cfg.supports_decode:
        return False, "encoder-only architecture has no decode step"
    return True, ""


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(tree, mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda leaf, spec: _sds(leaf.shape, leaf.dtype, mesh, spec),
        tree, spec_tree)


@dataclass
class StepSpec:
    fn: Callable          # to jit
    args: tuple           # ShapeDtypeStructs
    out_shardings: Any    # or None
    meta: dict


def _extra_batch(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int,
                 dtype) -> dict:
    """Modality-stub inputs (brief carve-out): precomputed embeddings."""
    extras = {}
    data_spec = batch_spec(mesh, batch, extra_dims=2)
    if cfg.arch_type == "vlm":
        n_p = min(cfg.n_patches, seq)
        extras["vision_embed"] = _sds((batch, n_p, cfg.d_model), dtype,
                                      mesh, data_spec)
        extras["positions"] = _sds((3, batch, seq), jnp.int32, mesh,
                                   P(None, *batch_spec(mesh, batch, 1)))
    if cfg.is_encdec:
        extras["audio_embed"] = _sds((batch, cfg.n_frames, cfg.d_model),
                                     dtype, mesh, data_spec)
    return extras


def input_specs(cfg: ArchConfig, shape_name: str, mesh: Mesh,
                model: Model | None = None) -> StepSpec:
    """Build the (function, abstract-args) pair for one dry-run combo."""
    info = SHAPES[shape_name]
    seq, batch = info["seq"], info["batch"]
    cfg = cfg.replace(param_dtype="bfloat16")
    model = model or build_model(cfg, optimizer=momentum(accum_dtype=jnp.bfloat16))
    dtype = jnp.bfloat16

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, mesh)
    params = _tree_sds(params_shape, mesh, pspecs)

    tok_spec = batch_spec(mesh, batch, extra_dims=1)

    if info["kind"] == "train":
        opt_shape = jax.eval_shape(model.optimizer.init, params_shape)
        opt_specs = param_specs(opt_shape, mesh)
        opt_state = _tree_sds(opt_shape, mesh, opt_specs)
        batch_tree = {
            "tokens": _sds((batch, seq), jnp.int32, mesh, tok_spec),
            **_extra_batch(cfg, mesh, batch, seq, dtype),
        }
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        return StepSpec(
            fn=model.train_step,
            args=(params, opt_state, batch_tree, lr),
            out_shardings=None,
            meta=dict(cfg=cfg, kind="train", seq=seq, batch=batch),
        )

    if info["kind"] == "prefill":
        state_shape = jax.eval_shape(lambda: model.init_decode_state(batch, seq))
        sspecs = cache_specs(state_shape, mesh, batch)
        state = _tree_sds(state_shape, mesh, sspecs)
        batch_tree = {
            "tokens": _sds((batch, seq), jnp.int32, mesh, tok_spec),
            **_extra_batch(cfg, mesh, batch, seq, dtype),
        }
        return StepSpec(
            fn=model.prefill,
            args=(params, batch_tree, state),
            out_shardings=None,
            meta=dict(cfg=cfg, kind="prefill", seq=seq, batch=batch),
        )

    # decode: one new token against a seq-length cache
    state_shape = jax.eval_shape(lambda: model.init_decode_state(batch, seq))
    sspecs = cache_specs(state_shape, mesh, batch)
    state = _tree_sds(state_shape, mesh, sspecs)
    step_batch = {
        "token": _sds((batch, 1), jnp.int32, mesh,
                      batch_spec(mesh, batch, 1)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.mrope:
        step_batch["positions"] = _sds(
            (3, batch, 1), jnp.int32, mesh,
            P(None, *batch_spec(mesh, batch, 1)))
    return StepSpec(
        fn=model.decode_step,
        args=(params, state, step_batch),
        out_shardings=None,
        meta=dict(cfg=cfg, kind="decode", seq=seq, batch=batch),
    )
