"""Production mesh definitions (TPU v5e numbers).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

# Hardware constants used by the roofline analysis (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link

SINGLE_POD = (16, 16)           # 256 chips
MULTI_POD = (2, 16, 16)         # 2 pods × 256 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for tests (requires >= n_data*n_model host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
