"""Compiled-artifact analysis: collective bytes, roofline terms, MODEL_FLOPS.

Sources (§ROOFLINE of the brief):
  * ``compiled.cost_analysis()``  → HLO FLOPs / bytes accessed (per device —
    the compiled module is the SPMD-partitioned per-device program);
  * ``compiled.as_text()``        → post-partitioning HLO; we parse every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute and sum operand sizes;
  * analytic 6·N·D model FLOPs for the useful-compute ratio.

Collective byte model (per participating device, ring algorithms):
  all-reduce      2·(g-1)/g · result_bytes
  all-gather      (g-1)/g   · result_bytes      (result = gathered)
  reduce-scatter  (g-1)/g   · operand_bytes
  all-to-all      (g-1)/g   · result_bytes
  collective-permute  result_bytes
where g = replica-group size parsed from the op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


from repro.configs.base import ArchConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,1024,128]{...} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_by_kind: dict = field(default_factory=dict)
    total_device_bytes: float = 0.0
    ops: list = field(default_factory=list)

    def add(self, kind: str, result_bytes: int, group: int):
        g = max(group, 2)
        if kind == "all-reduce":
            moved = 2.0 * (g - 1) / g * result_bytes
        elif kind in ("all-gather", "all-to-all"):
            moved = (g - 1) / g * result_bytes
        elif kind == "reduce-scatter":
            moved = (g - 1) / g * result_bytes * g  # operand = result * g
        else:  # collective-permute
            moved = float(result_bytes)
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + moved
        self.total_device_bytes += moved
        self.ops.append((kind, result_bytes, g, moved))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            result_bytes = sum(
                _shape_bytes(dt, dm)
                for dt, dm in _TUPLE_SHAPE_RE.findall(tuple_part))
        else:
            result_bytes = _shape_bytes(dtype, dims)
        g = 2
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gm2 = _GROUPS_ALT_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        stats.add(kind, result_bytes, g)
    return stats


# ---------------------------------------------------------------------------
# Analytic model FLOPs (6·N·D dense / 6·N_active·D MoE)
# ---------------------------------------------------------------------------

def active_params(cfg: ArchConfig) -> tuple[int, int]:
    """(total_params, active_params_per_token) for the backbone."""
    D, dh = cfg.d_model, cfg.resolved_head_dim
    total = cfg.vocab * D * (1 if cfg.tie_embeddings else 2)
    act = total

    def attn_p():
        return D * (cfg.n_heads * dh) * 2 + D * (cfg.n_kv_heads * dh) * 2

    def mlp_p(dff):
        mult = 3 if cfg.ffn_act == "swiglu" else 2
        return mult * D * dff

    def mamba_p():
        di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.resolved_dt_rank
        return (D * 2 * di + di * (dtr + 2 * ds) + dtr * di + di * ds
                + di * D)

    def mlstm_p():
        di = int(cfg.lstm_proj_factor * D)
        di = (di // cfg.n_heads) * cfg.n_heads
        return D * 2 * di + 3 * di * di + di * 2 * cfg.n_heads + di * D

    def slstm_p():
        return D * 4 * D + D * 4 * D + D * D

    for mixer, f in cfg.pattern * cfg.n_units:
        pass
    per_unit_total = per_unit_active = 0
    for mixer, f in cfg.pattern:
        if mixer in ("attn", "swa"):
            m = attn_p()
        elif mixer == "mamba":
            m = mamba_p()
        elif mixer == "mlstm":
            m = mlstm_p()
        else:
            m = slstm_p()
        per_unit_total += m
        per_unit_active += m
        if f == "mlp":
            per_unit_total += mlp_p(cfg.d_ff)
            per_unit_active += mlp_p(cfg.d_ff)
        elif f == "moe":
            routed = cfg.n_experts * mlp_p(cfg.d_expert_ff) * 0 \
                + cfg.n_experts * 3 * D * cfg.d_expert_ff
            shared = (3 * D * cfg.n_shared_experts * cfg.d_expert_ff
                      if cfg.n_shared_experts else 0)
            per_unit_total += routed + shared + D * cfg.n_experts
            per_unit_active += (cfg.top_k * 3 * D * cfg.d_expert_ff
                                + shared + D * cfg.n_experts)
    total += per_unit_total * cfg.n_units
    act += per_unit_active * cfg.n_units
    if cfg.first_k_dense:
        dense = attn_p() + mlp_p(cfg.d_ff_dense or cfg.d_ff)
        total += dense * cfg.first_k_dense
        act += dense * cfg.first_k_dense
    if cfg.is_encdec:
        enc = (attn_p() + mlp_p(cfg.d_ff)) * cfg.n_encoder_layers
        cross = attn_p() * cfg.n_layers
        total += enc + cross + D * D
        act += enc + cross + D * D
    return int(total), int(act)


def model_flops(cfg: ArchConfig, n_tokens: int, kind: str) -> float:
    """6·N_active·D for train, 2·N_active·D for forward-only kinds."""
    _, act = active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * act * n_tokens


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_device: float
    bytes_device: float
    collective_bytes_device: float
    model_flops_total: float
    useful_ratio: float
    dominant: str

    def to_dict(self):
        return {k: getattr(self, k) for k in (
            "compute_s", "memory_s", "collective_s", "flops_device",
            "bytes_device", "collective_bytes_device", "model_flops_total",
            "useful_ratio", "dominant")}


def roofline_from_stats(stats, n_chips: int, cfg: ArchConfig,
                        n_tokens: int, kind: str) -> Roofline:
    """Roofline terms from loop-aware HLO stats (launch/hlo_stats.py).

    stats.flops/bytes are per-device (the compiled module is the SPMD
    per-device program); MODEL_FLOPS is the global 6·N_active·D and the
    useful ratio divides by chips."""
    flops_dev = float(stats.flops)
    bytes_dev = float(stats.bytes)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    coll_s = stats.collective_device_bytes / ICI_BW
    mf = model_flops(cfg, n_tokens, kind)
    useful = mf / (flops_dev * n_chips) if flops_dev else float("nan")
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    return Roofline(compute_s, memory_s, coll_s, flops_dev, bytes_dev,
                    stats.collective_device_bytes, mf, useful, dominant)


def roofline(cost: dict, coll: CollectiveStats, n_chips: int,
             cfg: ArchConfig, n_tokens: int, kind: str) -> Roofline:
    flops_dev = float(cost.get("flops", 0.0) or 0.0)
    bytes_dev = float(cost.get("bytes accessed", 0.0) or 0.0)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    coll_s = coll.total_device_bytes / ICI_BW
    mf = model_flops(cfg, n_tokens, kind)
    useful = mf / (flops_dev * n_chips) if flops_dev else float("nan")
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    return Roofline(compute_s, memory_s, coll_s, flops_dev, bytes_dev,
                    coll.total_device_bytes, mf, useful, dominant)
