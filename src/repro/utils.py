"""Small shared utilities: pytree flattening, rng splitting, shape math."""
from __future__ import annotations

import math
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    """Total bytes of a pytree of arrays."""
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_ravel(tree: PyTree):
    """Flatten a pytree to a 1-D vector; returns (vec, unravel_fn)."""
    return ravel_pytree(tree)


def tree_map(fn: Callable, *trees: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, *trees)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_weighted_sum(trees: Iterable[PyTree], weights) -> PyTree:
    """sum_i w_i * tree_i  (used by FedAvg)."""
    trees = list(trees)
    out = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = tree_add(out, tree_scale(t, w))
    return out


def tree_allfinite(tree: PyTree):
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.all(jnp.stack(leaves))


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def split_rngs(key, n: int):
    return list(jax.random.split(key, n))


# -- jaxpr accounting (structural asserts in tests and benches) -------------

# Primitives that imply host interaction from inside a traced program; a
# device-resident round loop must contain none of them.
HOST_SYNC_PRIMITIVES = frozenset({
    "io_callback", "pure_callback", "debug_callback", "callback",
    "infeed", "outfeed", "device_put",
})


def iter_jaxpr_eqns(jaxpr, into_pallas: bool = True):
    """Yield every eqn of ``jaxpr`` recursively (scan/cond/pjit sub-jaxprs
    included). ``into_pallas=False`` skips pallas_call kernel bodies —
    values there live in VMEM/registers, so HBM-intermediate accounting
    must not see them."""
    for eqn in jaxpr.eqns:
        yield eqn
        if not into_pallas and eqn.primitive.name == "pallas_call":
            continue
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from iter_jaxpr_eqns(inner, into_pallas)
                elif hasattr(sub, "eqns"):
                    yield from iter_jaxpr_eqns(sub, into_pallas)


def jaxpr_primitive_counts(fn: Callable, *args, **kwargs) -> dict:
    """{primitive name: count} over ``fn``'s full jaxpr — e.g.
    ``counts.get("pallas_call")`` is the kernel-launch count (a scanned body
    counts once regardless of trip count) and any name in
    ``HOST_SYNC_PRIMITIVES`` flags a device→host round-trip."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    counts: dict = {}
    for eqn in iter_jaxpr_eqns(jaxpr.jaxpr):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


def log2_int(x: int) -> int:
    l = int(math.log2(x))
    assert (1 << l) == x, f"{x} is not a power of two"
    return l
