"""Pure-jnp oracles for the masked wire kernels (bitwise ground truth).

Mirrors ``repro.kernels.ref`` for the privacy subsystem: the same math as
``repro.kernels.masked_wire`` expressed per-step in jnp, on the kernels'
flat ``(N, rows/4, 512)`` views. Parity tests compare the Pallas kernels
against these *under jit* and assert exact byte equality — the masked wire
is integer end-to-end, so there is no allclose anywhere.

The kernels generate their mask and RR streams IN-REGISTER from per-pair /
per-worker counter keys; the oracles instead consume explicitly
materialized mask and RR tensors. Feeding them
``masking.net_masks(..., word_bits=...)`` and ``dp.rr_bits(...)`` — the
order-exact host-side expansions of the very same counter streams — makes
kernel-vs-oracle a test of BOTH the fused arithmetic and the in-kernel
PRNG at once. The word dtype of ``masks`` picks the modulus: uint16
tensors make the oracle truncate exactly like the 16-bit wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.privacy.dp import rr_fields


def codes_any_ref(q, p1, p2, t, beta, alpha1) -> jax.Array:
    """Eq. (4) at t <= 1 / Eq. (5) after, float {-1, 0, +1} — the exact
    expression of the fused kernels' ``_codes_any`` (shared ``q - p1``
    evolution, branch selected on the traced round index)."""
    q = q.astype(jnp.float32)
    p1 = p1.astype(jnp.float32)
    p2 = p2.astype(jnp.float32)
    delta = q - p1
    step = p1 - p2
    c5 = jnp.where(jnp.abs(delta) >= beta * jnp.abs(step),
                   jnp.sign(delta * step), 0.0)
    c4 = ((delta > alpha1).astype(jnp.float32)
          - (delta < -alpha1).astype(jnp.float32))
    return jnp.where(jnp.asarray(t, jnp.float32) <= 1.0, c4, c5)


def masked_codes_ref(q, p1, p2, t, beta, alpha1, wq, masks, bits,
                     threshold) -> jax.Array:
    """Masked uplink oracle: ternarize -> bias -> RR -> fixed-point weight
    -> add net pairwise mask -> truncate to the wire modulus.

    q (N, R, 512) float; p1/p2 (R, 512); beta scalar or (N,); wq (N,)
    uint32 fixed-point weights; ``masks`` (N, R, 512) in the WIRE dtype
    (uint16 => 16-bit modulus, uint32 => 32-bit) — typically
    ``masking.net_masks(..., word_bits=...)`` or zeros; ``bits``
    (N, R, 512) uint32 full RR words (``dp.rr_bits``); ``threshold`` the
    uint16 RR flip threshold (0 = RR off). Returns (N, R, 512) in the
    wire dtype — one masked word per parameter.
    """
    beta_b = jnp.asarray(beta, jnp.float32).reshape(-1, 1, 1)
    code = codes_any_ref(q, p1[None], p2[None], t, beta_b, alpha1)
    field = (code + 1.0).astype(jnp.uint32)
    field = rr_fields(field, bits, threshold)
    # mod-2**32 accumulate, then truncate: congruence mod 2**16 survives
    # the wider intermediate, so this matches the kernel bit-for-bit.
    acc = wq.reshape(-1, 1, 1) * field + masks.astype(jnp.uint32)
    if masks.dtype == jnp.uint16:
        return (acc & jnp.uint32(0xFFFF)).astype(jnp.uint16)
    return acc


def masked_master_ref(q_pilot, masked, sum_wq, p1, p2, t, alpha0,
                      scale_mult) -> jax.Array:
    """Sum-then-unmask master oracle: modular sum of the masked worker
    words (pairwise masks cancel exactly), integer de-bias by the public
    ``sum_wq = sum_k W_k`` (truncated to the modulus), signed
    reinterpretation at the wire width, fixed-point descale (+ RR unbias)
    via ``scale_mult``, then the Eq. (3) combine.

    masked (N, R, 512) uint16 or uint32 (the dtype picks the modulus);
    q_pilot/p1/p2 (R, 512) float; ``t`` may be traced. Returns (R, 512)
    in q_pilot.dtype. Order-independent by construction (modular
    addition), so this single oracle covers every kernel block plan AND
    every collective reduction topology.

    For BITWISE comparison against the kernel, jit this oracle with ``t``
    and ``scale_mult`` passed as traced f32 scalars — the kernel receives
    them as runtime operands, and baking them as constants instead lets
    XLA:CPU make a different (1-ulp) FMA-contraction choice in the final
    ``q - coeff * mult`` when ``scale_mult`` is not a power of two.
    """
    s = jnp.sum(masked, axis=0, dtype=masked.dtype)
    sumw = jnp.asarray(sum_wq, jnp.uint32)
    if masked.dtype == jnp.uint16:
        sumw = (sumw & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        signed = jnp.int16
    else:
        signed = jnp.int32
    ci = jax.lax.bitcast_convert_type(s - sumw, signed)
    coeff = ci.astype(jnp.float32) * jnp.asarray(scale_mult, jnp.float32)
    step = p1.astype(jnp.float32) - p2.astype(jnp.float32)
    mult = jnp.where(jnp.asarray(t, jnp.float32) <= 1.0, alpha0, step)
    q = q_pilot.astype(jnp.float32)
    return (q - coeff * mult).astype(q_pilot.dtype)
