"""repro.privacy — the privacy-preserving wire subsystem.

Makes the packed ternary wire of PRs 1-4 itself privacy-preserving:
pairwise-masked secure aggregation (the master only ever sees the modular
SUM of the workers' fixed-point-weighted ternary fields), local-DP 3-ary
randomized response on the codes with exact unbiasing, an (eps, delta)
accountant that rides the round carry, and traced-program leakage audits
that enforce the §4.2 information-flow policy in both runtimes. See the
README "Privacy architecture" section for the threat model and math.
"""
from repro.privacy.accountant import PrivacyAccountant
from repro.privacy.audit import (check_fed_collectives, check_round_program,
                                 collective_payloads)
from repro.privacy.dp import rr_bits, rr_bits_worker, rr_fields
from repro.privacy.masking import (net_mask_slab, net_masks, pair_incidence,
                                   quantize_weights)
from repro.privacy.spec import PrivacySpec

__all__ = [
    "PrivacyAccountant", "PrivacySpec", "check_fed_collectives",
    "check_round_program", "collective_payloads", "net_mask_slab",
    "net_masks", "pair_incidence", "quantize_weights", "rr_bits",
    "rr_bits_worker", "rr_fields",
]
