"""repro.privacy — the privacy-preserving wire subsystem.

Makes the packed ternary wire of PRs 1-4 itself privacy-preserving:
pairwise-masked secure aggregation (the master only ever sees the modular
SUM of the workers' fixed-point-weighted ternary fields — mod 2**16 by
default, 2**32 on the conservative path), local-DP 3-ary randomized
response on the codes with exact unbiasing, an (eps, delta) accountant
that rides the round carry, and traced-program leakage audits that enforce
the §4.2 information-flow policy in both runtimes. The mask and RR streams
are COUNTER-based (``masking.mix32`` chains): kernels regenerate them
in-register from tiny per-pair/per-worker keys, and the host-side
expansions here are the order-exact reference oracles. ``recovery`` adds
the Bonawitz-style dropout half: t-of-n Shamir shares of the pair seeds
over GF(2^16) and the traced mask-repair path that keeps the cohort sum
exact when workers die mid-round. See the README "Privacy architecture"
and "Failure model" sections for the threat model and math.
"""
from repro.privacy.accountant import PrivacyAccountant
from repro.privacy.audit import (check_fed_collectives,
                                 check_recovery_target,
                                 check_round_program, collective_payloads)
from repro.privacy.dp import (rr_bits, rr_bits_worker, rr_fields,
                              rr_stream_key, rr_stream_keys)
from repro.privacy.masking import (mix32, net_mask_slab, net_masks,
                                   pair_incidence, pair_signs,
                                   pair_signs_row, pair_stream_keys,
                                   pair_stream_keys_row, quantize_weights,
                                   stream_key, tree_activity,
                                   tree_level_seed, tree_pair_signs,
                                   tree_pair_signs_row)
from repro.privacy.recovery import (deal_shares, deal_worker_shares,
                                    effective_masks, gf_inv, gf_mul,
                                    mask_repair_ref, reconstruct,
                                    recover_worker_keys,
                                    repair_coefficients, repair_pair_index)
from repro.privacy.spec import PrivacySpec

__all__ = [
    "PrivacyAccountant", "PrivacySpec", "check_fed_collectives",
    "check_recovery_target", "check_round_program", "collective_payloads",
    "deal_shares", "deal_worker_shares", "effective_masks", "gf_inv",
    "gf_mul", "mask_repair_ref", "mix32", "net_mask_slab", "net_masks",
    "pair_incidence", "pair_signs", "pair_signs_row", "pair_stream_keys",
    "pair_stream_keys_row", "quantize_weights", "reconstruct",
    "recover_worker_keys", "repair_coefficients", "repair_pair_index",
    "rr_bits", "rr_bits_worker", "rr_fields", "rr_stream_key",
    "rr_stream_keys", "stream_key", "tree_activity", "tree_level_seed",
    "tree_pair_signs", "tree_pair_signs_row",
]
