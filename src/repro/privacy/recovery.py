"""Bonawitz-style dropout recovery: seed secret-sharing + mask repair.

The pairwise-mask wire (``repro.privacy.masking``) cancels exactly only
over the participation set the masks were derived for. A worker that dies
AFTER committing its masked uplink (or whose uplink never arrives — a
pre-uplink death or a straggler past the timeout) must be dropped from the
aggregate, but every surviving sibling ``l`` already folded
``sign(l, k) * m_kl`` into its own words, so the survivors-only modular sum
carries the dead worker's uncancelled net mask as residue. This module
provides both halves of the classic fix:

* **Control plane — Shamir shares of the pair seeds.** At round setup each
  worker's row of pair stream keys (restricted to its sibling group — PR
  7's fanout-scoped masks make a death local to one subtree) is dealt as
  t-of-n Shamir shares over GF(2^16) to its siblings. After a death, any
  ``threshold`` surviving siblings reconstruct the dead worker's keys
  (:func:`recover_worker_keys`); fewer than ``threshold`` shares reveal
  *nothing* (probe 6 in ``examples/privacy_probes.py`` measures this), and
  reconstructing a still-LIVE worker's keys is a policy violation —
  :func:`repro.privacy.audit.check_recovery_target` raises
  :class:`~repro.core.privacy.LeakageError` before any share is combined.
  In the simulation the reconstructed keys equal the root-seed-derived
  ``pair_stream_keys`` row bitwise (the same stand-in-for-key-agreement
  convention the masking module documents), which is what lets the traced
  repair below consume the derived keys directly while tests pin the
  share-reconstruction path against them.

* **Data plane — the traced repair term.** Dropping dead rows from the
  modular sum removes each dead worker ``k``'s own row (its weighted
  fields AND its net mask) but leaves ``-sum_{l alive} sign(k, l) m_kl``
  residue in the survivors. The repair ADDS ``sum_{k dead, l alive}
  sign(k, l) * m_kl`` mod 2**modulus_bits — regenerated from the same
  counter PRNG, fused in the ``mask_repair_2d`` Pallas kernel
  (``repro.kernels.masked_wire``), applied ONCE at the root (modular sums
  commute, so leaf residue rides up the tree unchanged).
  :func:`repair_coefficients` builds the per-pair ±1 coefficients;
  :func:`effective_masks` computes the post-fault activity vectors with
  the graceful-degradation rule: a sibling group that suffered a death but
  retains fewer than ``threshold`` survivors cannot reconstruct, so the
  WHOLE group is zeroed (its subtree contributes exact zero — the PR 7
  dropped-subtree identity) and the round proceeds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.privacy import masking as pvm

GF_BITS = 16
GF_ORDER = 1 << GF_BITS
#: x^16 + x^12 + x^3 + x + 1 — primitive over GF(2), so GF(2^16) words are
#: exactly the uint16 wire symbols the masked path already moves.
GF_POLY = 0x1100B


def _gf_mul_scalar(a: int, b: int) -> int:
    """Carryless multiply mod GF_POLY — pure-Python, table-build only."""
    r = 0
    for _ in range(GF_BITS):
        if b & 1:
            r ^= a
        b >>= 1
        a <<= 1
        if a & GF_ORDER:
            a ^= GF_POLY
    return r


@functools.lru_cache(maxsize=1)
def _tables() -> tuple[np.ndarray, np.ndarray]:
    """(exp, log) discrete-log tables of GF(2^16)*. The generator is found
    by search (period asserted == 2^16 - 1, not assumed); ``exp`` is doubled
    so products index without a mod."""
    for g in (2, 3, 5, 7):
        exp = np.zeros(2 * (GF_ORDER - 1), np.uint32)
        log = np.zeros(GF_ORDER, np.uint32)
        x, period = 1, 0
        for i in range(GF_ORDER - 1):
            exp[i] = x
            log[x] = i
            x = _gf_mul_scalar(x, g)
            period = i + 1
            if x == 1:
                break
        if period == GF_ORDER - 1:
            exp[GF_ORDER - 1:] = exp[:GF_ORDER - 1]
            return exp, log
    raise AssertionError(f"no primitive element found for poly {GF_POLY:#x}")


def gf_mul(a, b) -> np.ndarray:
    """Elementwise GF(2^16) product (vectorized, zero-absorbing)."""
    exp, log = _tables()
    a = np.asarray(a, np.uint32) & 0xFFFF
    b = np.asarray(b, np.uint32) & 0xFFFF
    out = exp[log[a].astype(np.int64) + log[b].astype(np.int64)]
    return np.where((a == 0) | (b == 0), 0, out).astype(np.uint32)


def gf_inv(a) -> np.ndarray:
    """Elementwise GF(2^16) inverse; raises on zero."""
    exp, log = _tables()
    a = np.asarray(a, np.uint32) & 0xFFFF
    if np.any(a == 0):
        raise ZeroDivisionError("gf_inv(0)")
    return exp[GF_ORDER - 1 - log[a].astype(np.int64)].astype(np.uint32)


def _mix32_np(x) -> np.ndarray:
    """Host-side lowbias32 — bitwise the jnp :func:`masking.mix32` (the
    share-polynomial coefficients are control-plane data, never traced)."""
    x = np.asarray(x, np.uint64) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x7FEB352D) & 0xFFFFFFFF
    x ^= x >> 15
    x = (x * 0x846CA68B) & 0xFFFFFFFF
    x ^= x >> 16
    return x.astype(np.uint32)


def _share_coeffs(seed, worker, t, degree: int, size: int) -> np.ndarray:
    """Deterministic degree-``degree`` Shamir coefficients (uint16 symbols)
    for ``worker``'s round-``t`` dealing — a RECOVERY_DOMAIN mix32 chain, so
    they never collide with mask, RR or fault streams."""
    k = _mix32_np(np.uint64(int(seed) & 0xFFFFFFFF)
                  ^ np.uint64(pvm.RECOVERY_DOMAIN))
    k = _mix32_np(k.astype(np.uint64) + np.uint64(int(worker))
                  * np.uint64(pvm._SALT_STREAM))
    k = _mix32_np(k.astype(np.uint64) + np.uint64(int(t) & 0xFFFFFFFF)
                  * np.uint64(pvm._SALT_ROUND))
    k = _mix32_np(k.astype(np.uint64) + np.uint64(degree)
                  * np.uint64(pvm._SALT_SHARD))
    idx = np.arange(size, dtype=np.uint64)
    return (_mix32_np(k.astype(np.uint64) + idx) & 0xFFFF).astype(np.uint32)


def deal_shares(secret, n_shares: int, threshold: int, *,
                coeffs=None) -> np.ndarray:
    """t-of-n Shamir shares of uint16 symbols over GF(2^16).

    ``secret`` is any-shape uint16 symbols; share ``j`` (held at evaluation
    point ``x = j + 1``) is the degree-(threshold-1) polynomial through the
    secret at ``x = 0``. ``coeffs`` optionally pins the ``threshold - 1``
    non-constant coefficient planes (each ``secret``-shaped); by default
    they come from a fresh mix32 chain per call site via
    :func:`deal_worker_shares`. Returns ``(n_shares, *secret.shape)``.
    """
    if threshold < 1:
        raise ValueError(f"threshold must be >= 1, got {threshold}")
    if n_shares < threshold:
        raise ValueError(f"cannot deal {n_shares} shares at threshold "
                         f"{threshold}")
    secret = np.asarray(secret, np.uint32) & 0xFFFF
    if coeffs is None:
        coeffs = [_share_coeffs(0, 0, 0, d, secret.size).reshape(secret.shape)
                  for d in range(1, threshold)]
    out = np.zeros((n_shares,) + secret.shape, np.uint32)
    for j in range(n_shares):
        x = np.uint32(j + 1)
        acc = secret.copy()
        xp = np.uint32(1)
        for c in coeffs:
            xp = gf_mul(xp, x)
            acc ^= gf_mul(np.asarray(c, np.uint32) & 0xFFFF, xp)
        out[j] = acc
    return out.astype(np.uint16)


def reconstruct(shares, xs) -> np.ndarray:
    """Lagrange-interpolate the secret at ``x = 0`` from ``(m, ...)``
    shares held at points ``xs`` (1-based, distinct). Exact when ``m``
    reaches the dealing threshold; with fewer shares the interpolation is
    consistent with EVERY candidate secret (perfect secrecy — probe 6)."""
    shares = np.asarray(shares, np.uint32) & 0xFFFF
    xs = np.asarray(xs, np.uint32) & 0xFFFF
    if len(set(int(x) for x in xs)) != xs.shape[0]:
        raise ValueError("share points must be distinct")
    out = np.zeros(shares.shape[1:], np.uint32)
    for j in range(xs.shape[0]):
        lj = np.uint32(1)
        for i in range(xs.shape[0]):
            if i == j:
                continue
            # l_j(0) = prod x_i / (x_i - x_j); subtraction is XOR in GF(2^k)
            lj = gf_mul(lj, gf_mul(xs[i], gf_inv(xs[i] ^ xs[j])))
        out ^= gf_mul(shares[j], lj)
    return out.astype(np.uint16)


# ---------------------------------------------------------------------------
# Worker-level dealing/reconstruction (control plane, host-side)
# ---------------------------------------------------------------------------

def group_members(worker: int, n: int, group_size: int | None) -> np.ndarray:
    """The sibling group of ``worker``: the contiguous ``group_size`` block
    (a tree leaf group) or the whole cohort when ``group_size`` is None
    (the flat wire — one cohort-wide group)."""
    if group_size is None:
        return np.arange(n, dtype=np.int32)
    g = worker // group_size
    lo = g * group_size
    return np.arange(lo, min(lo + group_size, n), dtype=np.int32)


def worker_pair_symbols(seed, worker: int, n: int, t, *,
                        group_size: int | None = None,
                        shard_idx: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(members, symbols): the secret to share — ``worker``'s pair stream
    keys toward its sibling group for round ``t``, each uint32 key split
    into two GF(2^16) symbols (low half first) -> ``(s, 2)`` uint16."""
    members = group_members(worker, n, group_size)
    keys = np.asarray(pvm.pair_stream_keys(seed, n, t, shard_idx))
    row = keys[worker][members]
    sym = np.stack([row & 0xFFFF, row >> 16], axis=-1).astype(np.uint16)
    return members, sym


def deal_worker_shares(seed, worker: int, n: int, t, threshold: int, *,
                       group_size: int | None = None, shard_idx: int = 0
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deal ``worker``'s per-pair key secret to its sibling group.

    Returns ``(members, xs, shares)``: ``shares[j]`` (shape ``(s, 2)``
    uint16) is the share held by ``members[j]`` at point ``xs[j] = j + 1``.
    Coefficients chain deterministically from (seed, worker, round, degree)
    in the RECOVERY domain, so a re-dealt round reproduces its shares.
    """
    members, sym = worker_pair_symbols(seed, worker, n, t,
                                       group_size=group_size,
                                       shard_idx=shard_idx)
    s = members.shape[0]
    if threshold > s:
        raise ValueError(f"threshold {threshold} exceeds sibling group "
                         f"size {s}")
    coeffs = [_share_coeffs(seed, worker, t, d, sym.size).reshape(sym.shape)
              for d in range(1, threshold)]
    shares = deal_shares(sym, s, threshold, coeffs=coeffs)
    xs = np.arange(1, s + 1, dtype=np.uint16)
    return members, xs, shares


def recover_worker_keys(seed, worker: int, n: int, t, threshold: int, *,
                        alive, group_size: int | None = None,
                        shard_idx: int = 0
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Reconstruct a DEAD worker's within-group pair keys from >= threshold
    surviving siblings' shares.

    Raises :class:`~repro.core.privacy.LeakageError` when ``alive`` still
    marks the target live (recovery must only ever target declared-dead
    workers), and :class:`ValueError` when fewer than ``threshold``
    siblings survive — the caller then degrades the whole group to an
    exact-zero subtree instead (see :func:`effective_masks`).
    Returns ``(members, keys)`` with ``keys`` the (s,) uint32 stream keys.
    """
    from repro.privacy import audit as pv_audit
    pv_audit.check_recovery_target(worker, alive)
    members, xs, shares = deal_worker_shares(seed, worker, n, t, threshold,
                                             group_size=group_size,
                                             shard_idx=shard_idx)
    alive = np.asarray(alive)
    holders = [j for j, m in enumerate(members)
               if int(m) != int(worker) and alive[int(m)] > 0]
    if len(holders) < threshold:
        raise ValueError(
            f"sibling group of worker {worker} fell below threshold: "
            f"{len(holders)} surviving share-holders < {threshold}")
    sel = np.asarray(holders[:threshold])
    sym = reconstruct(shares[sel], xs[sel]).astype(np.uint32)
    keys = (sym[..., 0] | (sym[..., 1] << 16)).astype(np.uint32)
    return members, keys


# ---------------------------------------------------------------------------
# Traced repair helpers (data plane)
# ---------------------------------------------------------------------------

def effective_masks(pmask, alive, threshold: int, group_size: int | None,
                    n: int):
    """Post-fault activity split: ``(alive_eff, dead_eff)`` float32 (n,).

    ``alive_eff`` marks workers that participated AND survived;
    ``dead_eff`` marks post-commit deaths whose mask residue needs repair.
    Both zero out every member of a NON-VIABLE sibling group — one that
    suffered a death but kept fewer than ``threshold`` survivors, so the
    keys cannot be reconstructed: the whole subtree degrades to exact zero
    (the PR 7 dropped-subtree identity) and its deaths need no repair. A
    group with no deaths is viable regardless of size — reconstruction
    (and hence the t-of-n threshold) only matters when a death occurred.
    """
    av = jnp.asarray(alive) > 0
    pm = (jnp.ones((n,), bool) if pmask is None
          else jnp.asarray(pmask) > 0)
    live = (pm & av).astype(jnp.int32)
    dead = (pm & ~av).astype(jnp.int32)
    g = n if group_size is None else group_size
    ng = -(-n // g)
    pad = ng * g - n
    lp = jnp.pad(live, (0, pad)).reshape(ng, g)
    dp = jnp.pad(dead, (0, pad)).reshape(ng, g)
    viable = ((jnp.sum(dp, axis=1) == 0)
              | (jnp.sum(lp, axis=1) >= threshold))
    v = jnp.repeat(viable, g)[:n].astype(jnp.int32)
    return ((live * v).astype(jnp.float32),
            (dead * v).astype(jnp.float32))


def repair_pair_index(n: int, sibling: int | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Static endpoint indices of the pairs a repair can touch: all
    unordered pairs (flat wire) or only within-sibling-group pairs (tree
    leaves — ``n * (sibling - 1) / 2`` streams instead of ``n(n-1)/2``)."""
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)
             if sibling is None or i // sibling == j // sibling]
    i_idx = np.asarray([i for i, _ in pairs], np.int32)
    j_idx = np.asarray([j for _, j in pairs], np.int32)
    return i_idx, j_idx


def repair_coefficients(keys_mat, signs_mat, alive_eff, dead_eff,
                        i_idx: np.ndarray, j_idx: np.ndarray):
    """Per-pair (keys, coeff) of the repair term
    ``sum_{k dead, l alive} sign(k, l) * m_kl``.

    ``signs_mat`` is the SAME participation-scoped antisymmetric matrix the
    uplink committed (flat or tree-leaf scoped); an unordered pair {i, j}
    contributes via whichever endpoint died, so its flat coefficient is
    ``C[i, j] + C[j, i]`` with ``C = signs * (dead x alive)`` — always in
    {-1, 0, +1} (an endpoint cannot be both dead and alive, and a
    both-dead pair's masks left with their rows). Returns ``((P,) uint32
    keys, (P,) int32 coeffs)`` ready for the ``mask_repair_2d`` kernel.
    """
    a = (jnp.asarray(alive_eff) > 0).astype(jnp.int32)
    d = (jnp.asarray(dead_eff) > 0).astype(jnp.int32)
    c = jnp.asarray(signs_mat, jnp.int32) * (d[:, None] * a[None, :])
    coeff_mat = c + c.T
    keys = jnp.asarray(keys_mat, jnp.uint32)[i_idx, j_idx]
    coeff = coeff_mat[i_idx, j_idx]
    return keys, coeff


def mask_repair_ref(words, pair_keys, pair_coeff, *, word_bits: int):
    """Order-exact jnp oracle of the fused repair kernel: add
    ``coeff[p] * stream(keys[p])`` mod 2**word_bits into a (rows, 512)
    masked-word slab (kernel view; flat element index ``r * 512 + c``)."""
    rows, wide = words.shape
    size = rows * wide
    h = pvm.index_hash(size, word_bits)
    total = jnp.zeros((size,), jnp.int32)
    for p in range(int(pair_keys.shape[0])):
        vals = pvm.stream_values(pair_keys[p], h, word_bits)
        total = total + pair_coeff[p] * vals.astype(jnp.int32)
    total = total.reshape(rows, wide)
    if word_bits == 16:
        out = (words.astype(jnp.int32) + total) & jnp.int32(0xFFFF)
        return out.astype(jnp.uint16)
    acc = jax.lax.bitcast_convert_type(words, jnp.int32) + total
    return jax.lax.bitcast_convert_type(acc, jnp.uint32)
