"""Pairwise additive masks for secure aggregation — stateless, per-round.

Classic pairwise masking (Bonawitz et al., adapted to the FedPC wire): every
unordered worker pair ``(k, l)``, ``k < l``, shares a seed; each round both
derive the same uint32 mask tensor ``m_kl = bits(fold_in(seed_kl, t))`` and
worker ``k`` *adds* it while worker ``l`` *subtracts* it (mod 2**32). The
net mask of worker ``k`` is

    M_k = sum_{l > k} m_kl - sum_{l < k} m_lk        (mod 2**32)

and ``sum_k M_k = 0`` exactly — integer cancellation, no epsilon of float
error, independent of summation order or reduction topology (modular
addition is associative+commutative), which is what lets the distributed
runtime reduce with ``psum_scatter + all_gather`` and stay bit-identical to
a replicated sum.

Everything is stateless: seeds chain from one public root via ``fold_in``
(a real deployment would run a pairwise key agreement; the simulation's
root-seed derivation stands in for it — see the README threat model), and
the round index folds in last, so resumed runs regenerate the identical
mask schedule. Under partial participation the masks of a pair are active
only when BOTH endpoints are sampled (the participation mask is public), so
the cancellation holds over exactly the reporting set.

Cost: the simulator materializes all ``N(N-1)/2`` pair masks per round
(the O(N^2) price of pairwise secure aggregation); each distributed fed
instance generates ``N`` slab-sized pair streams — its own ``N-1`` plus
one statically unavoidable self-pair stream whose sign is zero (the worker
index is a traced mesh index, so the l == idx case cannot be pruned at
trace time).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def pair_index(i, j, n: int):
    """Symmetric pair id of the unordered pair {i, j} in [0, n^2): both
    endpoints derive the same id (min-major), so both fold the same seed."""
    lo = jnp.minimum(i, j)
    hi = jnp.maximum(i, j)
    return lo * n + hi


def pair_incidence(n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Static pair structure for an N-worker cohort.

    Returns ``(C, i_idx, j_idx)`` where pairs are enumerated ``(i, j)`` with
    ``i < j``; ``C`` is the (n, P) signed incidence matrix (+1 for the lower
    endpoint, -1 for the upper — ``net = C @ pair_masks`` mod 2**32) and
    ``i_idx``/``j_idx`` are the (P,) endpoint indices.
    """
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    p = len(pairs)
    c = np.zeros((n, p), np.int32)
    for col, (i, j) in enumerate(pairs):
        c[i, col] = 1
        c[j, col] = -1
    i_idx = np.asarray([i for i, _ in pairs], np.int32)
    j_idx = np.asarray([j for _, j in pairs], np.int32)
    return c, i_idx, j_idx


def _pair_round_bits(seed: int, pid, t, shape) -> jax.Array:
    """The uint32 mask tensor of one pair for round ``t`` (both may be
    traced): ``bits(fold_in(fold_in(root, pid), t))``."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pid)
    return jax.random.bits(jax.random.fold_in(key, t), shape, jnp.uint32)


def net_masks(seed: int, n: int, t, shape: tuple, *,
              participation=None) -> jax.Array:
    """Every worker's net additive mask for round ``t``: uint32
    ``(n, *shape)`` summing to exactly zero mod 2**32 over the active set.

    ``t`` may be traced (the round index inside ``scan_rounds``).
    ``participation`` is an optional public (n,) 0/1 mask: a pair's mask is
    active only when both endpoints are sampled, so the masks of exactly
    the reporting workers cancel. Non-participants get an all-zero mask
    (they contribute nothing to the aggregate anyway — their weight is 0).
    """
    if n < 2:
        return jnp.zeros((n,) + tuple(shape), jnp.uint32)
    c, i_idx, j_idx = pair_incidence(n)
    pids = i_idx.astype(np.int64) * n + j_idx
    # jnp.array (not asarray): constants must embed, not device_put — the
    # round program stays free of host-sync primitives.
    bits = jax.vmap(
        lambda pid: _pair_round_bits(seed, pid, t, tuple(shape)))(
        jnp.array(pids, jnp.int32))                         # (P, *shape)
    signs = jnp.array(c, jnp.int32)                          # (n, P)
    if participation is not None:
        m = (jnp.asarray(participation) > 0).astype(jnp.int32)
        signs = signs * (m[i_idx] * m[j_idx])[None, :]
    # Signed modular sum: int32 dot wraps exactly like uint32 addition.
    net = jnp.tensordot(signs,
                        jax.lax.bitcast_convert_type(bits, jnp.int32),
                        axes=1)
    return jax.lax.bitcast_convert_type(net, jnp.uint32)


def net_mask_slab(seed: int, idx, n: int, t, shape: tuple, shard_idx=0, *,
                  participation=None) -> jax.Array:
    """One worker's net mask over its model-shard slab — the distributed
    form of :func:`net_masks` (worker ``idx`` and ``shard_idx`` may be
    traced mesh indices). Each (pair, round, model shard) gets its own
    stateless stream; cancellation is elementwise per shard because both
    endpoints fold the same ``shard_idx``. The loop spans all ``n``
    workers — the self-pair (and, under participation, inactive pairs)
    still generate a stream that is then sign-zeroed, because ``idx`` is
    traced and the case cannot be pruned statically.
    """
    if n < 2:
        return jnp.zeros(tuple(shape), jnp.uint32)
    total = jnp.zeros(tuple(shape), jnp.int32)
    for l in range(n):
        pid = pair_index(idx, l, n)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pid)
        key = jax.random.fold_in(key, t)
        bits = jax.random.bits(jax.random.fold_in(key, shard_idx),
                               tuple(shape), jnp.uint32)
        sign = jnp.where(l == idx, 0,
                         jnp.where(idx < l, 1, -1)).astype(jnp.int32)
        if participation is not None:
            m = (jnp.asarray(participation) > 0).astype(jnp.int32)
            sign = sign * m[l] * m[idx]
        total = total + sign * jax.lax.bitcast_convert_type(bits, jnp.int32)
    return jax.lax.bitcast_convert_type(total, jnp.uint32)


def quantize_weights(w: jax.Array, fixpoint_bits: int) -> jax.Array:
    """Public Eq. (3) weights -> uint32 fixed point:
    ``W_k = round(w_k 2**bits)``. ``sum_k w_k <= 1`` keeps every product
    ``W_k * field`` (field <= 2) and the cohort sum well inside 32 bits."""
    scale = float(1 << fixpoint_bits)
    return jnp.round(jnp.asarray(w, jnp.float32) * scale).astype(jnp.uint32)
