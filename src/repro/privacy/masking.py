"""Pairwise additive masks for secure aggregation — stateless, per-round.

Classic pairwise masking (Bonawitz et al., adapted to the FedPC wire): every
unordered worker pair ``(k, l)``, ``k < l``, shares a key; each round both
derive the same mask stream and worker ``k`` *adds* it while worker ``l``
*subtracts* it (mod 2**modulus_bits). The net mask of worker ``k`` is

    M_k = sum_{l > k} m_kl - sum_{l < k} m_lk        (mod 2**modulus_bits)

and ``sum_k M_k = 0`` exactly — integer cancellation, no epsilon of float
error, independent of summation order or reduction topology (modular
addition is associative+commutative), which is what lets the distributed
runtime reduce with ``psum_scatter + all_gather`` and stay bit-identical to
a replicated sum.

The streams are COUNTER-BASED: the mask word of pair ``(k, l)`` at absolute
flat element index ``e`` is

    word(e) = mix32(mix32(e') + key_kl),   key_kl = stream_key(seed, pid,
                                                              t, shard)

where ``mix32`` is the lowbias32 integer finalizer, ``pid = min*n + max``
the symmetric pair id, and ``e' = e`` for the 32-bit modulus or ``e >> 1``
for the 16-bit one (one 32-bit stream word feeds TWO consecutive uint16
lanes — low half at even ``e``, high at odd — halving mask-generation
cost). Because the stream is a pure function of (key, element index), the
Pallas kernels regenerate it IN-REGISTER per tile from the tiny ``(n, n)``
key matrix — no ``(N, rows, 512)`` mask tensor ever exists in HBM — while
this module's :func:`net_masks` / :func:`net_mask_slab` compute the same
words in plain jnp as the order-exact reference oracle for parity tests.
``mix32(e')`` is shared across every pair stream of a tile, so consecutive
pairs reuse the counter hash and only pay the ``+ key`` finalizer.

Everything is stateless: keys chain from one public root via ``mix32``
salting (a real deployment would run a pairwise key agreement; the
simulation's root-seed derivation stands in for it — see the README threat
model), and the round index salts last, so resumed runs regenerate the
identical mask schedule. Under partial participation the masks of a pair
are active only when BOTH endpoints are sampled (the participation mask is
public — it zeroes the pair's sign), so the cancellation holds over
exactly the reporting set.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

# Domain-separation salts (mask vs randomized-response vs fault-plan vs
# share-dealing key derivation) and the per-level mixing constants of the
# key chain.
MASK_DOMAIN = 0x9E3779B9
RR_DOMAIN = 0x3C6EF372
FAULT_DOMAIN = 0x94D049BB
RECOVERY_DOMAIN = 0xBF58476D
_SALT_STREAM = 0x85EBCA6B
_SALT_ROUND = 0xC2B2AE35
_SALT_SHARD = 0x27D4EB2F
_SALT_TREE_LEVEL = 0x165667B1


def mix32(x) -> jax.Array:
    """The lowbias32 finalizer — a full-avalanche uint32 -> uint32 hash.

    Pure shifts/multiplies, so it runs identically in plain jnp and inside
    Pallas kernel bodies (the kernel/oracle bitwise identity is this one
    expression, not two copies)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def stream_key(seed, stream_id, t, shard_idx=0, *,
               domain: int = MASK_DOMAIN) -> jax.Array:
    """Per-(stream, round, shard) uint32 key of a counter stream.

    ``stream_id`` is the symmetric pair id for masks (``pair_index``) or
    the worker index for RR; ``t`` the (possibly traced) round;
    ``shard_idx`` the model-shard index (the flat layout's padding — and
    so the element indexing — depends on the shard count, which is why
    streams are per-shard). ``domain`` separates mask keys from RR keys.
    All inputs may be traced; vectorized inputs broadcast."""
    k = mix32(jnp.asarray(seed, jnp.uint32) ^ jnp.uint32(domain))
    k = mix32(k + jnp.asarray(stream_id, jnp.uint32)
              * jnp.uint32(_SALT_STREAM))
    k = mix32(k + jnp.asarray(t, jnp.uint32) * jnp.uint32(_SALT_ROUND))
    k = mix32(k + jnp.asarray(shard_idx, jnp.uint32)
              * jnp.uint32(_SALT_SHARD))
    return k


def mask_stream(key, hashed_idx) -> jax.Array:
    """Stream word(s) at pre-hashed counter(s): ``mix32(mix32(e) + key)``.

    Split from the counter hash so one ``mix32(e)`` tile serves every pair
    stream (keys differ, the counter hash does not)."""
    return mix32(jnp.asarray(hashed_idx, jnp.uint32)
                 + jnp.asarray(key, jnp.uint32))


def halves16(u: jax.Array) -> jax.Array:
    """Interleave the 16-bit halves of uint32 stream words along the last
    axis: (..., w) -> (..., 2w) of values in [0, 2**16), low half first —
    the 16-bit modulus' two-lanes-per-word layout."""
    lo = u & jnp.uint32(0xFFFF)
    hi = u >> jnp.uint32(16)
    return jnp.stack([lo, hi], axis=-1).reshape(
        u.shape[:-1] + (2 * u.shape[-1],))


def stream_values(key, hashed_idx, word_bits: int) -> jax.Array:
    """Mask values for one stream as uint32: full words at 32, interleaved
    16-bit halves at 16 (``hashed_idx`` then holds ``mix32(e >> 1)`` over
    HALF the elements; output doubles the last axis)."""
    u = mask_stream(key, hashed_idx)
    return halves16(u) if word_bits == 16 else u


def index_hash(size: int, word_bits: int, base=0) -> jax.Array:
    """The shared counter-hash vector of a contiguous element range
    ``[base, base + size)``: ``mix32(e)`` per element at 32-bit, or
    ``mix32(e >> 1)`` per element PAIR at 16-bit (``base`` must then be
    even; returns ``size // 2`` entries — pair with :func:`halves16`)."""
    if word_bits == 16:
        return mix32(jnp.asarray(base, jnp.uint32) // jnp.uint32(2)
                     + jnp.arange(size // 2, dtype=jnp.uint32))
    return mix32(jnp.asarray(base, jnp.uint32)
                 + jnp.arange(size, dtype=jnp.uint32))


def pair_index(i, j, n: int):
    """Symmetric pair id of the unordered pair {i, j} in [0, n^2): both
    endpoints derive the same id (min-major), so both mix the same key."""
    lo = jnp.minimum(i, j)
    hi = jnp.maximum(i, j)
    return lo * n + hi


def pair_incidence(n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Static pair structure for an N-worker cohort.

    Returns ``(C, i_idx, j_idx)`` where pairs are enumerated ``(i, j)`` with
    ``i < j``; ``C`` is the (n, P) signed incidence matrix (+1 for the lower
    endpoint, -1 for the upper — ``net = C @ pair_masks`` mod 2**wb) and
    ``i_idx``/``j_idx`` are the (P,) endpoint indices.
    """
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    p = len(pairs)
    c = np.zeros((n, p), np.int32)
    for col, (i, j) in enumerate(pairs):
        c[i, col] = 1
        c[j, col] = -1
    i_idx = np.asarray([i for i, _ in pairs], np.int32)
    j_idx = np.asarray([j for _, j in pairs], np.int32)
    return c, i_idx, j_idx


def pair_stream_keys(seed, n: int, t, shard_idx=0) -> jax.Array:
    """The (n, n) symmetric matrix of pair stream keys for round ``t`` —
    the ONLY mask state a kernel launch consumes (n^2 words, not
    n x rows x 512). The diagonal (self-pairs) is derived but its sign is
    always zero. ``t``/``shard_idx`` may be traced."""
    idx = jnp.arange(n)
    pid = pair_index(idx[:, None], idx[None, :], n)
    return stream_key(seed, pid, t, shard_idx)


def pair_signs(n: int, *, participation=None) -> jax.Array:
    """The (n, n) antisymmetric sign matrix: ``signs[i, j]`` is the factor
    worker ``i`` applies to pair stream ``{i, j}`` (+1 below the diagonal
    pair order, -1 above, 0 on it), with participation folded in — a
    pair's masks are active only when BOTH endpoints are sampled."""
    idx = jnp.arange(n)
    i = idx[:, None]
    j = idx[None, :]
    signs = jnp.where(i == j, 0, jnp.where(i < j, 1, -1)).astype(jnp.int32)
    if participation is not None:
        m = (jnp.asarray(participation) > 0).astype(jnp.int32)
        signs = signs * (m[:, None] * m[None, :])
    return signs


def tree_level_seed(seed, level: int) -> jax.Array:
    """Mask seed of tree node level ``level`` (0 = leaves). Level 0 keeps
    the cohort's root seed (the leaf uplink is the flat uplink with scoped
    signs); every higher level mixes a level salt so a level-l node's pair
    streams are independent of the leaf streams with the same pair id."""
    if level == 0:
        return jnp.asarray(seed, jnp.uint32)
    return mix32(jnp.asarray(seed, jnp.uint32)
                 + jnp.uint32(level) * jnp.uint32(_SALT_TREE_LEVEL))


def tree_pair_signs(n: int, sibling: int, *, participation=None) -> jax.Array:
    """:func:`pair_signs` scoped to contiguous sibling groups of size
    ``sibling``: a pair's masks are active only when both endpoints share a
    parent (``i // sibling == j // sibling``), so each node's net mask
    cancels exactly inside its parent's partial sum — one tree level up,
    never later. Participation folds in as in the flat matrix."""
    signs = pair_signs(n, participation=participation)
    idx = jnp.arange(n)
    same = (idx[:, None] // sibling) == (idx[None, :] // sibling)
    return signs * same.astype(jnp.int32)


def tree_pair_signs_row(idx, n: int, sibling: int, *,
                        participation=None) -> jax.Array:
    """One node's (n,) row of :func:`tree_pair_signs` (``idx`` traced)."""
    signs = pair_signs_row(idx, n, participation=participation)
    others = jnp.arange(n)
    same = (others // sibling) == (jnp.asarray(idx) // sibling)
    return signs * same.astype(jnp.int32)


def tree_activity(mask, fanout: int) -> jax.Array:
    """Fold a (w,) participation/activity mask one tree level up: a node
    is active iff ANY of its (at most ``fanout``) children is. Returns
    (ceil(w/fanout),) float32 0/1 — the participation vector of the next
    level's sign scoping, so a fully-dropped subtree's node generates no
    mask and its partial is exactly zero."""
    m = (jnp.asarray(mask) > 0).astype(jnp.float32)
    w = m.shape[0]
    g = -(-w // fanout)
    m = jnp.pad(m, (0, g * fanout - w))
    return jnp.max(m.reshape(g, fanout), axis=1)


def pair_stream_keys_row(seed, idx, n: int, t, shard_idx=0) -> jax.Array:
    """One worker's (n,) row of :func:`pair_stream_keys` — the distributed
    form (``idx`` is a traced mesh index)."""
    others = jnp.arange(n)
    return stream_key(seed, pair_index(idx, others, n), t, shard_idx)


def pair_signs_row(idx, n: int, *, participation=None) -> jax.Array:
    """One worker's (n,) row of :func:`pair_signs` (``idx`` traced)."""
    others = jnp.arange(n)
    signs = jnp.where(others == idx, 0,
                      jnp.where(idx < others, 1, -1)).astype(jnp.int32)
    if participation is not None:
        m = (jnp.asarray(participation) > 0).astype(jnp.int32)
        signs = signs * m * m[idx]
    return signs


def _pair_values(seed, pids, t, size: int, word_bits: int,
                 shard_idx=0) -> jax.Array:
    """(P, size) uint32 mask VALUES (< 2**word_bits) of the given pair
    ids — the oracle-side stream expansion."""
    h = index_hash(size if word_bits == 32 else 2 * ((size + 1) // 2),
                   word_bits)
    keys = stream_key(seed, pids, t, shard_idx)
    vals = stream_values(keys[:, None], h[None, :], word_bits)
    return vals[:, :size]


def net_masks(seed, n: int, t, shape: tuple, *, word_bits: int = 32,
              participation=None, shard_idx=0) -> jax.Array:
    """Every worker's net additive mask for round ``t``: ``(n, *shape)`` of
    ``word_dtype`` summing to exactly zero mod 2**word_bits over the
    active set — the ORDER-EXACT REFERENCE ORACLE of the in-kernel stream
    generation (the kernels never consume this tensor; parity tests do).

    ``t`` may be traced (the round index inside ``scan_rounds``).
    ``participation`` is an optional public (n,) 0/1 mask: a pair's mask is
    active only when both endpoints are sampled, so the masks of exactly
    the reporting workers cancel. Non-participants get an all-zero mask
    (they contribute nothing to the aggregate anyway — their weight is 0).
    """
    out_dtype = jnp.uint16 if word_bits == 16 else jnp.uint32
    size = int(np.prod(shape))
    if n < 2:
        return jnp.zeros((n,) + tuple(shape), out_dtype)
    c, i_idx, j_idx = pair_incidence(n)
    pids = i_idx.astype(np.int64) * n + j_idx
    # jnp.array (not asarray): constants must embed, not device_put — the
    # round program stays free of host-sync primitives.
    vals = _pair_values(seed, jnp.array(pids, jnp.int32), t, size,
                        word_bits, shard_idx)                 # (P, size)
    signs = jnp.array(c, jnp.int32)                           # (n, P)
    if participation is not None:
        m = (jnp.asarray(participation) > 0).astype(jnp.int32)
        signs = signs * (m[i_idx] * m[j_idx])[None, :]
    # Signed modular sum: int32 dot wraps exactly like uint32 addition
    # (and mod 2**16 of mod 2**32 arithmetic is exact).
    net = jnp.tensordot(signs, vals.astype(jnp.int32), axes=1)
    if word_bits == 16:
        net = (net & jnp.int32(0xFFFF)).astype(out_dtype)
    else:
        net = jax.lax.bitcast_convert_type(net, jnp.uint32)
    return net.reshape((n,) + tuple(shape))


def net_mask_slab(seed, idx, n: int, t, shape: tuple, shard_idx=0, *,
                  word_bits: int = 32, participation=None,
                  signs_row=None) -> jax.Array:
    """One worker's net mask over its model-shard slab — the distributed
    form of :func:`net_masks` (worker ``idx`` and ``shard_idx`` may be
    traced mesh indices). Each (pair, round, model shard) gets its own
    stateless stream; cancellation is elementwise per shard because both
    endpoints mix the same ``shard_idx``. The loop spans all ``n``
    workers — the self-pair (and, under participation, inactive pairs)
    still generate a stream that is then sign-zeroed, because ``idx`` is
    traced and the case cannot be pruned statically. ``signs_row``
    overrides the sign derivation (the tree reduce passes sibling-scoped
    :func:`tree_pair_signs_row` rows for its per-level node masks).
    """
    out_dtype = jnp.uint16 if word_bits == 16 else jnp.uint32
    size = int(np.prod(shape))
    if n < 2:
        return jnp.zeros(tuple(shape), out_dtype)
    keys = pair_stream_keys_row(seed, idx, n, t, shard_idx)
    signs = (pair_signs_row(idx, n, participation=participation)
             if signs_row is None else signs_row)
    h = index_hash(size if word_bits == 32 else 2 * ((size + 1) // 2),
                   word_bits)
    total = jnp.zeros((size,), jnp.int32)
    for l in range(n):
        vals = stream_values(keys[l], h, word_bits)[:size]
        total = total + signs[l] * vals.astype(jnp.int32)
    if word_bits == 16:
        total = (total & jnp.int32(0xFFFF)).astype(out_dtype)
    else:
        total = jax.lax.bitcast_convert_type(total, jnp.uint32)
    return total.reshape(tuple(shape))


def quantize_weights(w: jax.Array, fixpoint_bits: int) -> jax.Array:
    """Public Eq. (3) weights -> uint32 fixed point:
    ``W_k = round(w_k 2**bits)``. ``sum_k w_k <= 1`` keeps every product
    ``W_k * field`` (field <= 2) and the cohort sum well inside the
    modulus (see ``PrivacySpec.wrap_headroom_workers`` for the exact
    N bound at each ``modulus_bits``)."""
    scale = float(1 << fixpoint_bits)
    return jnp.round(jnp.asarray(w, jnp.float32) * scale).astype(jnp.uint32)
