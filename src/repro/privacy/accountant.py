"""PrivacyAccountant — per-round (eps, delta) composition as a pytree.

The accountant is four device scalars, which makes it a valid ``lax.scan``
carry: it rides inside :class:`repro.fed.rounds.RoundState`, is updated by
``round_step`` whenever the round's wire ran the DP mechanism, serializes
through ``repro.checkpoint`` with the rest of the state, and survives a
mid-federation resume bit-exactly.

Two read-outs of the same ledger:

* **basic composition** — ``eps_total = sum_t eps_t`` (pure DP adds up);
* **advanced composition** (Dwork–Rothblum–Vadhan, heterogeneous form) —
  for any ``delta > 0``,

      eps(delta) = sqrt(2 ln(1/delta) sum_t eps_t^2)
                   + sum_t eps_t (e^{eps_t} - 1)

  which beats the linear bound once ``T eps^2`` is small; the accountant
  keeps ``sum eps^2`` and ``sum eps(e^eps - 1)`` so both read-outs are O(1)
  regardless of how many rounds were composed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PrivacyAccountant(NamedTuple):
    """Running per-coordinate (eps, delta) ledger over composed rounds."""
    spent_rounds: jax.Array   # int32 scalar — rounds that ran the mechanism
    eps_sum: jax.Array        # float32 — sum_t eps_t
    eps_sq_sum: jax.Array     # float32 — sum_t eps_t^2
    eps_lin_sum: jax.Array    # float32 — sum_t eps_t (e^{eps_t} - 1)

    @classmethod
    def zero(cls) -> "PrivacyAccountant":
        return cls(spent_rounds=jnp.asarray(0, jnp.int32),
                   eps_sum=jnp.asarray(0.0, jnp.float32),
                   eps_sq_sum=jnp.asarray(0.0, jnp.float32),
                   eps_lin_sum=jnp.asarray(0.0, jnp.float32))

    def add(self, eps) -> "PrivacyAccountant":
        """Compose one round of a pure-eps mechanism (traceable)."""
        e = jnp.asarray(eps, jnp.float32)
        return PrivacyAccountant(
            spent_rounds=self.spent_rounds + 1,
            eps_sum=self.eps_sum + e,
            eps_sq_sum=self.eps_sq_sum + e * e,
            eps_lin_sum=self.eps_lin_sum + e * (jnp.exp(e) - 1.0))

    def epsilon(self, delta: float | None = None) -> jax.Array:
        """Total eps spent: basic composition when ``delta`` is None, the
        advanced-composition bound at ``delta`` otherwise."""
        if delta is None:
            return self.eps_sum
        return (jnp.sqrt(2.0 * jnp.log(1.0 / delta) * self.eps_sq_sum)
                + self.eps_lin_sum)

    def best_epsilon(self, delta: float) -> jax.Array:
        """min(basic, advanced) — advanced only wins for long federations."""
        return jnp.minimum(self.epsilon(), self.epsilon(delta))
