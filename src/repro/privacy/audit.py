"""Traced-program leakage audits — §4.2 enforcement for both runtimes.

The leakage ledger (``repro.core.privacy``) records what crosses the
worker→master boundary; these helpers *enforce* the policy on the traced
round program itself, so a runtime can fail fast at setup instead of
trusting its drivers. Auditing works on jaxprs: traces run against
``ShapeDtypeStruct`` specs (never real data, and safe to call while an
outer jit trace is active).

Two boundaries are audited:

* **Simulator** (:func:`check_round_program`): in ``round_step`` the
  master-side math is the final pallas launch. Its float operands must be
  single-buffer slabs (the pilot gather + public history) — no float
  operand stacked over the worker axis may reach it, i.e. non-pilot
  full-precision parameters never enter master-side compute. On the masked
  wire path, additionally no plaintext ternary-code tensor (int8/uint8) may
  materialize anywhere in the program outside kernel bodies — codes exist
  only in VMEM registers and leave the worker already masked — and no
  worker launch may consume a mask-shaped unsigned-int tensor: the pairwise
  mask and RR streams are generated INSIDE the kernels from per-pair /
  per-worker counter keys, so a materialized (N, rows, 512) mask operand in
  the uplink is a leak-shaped smell (an HBM copy of per-worker secrets the
  policy says must stay in registers) as well as the exact perf regression
  the in-kernel PRNG removed.
* **Distributed** (:func:`check_fed_collectives`): what crosses between
  fed instances is exactly the collective payloads. No float payload
  stacked over the fed axis may cross (the pilot travels as a masked psum
  of a single slab), and on the masked wire no int8/uint8 code payload may
  cross — only masked words in a ``MASKED_WORD_DTYPES`` integer dtype
  (uint16 at the default modulus, uint32 at 32).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.privacy import LeakageError
from repro.utils import iter_jaxpr_eqns

#: Primitives that move data between fed instances (jax names across
#: versions: psum_scatter lowers to reduce_scatter).
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "all_gather", "psum_scatter", "reduce_scatter", "all_to_all",
    "ppermute", "pmax", "pmin",
})

_CODE_DTYPE_NAMES = ("int8", "uint8")

#: The wire words the masked path is allowed to move across the fed axis —
#: one word per parameter at either supported modulus.
MASKED_WORD_DTYPES = ("uint16", "uint32")


def _is_code_dtype(dtype) -> bool:
    return str(dtype) in _CODE_DTYPE_NAMES


def _is_unsigned_dtype(dtype) -> bool:
    try:
        return jnp.issubdtype(dtype, jnp.unsignedinteger)
    except TypeError:
        return False


def _is_signed_int_buffer(shape, dtype) -> bool:
    """True for a signed-integer tensor with buffer-scale volume. On the
    masked wire the de-biased (bitcast-signed) sum exists only at the root,
    after unmasking — a signed int16/int32 buffer in a fed collective is a
    partial that was de-masked below the root. Scalar signed metadata
    (round counters, pilot index) stays allowed."""
    try:
        if not jnp.issubdtype(dtype, jnp.signedinteger):
            return False
    except TypeError:
        return False
    volume = 1
    for d in shape:
        volume *= d
    return volume > _SCALAR_PAYLOAD_MAX


def _is_float_dtype(dtype) -> bool:
    # guarded: extended dtypes (PRNG keys) reject jnp.issubdtype
    try:
        return jnp.issubdtype(dtype, jnp.floating)
    except TypeError:
        return False


# A per-worker float payload this small is protocol metadata (Eq. (3)
# weights, costs, goodness — all public scalars per §4.2), not a parameter
# buffer; the smallest real buffer slab is one (8, 128) tile.
_SCALAR_PAYLOAD_MAX = 8


def _stacked_float_buffer(shape, dtype, n: int) -> bool:
    """True when (shape, dtype) is a float tensor stacked over the worker
    axis with real per-worker volume — i.e. parameter-bearing, not the
    public per-worker scalars the protocol always shares."""
    if not _is_float_dtype(dtype) or len(shape) < 1 or shape[0] != n:
        return False
    per_worker = 1
    for d in shape[1:]:
        per_worker *= d
    return per_worker > _SCALAR_PAYLOAD_MAX


def _stacked_mask_buffer(shape, dtype, n: int) -> bool:
    """True when (shape, dtype) looks like a materialized per-worker mask /
    RR tensor: unsigned words stacked over the worker axis with more than
    key-matrix volume per worker. The in-kernel-PRNG uplink consumes only
    the (N, N) pair-key/sign matrices, the (N,) RR keys and the (N, 1)
    fixed-point weights — all at most N words per worker — so anything
    bigger (an (N, rows, 512) mask plane) is a mask tensor round-tripping
    through HBM."""
    if not _is_unsigned_dtype(dtype) or len(shape) < 1 or shape[0] != n:
        return False
    per_worker = 1
    for d in shape[1:]:
        per_worker *= d
    return per_worker > max(_SCALAR_PAYLOAD_MAX, n)


def as_specs(tree: Any) -> Any:
    """Arrays -> ShapeDtypeStructs (non-arrays pass through) so audits can
    trace a program without touching real data."""
    return jax.tree_util.tree_map(
        lambda x: (jax.ShapeDtypeStruct(x.shape, x.dtype)
                   if hasattr(x, "shape") and hasattr(x, "dtype") else x),
        tree)


def _jaxpr_of(fn: Callable, *args, **kwargs):
    specs = as_specs((args, kwargs))
    return jax.make_jaxpr(lambda a, k: fn(*a, **k))(*specs).jaxpr


def collective_payloads(fn: Callable, *args, **kwargs) -> list[dict]:
    """Every collective operand in ``fn``'s traced program:
    ``{"primitive", "shape", "dtype"}`` per payload tensor."""
    out = []
    for eqn in iter_jaxpr_eqns(_jaxpr_of(fn, *args, **kwargs)):
        if eqn.primitive.name in COLLECTIVE_PRIMITIVES:
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "shape", None) is not None:
                    out.append({"primitive": eqn.primitive.name,
                                "shape": tuple(aval.shape),
                                "dtype": str(aval.dtype)})
    return out


def check_fed_collectives(fn: Callable, *args, n_fed: int,
                          masked: bool = False, **kwargs) -> dict:
    """Audit a distributed sync program's cross-instance payloads.

    Raises :class:`LeakageError` when a floating-point payload stacked over
    the fed axis crosses a collective (a gather of full-precision worker
    params), or — with ``masked=True`` — when any plaintext int8/uint8 code
    payload crosses at all. Returns a summary for ledger recording.
    """
    payloads = collective_payloads(fn, *args, **kwargs)
    for p in payloads:
        if _stacked_float_buffer(p["shape"], p["dtype"], n_fed):
            raise LeakageError(
                f"full-precision payload stacked over the fed axis crosses "
                f"a {p['primitive']}: shape {p['shape']} {p['dtype']}")
        if masked and _is_code_dtype(p["dtype"]):
            raise LeakageError(
                f"plaintext ternary codes cross a {p['primitive']} on the "
                f"masked wire: shape {p['shape']} {p['dtype']}")
        if (masked and _is_unsigned_dtype(p["dtype"])
                and p["dtype"] not in MASKED_WORD_DTYPES):
            raise LeakageError(
                f"unexpected unsigned payload crosses a {p['primitive']} "
                f"on the masked wire: shape {p['shape']} {p['dtype']} — "
                f"masked words must be one of {MASKED_WORD_DTYPES}")
        if masked and _is_signed_int_buffer(p["shape"], p["dtype"]):
            raise LeakageError(
                f"de-masked integer partial crosses a {p['primitive']} "
                f"below the root: shape {p['shape']} {p['dtype']} — "
                f"tree edges must carry masked unsigned words; the signed "
                f"de-biased sum exists only after the root unmask")
    return {"boundary": "fed-collectives", "n_payloads": len(payloads),
            "masked": masked}


def check_recovery_target(worker: int, alive) -> None:
    """Guard the dropout-recovery control plane: mask-seed reconstruction
    may only ever target a DECLARED-DEAD worker.

    Reconstructing a still-live worker's per-pair keys would let the
    server strip that worker's masks from its committed uplink — the exact
    attack secure aggregation exists to prevent — so
    ``recovery.recover_worker_keys`` calls this before combining any
    shares, and a live target raises :class:`LeakageError` instead of
    reconstructing. ``alive`` is the public (n,) survival mask of the
    round (host or device values; >0 means live)."""
    a = jnp.asarray(alive)
    if bool(a[int(worker)] > 0):
        raise LeakageError(
            f"mask-seed recovery targeted worker {int(worker)}, which is "
            f"still live this round — recovery may only reconstruct "
            f"declared-dead workers' seeds")


def check_round_program(fn: Callable, *args, n_workers: int,
                        masked: bool = False, **kwargs) -> dict:
    """Audit a simulator round program (``round_step`` or a jitted wrapper).

    The final pallas launch is the master update; none of its float
    operands may be stacked over the worker axis (the only float inputs are
    the dynamically gathered pilot slab and the public history). With
    ``masked=True``, additionally assert that (a) no int8/uint8
    ternary-code tensor materializes anywhere outside kernel bodies — the
    packed plaintext wire buffer of the unmasked path must not exist — and
    (b) the uplink launch (the first in the program) does not consume a
    mask-shaped unsigned-int operand stacked over the worker axis: mask and
    RR streams must be generated in-kernel from counter keys, never
    materialized in HBM and fed to the uplink (the pre-in-kernel-PRNG
    signature). Interior tree launches after the uplink legitimately
    consume stacked masked-word partials and are exempt from (b), and (c)
    no dict-carried output of the program (the info/telemetry record the
    driver fetches to the host) holds a float payload stacked over the
    worker axis — the trace must record counts and public per-worker
    scalars, never parameter-bearing buffers. Only dict subtrees are
    audited for (c): the carry's (rows, 128) buffer slabs are shared
    state, not per-worker exports, even when rows happens to equal N.
    """
    jaxpr = _jaxpr_of(fn, *args, **kwargs)
    launches = [e for e in iter_jaxpr_eqns(jaxpr, into_pallas=False)
                if e.primitive.name == "pallas_call"]
    if not launches:
        raise LeakageError("no kernel launch found to audit")
    master = launches[-1]
    for v in master.invars:
        aval = getattr(v, "aval", None)
        if aval is None or not getattr(aval, "shape", None):
            continue
        if _stacked_float_buffer(tuple(aval.shape), aval.dtype, n_workers):
            raise LeakageError(
                f"master launch consumes a float operand stacked over the "
                f"worker axis: shape {tuple(aval.shape)} {aval.dtype} — "
                f"non-pilot full-precision params crossed the boundary")
    if masked:
        for eqn in iter_jaxpr_eqns(jaxpr, into_pallas=False):
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None:
                    continue
                if _is_code_dtype(getattr(aval, "dtype", None)):
                    raise LeakageError(
                        f"plaintext code tensor materialized on the masked "
                        f"wire path: {eqn.primitive.name} -> "
                        f"{tuple(aval.shape)} {aval.dtype}")
        # Only the first launch is the worker uplink; later launches on the
        # tree path are interior partial-sum nodes whose operands are
        # legitimately (C, rows, 512) stacks of already-masked wire words.
        for launch in launches[:1]:
            for v in launch.invars:
                aval = getattr(v, "aval", None)
                if aval is None or not getattr(aval, "shape", None):
                    continue
                if _stacked_mask_buffer(tuple(aval.shape), aval.dtype,
                                        n_workers):
                    raise LeakageError(
                        f"uplink launch consumes a materialized mask "
                        f"tensor: shape {tuple(aval.shape)} {aval.dtype} — "
                        f"mask/RR streams must be generated in-kernel from "
                        f"counter keys, not round-tripped through HBM")
        _check_info_payloads(fn, args, kwargs, n_workers)
    return {"boundary": "round-step", "n_launches": len(launches),
            "masked": masked}


def _check_info_payloads(fn: Callable, args, kwargs, n_workers: int) -> None:
    """Part (c) of the masked audit: shape-evaluate the program and scan
    its dict-carried outputs (the info/telemetry records a driver exports
    off-device) for per-worker float payloads."""
    from jax.tree_util import DictKey, tree_flatten_with_path
    spec_args, spec_kwargs = as_specs((args, kwargs))
    out = jax.eval_shape(lambda a, k: fn(*a, **k), spec_args, spec_kwargs)
    for path, leaf in tree_flatten_with_path(out)[0]:
        if not any(isinstance(p, DictKey) for p in path):
            continue
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is None:
            continue
        if _stacked_float_buffer(tuple(shape), dtype, n_workers):
            name = jax.tree_util.keystr(path)
            raise LeakageError(
                f"round info/trace record carries a per-worker float "
                f"payload at {name}: shape {tuple(shape)} {dtype} — "
                f"telemetry must export counts and public scalars only, "
                f"never parameter-bearing buffers")
