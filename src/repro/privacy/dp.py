"""Local-DP ternary randomized response on the 2-bit wire codes.

The mechanism is the natural 3-ary randomized response over the biased
field alphabet {0, 1, 2} (code + 1): with probability ``1 - p`` report the
true field, else report a uniform draw from all three symbols. Per round
and per coordinate this is pure eps-DP with

    e^eps = P[out = v | in = v] / P[out = v | in = v'] =
          = (1 - p + p/3) / (p/3)          =>  eps = ln((3 - 2p) / p).

Both the flip decision and the replacement symbol come from ONE uint32 per
element: the flip compares the low 16 bits against a quantized threshold
(so ``p`` lives on a 1/65536 grid — ``PrivacySpec`` reports the realized
values), the replacement is the high 16 bits mod 3 (bias 1/65536 —
negligible and identical in kernel and oracle). The word is a COUNTER
stream like the pairwise masks (``repro.privacy.masking``): worker ``k``'s
RR word at flat element ``e`` is ``mix32(mix32(e) + rr_key_k)`` with
``rr_key_k = stream_key(dp_seed, k, t, shard, domain=RR_DOMAIN)`` — a
per-worker uint32 key in its own salt domain, so the Pallas kernels
regenerate the plane in-register from an (n,) key vector and no RR bit
tensor exists in HBM either. RR always draws FULL 32-bit words per
element, independent of the wire modulus (the 16-bit masked path still
needs 16 flip + 16 replacement bits per element).

Unbiasing: E[RR(field)] = (1 - p) field + p (the uniform mean over
{0, 1, 2} is 1), so after the master subtracts ``sum_k W_k`` (the de-bias
that converts fields to codes) the aggregated coefficient carries exactly a
factor ``1 - p``; dividing by it (folded into ``PrivacySpec.scale_mult``)
makes the *expected* master update equal the noiseless one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.privacy.masking import (RR_DOMAIN, index_hash, mask_stream,
                                   stream_key)


def rr_stream_key(seed, t, worker_idx, shard_idx=0) -> jax.Array:
    """One worker's uint32 RR stream key for (round, shard) — the only RR
    state a kernel launch consumes. All inputs may be traced."""
    return stream_key(seed, worker_idx, t, shard_idx, domain=RR_DOMAIN)


def rr_stream_keys(seed, t, n: int, shard_idx=0) -> jax.Array:
    """The (n,) per-worker RR key vector of one round."""
    return rr_stream_key(seed, t, jnp.arange(n), shard_idx)


def rr_bits(seed, t, n: int, shape: tuple) -> jax.Array:
    """The cohort's randomized-response word planes: uint32 ``(n, *shape)``
    — the reference oracle of the in-kernel RR stream (keyed by the
    possibly-traced round index; resume-stable)."""
    import numpy as np
    size = int(np.prod(shape))
    keys = rr_stream_keys(seed, t, n)
    h = index_hash(size, 32)
    return mask_stream(keys[:, None], h[None, :]).reshape((n,) + tuple(shape))


def rr_bits_worker(seed, t, worker_idx, shape: tuple,
                   shard_idx=0) -> jax.Array:
    """One worker's RR word plane over its model-shard slab — the
    distributed form, keyed by (round, worker, model shard). Like the
    pairwise masks, the stream is per-shard (the flat layout's padding —
    and so the element indexing — depends on the shard count), which is
    why cross-mesh bitwise parity is a DP-off property; with DP on the
    mechanism is still identical in distribution on every mesh."""
    import numpy as np
    size = int(np.prod(shape))
    key = rr_stream_key(seed, t, worker_idx, shard_idx)
    return mask_stream(key, index_hash(size, 32)).reshape(tuple(shape))


def rr_fields(fields: jax.Array, bits: jax.Array, threshold) -> jax.Array:
    """Apply 3-ary RR to uint32 biased fields {0, 1, 2}. ``threshold`` is
    the uint16 flip threshold (``PrivacySpec.rr_threshold``); 0 = identity.
    This exact expression is what the masked uplink kernel computes
    in-register — kernel vs this oracle is a bitwise comparison."""
    # Constants are built in-trace (not captured module-level arrays) so
    # this very function is callable inside the Pallas kernel body — the
    # kernel/oracle bitwise identity is one expression, not two copies.
    thr = jnp.asarray(threshold, jnp.uint32)
    flip = (bits & jnp.uint32(0xFFFF)) < thr
    rep = jax.lax.shift_right_logical(bits, jnp.uint32(16)) % jnp.uint32(3)
    return jnp.where(flip, rep, fields)
