"""Local-DP ternary randomized response on the 2-bit wire codes.

The mechanism is the natural 3-ary randomized response over the biased
field alphabet {0, 1, 2} (code + 1): with probability ``1 - p`` report the
true field, else report a uniform draw from all three symbols. Per round
and per coordinate this is pure eps-DP with

    e^eps = P[out = v | in = v] / P[out = v | in = v'] =
          = (1 - p + p/3) / (p/3)          =>  eps = ln((3 - 2p) / p).

Both the flip decision and the replacement symbol come from ONE uint32 per
element (stateless: ``bits(fold_in(root, t))``): the flip compares the low
16 bits against a quantized threshold (so ``p`` lives on a 1/65536 grid —
``PrivacySpec`` reports the realized values), the replacement is the high
16 bits mod 3 (bias 1/65536 — negligible and identical in kernel and
oracle). Low and high halves of a threefry word are independent, so the
two decisions don't correlate.

Unbiasing: E[RR(field)] = (1 - p) field + p (the uniform mean over
{0, 1, 2} is 1), so after the master subtracts ``sum_k W_k`` (the de-bias
that converts fields to codes) the aggregated coefficient carries exactly a
factor ``1 - p``; dividing by it (folded into ``PrivacySpec.scale_mult``)
makes the *expected* master update equal the noiseless one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

def rr_bits(seed: int, t, shape: tuple) -> jax.Array:
    """The round's randomized-response bit plane: uint32 of ``shape``,
    keyed by the (possibly traced) round index only — resume-stable."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), t)
    return jax.random.bits(key, tuple(shape), jnp.uint32)


def rr_bits_worker(seed: int, t, worker_idx, shape: tuple,
                   shard_idx=0) -> jax.Array:
    """One worker's RR bit plane over its model-shard slab — the
    distributed form, keyed by (round, worker, model shard). Like the
    pairwise masks, the stream is per-shard (the flat layout's padding —
    and so the element indexing — depends on the shard count), which is
    why cross-mesh bitwise parity is a DP-off property; with DP on the
    mechanism is still identical in distribution on every mesh."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), t)
    key = jax.random.fold_in(key, worker_idx)
    return jax.random.bits(jax.random.fold_in(key, shard_idx),
                           tuple(shape), jnp.uint32)


def rr_fields(fields: jax.Array, bits: jax.Array, threshold) -> jax.Array:
    """Apply 3-ary RR to uint32 biased fields {0, 1, 2}. ``threshold`` is
    the uint16 flip threshold (``PrivacySpec.rr_threshold``); 0 = identity.
    This exact expression is what the masked uplink kernel computes
    in-register — kernel vs this oracle is a bitwise comparison."""
    # Constants are built in-trace (not captured module-level arrays) so
    # this very function is callable inside the Pallas kernel body — the
    # kernel/oracle bitwise identity is one expression, not two copies.
    thr = jnp.asarray(threshold, jnp.uint32)
    flip = (bits & jnp.uint32(0xFFFF)) < thr
    rep = jax.lax.shift_right_logical(bits, jnp.uint32(16)) % jnp.uint32(3)
    return jnp.where(flip, rep, fields)
