"""PrivacySpec — the one config object of the privacy-preserving wire.

The spec bundles the three privacy pillars the wire path can switch on:

* **Pairwise-masked secure aggregation** (``secure_agg``): every worker adds
  a per-round additive mask to its fixed-point-weighted ternary fields
  before they leave the device. Masks are derived from stateless *pairwise*
  seeds (``fold_in(seed_kl, round)``) and sum to zero over the cohort, so
  the master recovers exactly ``sum_k W_k field_k`` mod 2**32 — never an
  individual worker's ternary directions. ``mask_seed=None`` turns masking
  off while keeping the integer secure-agg wire format (the debug /
  bitwise-reference configuration: because cancellation is exact in the
  integer domain, masked and unmasked runs are bit-identical).
* **Local-DP ternary randomized response** (``dp_epsilon``): each 2-bit
  code is independently replaced, with probability ``flip_prob``, by a
  uniform draw from {-1, 0, +1} — the natural 3-ary randomized-response
  mechanism. Per round and per coordinate this is pure
  ``eps_round``-DP; the master's de-bias step divides the aggregated
  coefficient by ``1 - flip_prob`` so the expected update equals the
  noiseless one.
* **Accounting / enforcement**: ``delta`` parameterizes the advanced-
  composition read-out of :class:`repro.privacy.accountant
  .PrivacyAccountant`; ``enforce`` makes the runtimes audit their traced
  round program against the §4.2 leakage policy at setup time
  (``repro.privacy.audit``).

Fixed-point weighting: Eq. (3) needs ``sum_k w_k T_k`` with *public*
per-worker weights ``w_k = p_k beta_k``. Exact modular cancellation demands
integers, so each worker scales its codes by ``W_k = round(w_k 2**fixpoint_
bits)`` and the master multiplies the integer sum by ``2**-fixpoint_bits``
(a power of two — the scaling itself is exact). Since ``sum_k w_k <= 1``,
the true sum is bounded by ``2**(fixpoint_bits+1)`` and never wraps; the
only approximation vs the float wire is the weight rounding
(``|W_k/2**bits - w_k| <= 2**-(bits+1)``).

Modulus: ``modulus_bits`` picks the wire word — 16 (the default: half the
bytes of the original secure-agg wire, 8x the 2-bit plaintext codes) or 32
(the conservative path). The de-bias residue ``sum_k W_k (field_k - 1)``
must stay inside the SIGNED half of the modulus, so ``fixpoint_bits`` is
coupled to it: the per-modulus default (14 for 16-bit, 24 for 32-bit)
leaves ``2**(modulus_bits-1) - 2**fixpoint_bits`` words of wrap headroom
— see :meth:`PrivacySpec.wrap_headroom_workers`. Everything else (mask
cancellation, RR, the descale) is modulus-generic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# The RR flip decision is drawn from the low 16 bits of a uint32, so the
# flip probability is realized on a 1/65536 grid; flip_prob/eps_round report
# the realized (quantized) values, and the unbias divides by exactly them.
RR_RESOLUTION = 1 << 16

# Largest per-round epsilon whose flip probability still rounds to a
# non-zero threshold (p = 3/(e^eps + 2) >= 0.5/65536).
MAX_DP_EPSILON = math.log(3.0 * RR_RESOLUTION / 0.5 - 2.0)

# Smallest epsilon whose flip probability rounds BELOW 1.0: at p == 1 the
# output is pure uniform noise (a degenerate eps=0 mechanism) and the
# 1/(1-p) unbias is undefined — reject it at construction instead of
# dividing by zero in the master's descale.
MIN_DP_EPSILON = math.log(3.0 * RR_RESOLUTION / (RR_RESOLUTION - 0.5) - 2.0)


# Per-modulus fixed-point defaults and upper bounds: the de-bias residue
# |sum_k W_k code_k| <= sum_k W_k <= 2**fb + N/2 must stay under
# 2**(modulus_bits - 1) for the signed reinterpretation to be exact.
_FIXPOINT_DEFAULT = {16: 14, 32: 24}
_FIXPOINT_MAX = {16: 14, 32: 26}


@dataclass(frozen=True)
class PrivacySpec:
    """Configuration of the secure-aggregation + DP wire path."""
    secure_agg: bool = True        # pairwise-masked integer aggregation
    mask_seed: int | None = 0      # pairwise-seed root; None = masking off
    modulus_bits: int = 16         # wire word width: 16 (default) or 32
    fixpoint_bits: int | None = None  # weight scale 2**bits; None = default
    dp_epsilon: float | None = None  # per-round per-coordinate eps; None=off
    dp_seed: int = 1               # randomized-response bit stream root
    delta: float = 1e-5            # advanced-composition delta
    enforce: bool = True           # audit runtimes' traced round programs
    recovery_threshold: int | None = None  # Shamir t for dropout recovery

    def __post_init__(self):
        if self.recovery_threshold is not None and self.recovery_threshold < 2:
            raise ValueError(
                f"recovery_threshold must be >= 2 (a 1-of-n dealing would "
                f"hand every sibling the dead worker's seeds outright), "
                f"got {self.recovery_threshold}")
        if self.modulus_bits not in (16, 32):
            raise ValueError(
                f"modulus_bits must be 16 or 32 (the wire word is one "
                f"uint16/uint32 per parameter), got {self.modulus_bits}")
        if self.fixpoint_bits is None:
            object.__setattr__(self, "fixpoint_bits",
                               _FIXPOINT_DEFAULT[self.modulus_bits])
        hi = _FIXPOINT_MAX[self.modulus_bits]
        if not 8 <= self.fixpoint_bits <= hi:
            raise ValueError(
                f"fixpoint_bits must be in [8, {hi}] for modulus_bits="
                f"{self.modulus_bits} (the signed de-bias residue "
                f"sum_k W_k code_k must stay under 2**{self.modulus_bits - 1}"
                f"), got {self.fixpoint_bits}")
        if self.dp_epsilon is not None:
            if not MIN_DP_EPSILON <= self.dp_epsilon <= MAX_DP_EPSILON:
                raise ValueError(
                    f"dp_epsilon must be in [{MIN_DP_EPSILON:.2e}, "
                    f"{MAX_DP_EPSILON:.2f}] (the RR threshold quantizes to "
                    f"1/{RR_RESOLUTION}; below the floor the flip "
                    f"probability rounds to 1 and the unbias is undefined), "
                    f"got {self.dp_epsilon}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")

    # -- derived switches ---------------------------------------------------

    @property
    def dp_on(self) -> bool:
        return self.dp_epsilon is not None

    @property
    def masking_on(self) -> bool:
        return self.secure_agg and self.mask_seed is not None

    @property
    def active(self) -> bool:
        """Whether the round must take the masked integer wire path."""
        return self.secure_agg or self.dp_on

    # -- randomized response ------------------------------------------------

    @property
    def rr_threshold(self) -> int:
        """uint16 flip threshold: flip when ``bits & 0xFFFF < threshold``.
        Clamped to [1, 2**16 - 1]: a threshold of 2**16 would realize
        p == 1 (pure noise, undefined unbias)."""
        if not self.dp_on:
            return 0
        p = 3.0 / (math.exp(self.dp_epsilon) + 2.0)
        return min(RR_RESOLUTION - 1, max(1, round(p * RR_RESOLUTION)))

    @property
    def flip_prob(self) -> float:
        """The *realized* flip probability (threshold / 2**16)."""
        return self.rr_threshold / RR_RESOLUTION

    @property
    def eps_round(self) -> float:
        """Realized per-round per-coordinate epsilon of the 3-ary RR:
        ``ln((3 - 2p) / p)`` for the quantized flip probability ``p``."""
        if not self.dp_on:
            return 0.0
        p = self.flip_prob
        return math.log((3.0 - 2.0 * p) / p)

    # -- fixed-point weighting ----------------------------------------------

    @property
    def word_dtype(self):
        """The wire word dtype of this modulus (jnp.uint16 / jnp.uint32)."""
        import jax.numpy as jnp
        return jnp.uint16 if self.modulus_bits == 16 else jnp.uint32

    def wrap_headroom_workers(self) -> int:
        """How large a cohort provably cannot wrap the signed de-bias
        residue: ``|sum_k W_k code_k| <= sum_k W_k <= 2**fb + N/2`` (the
        N/2 is worst-case per-worker weight rounding under
        ``sum_k w_k <= 1``), which must stay under ``2**(mb-1)``. Returns
        the largest N satisfying the bound."""
        return 2 * ((1 << (self.modulus_bits - 1))
                    - (1 << self.fixpoint_bits)) - 1

    @property
    def scale(self) -> float:
        return float(1 << self.fixpoint_bits)

    @property
    def scale_mult(self) -> float:
        """The master's single de-bias multiplier: the fixed-point descale
        (exact power of two) folded with the RR unbias ``1/(1 - p)``."""
        return (1.0 / self.scale) / (1.0 - self.flip_prob)
