from repro.sharding.specs import (  # noqa: F401
    param_specs,
    batch_spec,
    cache_specs,
    data_axes,
    wire_specs,
)
