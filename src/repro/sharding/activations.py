"""Activation sharding constraints (logical-axis style).

Without constraints, XLA's SPMD partitioner may satisfy FSDP parameter
shardings by *contracting over the data-sharded weight dim* — which
replicates the batch and all-reduces full attention-score tensors (observed:
86 GB/device all-reduces on qwen3 train_4k). Pinning the residual stream to
(batch→data axes) and the wide intermediates to (feature→'model') makes the
partitioner all-gather weights instead (true FSDP) and keeps the only
activation collectives the Megatron row-parallel all-reduces.

All helpers no-op when no mesh is active (CPU unit tests) and silently drop
any axis that does not divide the corresponding dim (e.g. batch=1 in
long_500k — the cache specs then carry the parallelism).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


_DISABLED = [False]


def set_disabled(value: bool) -> None:
    """Disable all activation constraints (used by the fed dry-run, where
    local training is vmapped over the fed axis and the residual-stream
    constraints would fight the fed slicing)."""
    _DISABLED[0] = bool(value)


def _current_mesh():
    if _DISABLED[0]:
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def constrain(x, raw_spec):
    """raw_spec: tuple per dim — None | axis-name | 'DP' (data axes) |
    tuple of axis names. Drops non-divisible/absent axes."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != len(raw_spec):
        return x
    spec = []
    for dim, ax in zip(x.shape, raw_spec):
        if ax is None:
            spec.append(None)
            continue
        if ax == "DP":
            axs = _dp_axes(mesh)
        elif isinstance(ax, str):
            axs = (ax,) if ax in mesh.axis_names else ()
        else:
            axs = ()
            for a in ax:
                if a == "DP":
                    axs += _dp_axes(mesh)
                elif a in mesh.axis_names:
                    axs += (a,)
        size = 1
        for a in axs:
            size *= mesh.shape[a]
        if axs and size > 0 and dim % size == 0:
            spec.append(axs if len(axs) > 1 else axs[0])
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def residual(x):
    """(B, S, D): batch over data axes, D replicated."""
    return constrain(x, ("DP", None, None))


def heads(x):
    """(B, S, H, dh): batch over data axes; heads over 'model' when they
    divide it, else sequence over 'model' (sequence-parallel attention —
    e.g. qwen3's 40 heads on a 16-wide model axis)."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != 4:
        return x
    msize = mesh.shape.get("model", 1)
    if x.shape[2] % msize == 0:
        return constrain(x, ("DP", None, "model", None))
    return constrain(x, ("DP", "model", None, None))


def ffn_hidden(x):
    """(B, S, F): wide intermediate over model."""
    return constrain(x, ("DP", None, "model"))


def logits(x):
    """(B, S, V): vocab over model."""
    return constrain(x, ("DP", None, "model"))


def expert_buf(x):
    """(E, C, D): expert-parallel over model when E divides it; else
    tensor-parallel experts — capacity over the data axes."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    if x.shape[0] % mesh.shape.get("model", 1) == 0:
        return constrain(x, ("model", None, None))
    return constrain(x, (None, "DP", None))


def dp_size() -> int:
    """Number of data-parallel shards in the active mesh (1 off-mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size() -> int:
    """Size of the 'model' axis in the active mesh (1 off-mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return 1
    return mesh.shape.get("model", 1)


def expert_block_buf(x):
    """(E, s, C_loc, D) block-dispatched expert buffer: blocks over DP,
    experts over model when divisible."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != 4:
        return x
    e_ax = "model" if x.shape[0] % mesh.shape.get("model", 1) == 0 else None
    return constrain(x, (e_ax, "DP", None, None))


def expert_block_hidden(x):
    """(E, s, C_loc, F)."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != 4:
        return x
    if x.shape[0] % mesh.shape.get("model", 1) == 0:
        return constrain(x, ("model", "DP", None, None))
    return constrain(x, (None, "DP", None, "model"))


def expert_weights(w, transposed: bool = False):
    """Use-site constraint for tensor-parallel expert weights (E not
    divisible by 'model'): FSDP shard on the F dim, contraction dims
    replicated — input shardings alone are only hints to the SPMD
    partitioner; the use-site constraint is what actually stops the
    partial-sum all-reduce strategy. (E,D,F) or transposed (E,F,D)."""
    mesh = _current_mesh()
    if mesh is None or w.ndim != 3:
        return w
    if w.shape[0] % mesh.shape.get("model", 1) == 0:
        return w                       # expert-parallel path, leave alone
    spec = (None, ("model", "DP"), None) if transposed         else (None, None, ("model", "DP"))
    return constrain(w, spec)


def expert_hidden(x):
    """(E, C, F) expert intermediate: expert-parallel, or capacity×FF."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    if x.shape[0] % mesh.shape.get("model", 1) == 0:
        return constrain(x, ("model", None, None))
    return constrain(x, (None, "DP", "model"))


def ssm_state(x):
    """(B, di, ds): channels over model."""
    return constrain(x, ("DP", "model", None))
