"""PartitionSpec rules: FSDP + tensor-parallel layout for the model zoo.

Conventions (see models/*):
  * block params are stacked along a leading ``units`` axis (scanned) —
    that axis is never sharded;
  * column-parallel weights (D, F): D→data axes (FSDP), F→model axis;
  * row-parallel weights (F, D): F→model, D→data;
  * MoE expert stacks (E, D, F): expert-parallel over 'model' when E divides
    the model-axis size, else tensor-parallel inside each expert;
  * embeddings: vocab over 'model' (in), lm_head vocab over 'model' (out,
    Megatron-style sharded logits), other dim over data axes;
  * norms/scalars: replicated.

Multi-pod: the data shards span ('pod', 'data') — full FSDP across all chips.
The fed runtime instead keeps distinct per-worker values along an explicit
leading fed axis (see fed/distributed.py); these rules cover the plain
data/tensor-parallel path.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The axes that jointly play the 'data/FSDP' role."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def _ax(axes):
    """Normalize a 1-tuple of axis names to the bare name."""
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def _div(n: int, axis_size: int) -> bool:
    return axis_size > 0 and n % axis_size == 0


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


# Leaf-name regexes → role. First match wins.
_RULES: list[tuple[str, str]] = [
    (r"(^|/)embed$", "embed"),
    (r"(^|/)lm_head$", "lm_head"),
    (r"(^|/)(wq|wk|wv|w_gate|w_up|in_proj|dt_proj|up_proj|audio_proj|patch_proj)$", "col"),
    (r"(^|/)(wo|w_down|out_proj)$", "row"),
    (r"(^|/)router$", "router"),
    (r"(^|/)experts_(gate|up)$", "expert_col"),
    (r"(^|/)experts_down$", "expert_row"),
    (r"(^|/)(x_proj)$", "row"),          # (d_inner, k): d_inner is model-sharded
    (r"(^|/)(A_log)$", "ssm_state"),     # (d_inner, d_state)
    (r"(^|/)(conv_w)$", "conv"),         # (d_conv, d_inner)
    (r"(^|/)(D_skip|dt_bias|conv_b)$", "vec_model"),  # (d_inner,)
    (r"(^|/)(q_norm|k_norm|norm|norm1|norm2|norm3|norm_f|scale|bias|gates_b)$", "rep"),
    (r"(^|/)(gates_w)$", "col"),         # lstm gate projections (D, k*di)
    (r"(^|/)(r_gates_w)$", "lstm_rec"),  # slstm recurrent (di, k*di)
]


def _role(path: str) -> str:
    for pat, role in _RULES:
        if re.search(pat, path):
            return role
    return "auto"


def _spec_for(role: str, shape: tuple[int, ...], mesh: Mesh,
              stacked: bool) -> P:
    """Build a PartitionSpec for the *unstacked* trailing dims, then prepend
    None for the units axis if stacked."""
    dp = data_axes(mesh)
    dp_sz = _axis_size(mesh, dp)
    mp_sz = mesh.shape.get("model", 1)
    dims = shape[1:] if stacked else shape
    nd = len(dims)

    def fits(i, sz):
        return _div(dims[i], sz)

    spec: list = [None] * nd
    if role == "embed" and nd == 2:                      # (V, D)
        if fits(0, mp_sz):
            spec[0] = "model"
        if fits(1, dp_sz):
            spec[1] = _ax(dp)
    elif role == "lm_head" and nd == 2:                  # (D, V)
        if fits(0, dp_sz):
            spec[0] = _ax(dp)
        if fits(1, mp_sz):
            spec[1] = "model"
    elif role == "col" and nd == 2:                      # (D, F)
        if fits(0, dp_sz):
            spec[0] = _ax(dp)
        if fits(1, mp_sz):
            spec[1] = "model"
    elif role == "row" and nd == 2:                      # (F, D)
        if fits(0, mp_sz):
            spec[0] = "model"
        if fits(1, dp_sz):
            spec[1] = _ax(dp)
    elif role == "router" and nd == 2:                   # (D, E)
        if fits(0, dp_sz):
            spec[0] = _ax(dp)
    elif role in ("expert_col", "expert_row") and nd == 3:  # (E, D, F)/(E, F, D)
        if fits(0, mp_sz):                               # expert-parallel
            spec[0] = "model"
            inner = 1 if role == "expert_col" else 2     # the D dim
            if fits(inner, dp_sz):
                spec[inner] = _ax(dp)
        else:
            # tensor-parallel experts. The FSDP shard rides on the F dim
            # together with 'model' — sharding the CONTRACTION dim (D for
            # gate/up, F itself is contracted in down but gathered first)
            # over 'data' makes XLA emit partial-sum all-reduces of
            # (E, C, ·)-sized activations (observed: 9 TB/device on grok);
            # F-sharded weights instead all-gather ~MBs of weights.
            f_axes = ("model",) + dp
            if role == "expert_col":                     # (E, D, F)
                if fits(2, mp_sz * dp_sz):
                    spec[2] = f_axes
                elif fits(2, mp_sz):
                    spec[2] = "model"
            else:                                        # (E, F, D)
                if fits(1, mp_sz * dp_sz):
                    spec[1] = f_axes
                elif fits(1, mp_sz):
                    spec[1] = "model"
    elif role == "ssm_state" and nd == 2:                # (d_inner, d_state)
        if fits(0, mp_sz):
            spec[0] = "model"
    elif role == "conv" and nd == 2:                     # (d_conv, d_inner)
        if fits(1, mp_sz):
            spec[1] = "model"
    elif role == "vec_model" and nd == 1:
        if fits(0, mp_sz):
            spec[0] = "model"
    elif role == "lstm_rec" and nd == 2:                 # (di, k*di)
        if fits(1, mp_sz):
            spec[1] = "model"
    elif role == "rep":
        pass
    else:  # auto: shard the last dim over model, the first over data
        if nd >= 1 and fits(nd - 1, mp_sz):
            spec[nd - 1] = "model"
        if nd >= 2 and fits(0, dp_sz):
            spec[0] = _ax(dp)

    if stacked:
        spec = [None] + spec
    return P(*spec)


def param_specs(params: PyTree, mesh: Mesh,
                stacked_prefixes: tuple[str, ...] = ("blocks", "units",
                                                     "encoder_blocks",
                                                     "decoder_blocks")) -> PyTree:
    """PartitionSpec pytree matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        p = _path_str(path)
        stacked = any(p.startswith(pre + "/") or f"/{pre}/" in p
                      for pre in stacked_prefixes)
        specs.append(_spec_for(_role(p), tuple(leaf.shape), mesh, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )


def wire_specs(fed_axis: str, model_axis: str | None) -> dict:
    """PartitionSpecs for the flat wire buffers of the fed round sync.

    The ``(rows, 128)`` FlatParams buffers shard their *row* axis over the
    model axis (each model shard owns a ``(rows/M, 128)`` slab); the stacked
    per-worker buffers additionally split their leading worker axis over the
    fed axis. ``model_axis=None`` replicates the rows (the pre-sharded wire
    path — kept for parity testing and meshes without a model axis).

    Keys: ``stacked`` (F, rows, 128) worker buffers; ``history`` (rows, 128)
    public P^{t-1}/P^{t-2}; ``out`` (rows, 128) new global buffer.
    """
    return {
        "stacked": P(fed_axis, model_axis, None),
        "history": P(model_axis, None),
        "out": P(model_axis, None),
    }


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Tokens/labels (B, S, ...): shard B over the data axes if divisible."""
    dp = data_axes(mesh)
    if _div(batch, _axis_size(mesh, dp)):
        return P(_ax(dp), *([None] * extra_dims))
    # fall back to sharding over just 'data'
    if _div(batch, mesh.shape.get("data", 1)):
        return P("data", *([None] * extra_dims))
    return P(*([None] * (1 + extra_dims)))


def cache_specs(cache: PyTree, mesh: Mesh, batch: int) -> PyTree:
    """KV / SSM state sharding. Rank-4 KV caches (B, S, H, dh): batch over
    data axes when divisible, else sequence over data axes; heads over model
    when divisible. Rank-3 SSM states (B, di, ds): di over model. Scalars
    (positions) replicated."""
    dp = data_axes(mesh)
    dp_sz = _axis_size(mesh, dp)
    mp_sz = mesh.shape.get("model", 1)

    def spec(leaf):
        s = leaf.shape
        if leaf.ndim == 4:  # (B, S, H, dh)
            b = _ax(dp) if _div(s[0], dp_sz) else None
            seq = _ax(dp) if (b is None and _div(s[1], dp_sz)) else None
            h = "model" if _div(s[2], mp_sz) else None
            return P(b, seq, h, None)
        if leaf.ndim == 3:  # (B, d_inner, d_state) or (B, d_conv, d_inner)
            b = _ax(dp) if _div(s[0], dp_sz) else None
            mid = "model" if _div(s[1], mp_sz) else None
            last = None
            if mid is None and _div(s[2], mp_sz):
                last = "model"
            return P(b, mid, last)
        if leaf.ndim == 2:  # (B, d) lstm hidden
            b = _ax(dp) if _div(s[0], dp_sz) else None
            d = "model" if _div(s[1], mp_sz) else None
            return P(b, d)
        return P()

    return jax.tree_util.tree_map(spec, cache)
