"""2-bit packing of ternary codes — the wire format of §3.3.

The paper: "we can represent these three values by 2 bits … we can compress 4
ternary values into 1 Byte", giving the 16× upload reduction of Eq. (8)
(vs. float32 weights; 32× vs. float64).

Code mapping (biased): t + 1 ∈ {0, 1, 2} → 2-bit field. Four fields pack
little-endian into one uint8: byte = c0 | c1<<2 | c2<<4 | c3<<6.

These are the jnp reference semantics; ``repro.kernels.pack2bit`` implements
the same transform as a Pallas TPU kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import PyTree, round_up

PACK_FACTOR = 4  # ternary codes per byte


def packed_size(n: int) -> int:
    """Bytes needed for n ternary codes."""
    return round_up(n, PACK_FACTOR) // PACK_FACTOR


def pack2bit(t: jax.Array) -> jax.Array:
    """Pack int8 ternary codes {-1,0,1} (flat or any shape) into uint8.

    Returns a 1-D uint8 array of ``packed_size(t.size)`` bytes. Input is
    zero-padded up to a multiple of 4 codes.
    """
    flat = t.reshape(-1).astype(jnp.int8)
    n = flat.shape[0]
    pad = round_up(n, PACK_FACTOR) - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.int8)])
    codes = (flat + 1).astype(jnp.uint8).reshape(-1, PACK_FACTOR)  # {0,1,2}
    shifts = jnp.array([0, 2, 4, 6], jnp.uint8)
    return jnp.sum(codes << shifts, axis=-1).astype(jnp.uint8)


def unpack2bit(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack2bit`; returns the first ``n`` int8 codes."""
    b = packed.reshape(-1, 1).astype(jnp.uint8)
    shifts = jnp.array([0, 2, 4, 6], jnp.uint8)
    fields = (b >> shifts) & jnp.uint8(0x3)          # (bytes, 4)
    codes = fields.reshape(-1).astype(jnp.int8) - 1  # back to {-1,0,1}
    return codes[:n]


def pack_tree(t: PyTree) -> tuple[jax.Array, list]:
    """Pack a whole pytree of ternary codes into one uint8 buffer.

    Returns (buffer, layout) where layout records (treedef, shapes) so the
    receiver can unpack without out-of-band information beyond the public
    model architecture (which the master already has).
    """
    leaves, treedef = jax.tree_util.tree_flatten(t)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves]).astype(jnp.int8)
    layout = (treedef, [l.shape for l in leaves])
    return pack2bit(flat), layout


def unpack_tree(packed: jax.Array, layout) -> PyTree:
    treedef, shapes = layout
    # math.prod: pure host arithmetic — the old jnp.prod forced a device
    # sync per leaf just to compute a static size.
    sizes = [math.prod(s) for s in shapes]
    flat = unpack2bit(packed, sum(sizes))
    leaves, off = [], 0
    for s, size in zip(shapes, sizes):
        leaves.append(flat[off : off + size].reshape(s))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)
