"""FedPC round logic — Algorithms 1 & 2 of the paper, as pure functions.

The master state carries the global model and its two-step history (needed by
both Eq. (5) on workers and Eq. (3) on the master) plus last-round costs for
the goodness function. A round is::

    results_k = worker local training (private hparams)      [Alg. 2 line 1]
    costs     = gather scalar costs                          [Alg. 1 line 3]
    k*        = argmax goodness(costs, prev_costs, sizes)    [Alg. 1 line 4]
    Q_pilot   = full weights from k*                         [Alg. 1 line 5]
    T_k       = ternary(Q_k, P^{t-1}, P^{t-2}, beta_k)       [Alg. 1 line 6]
    P^t       = Eq. (3)                                      [Alg. 1 line 7]

This module is runtime-agnostic: ``repro.fed.simulator`` drives it with an
in-process list of workers (the paper's testbed), ``repro.fed.distributed``
drives the same math through shard_map collectives on the TPU mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.goodness import select_pilot as _select_pilot
from repro.core.ternary import ternarize_tree, ternarize_tree_round1
from repro.core.tree import TreeSpec
from repro.core.update import master_update_tree
from repro.privacy.spec import PrivacySpec
from repro.utils import PyTree


@dataclass(frozen=True)
class FedPCConfig:
    n_workers: int
    alpha0: float = 0.01          # master lr for the round-1 rule of Eq. (3)
    beta: float = 0.2             # significance threshold (paper: (0,1), e.g. 0.2)
    alpha_round1: float = 0.01    # Eq. (4) threshold (worker lr at round 1)
    pack_bits: int = 2            # wire width per ternary code
    weight_bits: int = 32         # wire width per weight (paper uses fp32)
    betas: tuple | None = None    # per-worker beta_k (len n_workers); None = uniform
    participation: float = 1.0    # FedAvg-style C-fraction of workers per round
    privacy: PrivacySpec | None = None  # secure-agg / local-DP wire
    renorm_shares: bool = False   # Eq. (3) shares renormalized over sampled set
    tree: TreeSpec | None = None  # hierarchical fan-in aggregation tree
    # Deterministic fault schedule (repro.fed.faults.FaultPlan). Typed loosely:
    # repro.fed imports this module, so the concrete class cannot be named here.
    faults: Any = None

    def __post_init__(self):
        if self.betas is not None and len(self.betas) != self.n_workers:
            raise ValueError(
                f"betas has {len(self.betas)} entries for "
                f"{self.n_workers} workers")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}")

    @property
    def beta_vector(self):
        """(N,) per-worker beta_k — ``betas`` when set, else uniform."""
        if self.betas is not None:
            return jnp.asarray(self.betas, jnp.float32)
        return jnp.full((self.n_workers,), self.beta, jnp.float32)


class FedPCState(NamedTuple):
    """Master-side state between rounds (all public to every participant)."""
    params: PyTree        # P^{t-1} — current global model
    params_prev: PyTree   # P^{t-2} — needed by Eq. (3)/(5)
    prev_costs: jax.Array  # (N,) last-round worker costs, +inf before round 1
    round: jax.Array       # scalar int32, 1-based round about to run


class WorkerResult(NamedTuple):
    """What worker k produces locally before any communication."""
    params: PyTree        # Q_k^t — stays on the worker unless pilot
    cost: jax.Array       # C_k^t — the only always-uploaded value


def init_state(params: PyTree, n_workers: int) -> FedPCState:
    return FedPCState(
        params=params,
        params_prev=jax.tree_util.tree_map(jnp.zeros_like, params),
        prev_costs=jnp.full((n_workers,), jnp.inf, jnp.float32),
        round=jnp.asarray(1, jnp.int32),
    )


def worker_ternary(
    cfg: FedPCConfig,
    local_params: PyTree,
    state: FedPCState,
    beta=None,
) -> PyTree:
    """Alg. 2 line 8: Eq. (4) at round 1, Eq. (5) afterwards.

    Both branches are evaluated and selected on the (possibly traced) round
    index — they are elementwise and cheap relative to training. ``beta``
    (scalar, may be traced) overrides the shared threshold — the worker's
    own beta_k in the heterogeneous regime.
    """
    beta = cfg.beta if beta is None else beta
    t1 = ternarize_tree_round1(local_params, state.params, cfg.alpha_round1)
    # At round 1 params_prev is zeros; the selected branch ignores it.
    tt = ternarize_tree(local_params, state.params, state.params_prev, beta)
    pick = jnp.asarray(state.round) <= 1
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pick, a, b), t1, tt
    )


def master_round(
    cfg: FedPCConfig,
    state: FedPCState,
    stacked_params: PyTree,   # (N, ...) leaves — all workers' local models
    costs: jax.Array,         # (N,)
    sizes: jax.Array,         # (N,)
) -> tuple[FedPCState, dict]:
    """Alg. 1 lines 3–8 given gathered worker outputs.

    NOTE on fidelity vs. the wire protocol: mathematically the master needs
    only the pilot row of ``stacked_params`` plus everyone else's ternary
    codes. The simulator/distributed runtimes enforce that split (and account
    bytes accordingly); this function expresses the *math* over the stacked
    representation so it can be jit/shard_map'ed with static shapes.
    """
    k_star, scores = _select_pilot(costs, state.prev_costs, sizes, state.round)
    betas = cfg.beta_vector

    # Every worker's ternary codes (the pilot's row is masked in Eq. (3)),
    # each thresholded by its own beta_k.
    ternaries = jax.vmap(lambda p, b: worker_ternary(cfg, p, state, b))(
        stacked_params, betas)

    q_pilot = jax.tree_util.tree_map(lambda x: x[k_star], stacked_params)
    p_shares = sizes.astype(jnp.float32) / jnp.sum(sizes.astype(jnp.float32))

    new_params = master_update_tree(
        q_pilot, ternaries, p_shares, betas, k_star,
        state.params, state.params_prev, state.round, cfg.alpha0,
    )

    new_state = FedPCState(
        params=new_params,
        params_prev=state.params,
        prev_costs=costs.astype(jnp.float32),
        round=state.round + 1,
    )
    aux = {
        "k_star": k_star,
        "goodness": scores,
        "ternary_density": jnp.mean(
            jnp.stack([
                jnp.mean(jnp.abs(l.astype(jnp.float32)))
                for l in jax.tree_util.tree_leaves(ternaries)
            ])
        ),
    }
    return new_state, aux


def fedpc_round_jit(cfg: FedPCConfig):
    """A jit-compiled (state, stacked_params, costs, sizes) -> (state, aux)."""
    return jax.jit(partial(master_round, cfg))
