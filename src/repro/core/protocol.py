"""FedPC wire protocol: message types, commands and communication accounting.

Mirrors §3 of the paper. The master drives a synchronous round:

  1. broadcast global model P^{t-1} to all N workers        (download: V each)
  2. workers train locally, upload scalar cost C_k^t        (≈ free)
  3. master computes goodness (Eq. 1), picks pilot k*
  4. command SEND_MODEL to k*  → upload full model          (upload: V)
     command SEND_TERNARY to the rest → upload 2-bit codes  (upload: V/16 each)
  5. master applies Eq. (3)

Eq. (8) total per round:  D = V (N + 1) + V (N - 1) / 16   (float32 weights).

``CommLedger`` tracks simulated bytes per party per round so benchmarks can
reproduce Fig. 6 exactly and the distributed runtime can cross-check against
HLO-measured collective bytes.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any

from repro.core.packing import packed_size
from repro.utils import PyTree, tree_bytes, tree_size


class Command(enum.Enum):
    SEND_MODEL = "SEND_MODEL"
    SEND_TERNARY = "SEND_TERNARY"


@dataclass(frozen=True)
class CostReport:
    """Worker -> master after local training: the only always-shared scalar."""
    worker_id: int
    round: int
    cost: float


@dataclass(frozen=True)
class ModelUpload:
    """Pilot worker -> master: full local model instance Q_{k*}^t."""
    worker_id: int
    round: int
    params: PyTree


@dataclass(frozen=True)
class TernaryUpload:
    """Non-pilot worker -> master: 2-bit packed evolution codes."""
    worker_id: int
    round: int
    packed: Any          # uint8 buffer
    layout: Any          # (treedef, shapes) — public architecture info only


@dataclass
class CommLedger:
    """Byte accounting per round, per direction, per party."""
    downlink: list = field(default_factory=list)   # master -> workers
    uplink_model: list = field(default_factory=list)
    uplink_ternary: list = field(default_factory=list)

    def record_round(self, model_bytes: int, n_workers: int, n_params: int) -> dict:
        down = model_bytes * n_workers
        up_model = model_bytes
        up_ternary = packed_size(n_params) * (n_workers - 1)
        self.downlink.append(down)
        self.uplink_model.append(up_model)
        self.uplink_ternary.append(up_ternary)
        return {
            "downlink": down,
            "uplink_model": up_model,
            "uplink_ternary": up_ternary,
            "total": down + up_model + up_ternary,
        }

    def total(self) -> int:
        return sum(self.downlink) + sum(self.uplink_model) + sum(self.uplink_ternary)


# ---------------------------------------------------------------------------
# Analytic communication models (Fig. 6)
# ---------------------------------------------------------------------------

def _fedpc_wire_bytes(model_bytes: float, n_workers: int, code_bits: float,
                      weight_bits: int = 32) -> float:
    """The Eq. (8) shape: V(N+1) download+pilot, plus N-1 non-pilot
    uplinks at ``code_bits`` per parameter (R = weight_bits/code_bits)."""
    ratio = weight_bits / code_bits
    return model_bytes * (n_workers + 1) + model_bytes * (n_workers - 1) / ratio


def fedpc_bytes_per_round(model_bytes: float, n_workers: int,
                          weight_bits: int = 32) -> float:
    """Eq. (8): D = V(N+1) + V(N-1)/R, R = weight_bits/2 (2-bit codes)."""
    return _fedpc_wire_bytes(model_bytes, n_workers, 2.0, weight_bits)


def fedpc_masked_bytes_per_round(model_bytes: float, n_workers: int,
                                 word_bits: int = 32) -> float:
    """Secure-aggregation wire: non-pilot uplinks carry one masked word of
    ``word_bits`` (``PrivacySpec.modulus_bits``) per parameter — the
    modulus must hold the cohort sum of fixed-point-weighted fields — so
    the 2-bit code term of Eq. (8) grows to ``word_bits``: 8x plaintext at
    the 16-bit default, 16x at 32. Download and pilot upload are
    unchanged."""
    return _fedpc_wire_bytes(model_bytes, n_workers, float(word_bits))


def fedpc_tree_bytes_per_round(model_bytes: float, n_workers: int,
                               fanout: int, *, levels: int | None = None,
                               word_bits: int | None = None) -> float:
    """Eq. (8) under hierarchical fan-in aggregation.

    Download and pilot upload are topology-free: ``V(N+1)``. The N-1
    non-pilot leaf uplinks carry 2-bit codes on the plaintext tree
    (``word_bits=None``) or ``word_bits``-wide masked words on the secure
    wire. Each interior level l then moves ``w_l = ceil(w_{l-1}/fanout)``
    partials of one integer word per parameter (partials are word-wide on
    BOTH wires — the plain tree rides the uint32 integer wire), so the link
    INTO the root carries ``w_L ≤ fanout`` buffers instead of the flat
    master's N-1: per-level wire bytes shrink ~fanout× as the tree
    ascends."""
    from repro.core.tree import TreeSpec
    ts = TreeSpec(fanout=fanout, levels=levels)
    leaf_bits = 2.0 if word_bits is None else float(word_bits)
    interior_bits = 32.0 if word_bits is None else float(word_bits)
    total = model_bytes * (n_workers + 1)
    total += model_bytes * (n_workers - 1) * leaf_bits / 32.0
    for w_l in ts.level_widths(n_workers)[1:]:
        total += model_bytes * w_l * interior_bits / 32.0
    return total


def recovery_dealing_bytes_per_round(n_workers: int,
                                     group_size: int | None = None) -> float:
    """Dropout-recovery control plane, per round: each worker deals one
    Shamir share of its per-pair mask seeds to every sibling. A share is
    the worker's within-group key row — ``group_size - 1`` uint32 seeds (4
    bytes as two GF(2^16) symbols) — and ``group_size - 1`` siblings each
    hold one, so dealing costs ``n * (g - 1)^2 * 4`` bytes per round.
    ``group_size=None`` is the flat wire: one cohort-wide group."""
    g = n_workers if group_size is None else group_size
    return float(n_workers) * (g - 1) ** 2 * 4.0


def recovery_reconstruction_bytes(n_deaths: int, threshold: int,
                                  group_size: int | None = None, *,
                                  n_workers: int | None = None) -> float:
    """Dropout-recovery reconstruction traffic: per post-uplink death,
    ``threshold`` surviving siblings each upload their 4-byte-per-seed
    share of the dead worker's ``group_size - 1``-seed row."""
    if group_size is None:
        if n_workers is None:
            raise ValueError("flat-wire reconstruction needs n_workers")
        group_size = n_workers
    return float(n_deaths) * threshold * (group_size - 1) * 4.0


def fedavg_bytes_per_round(model_bytes: float, n_workers: int) -> float:
    """FedAvg / Phong et al.: every worker downloads and uploads the model."""
    return 2.0 * model_bytes * n_workers


def phong_bytes_per_round(model_bytes: float, n_workers: int) -> float:
    """Phong et al. (sequential weight transmission) — same 2VN per epoch as
    used for the paper's Fig. 6 comparison."""
    return 2.0 * model_bytes * n_workers


def reduction_vs_fedavg(model_bytes: float, n_workers: int,
                        weight_bits: int = 32) -> float:
    """Fractional savings of FedPC vs FedAvg (paper: 31.25%..42.20%)."""
    fp = fedpc_bytes_per_round(model_bytes, n_workers, weight_bits)
    fa = fedavg_bytes_per_round(model_bytes, n_workers)
    return 1.0 - fp / fa


def model_size_bytes(params: PyTree, force_itemsize: int | None = 4) -> int:
    """Size of a model instance on the wire. The paper uses float32 (§5.2);
    pass ``force_itemsize=None`` to use the in-memory dtypes instead."""
    if force_itemsize is None:
        return tree_bytes(params)
    return tree_size(params) * force_itemsize
