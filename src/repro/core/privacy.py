"""Privacy machinery of §4.2 — enforced information-flow + worker defences.

What FedPC's privacy argument actually rests on (Thms 2–4):

  1. Non-pilot workers reveal only ternary signs w.r.t. *public* history
     (the master's own P^{t-1}, P^{t-2}) — never weights, never gradients.
  2. Worker hyper-parameters (lr, batch size, local epochs) are private, so
     even the pilot's weight delta is a sum of n unknown mini-batch gradients
     scaled by an unknown lr — a subset-sum-style non-linear inversion.
  3. The goodness rotation stops the master from polling one victim; if it
     *does* get stuck (collusion, Thm 4), the worker-side defences below
     trigger.

On a TPU pod all mesh slices belong to one job, so this module provides the
*protocol discipline* (a leakage ledger that fails tests if weight tensors of
non-pilot workers ever enter master-visible messages) and the worker-side
defences of the §4.2 discussion, not a cryptographic boundary. DESIGN.md
records this honestly as the changed trust assumption.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.utils import PyTree

# Message fields that are allowed to leave a worker slice.
ALLOWED_UPLINK_FIELDS = {
    "cost",            # scalar loss — Thm 2's only always-shared signal
    "packed_ternary",  # 2-bit codes — Thm 3
    "masked_words",    # secure-agg wire: mod-2^32 masked fixed-point words
    "pilot_params",    # full weights, ONLY when commanded SEND_MODEL
    "worker_id",
    "round",
    "seed_shares",     # dropout recovery: Shamir shares of pair-mask seeds
    "mask_recovery",   # dropout recovery: shares of a DEAD worker's seeds
}


class LeakageError(RuntimeError):
    pass


@dataclass
class LeakageLedger:
    """Records every value that crosses the worker→master boundary and
    enforces that full-precision parameters cross only on the pilot path.

    ``audits`` records traced-program enforcement runs (``repro.privacy
    .audit``): both runtimes audit their round program at setup when a
    :class:`~repro.privacy.spec.PrivacySpec` has ``enforce=True`` — a
    violation raises :class:`LeakageError` before any round runs, and the
    passing audit is logged here so tests (and operators) can see that
    enforcement actually happened rather than being test-only."""
    events: list = field(default_factory=list)
    audits: list = field(default_factory=list)

    def record_audit(self, runtime: str, report: dict) -> None:
        """Log a passed traced-program audit (see ``repro.privacy.audit``)."""
        self.audits.append({"runtime": runtime, **report})

    def record(self, worker_id: int, round_: int, kind: str,
               is_pilot: bool) -> None:
        if kind not in ALLOWED_UPLINK_FIELDS:
            raise LeakageError(f"disallowed uplink field {kind!r}")
        if kind == "pilot_params" and not is_pilot:
            raise LeakageError(
                f"worker {worker_id} attempted full-weight upload without "
                f"SEND_MODEL command at round {round_}"
            )
        self.events.append((round_, worker_id, kind, is_pilot))

    def pilot_rounds(self, worker_id: int) -> list[int]:
        return [r for (r, w, k, p) in self.events
                if w == worker_id and k == "pilot_params"]

    def consecutive_pilot_streak(self, worker_id: int) -> int:
        rounds = sorted(self.pilot_rounds(worker_id))
        streak = best = 0
        prev = None
        for r in rounds:
            streak = streak + 1 if prev is not None and r == prev + 1 else 1
            best = max(best, streak)
            prev = r
        return best


# ---------------------------------------------------------------------------
# Worker-side defences (discussion of §4.2)
# ---------------------------------------------------------------------------

def should_evade(pilot_streak: int, max_streak: int = 3) -> bool:
    """Paper: 'after a fixed number of steps, if the global model … is always
    identical to its local model instance', the worker defends itself."""
    return pilot_streak >= max_streak


def evade_cost(prev_cost: jax.Array) -> jax.Array:
    """Defence (2): report the cost unchanged so goodness (Eq. 1) is zero and
    the master must pick someone else."""
    return prev_cost


def dp_noise_tree(params: PyTree, key: jax.Array, sigma: float) -> PyTree:
    """Defence (1): Gaussian-mechanism noise on the uploaded instance."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        l + sigma * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
        for l, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def gradient_inversion_hardness(n_batches: int, known_lr: bool) -> dict:
    """Thm 2 bookkeeping: unknowns vs. equations available to an
    honest-but-curious master observing one worker for 2(n+1) epochs."""
    unknowns = n_batches + (0 if known_lr else 1)
    equations = 1  # per observed consecutive-epoch pair: one vector equation
    return {
        "unknowns_per_epoch": unknowns,
        "equations_per_pair": equations,
        "underdetermined": unknowns > equations,
    }
