"""FedPC core: the paper's contribution as composable JAX functions.

Public API:
  - ternary:   Eq. (4)/(5) evolution ternarization
  - goodness:  Eq. (1) pilot selection
  - update:    Eq. (3) master update rule
  - packing:   2-bit wire format (§3.3, 16× compression)
  - protocol:  messages + Eq. (8) communication accounting
  - fedpc:     round orchestration (Algorithms 1 & 2)
  - baselines: FedAvg, Phong et al. sequential weight transmission
  - privacy:   §4.2 information-flow ledger and worker defences
"""
from repro.core.fedpc import (  # noqa: F401
    FedPCConfig,
    FedPCState,
    WorkerResult,
    init_state,
    master_round,
    worker_ternary,
)
from repro.core.goodness import goodness, select_pilot  # noqa: F401
from repro.core.packing import pack2bit, packed_size, unpack2bit  # noqa: F401
from repro.core.protocol import (  # noqa: F401
    CommLedger,
    fedavg_bytes_per_round,
    fedpc_bytes_per_round,
    phong_bytes_per_round,
    reduction_vs_fedavg,
)
from repro.core.ternary import ternarize, ternarize_round1  # noqa: F401
from repro.core.update import master_update, master_update_round1  # noqa: F401
