"""Ternarization of parameter evolution — Eq. (4) and Eq. (5) of the paper.

Workers never upload weights or gradients; they upload, per parameter, the
*direction of evolution* quantized to {-1, 0, +1}:

Round 1 (Eq. 4) — no history yet, threshold is the worker's own lr ``alpha_k``
against the public random init ``P^0``::

    T = -1  if  Q - P0 < -alpha
    T =  0  if |Q - P0| <= alpha
    T = +1  if  Q - P0 >  alpha

Round t >= 2 (Eq. 5) — threshold is ``beta_k |P^{t-1} - P^{t-2}|`` (a fraction
of the global model's own previous step)::

    T = 0        if |Q - P1| < beta * |P1 - P2|
    T = sign(f)  otherwise,  f = (Q - P1) * (P1 - P2)

All functions are elementwise over arbitrary-shaped arrays and are the pure
jnp *reference* semantics; ``repro.kernels`` provides Pallas TPU kernels with
identical numerics (validated against these in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import PyTree

TERNARY_DTYPE = jnp.int8


def ternarize_round1(q: jax.Array, p0: jax.Array, alpha: jax.Array | float) -> jax.Array:
    """Eq. (4): ternary code for the first round, vs. the initial model."""
    d = (q - p0).astype(jnp.float32)
    pos = (d > alpha).astype(TERNARY_DTYPE)
    neg = (d < -alpha).astype(TERNARY_DTYPE)
    return pos - neg


def ternarize(
    q: jax.Array,
    p_prev: jax.Array,
    p_prev2: jax.Array,
    beta: jax.Array | float,
) -> jax.Array:
    """Eq. (5): ternary code from round 2 onward, vs. global-model history."""
    q = q.astype(jnp.float32)
    p1 = p_prev.astype(jnp.float32)
    p2 = p_prev2.astype(jnp.float32)
    step = p1 - p2
    delta = q - p1
    significant = jnp.abs(delta) >= beta * jnp.abs(step)
    f = delta * step
    return jnp.where(significant, jnp.sign(f), 0.0).astype(TERNARY_DTYPE)


def ternarize_tree_round1(q: PyTree, p0: PyTree, alpha: float) -> PyTree:
    return jax.tree_util.tree_map(
        lambda a, b: ternarize_round1(a, b, alpha), q, p0
    )


def ternarize_tree(q: PyTree, p_prev: PyTree, p_prev2: PyTree, beta: float) -> PyTree:
    return jax.tree_util.tree_map(
        lambda a, b, c: ternarize(a, b, c, beta), q, p_prev, p_prev2
    )


def ternary_density(t: jax.Array) -> jax.Array:
    """Fraction of non-zero codes — diagnostic for how much signal a worker
    contributes (all-zero vectors are the paper's §4.2 evasion behaviour)."""
    return jnp.mean(jnp.abs(t.astype(jnp.float32)))
