"""Hierarchical aggregation topology — the fan-in tree of sub-aggregators.

Flat FedPC aggregates all N workers in one grid-accumulated master launch:
wire fan-in, kernel grid, and root-link bytes all grow linearly with the
federation size. A :class:`TreeSpec` replaces that with a fan-in tree:
leaves are workers, each internal node folds at most ``fanout`` children
into one *partial* accumulator (``kernels.partial_sum``), and the root runs
the existing master update over the last level's partials. Because the
masked wire's modular accumulation is order-free (PR 5/6), the tree is
bitwise-identical to the flat master by construction — de-bias and descale
by the public ΣW_k happen exactly once, at the root.

The tree is a pure index calculation: level 0 is the N leaves, level ``l``
has ``ceil(w_{l-1} / fanout)`` nodes, and node ``k`` of a level is the
parent of children ``k*fanout .. (k+1)*fanout-1`` of the level below
(contiguous sibling groups — the grouping the mask scoping and the
partial-sum kernels share). ``levels=None`` auto-derives the depth: the
smallest L >= 1 whose width fits under the root's own fan-in budget
(``w_L <= fanout``).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.utils import cdiv


@dataclass(frozen=True)
class TreeSpec:
    """A fan-in aggregation tree: ``fanout`` children per internal node,
    ``levels`` partial-sum levels between the leaves and the root (None =
    auto-derive from the cohort size)."""
    fanout: int
    levels: int | None = None

    def __post_init__(self):
        if self.fanout < 2:
            raise ValueError(f"tree fanout must be >= 2, got {self.fanout}")
        if self.levels is not None and self.levels < 1:
            raise ValueError(
                f"tree levels must be >= 1 when set, got {self.levels}")

    def n_levels(self, n: int) -> int:
        """Partial-sum levels for an N-leaf cohort: ``levels`` when pinned,
        else the smallest L >= 1 with ``w_L <= fanout``."""
        if self.levels is not None:
            return self.levels
        level, width = 1, cdiv(n, self.fanout)
        while width > self.fanout:
            level, width = level + 1, cdiv(width, self.fanout)
        return level

    def level_widths(self, n: int) -> list[int]:
        """``[w_0 .. w_L]``: node counts per level, leaves first. The root
        consumes the ``w_L`` last-level partials."""
        widths = [n]
        for _ in range(self.n_levels(n)):
            widths.append(cdiv(widths[-1], self.fanout))
        return widths

    def sibling_size(self, level: int, n: int) -> int:
        """Mask-scoping group size of the nodes AT ``level``: blocks of
        ``fanout`` (the masks cancel inside the parent's partial sum) for
        every level below the last, and one group spanning all ``w_L``
        last-level nodes (those masks cancel at the root)."""
        widths = self.level_widths(n)
        last = len(widths) - 1
        return self.fanout if level < last else max(widths[last], 1)

    def launches(self, n: int) -> int:
        """Kernel launches of one tree round: 1 uplink + L partial sums +
        1 root master — grows with depth, not with N."""
        return self.n_levels(n) + 2
