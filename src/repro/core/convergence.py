"""Convergence tracking (Fig. 4 / Theorem 1 empirical counterpart)."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class CostHistory:
    """Per-round global training cost, with the paper's observed behaviour
    checks: cost stabilizes after enough rounds; the first 2 rounds may be
    slow because ternary direction info only becomes correct at round 3."""
    costs: list = field(default_factory=list)

    def append(self, cost: float) -> None:
        self.costs.append(float(cost))

    def converged(self, window: int = 5, tol: float = 1e-3) -> bool:
        if len(self.costs) < window + 1:
            return False
        recent = np.asarray(self.costs[-window:])
        return float(np.max(recent) - np.min(recent)) < tol * max(
            1.0, abs(float(np.mean(recent)))
        )

    def monotone_fraction(self) -> float:
        """Fraction of rounds where cost did not increase — a soft empirical
        convergence signal (strict monotonicity is not guaranteed by Thm 1)."""
        if len(self.costs) < 2:
            return 1.0
        c = np.asarray(self.costs)
        return float(np.mean(c[1:] <= c[:-1] + 1e-12))

    def total_reduction(self) -> float:
        if len(self.costs) < 2:
            return 0.0
        return self.costs[0] - self.costs[-1]
