"""Master update rule — Eq. (3) of the paper.

Given the pilot's full weights and the other workers' ternary codes, the
master forms the next global model::

    t == 1:  P^1 = Q_{k*}^1 - alpha_0 * sum_{k != k*} p_k T_k
    t  > 1:  P^t = Q_{k*}^t - sum_{k != k*} p_k beta_k T_k (P^{t-1} - P^{t-2})

where p_k = S_k / S is each worker's data share. The non-pilot contribution
nudges every parameter along (or against) the global model's *own previous
step*, scaled by how much data agrees with that direction.

Array-level reference semantics live here; ``repro.kernels.master_update``
fuses the t>1 rule (codes stacked over a worker axis) into one Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import PyTree


def masked_weights(p_shares: jax.Array, betas: jax.Array, k_star) -> jax.Array:
    """Per-worker coefficients p_k * beta_k with the pilot masked out."""
    n = p_shares.shape[0]
    mask = jnp.arange(n) != k_star
    return jnp.where(mask, p_shares * betas, 0.0)


def master_update_round1(
    q_pilot: jax.Array,
    ternaries: jax.Array,   # (N, *shape) int8 — pilot row may be garbage, masked
    p_shares: jax.Array,    # (N,)
    k_star,
    alpha0: float,
) -> jax.Array:
    n = p_shares.shape[0]
    mask = (jnp.arange(n) != k_star).astype(jnp.float32)
    w = mask * p_shares  # (N,)
    contrib = jnp.tensordot(w, ternaries.astype(jnp.float32), axes=1)
    return (q_pilot.astype(jnp.float32) - alpha0 * contrib).astype(q_pilot.dtype)


def master_update(
    q_pilot: jax.Array,
    ternaries: jax.Array,   # (N, *shape) int8
    p_shares: jax.Array,    # (N,)
    betas: jax.Array,       # (N,)
    k_star,
    p_prev: jax.Array,
    p_prev2: jax.Array,
) -> jax.Array:
    """Eq. (3), t > 1."""
    w = masked_weights(p_shares, betas, k_star)              # (N,)
    coeff = jnp.tensordot(w, ternaries.astype(jnp.float32), axes=1)
    step = (p_prev - p_prev2).astype(jnp.float32)
    return (q_pilot.astype(jnp.float32) - coeff * step).astype(q_pilot.dtype)


def master_update_tree(
    q_pilot: PyTree,
    ternaries: PyTree,      # pytree of (N, *leaf.shape) int8 stacks
    p_shares: jax.Array,
    betas: jax.Array,
    k_star,
    p_prev: PyTree,
    p_prev2: PyTree,
    t,
    alpha0: float = 0.01,
) -> PyTree:
    """Pytree-level Eq. (3) handling both the t==1 and t>1 branches.

    ``t`` may be a traced scalar; both branches are cheap elementwise ops so
    we evaluate both and select (keeps the function jit-friendly)."""
    def per_leaf(qp, tern, p1, p2):
        r1 = master_update_round1(qp, tern, p_shares, k_star, alpha0)
        rt = master_update(qp, tern, p_shares, betas, k_star, p1, p2)
        return jnp.where(jnp.asarray(t) <= 1, r1, rt)

    return jax.tree_util.tree_map(per_leaf, q_pilot, ternaries, p_prev, p_prev2)
