"""Goodness function — Eq. (1) of the paper.

The master scores each worker's round from (cost, dataset size) only::

    G_k^t = S_k / C_k^t                  if t == 1
    G_k^t = S_k (C_k^{t-1} - C_k^t)      if t  > 1

and selects the argmax as the *pilot* worker k* — the only worker asked to
upload its full model instance this round. Everything here is pure and
jit-able; the costs are N scalars so this is communication-free in the
distributed runtime (one tiny all_gather).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def goodness(
    costs: jax.Array,        # (N,) float — C_k^t
    prev_costs: jax.Array,   # (N,) float — C_k^{t-1} (ignored when t == 1)
    sizes: jax.Array,        # (N,) float or int — S_k
    t: jax.Array | int,      # round index, 1-based
    mask: jax.Array | None = None,  # (N,) participation; None = everyone
) -> jax.Array:
    """Eq. (1). Returns (N,) goodness scores.

    With a participation ``mask`` (1 = sampled this round), non-participants
    score ``-inf`` so the pilot is always drawn from the sampled set —
    the FedAvg-style C-fraction regime of McMahan et al. (1602.05629).
    A worker with no cost history yet (``prev_cost == +inf`` — first
    sampled after round 1) scores by the round-1 rule ``S_k / C_k`` rather
    than the degenerate ``S_k · (inf − C_k) = inf``, which would hijack
    pilot selection by index regardless of sizes and costs.
    """
    sizes = sizes.astype(jnp.float32)
    costs = costs.astype(jnp.float32)
    prev_costs = prev_costs.astype(jnp.float32)
    g1 = sizes / jnp.maximum(costs, 1e-12)
    gt = jnp.where(jnp.isfinite(prev_costs),
                   sizes * (prev_costs - costs), g1)
    g = jnp.where(jnp.asarray(t) <= 1, g1, gt)
    if mask is not None:
        g = jnp.where(mask > 0, g, -jnp.inf)
    return g


def select_pilot(
    costs: jax.Array,
    prev_costs: jax.Array,
    sizes: jax.Array,
    t: jax.Array | int,
    mask: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (k_star, scores). Ties break to the lowest index (argmax).
    Fully traceable — ``k_star`` stays a device scalar; with ``mask`` the
    pilot is guaranteed to be a participating worker."""
    scores = goodness(costs, prev_costs, sizes, t, mask)
    return jnp.argmax(scores), scores


def rotation_entropy(pilot_history: jax.Array, n_workers: int) -> jax.Array:
    """Diagnostic for the privacy discussion of §4.2: empirical entropy of the
    pilot-selection distribution over a window. High entropy ⇒ the master
    cannot repeatedly poll one victim worker; ~0 ⇒ the evasion rules of the
    paper's discussion section should trigger on the worker side."""
    counts = jnp.bincount(pilot_history, length=n_workers).astype(jnp.float32)
    p = counts / jnp.maximum(jnp.sum(counts), 1.0)
    return -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0))
