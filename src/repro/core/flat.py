"""FlatParams — the single-buffer wire representation of a model pytree.

The FedPC wire path (Eq. (4)/(5) ternarization, §3.3 2-bit packing, Eq. (3)
master update) is elementwise over *every* parameter, so nothing about it is
per-leaf. Flattening the whole pytree once into a single padded ``(rows, 128)``
float32 buffer lets the fused Pallas kernels (``repro.kernels.fused_wire``)
run the entire round's wire math in a handful of launches instead of four
kernels × leaves × workers, and makes the packed buffer the thing that feeds
collectives directly.

Layout
------
Leaves are raveled in ``tree_flatten`` order and concatenated into one vector
of ``n`` scalars, zero-padded to ``rows * 128`` with ``rows % ROW_MULTIPLE
== 0``. ``ROW_MULTIPLE = 32`` guarantees every view the kernels need is
aligned:

* ``(rows, 128)``          — float32 buffer, 8-sublane aligned;
* ``(rows // 4, 512)``     — the uplink kernel's input view (4 consecutive
  codes per output byte, matching §3.3 / ``core.packing.pack2bit`` order);
* ``(rows // 4, 128)``     — the packed uint8 wire buffer, lane-aligned.

The zero padding is a fixed point of the whole wire path: ternarizing
``q = p1 = p2 = 0`` yields code 0, and the master update maps a zero tail to
a zero tail, so padded scalars never leak into real parameters.

``FlatLayout`` is cached per (treedef, shapes, dtypes) so repeated rounds pay
for layout computation once.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import PyTree, round_up

LANES = 128
ROW_MULTIPLE = 32          # keeps rows, rows//4 sublane-aligned (see above)
PACK = 4                   # ternary codes per wire byte (§3.3)


class FlatLayout(NamedTuple):
    """Static description of how a pytree maps into the flat buffer."""
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]   # start of each leaf in the flat vector
    n: int                     # total real scalars
    rows: int                  # padded buffer rows (rows % ROW_MULTIPLE == 0)

    @property
    def padded(self) -> int:
        return self.rows * LANES

    @property
    def packed_rows(self) -> int:
        """Rows of the (packed_rows, 128) uint8 wire buffer."""
        return self.rows // PACK

    @property
    def packed_bytes(self) -> int:
        """Exact §3.3 wire bytes for the *real* scalars (Eq. (8) accounting
        is over ``n``, not the padded buffer)."""
        return round_up(self.n, PACK) // PACK


class FlatParams(NamedTuple):
    """A model pytree flattened to one padded (rows, 128) float32 buffer."""
    buf: jax.Array
    layout: FlatLayout

    @classmethod
    def from_tree(cls, tree: PyTree, layout: FlatLayout | None = None
                  ) -> "FlatParams":
        layout = layout or layout_of(tree)
        return cls(flatten_tree(tree, layout), layout)

    def to_tree(self) -> PyTree:
        return unflatten_tree(self.buf, self.layout)


_layout_cache: dict = {}


def layout_of(tree: PyTree) -> FlatLayout:
    """Cached FlatLayout for a pytree (keyed on structure+shapes+dtypes)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    key = (treedef, shapes, dtypes)
    hit = _layout_cache.get(key)
    if hit is not None:
        return hit
    sizes = tuple(math.prod(s) for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    n = off
    rows = round_up(max(-(-n // LANES), 1), ROW_MULTIPLE)
    layout = FlatLayout(treedef, shapes, dtypes, sizes, tuple(offsets),
                        n, rows)
    _layout_cache[key] = layout
    return layout


def flatten_tree(tree: PyTree, layout: FlatLayout) -> jax.Array:
    """Pytree → padded (rows, 128) float32 buffer."""
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat = jnp.pad(flat, (0, layout.padded - layout.n))
    return flat.reshape(layout.rows, LANES)


def unflatten_tree(buf: jax.Array, layout: FlatLayout) -> PyTree:
    """Padded (rows, 128) buffer → pytree (leaves cast back to their dtypes)."""
    flat = buf.reshape(-1)
    leaves = [
        jax.lax.slice(flat, (o,), (o + s,)).reshape(shape).astype(dt)
        for o, s, shape, dt in zip(layout.offsets, layout.sizes,
                                   layout.shapes, layout.dtypes)
    ]
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def flatten_stacked(tree_F: PyTree, layout: FlatLayout) -> jax.Array:
    """Pytree with (F, *shape) leaves → (F, rows, 128) float32 buffers.

    Used by the distributed runtime where all fed workers' models arrive
    stacked over the leading axis.
    """
    leaves = jax.tree_util.tree_leaves(tree_F)
    f = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(f, -1).astype(jnp.float32) for l in leaves], axis=1)
    flat = jnp.pad(flat, ((0, 0), (0, layout.padded - layout.n)))
    return flat.reshape(f, layout.rows, LANES)
