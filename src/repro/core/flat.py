"""FlatParams — the single-buffer wire representation of a model pytree.

The FedPC wire path (Eq. (4)/(5) ternarization, §3.3 2-bit packing, Eq. (3)
master update) is elementwise over *every* parameter, so nothing about it is
per-leaf. Flattening the whole pytree once into a single padded ``(rows, 128)``
float32 buffer lets the fused Pallas kernels (``repro.kernels.fused_wire``)
run the entire round's wire math in a handful of launches instead of four
kernels × leaves × workers, and makes the packed buffer the thing that feeds
collectives directly.

Layout
------
Leaves are raveled in ``tree_flatten`` order and concatenated into one vector
of ``n`` scalars, zero-padded to ``rows * 128`` with ``rows % ROW_MULTIPLE
== 0``. ``ROW_MULTIPLE = 32`` guarantees every view the kernels need is
aligned:

* ``(rows, 128)``          — float32 buffer, 8-sublane aligned;
* ``(rows // 4, 512)``     — the uplink kernel's input view (4 consecutive
  codes per output byte, matching §3.3 / ``core.packing.pack2bit`` order);
* ``(rows // 4, 128)``     — the packed uint8 wire buffer, lane-aligned.

The zero padding is a fixed point of the whole wire path: ternarizing
``q = p1 = p2 = 0`` yields code 0, and the master update maps a zero tail to
a zero tail, so padded scalars never leak into real parameters.

Model sharding
--------------
``layout_of(tree, shards=M)`` rounds ``rows`` up to a multiple of
``ROW_MULTIPLE * M`` so the buffer splits into ``M`` equal ``(rows/M, 128)``
*slabs*, each itself satisfying every alignment above. The distributed fed
sync shards the wire buffers over the model mesh axis this way: every model
shard runs the fused kernels on its own slab and the collectives move
``rows/M`` rows per device instead of a replicated full buffer.

``FlatLayout`` is cached per (treedef, shapes, dtypes, shards) so repeated
rounds pay for layout computation once; the cache is a small LRU so
long-lived multi-model processes don't grow it without bound.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import PyTree, round_up

LANES = 128
ROW_MULTIPLE = 32          # keeps rows, rows//4 sublane-aligned (see above)
PACK = 4                   # ternary codes per wire byte (§3.3)


class FlatLayout(NamedTuple):
    """Static description of how a pytree maps into the flat buffer."""
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]   # start of each leaf in the flat vector
    n: int                     # total real scalars
    rows: int                  # padded buffer rows (rows % ROW_MULTIPLE == 0)
    shards: int = 1            # model-axis slabs (rows % (ROW_MULTIPLE*shards) == 0)

    @property
    def padded(self) -> int:
        return self.rows * LANES

    @property
    def packed_rows(self) -> int:
        """Rows of the (packed_rows, 128) uint8 wire buffer."""
        return self.rows // PACK

    @property
    def shard_rows(self) -> int:
        """Rows of one model shard's (shard_rows, 128) slab."""
        return self.rows // self.shards

    @property
    def packed_shard_rows(self) -> int:
        """Rows of one model shard's (·, 128) packed uint8 slab."""
        return self.shard_rows // PACK

    @property
    def packed_bytes(self) -> int:
        """Exact §3.3 wire bytes for the *real* scalars (Eq. (8) accounting
        is over ``n``, not the padded buffer)."""
        return round_up(self.n, PACK) // PACK


class FlatParams(NamedTuple):
    """A model pytree flattened to one padded (rows, 128) float32 buffer."""
    buf: jax.Array
    layout: FlatLayout

    @classmethod
    def from_tree(cls, tree: PyTree, layout: FlatLayout | None = None
                  ) -> "FlatParams":
        layout = layout or layout_of(tree)
        return cls(flatten_tree(tree, layout), layout)

    def to_tree(self) -> PyTree:
        return unflatten_tree(self.buf, self.layout)


LAYOUT_CACHE_MAX = 32
_layout_cache: OrderedDict = OrderedDict()


def layout_of(tree: PyTree, shards: int = 1) -> FlatLayout:
    """Cached FlatLayout for a pytree (keyed on structure+shapes+dtypes+shards).

    ``shards`` pads ``rows`` to a multiple of ``ROW_MULTIPLE * shards`` so the
    buffer splits into ``shards`` aligned slabs (model-axis wire sharding).
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    key = (treedef, shapes, dtypes, shards)
    hit = _layout_cache.get(key)
    if hit is not None:
        _layout_cache.move_to_end(key)
        return hit
    sizes = tuple(math.prod(s) for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    n = off
    rows = round_up(max(-(-n // LANES), 1), ROW_MULTIPLE * shards)
    layout = FlatLayout(treedef, shapes, dtypes, sizes, tuple(offsets),
                        n, rows, shards)
    _layout_cache[key] = layout
    while len(_layout_cache) > LAYOUT_CACHE_MAX:
        _layout_cache.popitem(last=False)
    return layout


def flatten_tree(tree: PyTree, layout: FlatLayout) -> jax.Array:
    """Pytree → padded (rows, 128) float32 buffer."""
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat = jnp.pad(flat, (0, layout.padded - layout.n))
    return flat.reshape(layout.rows, LANES)


def unflatten_tree(buf: jax.Array, layout: FlatLayout) -> PyTree:
    """Padded (rows, 128) buffer → pytree (leaves cast back to their dtypes)."""
    flat = buf.reshape(-1)
    leaves = [
        jax.lax.slice(flat, (o,), (o + s,)).reshape(shape).astype(dt)
        for o, s, shape, dt in zip(layout.offsets, layout.sizes,
                                   layout.shapes, layout.dtypes)
    ]
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def flatten_stacked(tree_F: PyTree, layout: FlatLayout) -> jax.Array:
    """Pytree with (F, *shape) leaves → (F, rows, 128) float32 buffers.

    Used by the distributed runtime where all fed workers' models arrive
    stacked over the leading axis.
    """
    leaves = jax.tree_util.tree_leaves(tree_F)
    f = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(f, -1).astype(jnp.float32) for l in leaves], axis=1)
    flat = jnp.pad(flat, ((0, 0), (0, layout.padded - layout.n)))
    return flat.reshape(f, layout.rows, LANES)
