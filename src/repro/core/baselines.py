"""Baselines the paper compares against (§5): FedAvg and Phong et al.

* FedAvg (McMahan et al., 2017): every round, all N workers train locally and
  upload full weights; the master takes the data-share weighted average.
* Phong & Phuong (2019), "weight transmission": the model travels
  *sequentially* through the workers — worker k trains, passes weights to
  worker k+1. One "epoch" = one full pass over all workers. No averaging.

Both exchange full weights (2·V·N bytes per epoch — see protocol.py), which
is the communication bar FedPC undercuts.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.utils import PyTree, tree_weighted_sum


def fedavg_aggregate(local_params: Sequence[PyTree], sizes) -> PyTree:
    """Data-share weighted parameter average."""
    sizes = jnp.asarray(sizes, jnp.float32)
    weights = sizes / jnp.sum(sizes)
    return tree_weighted_sum(local_params, list(weights))


def fedavg_aggregate_stacked(stacked: PyTree, sizes) -> PyTree:
    """FedAvg over a stacked (N, ...) worker axis — used by the distributed
    runtime where worker models live on different mesh slices."""
    sizes = jnp.asarray(sizes, jnp.float32)
    w = sizes / jnp.sum(sizes)
    def avg(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wb, axis=0).astype(x.dtype)
    return jax.tree_util.tree_map(avg, stacked)


def phong_sequential_round(
    params: PyTree,
    train_fns: Sequence[Callable[[PyTree], tuple[PyTree, jax.Array]]],
) -> tuple[PyTree, list]:
    """One Phong et al. epoch: the model visits each worker in order.

    ``train_fns[k]`` runs worker k's local training from the given weights and
    returns (new_params, cost). Returns final params and per-worker costs.
    """
    costs = []
    for fn in train_fns:
        params, cost = fn(params)
        costs.append(cost)
    return params, costs
