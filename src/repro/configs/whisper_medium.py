"""Whisper-medium [audio] — arXiv:2212.04356.

Encoder-decoder, 24+24L, d_model 1024, 16 heads (MHA), d_ff 4096,
vocab 51865, GELU MLPs. The mel-spectrogram + conv frontend is a STUB per
the brief: `input_specs()` feeds precomputed frame embeddings
(B, n_frames=1500, d_model) through a trainable linear adapter.
Decode shapes exercise the text decoder (self-attn cache + fixed cross-attn
cache); long_500k is skipped (enc-dec, full attention — DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    arch_type="audio",
    citation="arXiv:2212.04356",
    n_layers=24,                # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    max_seq=32768,
    ffn_act="gelu",
    pattern=(("attn", "mlp"),),
    n_frames=1500,
))
