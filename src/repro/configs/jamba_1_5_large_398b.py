"""Jamba-1.5-Large 398B [hybrid] — arXiv:2403.19887.

72L, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536.
Mamba:attention 7:1 interleave (one attention layer per 8-layer period),
MoE every other layer: 16 experts top-2. SSM layers make decode state O(1)
in context → long_500k runs natively.
"""
from repro.configs.base import ArchConfig, register

_PERIOD = (
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("attn", "moe"),     # the 1-in-8 attention layer
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
)

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    citation="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    max_seq=262144,
    pattern=_PERIOD,
    n_experts=16,
    top_k=2,
    d_expert_ff=24576,
    d_state=16,
    d_conv=4,
    expand=2,
))
