"""Architecture config schema + registry.

Each assigned architecture gets one ``<id>.py`` exporting ``CONFIG``; the
registry maps ``--arch <id>`` to it. ``reduced()`` builds the smoke-test
variant mandated by the brief (≤2 pattern periods, d_model ≤ 512, ≤4
experts) of the *same family*.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

# A block descriptor: (mixer, ffn).
#   mixer ∈ {'attn', 'swa', 'mamba', 'mlstm', 'slstm'}
#   ffn   ∈ {'mlp', 'moe', 'none'}
Block = tuple[str, str]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | hybrid | ssm | audio | vlm
    citation: str

    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    vocab: int = 32000
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e6
    sliding_window: Optional[int] = None   # tokens; None = full attention
    max_seq: int = 131072
    ffn_act: str = "swiglu"                # swiglu | gelu

    # layer pattern: repeated `period = len(pattern)` times after the first
    # `first_k_dense` plain (attn, mlp) blocks.
    pattern: tuple = (("attn", "mlp"),)
    first_k_dense: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert_ff: int = 0
    d_ff_dense: Optional[int] = None       # width of first_k_dense MLPs
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # Mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None          # default d_model // 16

    # xLSTM
    lstm_proj_factor: float = 2.0          # mLSTM up-projection

    # encoder-decoder (audio)
    n_encoder_layers: int = 0
    n_frames: int = 1500                   # whisper 30 s @ 50 Hz

    # VLM
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)   # t/h/w split of head_dim//2
    n_patches: int = 1024

    norm_eps: float = 1e-5
    param_dtype: str = "float32"           # smoke default; dryrun uses bf16
    tie_embeddings: bool = False

    def __post_init__(self):
        period = len(self.pattern)
        assert (self.n_layers - self.first_k_dense) % period == 0, (
            f"{self.name}: {self.n_layers} layers − {self.first_k_dense} "
            f"dense not divisible by pattern period {period}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(self.d_model // 16, 1)

    @property
    def n_units(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def supports_long_decode(self) -> bool:
        """True iff decode memory is sub-quadratic in context (SSM/hybrid or
        sliding-window attention) — gates the long_500k shape."""
        mixers = {m for m, _ in self.pattern}
        recurrent = {"mamba", "mlstm", "slstm"}
        if mixers & recurrent:
            return True   # pure SSM or hybrid (attention is a minority and
                          # its KV cache at B=1 stays modest, e.g. Jamba 1:7)
        return self.sliding_window is not None or "swa" in mixers

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims (brief: ≤2 periods,
        d_model ≤ 512, ≤4 experts)."""
        period = len(self.pattern)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, max(n_heads // 2, 1))
        head_dim = max(d_model // n_heads, 16)
        kw = dict(
            n_layers=self.first_k_dense + period * (1 if period > 1 else 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            max_seq=1024,
            sliding_window=(64 if self.sliding_window else None),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_expert_ff=min(self.d_expert_ff, 128) if self.d_expert_ff else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_frames=min(self.n_frames, 32),
            n_patches=min(self.n_patches, 16),
            mrope_sections=(
                (head_dim // 2 - 2 * (3 * (head_dim // 2) // 8),
                 3 * (head_dim // 2) // 8,
                 3 * (head_dim // 2) // 8)
                if self.mrope else self.mrope_sections),
            dt_rank=max(d_model // 16, 1),
            param_dtype="float32",
        )
        return self.replace(**kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401 — populate registry
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
