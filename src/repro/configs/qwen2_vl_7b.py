"""Qwen2-VL-7B [vlm] — arXiv:2409.12191.

28L, d_model 3584, 28 heads (GQA kv=4), d_ff 18944, vocab 152064.
M-RoPE (t/h/w position components). The ViT vision tower is a STUB per the
brief: `input_specs()` provides patch embeddings (B, n_patches, d_model)
merged into the token stream through a trainable projector.
Full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    citation="arXiv:2409.12191",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    max_seq=32768,
    rope_theta=1e6,
    pattern=(("attn", "mlp"),),
    mrope=True,
    mrope_sections=(16, 24, 24),
    n_patches=1024,
))
