"""Federation scenario presets — the round core's knobs, bundled.

The pure round core (``repro.fed.rounds``) exposes two scenario axes beyond
the paper's uniform full-participation setup: FedAvg-style C-fraction
**partial participation** (McMahan et al., 1602.05629 — the normal operating
regime for cross-device federation) and **heterogeneous per-worker beta_k**
(per-client adaptive quantization, cf. the communication survey 2405.20431).
A :class:`FedScenario` names one point in that space so benchmarks,
examples and tests exercise the same regimes by name. The privacy axis
(``repro.privacy``) rides along as an optional
:class:`~repro.privacy.spec.PrivacySpec`: secure-aggregation masking and
local-DP randomized response on the wire.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.privacy.spec import PrivacySpec


@dataclass(frozen=True)
class FedScenario:
    """One federation regime: who participates, with what thresholds."""
    name: str
    participation: float = 1.0        # C-fraction of workers per round
    beta_menu: tuple | None = None    # per-worker beta_k draws; None=uniform
    privacy: PrivacySpec | None = None  # secure-agg / local-DP wire
    description: str = ""

    def betas_for(self, n_workers: int, seed: int = 0) -> tuple | None:
        """Deterministic per-worker beta_k draw (None in uniform regimes) —
        feed to ``FedPCConfig(betas=...)`` / ``run_fedpc(betas=...)``."""
        if self.beta_menu is None:
            return None
        rng = np.random.default_rng(seed + 4099)
        return tuple(float(rng.choice(self.beta_menu))
                     for _ in range(n_workers))


_SCENARIOS = {
    s.name: s for s in (
        FedScenario(
            "paper-uniform",
            description="The paper's §5 setup: everyone participates, one "
                        "shared beta."),
        FedScenario(
            "hetero-beta", beta_menu=(0.1, 0.2, 0.3),
            description="Full participation, per-worker significance "
                        "thresholds beta_k drawn from a menu."),
        FedScenario(
            "cross-device", participation=0.5,
            description="FedAvg-style C=0.5 sampling: half the fleet is "
                        "drawn each round."),
        FedScenario(
            "cross-device-hetero", participation=0.25,
            beta_menu=(0.1, 0.2, 0.3),
            description="C=0.25 sampling + heterogeneous beta_k — the "
                        "adaptive-quantization cross-device regime."),
        FedScenario(
            "secure-agg", privacy=PrivacySpec(),
            description="Pairwise-masked secure aggregation: the master "
                        "sees only the modular sum of fixed-point-weighted "
                        "ternary fields, never a worker's directions."),
        FedScenario(
            "secure-agg-ldp", participation=0.5,
            privacy=PrivacySpec(dp_epsilon=4.0),
            description="Secure aggregation + per-round eps=4 local-DP "
                        "randomized response on the codes, under C=0.5 "
                        "sampling — the full privacy stack."),
    )
}


def get_scenario(name: str) -> FedScenario:
    if name not in _SCENARIOS:
        raise KeyError(
            f"unknown federation scenario {name!r}; have "
            f"{sorted(_SCENARIOS)}")
    return _SCENARIOS[name]


def list_scenarios() -> list[str]:
    return sorted(_SCENARIOS)
