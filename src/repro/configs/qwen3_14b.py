"""Qwen3-14B [dense] — hf:Qwen/Qwen3-8B family card (14B variant).

40L, d_model 5120, 40 heads (GQA kv=8, head_dim 128), d_ff 17408,
vocab 151936, qk-norm. Full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-14b",
    arch_type="dense",
    citation="hf:Qwen/Qwen3-8B",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    max_seq=32768,
    rope_theta=1e6,
    qk_norm=True,
    pattern=(("attn", "mlp"),),
))
