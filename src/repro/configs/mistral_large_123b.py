"""Mistral-Large-123B [dense] — hf:mistralai/Mistral-Large-Instruct-2407.

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768.
Pure full attention → long_500k decode is skipped (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-large-123b",
    arch_type="dense",
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    max_seq=32768,
    rope_theta=1e6,
    pattern=(("attn", "mlp"),),
))
