"""FedPC paper-analog config: a small dense model for the paper-table
benchmarks (Tables 1–4, Fig 4/6) on synthetic data.

The paper trains ResNet50-FIXUP / U-Net; offline we reproduce the
*federated-training behaviour* (approximation ratio, convergence,
communication) with a compact transformer — the FedPC protocol is
model-agnostic (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="fedpc-paper",
    arch_type="dense",
    citation="DOI 10.1016/j.sysarc.2022.102413 (this paper)",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    max_seq=256,
    rope_theta=1e4,
    pattern=(("attn", "mlp"),),
))
