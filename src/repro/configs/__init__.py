"""Config registry: importing this package registers every architecture."""
from repro.configs.base import ArchConfig, get_config, list_configs, register  # noqa: F401
from repro.configs.federation import (  # noqa: F401
    FedScenario, get_scenario, list_scenarios,
)

# Assigned architectures (public-literature pool) + the paper-analog config.
from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    fedpc_mlp,
    grok_1_314b,
    jamba_1_5_large_398b,
    mistral_large_123b,
    mistral_nemo_12b,
    phi4_mini_3_8b,
    qwen2_vl_7b,
    qwen3_14b,
    whisper_medium,
    xlstm_350m,
)

ASSIGNED = (
    "mistral-nemo-12b",
    "mistral-large-123b",
    "grok-1-314b",
    "jamba-1.5-large-398b",
    "phi4-mini-3.8b",
    "deepseek-moe-16b",
    "xlstm-350m",
    "whisper-medium",
    "qwen2-vl-7b",
    "qwen3-14b",
)
