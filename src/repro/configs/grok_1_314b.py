"""Grok-1 314B [moe] — hf:xai-org/grok-1.

64L, d_model 6144, 48 heads (GQA kv=8), vocab 131072, MoE: 8 experts top-2,
expert d_ff 32768. Full attention → long_500k skipped (DESIGN.md §4).
Experts (E=8) don't divide the model axis (16) → tensor-parallel experts
(see sharding/specs.py).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    citation="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    max_seq=8192,
    rope_theta=1e4,
    pattern=(("attn", "moe"),),
    n_experts=8,
    top_k=2,
    d_expert_ff=32768,
))
