"""DeepSeekMoE-16B [moe] — arXiv:2401.06066.

28L, d_model 2048, 16 heads (kv=16, i.e. MHA), fine-grained experts:
64 routed top-6 + 2 shared, expert d_ff 1408, vocab 102400. First layer is
a dense MLP (width 10944 per the paper) — `first_k_dense=1`.
Full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    citation="arXiv:2401.06066",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # per-expert width (assigned spec)
    d_ff_dense=10944,          # the single dense layer's MLP width
    vocab=102400,
    max_seq=16384,
    rope_theta=1e4,
    pattern=(("attn", "moe"),),
    first_k_dense=1,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_expert_ff=1408,
))
