"""Mistral-Nemo-12B [dense] — hf:mistralai/Mistral-Nemo-Base-2407.

40L, d_model 5120, 32 heads (GQA kv=8, head_dim 128), d_ff 14336,
vocab 131072, 128k context. We expose the sliding-window attention variant
(window = its 128k training context) so `long_500k` decode keeps a bounded
(windowed) KV cache — the documented dense-arch carve-out in DESIGN.md.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    max_seq=131072,
    sliding_window=131072,
    rope_theta=1e6,
    pattern=(("attn", "mlp"),),
))
