"""xLSTM-350M [ssm] — arXiv:2405.04517.

24L, d_model 1024, 4 heads, vocab 50304, d_ff=0 (mixer-only blocks).
Alternating sLSTM + mLSTM blocks. Recurrent state is O(1) in context →
long_500k runs natively; sLSTM is inherently sequential (paper §2 of
xLSTM acknowledges this) — see roofline notes.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    arch_type="ssm",
    citation="arXiv:2405.04517",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    max_seq=1048576,
    pattern=(("mlstm", "none"), ("slstm", "none")),
    lstm_proj_factor=2.0,
))
