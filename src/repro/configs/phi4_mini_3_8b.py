"""Phi-4-mini 3.8B [dense] — arXiv:2412.08905.

32L, d_model 3072, 24 heads (GQA kv=8), d_ff 8192, vocab 200064.
RoPE + SwiGLU + GQA. Full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    citation="arXiv:2412.08905",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    max_seq=131072,
    rope_theta=1e4,
    pattern=(("attn", "mlp"),),
))
