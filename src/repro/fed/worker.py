"""Worker-side local training (Algorithm 2, line 1).

Each worker owns: a private data shard, private hyper-parameters (batch
size, learning rate + decay, local epochs, optimizer) — exactly the private
information Theorem 2's privacy argument relies on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import BatchIterator
from repro.optim import optimizers as opt_mod
from repro.optim.schedules import step_decay
from repro.utils import PyTree

LR_MENU = (0.01,)                 # paper: initial lr 0.01 for everyone
EPOCH_MENU = (1, 2)               # local epochs per round
OPT_MENU = ("momentum", "adam", "sgd")
BETA_MENU = (0.1, 0.2, 0.3)       # heterogeneous per-worker beta_k choices


@dataclass
class WorkerConfig:
    worker_id: int
    batch_size: int
    lr0: float = 0.01
    lr_decay: float = 0.5
    lr_decay_every: int = 1000     # derived from local dataset size (paper)
    local_epochs: int = 1
    optimizer: str = "momentum"
    seed: int = 0
    # Private Eq. (5) significance threshold beta_k; None = no private draw
    # (the federation's shared beta applies). Set by beta_menu draws.
    beta: float | None = None


def make_worker_configs(n_workers: int, shard_sizes: list[int],
                        seed: int = 0,
                        batch_menu=(128, 64, 32),
                        beta_menu=None) -> list[WorkerConfig]:
    """Draw private hyper-parameters per worker, following §5.1: batch size
    from a menu, lr 0.01 with size-dependent step decay, 1–2 local epochs,
    momentum or adam. ``beta_menu`` (e.g. ``BETA_MENU``) additionally draws
    a per-worker significance threshold beta_k — the heterogeneous-wire
    regime; without it workers carry no private beta (the federation's
    shared beta applies) and the draws stay byte-identical to before."""
    rng = np.random.default_rng(seed)
    cfgs = []
    for k in range(n_workers):
        bs = int(rng.choice(batch_menu))
        bs = min(bs, max(shard_sizes[k], 1))
        steps_per_epoch = max(shard_sizes[k] // bs, 1)
        cfgs.append(WorkerConfig(
            worker_id=k,
            batch_size=bs,
            lr0=0.01,
            lr_decay=0.5,
            lr_decay_every=max(10 * steps_per_epoch, 1),
            local_epochs=int(rng.choice(EPOCH_MENU)),
            optimizer=str(rng.choice(OPT_MENU[:2])),
            seed=seed * 1000 + k,
            beta=(float(rng.choice(beta_menu)) if beta_menu is not None
                  else None),
        ))
    return cfgs


@dataclass
class Worker:
    """Stateful in-process worker for the simulator (the paper's testbed)."""
    cfg: WorkerConfig
    loader: BatchIterator
    loss_and_grad: Callable            # (params, batch) -> ((loss, aux), grads)
    opt: opt_mod.Optimizer = field(init=False)
    opt_state: Optional[PyTree] = None
    step: int = 0

    def __post_init__(self):
        self.opt = opt_mod.get(self.cfg.optimizer)
        self.lr_fn = step_decay(self.cfg.lr0, self.cfg.lr_decay,
                                self.cfg.lr_decay_every)
        self._scan_train_jit = None    # lazily-built jit of scan_train

    @property
    def uniform_batches(self) -> bool:
        """True when every batch of an epoch has the same shape — the
        condition for stacking a round's batches into one scan."""
        return self.loader.n % self.loader.batch_size == 0

    def train_round(self, params: PyTree) -> tuple[PyTree, float]:
        """Run `local_epochs` epochs from the given global params; return
        (local_params Q_k, cost C_k). Optimizer state is private and persists
        across rounds (fresh momentum for new params would also be valid —
        the paper leaves this to the worker).

        The single ``float(...)`` here is the round's only device→host sync.
        """
        params, cost = self.train_round_device(params)
        return params, float(cost)

    def scan_train(self, params: PyTree, opt_state: PyTree, step: jax.Array,
                   batches: tuple) -> tuple[PyTree, PyTree, jax.Array,
                                            jax.Array]:
        """One round of local training as a pure ``lax.scan`` over stacked
        batches (tuple of (steps, batch, ...) arrays).

        This is THE local-training recurrence: ``train_round_device`` jits
        it standalone, and the simulator's multi-round scan driver traces it
        inside its round body — XLA compiles the same computation either
        way, which is what makes the two drivers bitwise-identical.
        Returns (params, opt_state, step, mean cost).
        """
        def bstep(carry, batch):
            p, os, s, tot = carry
            lr = self.lr_fn(s)
            (loss, _aux), grads = self.loss_and_grad(p, batch)
            updates, os = self.opt.update(grads, os, p, lr)
            p = opt_mod.apply_updates(p, updates)
            return (p, os, s + 1, tot + loss), None

        n_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
        (params, opt_state, step, tot), _ = jax.lax.scan(
            bstep, (params, opt_state, step, jnp.zeros((), jnp.float32)),
            batches)
        return params, opt_state, step, tot / max(n_steps, 1)

    def stack_round_batches(self) -> tuple:
        """Draw one round's batch schedule from the loader and stack it into
        the (steps, batch, ...) arrays ``scan_train`` consumes."""
        bs = [b for _ in range(self.cfg.local_epochs)
              for b in self.loader.epoch()]
        return tuple(np.stack([b[j] for b in bs])
                     for j in range(len(bs[0])))

    def train_round_device(self, params: PyTree) -> tuple[PyTree, jax.Array]:
        """`train_round` without the host sync: the cost comes back as a
        device scalar and the whole round is ONE jitted dispatch
        (``scan_train`` over the round's stacked batches) when the shard
        size permits stacking; ragged shards fall back to the eager
        per-batch loop (still zero host syncs — the loss accumulates
        on-device)."""
        if self.opt_state is None:
            self.opt_state = self.opt.init(params)
        if self.uniform_batches:
            if self._scan_train_jit is None:
                self._scan_train_jit = jax.jit(self.scan_train)
            batches = self.stack_round_batches()
            n_steps = batches[0].shape[0]
            params, self.opt_state, _, cost = self._scan_train_jit(
                params, self.opt_state, jnp.asarray(self.step, jnp.int32),
                batches)
            self.step += n_steps
            return params, cost
        total_loss = jnp.zeros((), jnp.float32)
        n_batches = 0
        for _ in range(self.cfg.local_epochs):
            for batch in self.loader.epoch():
                lr = self.lr_fn(self.step)
                (loss, _aux), grads = self.loss_and_grad(params, batch)
                updates, self.opt_state = self.opt.update(
                    grads, self.opt_state, params, lr)
                params = opt_mod.apply_updates(params, updates)
                total_loss = total_loss + loss
                n_batches += 1
                self.step += 1
        return params, total_loss / max(n_batches, 1)
