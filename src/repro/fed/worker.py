"""Worker-side local training (Algorithm 2, line 1).

Each worker owns: a private data shard, private hyper-parameters (batch
size, learning rate + decay, local epochs, optimizer) — exactly the private
information Theorem 2's privacy argument relies on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import BatchIterator
from repro.optim import optimizers as opt_mod
from repro.optim.schedules import step_decay
from repro.utils import PyTree

LR_MENU = (0.01,)                 # paper: initial lr 0.01 for everyone
EPOCH_MENU = (1, 2)               # local epochs per round
OPT_MENU = ("momentum", "adam", "sgd")


@dataclass
class WorkerConfig:
    worker_id: int
    batch_size: int
    lr0: float = 0.01
    lr_decay: float = 0.5
    lr_decay_every: int = 1000     # derived from local dataset size (paper)
    local_epochs: int = 1
    optimizer: str = "momentum"
    seed: int = 0


def make_worker_configs(n_workers: int, shard_sizes: list[int],
                        seed: int = 0,
                        batch_menu=(128, 64, 32)) -> list[WorkerConfig]:
    """Draw private hyper-parameters per worker, following §5.1: batch size
    from a menu, lr 0.01 with size-dependent step decay, 1–2 local epochs,
    momentum or adam."""
    rng = np.random.default_rng(seed)
    cfgs = []
    for k in range(n_workers):
        bs = int(rng.choice(batch_menu))
        bs = min(bs, max(shard_sizes[k], 1))
        steps_per_epoch = max(shard_sizes[k] // bs, 1)
        cfgs.append(WorkerConfig(
            worker_id=k,
            batch_size=bs,
            lr0=0.01,
            lr_decay=0.5,
            lr_decay_every=max(10 * steps_per_epoch, 1),
            local_epochs=int(rng.choice(EPOCH_MENU)),
            optimizer=str(rng.choice(OPT_MENU[:2])),
            seed=seed * 1000 + k,
        ))
    return cfgs


@dataclass
class Worker:
    """Stateful in-process worker for the simulator (the paper's testbed)."""
    cfg: WorkerConfig
    loader: BatchIterator
    loss_and_grad: Callable            # (params, batch) -> ((loss, aux), grads)
    opt: opt_mod.Optimizer = field(init=False)
    opt_state: Optional[PyTree] = None
    step: int = 0

    def __post_init__(self):
        self.opt = opt_mod.get(self.cfg.optimizer)
        self.lr_fn = step_decay(self.cfg.lr0, self.cfg.lr_decay,
                                self.cfg.lr_decay_every)

    def train_round(self, params: PyTree) -> tuple[PyTree, float]:
        """Run `local_epochs` epochs from the given global params; return
        (local_params Q_k, cost C_k). Optimizer state is private and persists
        across rounds (fresh momentum for new params would also be valid —
        the paper leaves this to the worker).

        The single ``float(...)`` here is the round's only device→host sync;
        the per-batch loop below stays fully asynchronous on device.
        """
        params, cost = self.train_round_device(params)
        return params, float(cost)

    def train_round_device(self, params: PyTree) -> tuple[PyTree, jax.Array]:
        """`train_round` without the host sync: the cost comes back as a
        device scalar. The loss is accumulated on-device — converting it per
        batch (the old ``float(loss)``) blocked dispatch on every step and
        serialized the round on the transfer latency."""
        if self.opt_state is None:
            self.opt_state = self.opt.init(params)
        total_loss = jnp.zeros((), jnp.float32)
        n_batches = 0
        for _ in range(self.cfg.local_epochs):
            for batch in self.loader.epoch():
                lr = self.lr_fn(self.step)
                (loss, _aux), grads = self.loss_and_grad(params, batch)
                updates, self.opt_state = self.opt.update(
                    grads, self.opt_state, params, lr)
                params = opt_mod.apply_updates(params, updates)
                total_loss = total_loss + loss
                n_batches += 1
                self.step += 1
        return params, total_loss / max(n_batches, 1)
