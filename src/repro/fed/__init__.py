from repro.fed.worker import WorkerConfig, make_worker_configs  # noqa: F401
from repro.fed.rounds import RoundEngine, WireConfig, WirePath  # noqa: F401
from repro.fed.simulator import FedSimulator, SimResult  # noqa: F401
