from repro.fed.worker import Worker, WorkerConfig, make_worker_configs  # noqa: F401
from repro.fed.rounds import (  # noqa: F401
    RoundEngine, RoundState, WireConfig, WirePath, init_round_state,
    load_round_state, participation_mask, participation_masks,
    save_round_state, scan_rounds,
)
from repro.fed.simulator import FedSimulator, SimResult  # noqa: F401
