"""Single-process federated simulator — the paper's experimental testbed.

Drives FedPC, FedAvg and Phong et al. over N in-process workers with private
data shards and private hyper-parameters, with exact Eq. (8) byte accounting
and the §4.2 information-flow ledger enforced on every round.

This is what the paper-table benchmarks (Tables 2–4, Figs 4/6) run on; the
TPU-mesh counterpart with the same math as collectives is
``repro.fed.distributed``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import fedpc as fp
from repro.core import protocol as proto
from repro.core.goodness import select_pilot
from repro.core.privacy import LeakageLedger
from repro.fed import rounds as rd
from repro.fed.worker import Worker
from repro.utils import PyTree


@dataclass
class SimResult:
    algorithm: str
    params: PyTree
    costs: list = field(default_factory=list)          # per-round mean cost
    pilot_history: list = field(default_factory=list)  # FedPC only
    bytes_per_round: list = field(default_factory=list)
    eval_history: list = field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return float(np.sum(self.bytes_per_round))


class FedSimulator:
    def __init__(self, workers: list[Worker], init_params: PyTree,
                 fed_cfg: Optional[fp.FedPCConfig] = None,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 evade_streak: int = 0):
        self.workers = workers
        self.init_params = init_params
        self.n = len(workers)
        self.fed_cfg = fed_cfg or fp.FedPCConfig(n_workers=self.n)
        self.sizes = np.array([w.loader.n for w in workers], np.float32)
        self.eval_fn = eval_fn
        self.ledger = LeakageLedger()
        self.evade_streak = evade_streak  # 0 = defence off

    # ------------------------------------------------------------------
    # FedPC (Algorithms 1 & 2)
    # ------------------------------------------------------------------
    def run_fedpc(self, rounds: int, eval_every: int = 0) -> SimResult:
        cfg = self.fed_cfg
        state = fp.init_state(self.init_params, self.n)
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("fedpc", state.params)
        prev_costs_rep = [np.inf] * self.n

        # The round engine owns the whole wire path (Eq. (3)-(5)/§3.3) and
        # the (P^{t-1}, P^{t-2}) history buffers; this loop only trains
        # workers, selects the pilot and keeps the ledger/byte accounting.
        engine = rd.RoundEngine(self.init_params,
                                rd.WireConfig.from_fedpc(cfg))
        p_shares = jnp.asarray(self.sizes / self.sizes.sum())

        for t in range(1, rounds + 1):
            # --- workers train locally (parallel in the real system) ---
            locals_, costs = [], []
            for w in self.workers:
                q, c = w.train_round(state.params)
                locals_.append(q)
                costs.append(c)
                self.ledger.record(w.cfg.worker_id, t, "cost", False)

            # --- worker-side evasion defence (§4.2 discussion) ---
            rep_costs = list(costs)
            if self.evade_streak:
                for k in range(self.n):
                    if (self.ledger.consecutive_pilot_streak(k)
                            >= self.evade_streak):
                        rep_costs[k] = prev_costs_rep[k]  # goodness → 0

            costs_arr = jnp.asarray(rep_costs, jnp.float32)
            k_star, _ = select_pilot(
                costs_arr, state.prev_costs, jnp.asarray(self.sizes), t)
            k_star = int(k_star)

            # --- uplinks: pilot sends weights; others send 2-bit codes ---
            # The engine packs ALL N workers' wire buffers in ONE batched
            # kernel launch (the pilot's row is masked out of Eq. (3) by its
            # zero weight) and applies the fused master update — the whole
            # round's wire math is two launches regardless of N.
            self.ledger.record(k_star, t, "pilot_params", True)
            for k in range(self.n):
                if k != k_star:
                    self.ledger.record(k, t, "packed_ternary", False)
            bufs_q = engine.flatten_locals(locals_)
            new_params = engine.run_round(bufs_q, k_star, p_shares, t)

            state = fp.FedPCState(
                params=new_params, params_prev=state.params,
                prev_costs=costs_arr, round=jnp.asarray(t + 1))
            prev_costs_rep = rep_costs

            res.costs.append(float(np.average(costs, weights=self.sizes)))
            res.pilot_history.append(k_star)
            res.bytes_per_round.append(proto.fedpc_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(new_params)))
        res.params = state.params
        return res

    # ------------------------------------------------------------------
    # FedAvg baseline
    # ------------------------------------------------------------------
    def run_fedavg(self, rounds: int, eval_every: int = 0) -> SimResult:
        params = self.init_params
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("fedavg", params)
        for t in range(1, rounds + 1):
            locals_, costs = [], []
            for w in self.workers:
                q, c = w.train_round(params)
                locals_.append(q)
                costs.append(c)
            params = bl.fedavg_aggregate(locals_, self.sizes)
            res.costs.append(float(np.average(costs, weights=self.sizes)))
            res.bytes_per_round.append(proto.fedavg_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res

    # ------------------------------------------------------------------
    # Phong et al. baseline (sequential weight transmission)
    # ------------------------------------------------------------------
    def run_phong(self, rounds: int, eval_every: int = 0) -> SimResult:
        params = self.init_params
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("phong", params)
        for t in range(1, rounds + 1):
            costs = []
            for w in self.workers:          # model travels worker→worker
                params, c = w.train_round(params)
                costs.append(c)
            res.costs.append(float(np.mean(costs)))
            res.bytes_per_round.append(proto.phong_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res

    # ------------------------------------------------------------------
    # Centralized upper bound (Table 1)
    # ------------------------------------------------------------------
    def run_centralized(self, rounds: int, central_worker: Worker,
                        eval_every: int = 0) -> SimResult:
        params = self.init_params
        res = SimResult("centralized", params)
        for t in range(1, rounds + 1):
            params, c = central_worker.train_round(params)
            res.costs.append(c)
            res.bytes_per_round.append(0.0)
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res
