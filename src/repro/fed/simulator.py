"""Single-process federated simulator — the paper's experimental testbed.

Drives FedPC, FedAvg and Phong et al. over N in-process workers with private
data shards and private hyper-parameters, with exact Eq. (8) byte accounting
and the §4.2 information-flow ledger enforced on every round.

This is what the paper-table benchmarks (Tables 2–4, Figs 4/6) run on; the
TPU-mesh counterpart with the same math as collectives is
``repro.fed.distributed``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import fedpc as fp
from repro.core import flat as fl
from repro.core import protocol as proto
from repro.core.convergence import CostHistory
from repro.core.goodness import select_pilot
from repro.core.privacy import LeakageLedger, should_evade
from repro.core.update import masked_weights
from repro.fed.worker import Worker
from repro.kernels import ops
from repro.utils import PyTree, tree_size

# A §3.3 wire byte whose four 2-bit fields all decode to code 0 — used to
# fill the pilot's (masked) row of the stacked packed buffer.
ZERO_CODES_BYTE = 0b01010101


@dataclass
class SimResult:
    algorithm: str
    params: PyTree
    costs: list = field(default_factory=list)          # per-round mean cost
    pilot_history: list = field(default_factory=list)  # FedPC only
    bytes_per_round: list = field(default_factory=list)
    eval_history: list = field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return float(np.sum(self.bytes_per_round))


class FedSimulator:
    def __init__(self, workers: list[Worker], init_params: PyTree,
                 fed_cfg: Optional[fp.FedPCConfig] = None,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 evade_streak: int = 0):
        self.workers = workers
        self.init_params = init_params
        self.n = len(workers)
        self.fed_cfg = fed_cfg or fp.FedPCConfig(n_workers=self.n)
        self.sizes = np.array([w.loader.n for w in workers], np.float32)
        self.eval_fn = eval_fn
        self.ledger = LeakageLedger()
        self.evade_streak = evade_streak  # 0 = defence off

    # ------------------------------------------------------------------
    # FedPC (Algorithms 1 & 2)
    # ------------------------------------------------------------------
    def run_fedpc(self, rounds: int, eval_every: int = 0) -> SimResult:
        cfg = self.fed_cfg
        state = fp.init_state(self.init_params, self.n)
        model_bytes = proto.model_size_bytes(self.init_params)
        n_params = tree_size(self.init_params)
        res = SimResult("fedpc", state.params)
        prev_costs_rep = [np.inf] * self.n

        # Flat wire path: one cached layout, single (rows, 128) buffers for
        # the public history — re-flattened only when a new global model is
        # produced (the new buffer is carried to the next round).
        layout = fl.layout_of(self.init_params)
        buf_p1 = fl.flatten_tree(state.params, layout)        # P^{t-1}
        buf_p2 = jnp.zeros_like(buf_p1)                       # P^{t-2}
        pilot_fill = jnp.full((layout.packed_rows, fl.LANES),
                              ZERO_CODES_BYTE, jnp.uint8)

        for t in range(1, rounds + 1):
            # --- workers train locally (parallel in the real system) ---
            locals_, costs = [], []
            for w in self.workers:
                q, c = w.train_round(state.params)
                locals_.append(q)
                costs.append(c)
                self.ledger.record(w.cfg.worker_id, t, "cost", False)

            # --- worker-side evasion defence (§4.2 discussion) ---
            rep_costs = list(costs)
            if self.evade_streak:
                for k in range(self.n):
                    if (self.ledger.consecutive_pilot_streak(k)
                            >= self.evade_streak):
                        rep_costs[k] = prev_costs_rep[k]  # goodness → 0

            costs_arr = jnp.asarray(rep_costs, jnp.float32)
            k_star, _ = select_pilot(
                costs_arr, state.prev_costs, jnp.asarray(self.sizes), t)
            k_star = int(k_star)

            # --- uplinks: pilot sends weights; others send 2-bit codes ---
            # Each non-pilot's wire buffer comes from ONE fused kernel
            # (Eq. (4)/(5) → §3.3 pack, no int8 intermediate); the pilot row
            # is all-zero codes, masked out of Eq. (3) anyway.
            self.ledger.record(k_star, t, "pilot_params", True)
            buf_pilot = None
            packed = []
            for k in range(self.n):
                buf_q = fl.flatten_tree(locals_[k], layout)
                if k == k_star:
                    buf_pilot = buf_q
                    packed.append(pilot_fill)
                else:
                    packed.append(ops.flat_ternary_pack(
                        buf_q, buf_p1, buf_p2, t=t, beta=cfg.beta,
                        alpha1=cfg.alpha_round1))
                    self.ledger.record(k, t, "packed_ternary", False)
            packed_stacked = jnp.stack(packed)      # (N, rows//4, 128) wire

            p_shares = jnp.asarray(self.sizes / self.sizes.sum())
            betas = (jnp.ones((self.n,), jnp.float32) if t == 1
                     else jnp.full((self.n,), cfg.beta, jnp.float32))
            w_masked = masked_weights(p_shares, betas, k_star)
            new_buf = ops.flat_master_update(
                buf_pilot, packed_stacked, w_masked, buf_p1, buf_p2,
                t=t, alpha0=cfg.alpha0)
            new_params = fl.unflatten_tree(new_buf, layout)

            state = fp.FedPCState(
                params=new_params, params_prev=state.params,
                prev_costs=costs_arr, round=jnp.asarray(t + 1))
            buf_p1, buf_p2 = new_buf, buf_p1
            prev_costs_rep = rep_costs

            res.costs.append(float(np.average(costs, weights=self.sizes)))
            res.pilot_history.append(k_star)
            res.bytes_per_round.append(proto.fedpc_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(new_params)))
        res.params = state.params
        return res

    # ------------------------------------------------------------------
    # FedAvg baseline
    # ------------------------------------------------------------------
    def run_fedavg(self, rounds: int, eval_every: int = 0) -> SimResult:
        params = self.init_params
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("fedavg", params)
        for t in range(1, rounds + 1):
            locals_, costs = [], []
            for w in self.workers:
                q, c = w.train_round(params)
                locals_.append(q)
                costs.append(c)
            params = bl.fedavg_aggregate(locals_, self.sizes)
            res.costs.append(float(np.average(costs, weights=self.sizes)))
            res.bytes_per_round.append(proto.fedavg_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res

    # ------------------------------------------------------------------
    # Phong et al. baseline (sequential weight transmission)
    # ------------------------------------------------------------------
    def run_phong(self, rounds: int, eval_every: int = 0) -> SimResult:
        params = self.init_params
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("phong", params)
        for t in range(1, rounds + 1):
            costs = []
            for w in self.workers:          # model travels worker→worker
                params, c = w.train_round(params)
                costs.append(c)
            res.costs.append(float(np.mean(costs)))
            res.bytes_per_round.append(proto.phong_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res

    # ------------------------------------------------------------------
    # Centralized upper bound (Table 1)
    # ------------------------------------------------------------------
    def run_centralized(self, rounds: int, central_worker: Worker,
                        eval_every: int = 0) -> SimResult:
        params = self.init_params
        res = SimResult("centralized", params)
        for t in range(1, rounds + 1):
            params, c = central_worker.train_round(params)
            res.costs.append(c)
            res.bytes_per_round.append(0.0)
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res
