"""Single-process federated simulator — the paper's experimental testbed.

Drives FedPC, FedAvg and Phong et al. over N in-process workers with private
data shards and private hyper-parameters, with exact Eq. (8) byte accounting
and the §4.2 information-flow ledger enforced on every round.

Two FedPC drivers share the pure round core (``repro.fed.rounds``):

* :meth:`FedSimulator.run_fedpc` — workers are stateful Python objects, so
  rounds step in a Python loop, but the protocol is device-resident: pilot
  selection is traced (``k_star`` never syncs to the host mid-run), worker
  costs stay device scalars, and the ledger / pilot history are backfilled
  from ONE post-loop fetch. The only per-round host syncs left are the
  opt-in worker-side evasion defence (``evade_streak`` — inherently a host
  behaviour: workers compare their history to decide what to report) and
  ``eval_every``.
* :meth:`FedSimulator.run_fedpc_scan` — the jitted multi-round path: every
  worker's batch schedule is pre-drawn on the host, then ALL rounds run as
  one ``lax.scan`` over ``WirePath.round_step`` — two kernel launches per
  round, zero per-round device→host transfers.

Both drivers support the two scenario axes of the round core: FedAvg-style
C-fraction **partial participation** (sampled workers only; the same
pre-generated mask schedule feeds both drivers) and **heterogeneous
per-worker beta_k** on the wire.

This is what the paper-table benchmarks (Tables 2–4, Figs 4/6) run on; the
TPU-mesh counterpart with the same math as collectives is
``repro.fed.distributed``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import fedpc as fp
from repro.core import flat as fl
from repro.core import protocol as proto
from repro.core.privacy import LeakageLedger
from repro.fed import faults as ft
from repro.fed import rounds as rd
from repro.fed.worker import Worker
from repro.privacy import audit as pv_audit
from repro.telemetry import trace as tmt
from repro.utils import PyTree


@dataclass
class SimResult:
    algorithm: str
    params: PyTree
    costs: list = field(default_factory=list)          # per-round mean cost
    pilot_history: list = field(default_factory=list)  # FedPC only
    eval_history: list = field(default_factory=list)
    round_state: Optional[rd.RoundState] = None        # FedPC resume handle
    # The FedPC drivers' byte accounting lives in the telemetry rollup (the
    # device-recorded counts pushed through core.protocol and cross-checked
    # in build_trace); bytes_per_round / recovery_bytes_per_round are thin
    # views over it. The baseline drivers (fedavg/phong/centralized) have
    # no traced round program and append into the backing lists directly.
    telemetry: Optional[tmt.TraceSummary] = None
    _bytes: list = field(default_factory=list)
    _recovery_bytes: list = field(default_factory=list)

    @property
    def bytes_per_round(self) -> list:
        if self.telemetry is not None:
            return self.telemetry.bytes_per_round
        return self._bytes

    @property
    def recovery_bytes_per_round(self) -> list:
        # Dropout-recovery control-plane bytes (share dealing +
        # reconstruction), accounted SEPARATELY from the data-plane bytes.
        if self.telemetry is not None:
            return self.telemetry.recovery_bytes_per_round
        return self._recovery_bytes

    @property
    def total_bytes(self) -> float:
        return float(np.sum(self.bytes_per_round)
                     + np.sum(self.recovery_bytes_per_round))


def _should_donate() -> bool:
    """Donate the RoundState buffers into the jitted step where the backend
    honours donation (CPU silently copies and warns, so skip it there)."""
    return jax.default_backend() != "cpu"


def _own_state(state: rd.RoundState, was_caller_supplied: bool
               ) -> rd.RoundState:
    """Copy a caller-supplied resume state before it enters a donating jit —
    the caller keeps a valid handle (e.g. for save_round_state or a second
    driver run from the same checkpoint)."""
    if was_caller_supplied and _should_donate():
        return jax.tree_util.tree_map(jnp.copy, state)
    return state


class FedSimulator:
    def __init__(self, workers: list[Worker], init_params: PyTree,
                 fed_cfg: Optional[fp.FedPCConfig] = None,
                 eval_fn: Optional[Callable[[PyTree], float]] = None,
                 evade_streak: int = 0):
        self.workers = workers
        self.init_params = init_params
        self.n = len(workers)
        self.fed_cfg = fed_cfg or fp.FedPCConfig(n_workers=self.n)
        self.sizes = np.array([w.loader.n for w in workers], np.float32)
        self.eval_fn = eval_fn
        self.ledger = LeakageLedger()
        self.evade_streak = evade_streak  # 0 = defence off

    # ------------------------------------------------------------------
    # FedPC shared plumbing
    # ------------------------------------------------------------------
    def _resolve_scenario(self, participation, betas, rounds, seed, t0):
        """(masks host (R,N) float or None, betas device (N,) or None).

        Masks are keyed by ABSOLUTE round (``t0`` onward), so a resumed run
        draws the same schedule an uninterrupted run would for those rounds.
        """
        cfg = self.fed_cfg
        frac = cfg.participation if participation is None else participation
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"participation must be in (0, 1], got {frac}")
        masks = None
        if frac < 1.0:
            masks = np.asarray(rd.participation_masks(
                jax.random.PRNGKey(seed), rounds, self.n, frac,
                start_round=t0))
        if betas is not None:
            betas_arr = jnp.asarray(betas, jnp.float32)
        elif cfg.betas is not None:
            betas_arr = cfg.beta_vector
        else:
            # Workers that drew a private beta_k (make_worker_configs'
            # beta_menu sets WorkerConfig.beta; None = no draw) put them on
            # the wire, with cfg.beta filling any gaps; an undrawn fleet
            # stays on the shared-scalar path so cfg.beta remains the
            # single knob (bitwise-identical to before).
            wb = [w.cfg.beta for w in self.workers]
            betas_arr = (jnp.asarray(
                [cfg.beta if b is None else b for b in wb], jnp.float32)
                if any(b is not None for b in wb) else None)
        return masks, betas_arr

    def _wire_path(self, wire_block_rows, wire_block_workers) -> rd.WirePath:
        """The round's WirePath with the config's privacy/renorm axes."""
        cfg = self.fed_cfg
        return rd.WirePath(rd.WireConfig.from_fedpc(cfg),
                           block_rows=wire_block_rows,
                           block_workers=wire_block_workers,
                           privacy=cfg.privacy,
                           renorm_shares=cfg.renorm_shares,
                           tree=cfg.tree,
                           faults=cfg.faults)

    def _fault_codes(self, t0: int, n_rounds: int) -> np.ndarray | None:
        """(R, N) host copy of the fault schedule, or None without a plan.
        The plan is a pure function of (seed, round, worker), so the host
        recomputes it — no extra device→host traffic."""
        plan = self.fed_cfg.faults
        if plan is None or not plan.active:
            return None
        return np.stack([np.asarray(plan.codes(t0 + i, self.n))
                         for i in range(n_rounds)])

    def _fault_split(self, row: np.ndarray, codes: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(live_eff, dead, recoverable) boolean views of one round (the
        masked wire's viability rule): survivors in VIABLE sibling groups
        (>= recovery_threshold survivors after a death — the others
        degrade to zero subtrees), sampled faulted workers, and the
        subset of the dead whose seeds CAN be reconstructed (dead in a
        viable group)."""
        pm = row > 0
        live = pm & (codes == ft.FAULT_NONE)
        dead = pm & (codes != ft.FAULT_NONE)
        spec = self.fed_cfg.privacy
        thr = spec.recovery_threshold if spec is not None else None
        g = (self.fed_cfg.tree.fanout if self.fed_cfg.tree is not None
             else self.n)
        ng = -(-self.n // g)
        pad = ng * g - self.n
        lp = np.pad(live, (0, pad)).reshape(ng, g)
        dp = np.pad(dead, (0, pad)).reshape(ng, g)
        viable = (dp.sum(1) == 0) | (lp.sum(1) >= (thr or np.inf))
        v = np.repeat(viable, g)[:self.n]
        return live & v, dead, dead & v

    def _enforce_privacy(self, runtime: str, wire: rd.WirePath,
                         state: rd.RoundState, betas_arr,
                         has_mask: bool) -> None:
        """§4.2 enforcement hook: audit the traced round program (against
        ShapeDtypeStructs, no real data) before any round runs. A policy
        violation raises LeakageError here; the passing audit is recorded
        in the ledger."""
        spec = self.fed_cfg.privacy
        if spec is None or not spec.enforce:
            return
        bufs = jax.ShapeDtypeStruct((self.n,) + state.buf_p1.shape,
                                    jnp.float32)
        costs = jax.ShapeDtypeStruct((self.n,), jnp.float32)
        # The mask spec must flow through check_round_program's kwargs —
        # that is what as_specs/make_jaxpr convert to tracers; baking it
        # into the partial would leave a raw ShapeDtypeStruct inside the
        # traced program.
        mask_kw = ({"mask": jax.ShapeDtypeStruct((self.n,), jnp.float32)}
                   if has_mask else {})
        report = pv_audit.check_round_program(
            partial(wire.round_step, betas=betas_arr),
            state, bufs, costs, jnp.asarray(self.sizes),
            n_workers=self.n, masked=spec.active, **mask_kw)
        self.ledger.record_audit(runtime, report)

    def _backfill_ledger(self, t0: int, pilots: np.ndarray,
                         masks: np.ndarray | None) -> None:
        """Record each round's uplink events after the fact — the ledger is
        host metadata, so it is reconstructed from the single post-run fetch
        of the on-device pilot history (§4.2 invariants unchanged). On the
        masked wire the master receives mod-2^modulus masked words, never
        the per-worker 2-bit codes — the ledger records what crossed."""
        spec = self.fed_cfg.privacy
        code_kind = ("masked_words" if spec is not None and spec.active
                     else "packed_ternary")
        codes_mat = self._fault_codes(t0, len(pilots))
        recovery_on = (codes_mat is not None and spec is not None
                       and spec.masking_on
                       and spec.recovery_threshold is not None)
        for i, k_star in enumerate(pilots):
            t = t0 + i
            row = (np.ones(self.n) if masks is None
                   else np.asarray(masks[i]))
            # A pre-uplink death sends NOTHING this round; post-uplink
            # deaths and stragglers already committed their cost + words.
            sent = row > 0
            if codes_mat is not None:
                sent = sent & (codes_mat[i] != ft.DROP_BEFORE)
            if recovery_on:
                _, _, recoverable = self._fault_split(row, codes_mat[i])
                for k in range(self.n):
                    if row[k]:   # share dealing precedes the round's faults
                        self.ledger.record(k, t, "seed_shares", False)
                for k in np.flatnonzero(recoverable):
                    self.ledger.record(int(k), t, "mask_recovery", False)
            for k in range(self.n):
                if sent[k]:
                    self.ledger.record(k, t, "cost", False)
            self.ledger.record(int(k_star), t, "pilot_params", True)
            for k in range(self.n):
                if sent[k] and k != int(k_star):
                    self.ledger.record(k, t, code_kind, False)

    def _finish_fedpc(self, res: SimResult, state: rd.RoundState,
                      layout: fl.FlatLayout, t0: int,
                      k_stars: list, raw_costs: list,
                      masks: np.ndarray | None, model_bytes: int,
                      ledger_done: bool, records=None,
                      driver: str = "run_fedpc",
                      check_costs: bool = True) -> SimResult:
        """The ONE post-run device→host fetch: pilot history, costs and the
        stacked telemetry records come back together; ledger, byte
        accounting and trace assembly are host work.

        The host recomputes every round's participation/fault/byte model
        from its own schedules (the legacy ledger math) and
        ``telemetry.trace.build_trace`` cross-checks the device-recorded
        counts and the derived bytes against it — any divergence raises
        ``TelemetryMismatch`` instead of returning a wrong ledger.
        """
        pilots = np.asarray(jnp.stack(k_stars))
        costs_mat = np.asarray(jnp.stack(raw_costs))        # (R, N)
        if not ledger_done:
            self._backfill_ledger(t0, pilots, masks)
        spec = self.fed_cfg.privacy
        masked_wire = spec is not None and spec.active
        codes_mat = self._fault_codes(t0, len(pilots))
        host_rounds: list[dict] = []
        for i in range(len(pilots)):
            row = np.ones(self.n) if masks is None else masks[i]
            # The reported round cost averages only workers whose report
            # the master USED: sampled, not faulted, and (masked wire) in
            # a viable sibling group. (The scan driver's costs_mat carries
            # prev-round values for the excluded, the Python driver their
            # never-delivered local measurements — both are masked out
            # here, keeping the drivers bitwise.)
            n_recoverable = 0
            if codes_mat is None:
                eff = row
            elif masked_wire:
                live_eff, _, recoverable = self._fault_split(
                    row, codes_mat[i])
                eff = row * live_eff
                n_recoverable = int(recoverable.sum())
            else:
                eff = row * (codes_mat[i] == ft.FAULT_NONE)
            if np.sum(eff) == 0:   # every report lost: cost track carries
                res.costs.append(res.costs[-1] if res.costs
                                 else float("inf"))
            else:
                vals = np.where(eff > 0, costs_mat[i], 0.0)
                res.costs.append(float(np.average(
                    vals, weights=self.sizes * eff)))
            res.pilot_history.append(int(pilots[i]))
            n_part = int(np.sum(row > 0))
            if self.fed_cfg.tree is not None:
                wire_bytes = proto.fedpc_tree_bytes_per_round(
                    model_bytes, n_part, self.fed_cfg.tree.fanout,
                    levels=self.fed_cfg.tree.levels,
                    word_bits=spec.modulus_bits if masked_wire else None)
            elif masked_wire:
                wire_bytes = proto.fedpc_masked_bytes_per_round(
                    model_bytes, n_part, word_bits=spec.modulus_bits)
            else:
                wire_bytes = proto.fedpc_bytes_per_round(
                    model_bytes, n_part)
            rec_bytes = 0.0
            if codes_mat is not None:
                codes = codes_mat[i]
                # pre-uplink deaths never spent their uplink bytes
                n_pre = int(np.sum((row > 0) & (codes == ft.DROP_BEFORE)))
                leaf_bits = (float(spec.modulus_bits) if masked_wire
                             else 2.0)
                wire_bytes -= model_bytes * n_pre * leaf_bits / 32.0
                if (spec is not None and spec.masking_on
                        and spec.recovery_threshold is not None):
                    g = (self.fed_cfg.tree.fanout
                         if self.fed_cfg.tree is not None else None)
                    _, _, recoverable = self._fault_split(row, codes)
                    rec_bytes = (
                        proto.recovery_dealing_bytes_per_round(self.n, g)
                        + proto.recovery_reconstruction_bytes(
                            int(recoverable.sum()),
                            spec.recovery_threshold, g,
                            n_workers=self.n))
            host_rounds.append({
                "row": row > 0,
                "codes": None if codes_mat is None else codes_mat[i],
                "used": np.asarray(eff) > 0,
                "n_recoverable": n_recoverable,
                "pilot": int(pilots[i]), "cost": res.costs[-1],
                "wire_bytes": wire_bytes, "recovery_bytes": rec_bytes})
        if records is not None:
            tree = self.fed_cfg.tree
            meta = tmt.trace_meta(
                source="fed_simulator", algorithm="fedpc", driver=driver,
                n_workers=self.n, t0=t0, rounds=len(pilots),
                model_bytes=model_bytes,
                wire="masked" if masked_wire else "plain",
                masking=bool(spec is not None and spec.masking_on),
                modulus_bits=spec.modulus_bits if masked_wire else 0,
                fanout=tree.fanout if tree is not None else 0,
                levels=(tree.levels or 0) if tree is not None else 0,
                recovery_threshold=((spec.recovery_threshold or 0)
                                    if spec is not None else 0),
                faults_active=codes_mat is not None)
            recs_host = jax.tree_util.tree_map(np.asarray, records)
            res.telemetry = tmt.build_trace(meta, recs_host, host_rounds,
                                            check_costs=check_costs)
        else:       # telemetry disabled on the carry: legacy byte lists
            for h in host_rounds:
                res._bytes.append(h["wire_bytes"])
                res._recovery_bytes.append(h["recovery_bytes"])
        res.params = fl.unflatten_tree(state.buf_p1, layout)
        res.round_state = state
        return res

    # ------------------------------------------------------------------
    # FedPC (Algorithms 1 & 2) — Python-loop driver, stateful workers
    # ------------------------------------------------------------------
    def run_fedpc(self, rounds: int, eval_every: int = 0, *,
                  participation: Optional[float] = None,
                  betas=None, participation_seed: int = 0,
                  state: Optional[rd.RoundState] = None,
                  wire_block_rows: Optional[int] = None,
                  wire_block_workers: Optional[int] = None) -> SimResult:
        """Run ``rounds`` rounds (resuming from ``state`` if given).

        Per round: workers train locally (device costs), one traced
        ``round_step`` does pilot selection + batched uplink + fused master
        update (two kernel launches). Pilot history and costs stay on
        device until the end of the run. ``wire_block_rows`` /
        ``wire_block_workers`` pin the wire-kernel tiling (default: the
        ``kernels.tune`` plan for this shape — tiling never changes bits).
        """
        cfg = self.fed_cfg
        wire = self._wire_path(wire_block_rows, wire_block_workers)
        layout = fl.layout_of(self.init_params)
        resumed = state is not None
        if state is None:
            state = rd.init_round_state(self.init_params, self.n, layout,
                                        privacy=cfg.privacy)
        state = _own_state(state, resumed)
        t0 = int(state.round)                 # one setup-time sync
        masks, betas_arr = self._resolve_scenario(
            participation, betas, rounds, participation_seed, t0)
        if self.evade_streak and masks is not None:
            raise ValueError("evasion defence + partial participation is "
                             "not supported in one run")
        model_bytes = proto.model_size_bytes(self.init_params)
        params = fl.unflatten_tree(state.buf_p1, layout)
        res = SimResult("fedpc", params)
        sizes = jnp.asarray(self.sizes)
        self._enforce_privacy("run_fedpc", wire, state, betas_arr,
                              has_mask=masks is not None)

        step = jax.jit(
            partial(wire.round_step, betas=betas_arr),
            donate_argnums=(0,) if _should_donate() else ())
        # The defence's reported-cost memory: on resume, state.prev_costs
        # holds exactly the last reported costs (a fresh state holds the
        # same +inf this used to start from).
        prev_costs_rep = (list(np.asarray(state.prev_costs))
                          if self.evade_streak else [np.inf] * self.n)
        k_stars: list = []
        raw_costs: list = []
        recs: list = []

        for i in range(rounds):
            t = t0 + i
            row = None if masks is None else masks[i]
            # --- workers train locally (parallel in the real system) ---
            locals_, costs = [], []
            for k, w in enumerate(self.workers):
                if row is None or row[k]:
                    q, c = w.train_round_device(params)
                else:       # not sampled: nothing trains, nothing uploads
                    q, c = params, 0.0
                locals_.append(q)
                costs.append(jnp.asarray(c, jnp.float32))

            # --- worker-side evasion defence (§4.2 discussion): inherently
            # a host behaviour — each worker inspects its own pilot history
            # to decide what to report, so this path syncs k* per round ---
            rep_costs = list(costs)
            if self.evade_streak:
                for k in range(self.n):
                    if (self.ledger.consecutive_pilot_streak(k)
                            >= self.evade_streak):
                        rep_costs[k] = prev_costs_rep[k]  # goodness → 0

            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *locals_)
            bufs_q = fl.flatten_stacked(stacked, layout)
            costs_arr = jnp.stack(
                [jnp.asarray(c, jnp.float32) for c in rep_costs])
            mask_dev = None if row is None else jnp.asarray(row)
            state, new_buf, info = step(state, bufs_q, costs_arr, sizes,
                                        mask=mask_dev)
            params = fl.unflatten_tree(new_buf, layout)
            k_stars.append(info["k_star"])
            raw_costs.append(jnp.stack(costs))   # reported costs, un-evaded
            recs.append(info["telemetry"])       # device scalars, no sync
            prev_costs_rep = rep_costs

            if self.evade_streak:     # defence needs the ledger live
                k_host = int(info["k_star"])
                self._backfill_ledger(t, np.asarray([k_host]), None)
            if eval_every and self.eval_fn and (t - t0 + 1) % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))

        # Stack the per-round records like the scan would — the trace is
        # driver-invariant (pinned bitwise by tests/test_telemetry.py).
        records = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *recs)
        # With the evasion defence the device averaged the REPORTED costs
        # (what the master acted on) while res.costs tracks the measured
        # ones — the cost cross-check is meaningless there by design.
        return self._finish_fedpc(res, state, layout, t0, k_stars,
                                  raw_costs, masks, model_bytes,
                                  ledger_done=bool(self.evade_streak),
                                  records=records, driver="run_fedpc",
                                  check_costs=not bool(self.evade_streak))

    # ------------------------------------------------------------------
    # FedPC — scan driver: ALL rounds inside one jitted lax.scan
    # ------------------------------------------------------------------
    def run_fedpc_scan(self, rounds: int, *,
                       participation: Optional[float] = None,
                       betas=None, participation_seed: int = 0,
                       state: Optional[rd.RoundState] = None,
                       wire_block_rows: Optional[int] = None,
                       wire_block_workers: Optional[int] = None) -> SimResult:
        """The device-resident multi-round driver.

        Every worker's batch schedule for all ``rounds`` is pre-drawn on the
        host (consuming each loader's rng exactly as the Python driver
        would, skipped rounds included), then local training + the round
        protocol run as ONE ``lax.scan`` over ``WirePath.round_step``: two
        kernel launches per round, zero per-round device→host transfers.
        Ledger and pilot history are backfilled from a single post-scan
        fetch. Bitwise-identical to :meth:`run_fedpc` on the same fresh
        simulator state.

        Requires jit-able workers: every loader's shard size must be a
        multiple of its batch size (no ragged last batch). The evasion
        defence (per-round host behaviour) is not available here.
        """
        if self.evade_streak:
            raise ValueError("evade_streak requires the Python-loop driver "
                             "(per-round host behaviour)")
        cfg = self.fed_cfg
        wire = self._wire_path(wire_block_rows, wire_block_workers)
        layout = fl.layout_of(self.init_params)
        resumed = state is not None
        if state is None:
            state = rd.init_round_state(self.init_params, self.n, layout,
                                        privacy=cfg.privacy)
        state = _own_state(state, resumed)
        t0 = int(state.round)                 # one setup-time sync
        masks, betas_arr = self._resolve_scenario(
            participation, betas, rounds, participation_seed, t0)
        model_bytes = proto.model_size_bytes(self.init_params)
        params0 = fl.unflatten_tree(state.buf_p1, layout)
        res = SimResult("fedpc", params0)
        self._enforce_privacy("run_fedpc_scan", wire, state, betas_arr,
                              has_mask=masks is not None)

        # --- pre-draw every worker's batch schedule (host) --------------
        # Only the sample INDICES are pre-drawn — (rounds, steps, bs) int32
        # per worker; the shard itself lives on device once and the scan
        # body gathers batches from it, so device memory stays
        # O(shard + rounds·steps·bs·4B) instead of O(rounds · shard).
        shards, index_schedules, steps_per_round = [], [], []
        for k, w in enumerate(self.workers):
            if not w.uniform_batches:
                raise ValueError(
                    f"worker {k}: scan driver needs batch_size "
                    f"({w.loader.batch_size}) to divide the shard size "
                    f"({w.loader.n}) — no ragged last batch under scan")
            steps = w.cfg.local_epochs * w.loader.steps_per_epoch()
            steps_per_round.append(steps)
            rows = []
            for i in range(rounds):
                if masks is None or masks[i, k]:
                    rows.append(np.stack(
                        [sel for _ in range(w.cfg.local_epochs)
                         for sel in w.loader.epoch_indices()]))
                else:       # skipped round: loader rng untouched; the
                    # gathered batch is masked out of all state anyway
                    rows.append(np.zeros((steps, w.loader.batch_size),
                                         np.int64))
            index_schedules.append(jnp.asarray(np.stack(rows), jnp.int32))
            shards.append(tuple(jnp.asarray(a) for a in w.loader.arrays))
            if w.opt_state is None:
                w.opt_state = w.opt.init(params0)

        worker_carry = tuple(
            (w.opt_state, jnp.asarray(w.step, jnp.int32))
            for w in self.workers)
        masks_dev = None if masks is None else jnp.asarray(masks)
        sizes = jnp.asarray(self.sizes)

        def worker_fn(wc, buf, t):
            params = fl.unflatten_tree(buf, layout)
            r = t - t0                        # row into the schedules
            m_row = (None if masks_dev is None
                     else jnp.take(masks_dev, r, axis=0))
            new_wc, bufs, costs = [], [], []
            for k, w in enumerate(self.workers):
                opt_state, step0 = wc[k]
                idx = jnp.take(index_schedules[k], r, axis=0)  # (steps, bs)
                bk = tuple(
                    jnp.take(a, idx.reshape(-1), axis=0).reshape(
                        idx.shape + a.shape[1:])
                    for a in shards[k])
                # The same recurrence train_round_device jits standalone —
                # traced here inside the round body (bitwise-identical).
                pk, osk, sk, cost_k = w.scan_train(params, opt_state,
                                                   step0, bk)
                buf_k = fl.flatten_tree(pk, layout)
                if m_row is not None:         # skipped: state frozen
                    m = m_row[k] > 0
                    buf_k = jnp.where(m, buf_k, buf)
                    osk = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(m, a, b), osk, opt_state)
                    sk = jnp.where(m, sk, step0)
                    cost_k = jnp.where(m, cost_k, 0.0)
                new_wc.append((osk, sk))
                bufs.append(buf_k)
                costs.append(cost_k)
            return tuple(new_wc), jnp.stack(bufs), jnp.stack(costs)

        run = jax.jit(
            lambda st, wc: rd.scan_rounds(
                wire, st, worker_fn, wc, rounds, sizes,
                betas=betas_arr, masks=masks_dev),
            donate_argnums=(0,) if _should_donate() else ())
        state, worker_carry, infos = run(state, worker_carry)

        # write back worker state (host bookkeeping, once)
        for k, w in enumerate(self.workers):
            w.opt_state = worker_carry[k][0]
            part = rounds if masks is None else int(np.sum(masks[:, k] > 0))
            w.step += steps_per_round[k] * part

        k_stars = list(infos["k_star"])
        raw_costs = list(infos["costs"])
        return self._finish_fedpc(res, state, layout, t0, k_stars,
                                  raw_costs, masks, model_bytes,
                                  ledger_done=False,
                                  records=infos["telemetry"],
                                  driver="run_fedpc_scan")

    # ------------------------------------------------------------------
    # FedAvg baseline
    # ------------------------------------------------------------------
    def run_fedavg(self, rounds: int, eval_every: int = 0) -> SimResult:
        params = self.init_params
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("fedavg", params)
        for t in range(1, rounds + 1):
            locals_, costs = [], []
            for w in self.workers:
                q, c = w.train_round(params)
                locals_.append(q)
                costs.append(c)
            params = bl.fedavg_aggregate(locals_, self.sizes)
            res.costs.append(float(np.average(costs, weights=self.sizes)))
            res.bytes_per_round.append(proto.fedavg_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res

    # ------------------------------------------------------------------
    # Phong et al. baseline (sequential weight transmission)
    # ------------------------------------------------------------------
    def run_phong(self, rounds: int, eval_every: int = 0) -> SimResult:
        params = self.init_params
        model_bytes = proto.model_size_bytes(self.init_params)
        res = SimResult("phong", params)
        for t in range(1, rounds + 1):
            costs = []
            for w in self.workers:          # model travels worker→worker
                params, c = w.train_round(params)
                costs.append(c)
            res.costs.append(float(np.mean(costs)))
            res.bytes_per_round.append(proto.phong_bytes_per_round(
                model_bytes, self.n))
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res

    # ------------------------------------------------------------------
    # Centralized upper bound (Table 1)
    # ------------------------------------------------------------------
    def run_centralized(self, rounds: int, central_worker: Worker,
                        eval_every: int = 0) -> SimResult:
        params = self.init_params
        res = SimResult("centralized", params)
        for t in range(1, rounds + 1):
            params, c = central_worker.train_round(params)
            res.costs.append(c)
            res.bytes_per_round.append(0.0)
            if eval_every and self.eval_fn and t % eval_every == 0:
                res.eval_history.append((t, self.eval_fn(params)))
        res.params = params
        return res
