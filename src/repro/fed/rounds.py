"""The FedPC round engine — the wire protocol of Eq. (3)-(5)/§3.3 in ONE place.

Both runtimes are thin drivers over this module:

* ``repro.fed.simulator.run_fedpc`` — workers are in-process Python objects;
  the engine runs the whole uplink as one batched kernel launch over the
  stacked worker buffers and one fused master launch (``RoundEngine``).
* ``repro.fed.distributed.build_fed_sync`` — workers are slices of a mesh
  axis; the shard_map body calls the same :class:`WirePath` methods on its
  local slab and moves bytes with collectives between them.

The split of responsibilities:

* :class:`WirePath` owns the *math*: ternarize (Eq. (4)/(5)) → pack (§3.3)
  → aggregate (the masked Σ_k w_k T_k) → master update (Eq. (3)), over the
  flat ``(rows, 128)`` buffers of ``repro.core.flat``. Fused Pallas kernels
  where the data layout allows, jnp reference semantics (``codes`` /
  ``combine``) for runtimes that move their own bytes between the steps.
* :class:`RoundEngine` owns the *state*: the public two-step history
  (P^{t-1}, P^{t-2}) carried between rounds, rotated exactly as Algorithm 1
  prescribes.

Nothing here selects the pilot — goodness (Alg. 1 line 4) stays in
``repro.core.goodness`` and is shared by both runtimes already.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import flat as fl
from repro.core.ternary import ternarize, ternarize_round1
from repro.kernels import ops
from repro.utils import PyTree


@dataclass(frozen=True)
class WireConfig:
    """The three public protocol scalars of the FedPC wire path."""
    alpha0: float = 0.01      # Eq. (3) round-1 master step
    beta: float = 0.2         # Eq. (5) significance threshold
    alpha1: float = 0.01      # Eq. (4) round-1 threshold

    @classmethod
    def from_fedpc(cls, cfg) -> "WireConfig":
        """Lift the wire scalars out of a ``core.fedpc.FedPCConfig``."""
        return cls(alpha0=cfg.alpha0, beta=cfg.beta, alpha1=cfg.alpha_round1)


@dataclass(frozen=True)
class WirePath:
    """Ternarize → pack → aggregate → master-update over flat buffers.

    Buffers (any ``(rows, 128)`` slab of a ``FlatLayout``) are passed to
    each method explicitly, so one WirePath serves full buffers and model
    shards alike. ``interpret=None`` defers to the backend (Python
    interpret on CPU, compiled on TPU); ``block_rows=None`` uses the
    kernels' VMEM-sized default tile.
    """
    cfg: WireConfig = WireConfig()
    interpret: bool | None = None
    block_rows: int | None = None

    # -- elementwise protocol math (jnp semantics, traced round index) ------

    def codes(self, q: jax.Array, p1: jax.Array, p2: jax.Array,
              t) -> jax.Array:
        """Eq. (4) at t <= 1 (``p1`` holds P^0), Eq. (5) after; int8 codes
        of ``q.shape``. Works on any slab/shape — it is elementwise."""
        t1 = ternarize_round1(q, p1, self.cfg.alpha1)
        tt = ternarize(q, p1, p2, self.cfg.beta)
        return jnp.where(jnp.asarray(t) <= 1, t1, tt)

    def combine(self, q_pilot: jax.Array, coeff: jax.Array, p1: jax.Array,
                p2: jax.Array, t) -> jax.Array:
        """Eq. (3) given the aggregated ``coeff = Σ_k w_k T_k``: round 1
        steps by ``alpha0``, later rounds by the history step P^{t-1}-P^{t-2}."""
        step = (p1 - p2).astype(jnp.float32)
        r1 = q_pilot - self.cfg.alpha0 * coeff
        rt = q_pilot - coeff * step
        return jnp.where(jnp.asarray(t) <= 1, r1, rt)

    def weights(self, p_shares: jax.Array, k_star, t) -> jax.Array:
        """Masked per-worker Eq. (3) coefficients: p_k at round 1 (the
        alpha0 rule), p_k·beta_k after; the pilot's entry is zeroed."""
        n = p_shares.shape[0]
        mask = (jnp.arange(n) != k_star).astype(jnp.float32)
        scale = jnp.where(jnp.asarray(t) <= 1, 1.0, self.cfg.beta)
        return mask * p_shares.astype(jnp.float32) * scale

    # -- fused kernel path over (rows, 128) slabs ---------------------------

    def uplink(self, buf_q: jax.Array, buf_p1: jax.Array, buf_p2: jax.Array,
               *, t: int) -> jax.Array:
        """One worker's §3.3 wire buffer (static round): (rows, 128) →
        (rows//4, 128) uint8, one launch, no int8 intermediate."""
        return ops.flat_ternary_pack(
            buf_q, buf_p1, buf_p2, t=t, beta=self.cfg.beta,
            alpha1=self.cfg.alpha1, interpret=self.interpret,
            block_rows=self.block_rows)

    def uplink_traced(self, buf_q: jax.Array, buf_p1: jax.Array,
                      buf_p2: jax.Array, *, t) -> jax.Array:
        """Like :meth:`uplink` but ``t`` may be traced (branch selected
        in-register) — the distributed sync's per-slab uplink."""
        return ops.flat_ternary_pack_traced(
            buf_q, buf_p1, buf_p2, t=t, beta=self.cfg.beta,
            alpha1=self.cfg.alpha1, interpret=self.interpret,
            block_rows=self.block_rows)

    def uplink_stacked(self, bufs_q: jax.Array, buf_p1: jax.Array,
                       buf_p2: jax.Array, *, t) -> jax.Array:
        """All N workers' wire buffers in ONE launch: (N, rows, 128) →
        (N, rows//4, 128) uint8 — the simulator's batched uplink."""
        return ops.flat_ternary_pack_stacked(
            bufs_q, buf_p1, buf_p2, t=t, beta=self.cfg.beta,
            alpha1=self.cfg.alpha1, interpret=self.interpret,
            block_rows=self.block_rows)

    def master(self, buf_pilot: jax.Array, packed: jax.Array, w: jax.Array,
               buf_p1: jax.Array, buf_p2: jax.Array, *, t) -> jax.Array:
        """Fused Eq. (3) over packed wire codes: in-register 2-bit decode +
        masked weighted reduce + history step, one launch. ``t`` may be
        traced."""
        return ops.flat_master_update(
            buf_pilot, packed, w, buf_p1, buf_p2, t=t,
            alpha0=self.cfg.alpha0, interpret=self.interpret,
            block_rows=self.block_rows)

    def round_from_stacked(self, bufs_q: jax.Array, k_star, w: jax.Array,
                           buf_p1: jax.Array, buf_p2: jax.Array, *, t
                           ) -> tuple[jax.Array, jax.Array]:
        """A full round over stacked worker buffers: batched uplink + fused
        master — exactly two kernel launches regardless of N.

        The pilot's row is packed like everyone else's and masked out of
        Eq. (3) by ``w[k_star] == 0`` (bitwise identical to zero-filling it:
        0·T contributes exactly ±0.0 to the reduce).

        Returns ``(new_global_buf, packed_stacked)`` — the packed buffers
        ride along for byte accounting / ledger purposes.
        """
        packed = self.uplink_stacked(bufs_q, buf_p1, buf_p2, t=t)
        buf_pilot = bufs_q[k_star]
        new_buf = self.master(buf_pilot, packed, w, buf_p1, buf_p2, t=t)
        return new_buf, packed


class RoundEngine:
    """Carries the public history across rounds and drives :class:`WirePath`.

    The simulator's per-round protocol work reduces to::

        bufs_q = engine.flatten_locals(locals_)           # stack worker trees
        new_params = engine.run_round(bufs_q, k_star, p_shares, t)

    which is two kernel launches + one unflatten. The history rotation
    (P^{t-1}, P^{t-2}) ← (P^t, P^{t-1}) happens inside ``run_round``.
    """

    def __init__(self, init_params: PyTree, cfg: WireConfig | None = None,
                 *, shards: int = 1, interpret: bool | None = None,
                 block_rows: int | None = None):
        self.layout = fl.layout_of(init_params, shards=shards)
        self.wire = WirePath(cfg or WireConfig(),
                             interpret=interpret, block_rows=block_rows)
        self.buf_p1 = fl.flatten_tree(init_params, self.layout)   # P^{t-1}
        self.buf_p2 = jnp.zeros_like(self.buf_p1)                 # P^{t-2}

    def flatten_locals(self, locals_: list[PyTree]) -> jax.Array:
        """Stack N worker pytrees into the (N, rows, 128) uplink input."""
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *locals_)
        return fl.flatten_stacked(stacked, self.layout)

    def run_round(self, bufs_q: jax.Array, k_star, p_shares: jax.Array,
                  t) -> PyTree:
        """Alg. 1 lines 5-8 for one round; returns the new global pytree and
        advances the engine's history."""
        w = self.wire.weights(p_shares, k_star, t)
        new_buf, _packed = self.wire.round_from_stacked(
            bufs_q, k_star, w, self.buf_p1, self.buf_p2, t=t)
        self.buf_p1, self.buf_p2 = new_buf, self.buf_p1
        return fl.unflatten_tree(new_buf, self.layout)
