"""The FedPC round core — Algorithm 1 as a pure, device-resident recurrence.

The paper's round is one pure function of public state: score goodness
(Eq. (1)) → pick the pilot → ternarize/pack everyone's evolution
(Eq. (4)/(5), §3.3) → master update (Eq. (3)). This module expresses it
exactly that way:

* :class:`WirePath` owns the *math*: ternarize (Eq. (4)/(5)) → pack (§3.3)
  → aggregate (the masked Σ_k w_k β_k T_k) → master update (Eq. (3)), over
  the flat ``(rows, 128)`` buffers of ``repro.core.flat``. Fused Pallas
  kernels where the data layout allows, jnp reference semantics (``codes``
  / ``combine``) for runtimes that move their own bytes between the steps.
* :class:`RoundState` is the *whole* public inter-round state as one pytree:
  the history buffers P^{t-1}/P^{t-2}, last-round costs, and the round
  counter. It is a valid ``lax.scan`` carry and serializes through
  ``repro.checkpoint`` (:func:`save_round_state` / :func:`load_round_state`).
* :func:`WirePath.round_step` is the recurrence itself —
  ``(state, bufs_q, costs, sizes) -> (state', new_buf, info)`` — fully
  traceable: pilot selection stays on device (``k_star`` is never pulled to
  the host; the pilot buffer is gathered with a dynamic index), the batched
  uplink and the fused master update are the round's only two kernel
  launches, and both scenario axes ride along as optional operands: a
  per-round participation ``mask`` (sampled workers only) and a per-worker
  ``betas`` vector (heterogeneous beta_k on the wire).
* :func:`scan_rounds` drives many rounds as ONE ``lax.scan`` over
  ``round_step`` — zero per-round device→host transfers; the pilot history
  and per-round costs come back stacked in ``infos`` for a single post-scan
  fetch (ledger backfill).

Both runtimes are thin drivers over this core:

* ``repro.fed.simulator`` — in-process workers; ``run_fedpc`` steps
  ``round_step`` per round (workers are stateful Python), ``run_fedpc_scan``
  runs the whole federation under ``lax.scan``.
* ``repro.fed.distributed.build_fed_sync`` — workers are slices of a mesh
  axis; the shard_map body calls the same :class:`WirePath` methods on its
  local slab and moves bytes with collectives between them.

:class:`RoundEngine` remains as the thin stateful wrapper the per-round
drivers use (it holds the history buffers and calls the pure core).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import flat as fl
from repro.core.goodness import select_pilot
from repro.core.ternary import ternarize, ternarize_round1
from repro.core.tree import TreeSpec
from repro.kernels import ops
from repro.privacy import dp as pdp
from repro.privacy import masking as pvm
from repro.privacy import recovery as pvr
from repro.privacy.accountant import PrivacyAccountant
from repro.privacy.spec import PrivacySpec
from repro.telemetry import record as tmr
from repro.utils import PyTree

#: The plain (no-privacy) tree rides the integer wire so that float
#: non-associativity cannot break tree == flat bitwise parity: leaves are
#: weighted with fixed-point Eq. (3) coefficients at these parameters and
#: every tree edge carries modular uint32 words; the single root launch
#: de-biases by the public ΣW_k and descales by 2**-TREE_PLAIN_FIXPOINT_BITS.
TREE_PLAIN_WORD_BITS = 32
TREE_PLAIN_FIXPOINT_BITS = 24


@dataclass(frozen=True)
class WireConfig:
    """The three public protocol scalars of the FedPC wire path."""
    alpha0: float = 0.01      # Eq. (3) round-1 master step
    beta: float = 0.2         # Eq. (5) significance threshold
    alpha1: float = 0.01      # Eq. (4) round-1 threshold

    @classmethod
    def from_fedpc(cls, cfg) -> "WireConfig":
        """Lift the wire scalars out of a ``core.fedpc.FedPCConfig``."""
        return cls(alpha0=cfg.alpha0, beta=cfg.beta, alpha1=cfg.alpha_round1)


class RoundState(NamedTuple):
    """Device-resident inter-round federation state — one pure pytree.

    Everything Algorithm 1 carries between rounds, and nothing else: the
    two-step public history needed by Eq. (3)/(5), the previous costs needed
    by Eq. (1), and the 1-based index of the round about to run. Being a
    flat pytree of arrays makes it a ``lax.scan`` carry, a jit donation
    target, and a checkpointable object all at once.
    """
    buf_p1: jax.Array      # (rows, 128) — P^{t-1}
    buf_p2: jax.Array      # (rows, 128) — P^{t-2}
    prev_costs: jax.Array  # (N,) — C_k^{t-1}, +inf before round 1
    round: jax.Array       # scalar int32, 1-based round about to run
    accountant: Any = None  # PrivacyAccountant when the DP wire is on
    telemetry: Any = None   # TelemetryCarry — cumulative round counters


def init_round_state(init_params: PyTree, n_workers: int,
                     layout: fl.FlatLayout | None = None, *,
                     privacy: PrivacySpec | None = None,
                     telemetry: bool = True) -> RoundState:
    """Fresh :class:`RoundState` at round 1 (P^{t-2} = 0, costs = +inf).

    With a DP-enabled ``privacy`` spec the state carries a zeroed
    :class:`~repro.privacy.accountant.PrivacyAccountant` — four device
    scalars that ride the scan carry and the checkpoint alongside the
    history buffers. ``telemetry`` (default on) seeds a zeroed
    :class:`~repro.telemetry.record.TelemetryCarry` the same way, so the
    cumulative round counters checkpoint and resume with the federation.
    """
    layout = layout or fl.layout_of(init_params)
    buf_p1 = fl.flatten_tree(init_params, layout)
    return RoundState(
        buf_p1=buf_p1,
        buf_p2=jnp.zeros_like(buf_p1),
        prev_costs=jnp.full((n_workers,), jnp.inf, jnp.float32),
        round=jnp.asarray(1, jnp.int32),
        accountant=(PrivacyAccountant.zero()
                    if privacy is not None and privacy.dp_on else None),
        telemetry=tmr.TelemetryCarry.zero() if telemetry else None,
    )


def save_round_state(directory: str, state: RoundState,
                     metadata: dict | None = None) -> str:
    """Serialize a :class:`RoundState` through ``repro.checkpoint``.

    The (single, intentional) host sync here reads ``state.round`` for the
    checkpoint step — checkpointing is already an I/O barrier.
    """
    from repro.checkpoint import save_checkpoint
    meta = {"kind": "fedpc_round_state", **(metadata or {})}
    return save_checkpoint(directory, state._asdict(), int(state.round),
                           metadata=meta)


def load_round_state(directory: str, like: RoundState,
                     step: int | None = None) -> tuple[RoundState, dict]:
    """Restore a :class:`RoundState` saved by :func:`save_round_state`.

    ``like`` supplies the expected structure/shapes (strict-checked by the
    checkpoint layer) — e.g. ``init_round_state(params, n)``.
    """
    from repro.checkpoint import load_checkpoint
    tree, manifest = load_checkpoint(directory, like._asdict(), step)
    return RoundState(**tree), manifest


def participation_mask(key: jax.Array, n_workers: int,
                       fraction: float) -> jax.Array:
    """One round's FedAvg-style C-fraction mask: a traceable (N,) float32
    0/1 vector with ``max(1, round(C·N))`` uniformly sampled workers."""
    m = max(1, int(round(fraction * n_workers)))
    perm = jax.random.permutation(key, n_workers)
    return (perm < m).astype(jnp.float32)


def participation_masks(key: jax.Array, n_rounds: int, n_workers: int,
                        fraction: float, start_round: int = 1) -> jax.Array:
    """(n_rounds, N) masks — the per-round ``xs`` of :func:`scan_rounds`.
    Pre-generating them (rather than sampling inside the scan body) lets a
    Python-loop driver and the scan driver consume identical schedules.
    Each row is keyed by its ABSOLUTE round index (``start_round + i``), so
    a run resumed at round t draws exactly the rows an uninterrupted run
    would have used for rounds t, t+1, ..."""
    return jnp.stack([
        participation_mask(jax.random.fold_in(key, start_round + i),
                           n_workers, fraction)
        for i in range(n_rounds)])


@dataclass(frozen=True)
class WirePath:
    """Ternarize → pack → aggregate → master-update over flat buffers.

    Buffers (any ``(rows, 128)`` slab of a ``FlatLayout``) are passed to
    each method explicitly, so one WirePath serves full buffers and model
    shards alike. ``interpret=None`` defers to the backend (Python
    interpret on CPU, compiled on TPU); ``block_rows=None`` uses the
    kernels' VMEM-sized default tile.

    ``cfg.beta`` is the shared default threshold; every method that touches
    Eq. (5) or the Eq. (3) coefficients accepts an optional per-worker
    override (``beta=`` a traced scalar for single-worker slabs, ``betas=``
    a ``(N,)`` vector for stacked/aggregate forms).

    ``block_rows``/``block_workers`` pin the kernel tiling of the batched
    uplink and the accumulating master; left as None they resolve per
    (shape, N, backend) through the ``repro.kernels.tune`` table. Tiling
    never changes results — the master accumulates workers in a fixed
    sequential order, so every plan is bitwise-identical.

    ``privacy`` switches the round onto the secure-aggregation / local-DP
    wire (``repro.privacy``): the uplink becomes masked fixed-point words
    (``ternary_pack_masked_2d``) and the master a sum-then-unmask launch
    (``masked_master_update_2d``) — still two launches, still zero host
    syncs, and the master never sees an individual worker's ternary
    directions. ``renorm_shares`` enables the renormalized-share variant
    of Eq. (3) under partial participation: the data shares p_k are
    renormalized over the sampled set (mirroring the C-fraction FedAvg
    fix) instead of keeping the paper's global shares.

    ``tree`` switches the aggregation onto a hierarchical fan-in tree
    (:class:`repro.core.tree.TreeSpec`): instead of the master folding all
    N uplinks in one launch, each level folds sibling groups of ``fanout``
    children into one partial with a fused sub-aggregate kernel, and the
    root runs the master update over the last level's w_L ≤ fanout
    partials — master VMEM and grid are O(fanout), not O(N), and a round
    costs ``levels + 2`` launches. De-bias (−ΣW_k) and fixed-point descale
    happen exactly ONCE, at the root, over the public global ΣW_k, so the
    tree is bitwise identical to the flat path (modular accumulation is
    order-free). On the masked wire, pairwise mask streams are scoped per
    sibling group (leaf masks cancel inside the level-1 partial; each
    interior node adds its own level-salted sibling-scoped mask in-kernel)
    so every tree edge still carries masked words. Without privacy the
    tree rides the unmasked integer wire at ``TREE_PLAIN_WORD_BITS`` /
    ``TREE_PLAIN_FIXPOINT_BITS`` — identical bits to the flat integer
    comparator; vs the float flat master it differs only by the
    fixed-point weight quantization.

    ``faults`` attaches a deterministic failure schedule
    (:class:`repro.fed.faults.FaultPlan`): each round realizes per-worker
    fault codes from the plan's counter stream and excludes faulted workers
    from pilot selection and the aggregate. On the plain wire faults simply
    fold into the Eq. (3) weights (survivors-only, exactly); on the masked
    wire the uplink was already committed when a post-uplink death is
    observed, so the dead rows are dropped from the modular sum, the root
    de-bias reweights by the surviving ΣW_k, and the survivors' uncancelled
    pairwise masks toward the dead are repaired in one fused
    ``mask_repair_2d`` launch from the recovered pair streams
    (``repro.privacy.recovery`` — requires ``privacy.recovery_threshold``).
    A sibling group left with fewer than ``recovery_threshold`` survivors
    degrades to an exact-zero subtree instead of aborting.
    """
    cfg: WireConfig = WireConfig()
    interpret: bool | None = None
    block_rows: int | None = None
    block_workers: int | None = None
    privacy: PrivacySpec | None = None
    renorm_shares: bool = False
    tree: TreeSpec | None = None
    faults: Any = None

    # -- elementwise protocol math (jnp semantics, traced round index) ------

    def codes(self, q: jax.Array, p1: jax.Array, p2: jax.Array,
              t, *, beta=None) -> jax.Array:
        """Eq. (4) at t <= 1 (``p1`` holds P^0), Eq. (5) after; int8 codes
        of ``q.shape``. Works on any slab/shape — it is elementwise.
        ``beta`` (scalar, may be traced) overrides the shared threshold."""
        beta = self.cfg.beta if beta is None else beta
        t1 = ternarize_round1(q, p1, self.cfg.alpha1)
        tt = ternarize(q, p1, p2, beta)
        return jnp.where(jnp.asarray(t) <= 1, t1, tt)

    def combine(self, q_pilot: jax.Array, coeff: jax.Array, p1: jax.Array,
                p2: jax.Array, t) -> jax.Array:
        """Eq. (3) given the aggregated ``coeff = Σ_k w_k T_k``: round 1
        steps by ``alpha0``, later rounds by the history step P^{t-1}-P^{t-2}."""
        step = (p1 - p2).astype(jnp.float32)
        r1 = q_pilot - self.cfg.alpha0 * coeff
        rt = q_pilot - coeff * step
        return jnp.where(jnp.asarray(t) <= 1, r1, rt)

    def weights(self, p_shares: jax.Array, k_star, t, *, betas=None,
                mask=None) -> jax.Array:
        """Masked per-worker Eq. (3) coefficients: p_k at round 1 (the
        alpha0 rule), p_k·beta_k after; the pilot's entry is zeroed.

        ``betas`` is an optional (N,) per-worker beta_k vector (defaults to
        the shared ``cfg.beta``); ``mask`` an optional (N,) participation
        mask — non-participants contribute exactly ±0.0 to the reduce, the
        same mechanism that already masks the pilot. By default shares are
        NOT renormalized over the sampled set: p_k = S_k/S stays the
        paper's global data share, so a round's update magnitude scales
        with how much data actually reported; with ``renorm_shares`` the
        shares are renormalized over the sampled workers (the C-fraction
        FedAvg convention), keeping the update magnitude constant across
        rounds regardless of who reported."""
        n = p_shares.shape[0]
        if self.renorm_shares and mask is not None:
            pm = p_shares.astype(jnp.float32) * jnp.asarray(mask,
                                                            jnp.float32)
            p_shares = pm / jnp.maximum(jnp.sum(pm), 1e-12)
        not_pilot = (jnp.arange(n) != k_star).astype(jnp.float32)
        if betas is None:
            scale = jnp.where(jnp.asarray(t) <= 1, 1.0, self.cfg.beta)
        else:
            betas = jnp.asarray(betas, jnp.float32)
            scale = jnp.where(jnp.asarray(t) <= 1, jnp.ones_like(betas),
                              betas)
        w = not_pilot * p_shares.astype(jnp.float32) * scale
        if mask is not None:
            w = w * jnp.asarray(mask, jnp.float32)
        return w

    # -- fused kernel path over (rows, 128) slabs ---------------------------

    def uplink(self, buf_q: jax.Array, buf_p1: jax.Array, buf_p2: jax.Array,
               *, t: int) -> jax.Array:
        """One worker's §3.3 wire buffer (static round): (rows, 128) →
        (rows//4, 128) uint8, one launch, no int8 intermediate."""
        return ops.flat_ternary_pack(
            buf_q, buf_p1, buf_p2, t=t, beta=self.cfg.beta,
            alpha1=self.cfg.alpha1, interpret=self.interpret,
            block_rows=self.block_rows)

    def uplink_traced(self, buf_q: jax.Array, buf_p1: jax.Array,
                      buf_p2: jax.Array, *, t, beta=None) -> jax.Array:
        """Like :meth:`uplink` but ``t`` (and an optional per-worker
        ``beta``) may be traced — the distributed sync's per-slab uplink."""
        beta = self.cfg.beta if beta is None else beta
        return ops.flat_ternary_pack_traced(
            buf_q, buf_p1, buf_p2, t=t, beta=beta,
            alpha1=self.cfg.alpha1, interpret=self.interpret,
            block_rows=self.block_rows)

    def uplink_stacked(self, bufs_q: jax.Array, buf_p1: jax.Array,
                       buf_p2: jax.Array, *, t, betas=None) -> jax.Array:
        """All N workers' wire buffers in ONE launch: (N, rows, 128) →
        (N, rows//4, 128) uint8 — the batched uplink (rows-major grid, the
        shared history block is fetched once per row block, not once per
        worker). ``betas`` is an optional (N,) per-worker beta_k vector."""
        beta = self.cfg.beta if betas is None else betas
        return ops.flat_ternary_pack_stacked(
            bufs_q, buf_p1, buf_p2, t=t, beta=beta,
            alpha1=self.cfg.alpha1, interpret=self.interpret,
            block_rows=self.block_rows, block_workers=self.block_workers)

    def master(self, buf_pilot: jax.Array, packed: jax.Array, w: jax.Array,
               buf_p1: jax.Array, buf_p2: jax.Array, *, t) -> jax.Array:
        """Fused Eq. (3) over packed wire codes: register-only 2-bit decode
        (w folded into the de-bias) grid-accumulated over the worker axis
        into the resident output block — one launch, VMEM independent of N.
        ``t`` may be traced."""
        return ops.flat_master_update(
            buf_pilot, packed, w, buf_p1, buf_p2, t=t,
            alpha0=self.cfg.alpha0, interpret=self.interpret,
            block_rows=self.block_rows, block_workers=self.block_workers)

    # -- secure-aggregation / local-DP wire (repro.privacy) -----------------

    def uplink_masked(self, bufs_q: jax.Array, buf_p1: jax.Array,
                      buf_p2: jax.Array, *, t, w: jax.Array, betas=None,
                      pmask=None) -> tuple[jax.Array, jax.Array]:
        """All N workers' masked secure-agg wire words in ONE launch.

        Derives the round's (N, N) pairwise stream-key and sign matrices
        (counter chains keyed by the — possibly traced — absolute round
        ``t``, participation folded into the signs) and the (N,) RR key
        vector, quantizes the public Eq. (3) weights ``w`` to fixed point,
        and runs the fused masked uplink: the mask/RR planes are generated
        INSIDE the kernel from those keys — codes exist only in kernel
        registers, no mask tensor ever exists in HBM, and what HBM sees is
        masked ``spec.word_dtype`` words. ``pmask`` is the public
        participation mask (pairs are active only between sampled
        workers). Returns ``(masked_words, wq)``.
        """
        spec = self.privacy
        n = bufs_q.shape[0]
        wq = pvm.quantize_weights(w, spec.fixpoint_bits)
        keys = pvm.pair_stream_keys(
            spec.mask_seed if spec.masking_on else 0, n, t)
        if self.tree is not None:
            # Leaf masks are scoped to sibling groups so they cancel inside
            # the level-1 partial, not only at the root.
            signs = pvm.tree_pair_signs(n, self.tree.fanout,
                                        participation=pmask)
        else:
            signs = pvm.pair_signs(n, participation=pmask)
        rrk = pdp.rr_stream_keys(spec.dp_seed, t, n)
        beta = self.cfg.beta if betas is None else betas
        y = ops.flat_ternary_pack_masked(
            bufs_q, buf_p1, buf_p2, t=t, beta=beta,
            alpha1=self.cfg.alpha1, wq=wq, pair_keys=keys,
            pair_signs=signs, rr_keys=rrk,
            rr_threshold=spec.rr_threshold,
            word_bits=spec.modulus_bits, use_masks=spec.masking_on,
            interpret=self.interpret, block_rows=self.block_rows,
            block_workers=self.block_workers)
        return y, wq

    def uplink_masked_slab(self, buf_q: jax.Array, buf_p1: jax.Array,
                           buf_p2: jax.Array, *, t, wq_own, keys_row,
                           signs_row, rr_key, beta=None) -> jax.Array:
        """One worker's masked wire words over a single (sr, 128) slab —
        the distributed per-instance form (the stacked kernel at N = 1).
        ``wq_own`` is this worker's fixed-point weight (traced scalar);
        ``keys_row``/``signs_row`` its (n_fed,) row of the pairwise
        key/sign matrices (``masking.pair_stream_keys_row`` at a traced
        worker index); ``rr_key`` its uint32 RR stream key. The mask/RR
        streams are generated inside the kernel. Returns (sr//4, 512) in
        ``spec.word_dtype``.
        """
        spec = self.privacy
        beta = self.cfg.beta if beta is None else beta
        y = ops.flat_ternary_pack_masked(
            buf_q[None], buf_p1, buf_p2, t=t, beta=beta,
            alpha1=self.cfg.alpha1, wq=jnp.reshape(wq_own, (1,)),
            pair_keys=jnp.reshape(keys_row, (1, -1)),
            pair_signs=jnp.reshape(signs_row, (1, -1)),
            rr_keys=jnp.reshape(rr_key, (1,)),
            rr_threshold=spec.rr_threshold,
            word_bits=spec.modulus_bits, use_masks=spec.masking_on,
            interpret=self.interpret, block_rows=self.block_rows,
            block_workers=self.block_workers)
        return y[0]

    def master_masked(self, buf_pilot: jax.Array, masked: jax.Array,
                      wq: jax.Array, buf_p1: jax.Array, buf_p2: jax.Array,
                      *, t) -> jax.Array:
        """Sum-then-unmask Eq. (3): modular sum of the masked words (masks
        cancel exactly), integer de-bias by the public ``sum_k W_k``,
        fixed-point descale with the RR unbias folded in, combine."""
        spec = self.privacy
        return ops.flat_masked_master_update(
            buf_pilot, masked, jnp.sum(wq), buf_p1, buf_p2, t=t,
            alpha0=self.cfg.alpha0, scale_mult=spec.scale_mult,
            interpret=self.interpret, block_rows=self.block_rows,
            block_workers=self.block_workers)

    def _tree_fold_masked(self, y: jax.Array, *, t, pmask=None) -> jax.Array:
        """Fold the N masked leaf uplinks level by level down to the last
        level's w_L partials — one fused sub-aggregate launch per level.

        Level l's nodes each sum their ``fanout`` children (whose
        sibling-scoped masks cancel in the modular sum) and add their OWN
        net mask from the level-salted stream
        (``tree_level_seed(mask_seed, l)``), scoped to level-l sibling
        groups — so the words crossing every tree edge stay masked, and all
        masks have cancelled exactly when the root sums the last level.
        ``pmask`` participation folds upward: a node is active iff any
        descendant leaf is, and masks only pair active nodes."""
        spec, ts = self.privacy, self.tree
        n = y.shape[0]
        widths = ts.level_widths(n)
        act = None if pmask is None else jnp.asarray(pmask, jnp.float32)
        cur = y
        for lvl in range(1, len(widths)):
            g = widths[lvl]
            sib = ts.sibling_size(lvl, n)
            if act is not None:
                act = pvm.tree_activity(act, ts.fanout)
            if spec.masking_on:
                keys = pvm.pair_stream_keys(
                    pvm.tree_level_seed(spec.mask_seed, lvl), g, t)
            else:
                keys = jnp.zeros((g, g), jnp.uint32)
            signs = pvm.tree_pair_signs(g, sib, participation=act)
            cur = ops.flat_masked_partial_sum(
                cur, keys, signs, fanout=ts.fanout, sibling=sib,
                use_masks=spec.masking_on, interpret=self.interpret,
                block_rows=self.block_rows,
                block_groups=self.block_workers)
        return cur

    def _tree_round_plain(self, bufs_q: jax.Array, k_star, w: jax.Array,
                          buf_p1: jax.Array, buf_p2: jax.Array, *, t,
                          betas=None) -> tuple[jax.Array, jax.Array]:
        """The no-privacy tree round: packed §3.3 leaves → fixed-point
        weighted level-1 partials → unmasked interior folds → one root
        sum-and-descale. Rides the integer wire (uint32 words, Eq. (3)
        weights quantized at ``TREE_PLAIN_FIXPOINT_BITS``) so the result is
        invariant to tree shape — bitwise equal to the flat integer path
        for every fanout, ragged groups included."""
        ts = self.tree
        n = bufs_q.shape[0]
        packed = self.uplink_stacked(bufs_q, buf_p1, buf_p2, t=t,
                                     betas=betas)
        wq = pvm.quantize_weights(w, TREE_PLAIN_FIXPOINT_BITS)
        cur = ops.flat_partial_sum(
            packed, wq, fanout=ts.fanout, word_bits=TREE_PLAIN_WORD_BITS,
            interpret=self.interpret, block_rows=self.block_rows,
            block_groups=self.block_workers)
        widths = ts.level_widths(n)
        for lvl in range(2, len(widths)):
            g = widths[lvl]
            sib = ts.sibling_size(lvl, n)
            cur = ops.flat_masked_partial_sum(
                cur, jnp.zeros((g, g), jnp.uint32),
                jnp.zeros((g, g), jnp.int32), fanout=ts.fanout,
                sibling=sib, use_masks=False, interpret=self.interpret,
                block_rows=self.block_rows,
                block_groups=self.block_workers)
        buf_pilot = jnp.take(bufs_q, k_star, axis=0)
        new_buf = ops.flat_masked_master_update(
            buf_pilot, cur, jnp.sum(wq), buf_p1, buf_p2, t=t,
            alpha0=self.cfg.alpha0,
            scale_mult=2.0 ** -TREE_PLAIN_FIXPOINT_BITS,
            interpret=self.interpret, block_rows=self.block_rows,
            block_workers=self.block_workers)
        return new_buf, packed

    def round_from_stacked(self, bufs_q: jax.Array, k_star, w: jax.Array,
                           buf_p1: jax.Array, buf_p2: jax.Array, *, t,
                           betas=None, pmask=None, alive=None
                           ) -> tuple[jax.Array, jax.Array]:
        """A full round over stacked worker buffers: batched uplink + fused
        master — exactly two kernel launches regardless of N.

        The pilot's row is packed like everyone else's and masked out of
        Eq. (3) by ``w[k_star] == 0`` (bitwise identical to zero-filling it:
        0·T contributes exactly ±0.0 to the reduce) — the same mechanism
        drops non-participating workers when ``w`` carries a mask.

        ``k_star`` may be traced: the pilot buffer is gathered with a
        dynamic index, no host sync. With an active :class:`PrivacySpec`
        the round takes the masked wire instead (same launch count; the
        wire buffer is uint32 masked words). ``pmask`` is the public
        participation mask, consumed only by the masked wire's pairwise
        mask derivation. ``alive`` is the post-fault (N,) survival mask of
        the privacy wire's dropout-recovery path: dead rows leave the
        modular sum, the de-bias reweights by the surviving ΣW_k and the
        residual masks are repaired at the root (see :class:`WirePath`
        docstring). Returns ``(new_global_buf, wire_buffer)`` — the wire
        buffers ride along for byte accounting / ledger purposes.
        """
        if self.privacy is not None and self.privacy.active:
            spec = self.privacy
            y, wq = self.uplink_masked(bufs_q, buf_p1, buf_p2, t=t, w=w,
                                       betas=betas, pmask=pmask)
            repair = None
            if alive is not None:
                if spec.recovery_threshold is None:
                    raise ValueError(
                        "fault injection on the privacy wire requires "
                        "privacy.recovery_threshold (the Shamir t of the "
                        "dropout-recovery dealing) to be set")
                n = bufs_q.shape[0]
                gsz = self.tree.fanout if self.tree is not None else None
                alive_eff, dead_eff = pvr.effective_masks(
                    pmask, alive, spec.recovery_threshold, gsz, n)
                # Post-uplink deaths: each dead row leaves the modular sum
                # (its weighted fields AND its own net mask), taking its
                # W_k out of the de-bias; what remains is the survivors'
                # uncancelled masks toward the dead, repaired below.
                y = jnp.where(alive_eff[:, None, None] > 0, y,
                              jnp.zeros_like(y))
                wq = jnp.where(alive_eff > 0, wq, jnp.zeros_like(wq))
                if spec.masking_on:
                    i_idx, j_idx = pvr.repair_pair_index(n, gsz)
                    keys = pvm.pair_stream_keys(spec.mask_seed, n, t)
                    if self.tree is not None:
                        signs = pvm.tree_pair_signs(n, self.tree.fanout,
                                                    participation=pmask)
                    else:
                        signs = pvm.pair_signs(n, participation=pmask)
                    repair = pvr.repair_coefficients(
                        keys, signs, alive_eff, dead_eff, i_idx, j_idx)
            if self.tree is not None:
                y_top = self._tree_fold_masked(y, t=t, pmask=pmask)
            else:
                y_top = y
            if repair is not None:
                # Modular sums commute, so leaf-level residue rides the
                # tree unchanged and ONE fused launch at the root repairs
                # every surviving-toward-dead stream. The repair lands in a
                # static row: even a zeroed dead row still participates in
                # the master's modular sum.
                y_top = y_top.at[0].set(ops.flat_mask_repair(
                    y_top[0], repair[0], repair[1],
                    interpret=self.interpret, block_rows=self.block_rows))
            buf_pilot = jnp.take(bufs_q, k_star, axis=0)
            new_buf = self.master_masked(buf_pilot, y_top, wq, buf_p1,
                                         buf_p2, t=t)
            return new_buf, y
        if self.tree is not None:
            return self._tree_round_plain(bufs_q, k_star, w, buf_p1,
                                          buf_p2, t=t, betas=betas)
        packed = self.uplink_stacked(bufs_q, buf_p1, buf_p2, t=t,
                                     betas=betas)
        buf_pilot = jnp.take(bufs_q, k_star, axis=0)
        new_buf = self.master(buf_pilot, packed, w, buf_p1, buf_p2, t=t)
        return new_buf, packed

    # -- the pure recurrence ------------------------------------------------

    def round_step(self, state: RoundState, bufs_q: jax.Array,
                   costs: jax.Array, sizes: jax.Array, *, betas=None,
                   mask=None) -> tuple[RoundState, jax.Array, dict]:
        """Algorithm 1, one full round, as a pure traced function.

        ``state`` — inter-round carry; ``bufs_q`` (N, rows, 128) — every
        worker's flattened local model; ``costs``/``sizes`` (N,). Optional
        ``betas`` (N,) per-worker beta_k and ``mask`` (N,) participation
        (non-participants: excluded from pilot selection, zero Eq. (3)
        weight, previous cost carried forward — their ``bufs_q`` row may be
        anything, conventionally the current global buffer).

        With a :class:`~repro.fed.faults.FaultPlan` attached, the round
        additionally realizes its per-worker fault codes from
        ``state.round`` (so ``scan_rounds`` needs no extra operand) and
        excludes faulted workers exactly like non-participants — on the
        masked wire via the post-uplink dropout-recovery path.

        Returns ``(state', new_global_buf, info)`` with ``info`` holding the
        on-device round records (``k_star``, ``goodness``, ``costs``, plus
        ``alive`` when faults are active) that a driver fetches ONCE after
        all rounds to backfill ledger and pilot history. Exactly two kernel
        launches (plus one repair launch on post-fault masked rounds); zero
        host syncs.
        """
        t = state.round
        sizes = jnp.asarray(sizes, jnp.float32)
        costs = jnp.asarray(costs, jnp.float32)
        n = sizes.shape[0]
        av = codes = dead_eff = None
        masked_wire = self.privacy is not None and self.privacy.active
        if self.faults is not None and self.faults.active:
            codes = self.faults.codes(t, n)
            av = (codes == tmr.FAULT_NONE).astype(jnp.float32)
        if av is None:
            sel_mask = mask
        elif masked_wire:
            # A sibling group below the recovery threshold degrades to an
            # exact-zero subtree, so its SURVIVORS contribute nothing
            # either — the master (which knows the fault set and the
            # public threshold) excludes them from pilot selection and the
            # cost carry exactly like the dead.
            if self.privacy.recovery_threshold is None:
                raise ValueError(
                    "fault injection on the privacy wire requires "
                    "privacy.recovery_threshold (the Shamir t of the "
                    "dropout-recovery dealing) to be set")
            sel_mask, dead_eff = pvr.effective_masks(
                mask, av, self.privacy.recovery_threshold,
                self.tree.fanout if self.tree is not None else None,
                n)
        elif mask is None:
            sel_mask = av
        else:
            sel_mask = jnp.asarray(mask, jnp.float32) * av
        k_star, scores = select_pilot(costs, state.prev_costs, sizes, t,
                                      sel_mask)
        p_shares = sizes / jnp.sum(sizes)
        # The masked wire commits Eq. (3) weights BEFORE faults realize —
        # the uplink is already on the wire when a post-uplink death is
        # observed — so dead rows are excluded downstream and the de-bias
        # reweights by the surviving ΣW_k. The plain wire has no such
        # commitment: faults fold straight into the weights, which IS the
        # survivors-only aggregate.
        w_mask = mask if masked_wire else sel_mask
        w = self.weights(p_shares, k_star, t, betas=betas, mask=w_mask)
        new_buf, _wire = self.round_from_stacked(
            bufs_q, k_star, w, state.buf_p1, state.buf_p2, t=t, betas=betas,
            pmask=mask, alive=(av if masked_wire else None))
        if sel_mask is None:
            costs_eff = costs
        else:   # non-participants / faulted workers did not report a cost
            costs_eff = jnp.where(jnp.asarray(sel_mask) > 0, costs,
                                  state.prev_costs)
        accountant = state.accountant
        if (accountant is not None and self.privacy is not None
                and self.privacy.dp_on):
            accountant = accountant.add(self.privacy.eps_round)
        # The round's device-resident telemetry record: jnp reductions over
        # operands computed above — no extra launches, no host syncs. The
        # record rides info (stacked by the scan for the one post-run
        # fetch); the cumulative carry rides the state like the accountant.
        rec = tmr.build_round_record(
            t=t, k_star=k_star, n=n, costs=costs, sizes=sizes, mask=mask,
            codes=codes, sel_mask=sel_mask, dead_eff=dead_eff,
            modulus_bits=self.privacy.modulus_bits if masked_wire else 0,
            fanout=self.tree.fanout if self.tree is not None else 0,
            levels=(self.tree.n_levels(n) if self.tree is not None else 0))
        telemetry = state.telemetry
        if telemetry is not None:
            telemetry = telemetry.add(rec)
        new_state = RoundState(buf_p1=new_buf, buf_p2=state.buf_p1,
                               prev_costs=costs_eff, round=t + 1,
                               accountant=accountant, telemetry=telemetry)
        info = {"k_star": k_star, "goodness": scores, "costs": costs_eff,
                "telemetry": rec}
        if mask is not None:
            info["mask"] = jnp.asarray(mask, jnp.float32)
        if av is not None:
            info["alive"] = av
        return new_state, new_buf, info


WorkerFn = Callable[[Any, jax.Array, jax.Array],
                    tuple[Any, jax.Array, jax.Array]]


def scan_rounds(wire: WirePath, state: RoundState, worker_fn: WorkerFn,
                worker_carry: Any, n_rounds: int, sizes: jax.Array, *,
                betas=None, masks=None, participation: float | None = None,
                participation_key: jax.Array | None = None
                ) -> tuple[RoundState, Any, dict]:
    """Many rounds of Algorithm 1 as ONE ``lax.scan`` over ``round_step``.

    ``worker_fn(worker_carry, global_buf, t) -> (worker_carry, bufs_q,
    costs)`` produces the round's local models — it is traced into the scan
    body, so it must be pure (private optimizer states etc. live in
    ``worker_carry``). ``masks`` is an optional (n_rounds, N) participation
    schedule (see :func:`participation_masks`); ``betas`` an optional (N,)
    per-worker beta_k vector.

    Alternatively the participation mask can be sampled INSIDE the scan
    body — pass ``participation`` (the C fraction) and a
    ``participation_key``: each round draws
    ``participation_mask(fold_in(key, t), N, C)`` with the ABSOLUTE round
    index ``t`` from the carry, so no (n_rounds, N) host-side schedule is
    materialized (cross-device scale) and a resumed run draws exactly the
    rows an uninterrupted run would — bit-identical to the precomputed
    :func:`participation_masks` schedule from the same key. The sampled
    masks come back in ``infos["mask"]`` for ledger backfill.

    The scan body costs exactly two kernel launches and performs zero
    device→host transfers; ``infos`` comes back with per-round stacked
    ``k_star`` / ``goodness`` / ``costs`` for one post-scan fetch. XLA
    double-buffers the carry, so the history buffers are reused in place
    across rounds (jit the caller with ``donate_argnums`` on ``state`` to
    extend that to the initial buffers).
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    n_workers = sizes.shape[0]
    if participation is not None:
        if masks is not None:
            raise ValueError("pass a precomputed mask schedule OR in-scan "
                             "participation sampling, not both")
        if participation_key is None:
            raise ValueError("in-scan participation sampling needs a "
                             "participation_key")
        if not 0.0 < participation <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {participation}")

    def body(carry, x):
        st, wc = carry
        mask = x
        if participation is not None:
            mask = participation_mask(
                jax.random.fold_in(participation_key, st.round),
                n_workers, participation)
        wc, bufs_q, costs = worker_fn(wc, st.buf_p1, st.round)
        st, _new_buf, info = wire.round_step(st, bufs_q, costs, sizes,
                                             betas=betas, mask=mask)
        return (st, wc), info

    (state, worker_carry), infos = jax.lax.scan(
        body, (state, worker_carry), masks, length=n_rounds)
    return state, worker_carry, infos


class RoundEngine:
    """Carries the public history across rounds and drives :class:`WirePath`.

    The per-round drivers' protocol work reduces to::

        bufs_q = engine.flatten_locals(locals_)           # stack worker trees
        new_params = engine.run_round(bufs_q, k_star, p_shares, t)

    which is two kernel launches + one unflatten. The history rotation
    (P^{t-1}, P^{t-2}) ← (P^t, P^{t-1}) happens inside ``run_round``. This
    is a thin stateful wrapper over the pure core — jit-able multi-round
    drivers should carry a :class:`RoundState` through
    :meth:`WirePath.round_step` / :func:`scan_rounds` instead.
    """

    def __init__(self, init_params: PyTree, cfg: WireConfig | None = None,
                 *, shards: int = 1, interpret: bool | None = None,
                 block_rows: int | None = None,
                 block_workers: int | None = None):
        self.layout = fl.layout_of(init_params, shards=shards)
        self.wire = WirePath(cfg or WireConfig(), interpret=interpret,
                             block_rows=block_rows,
                             block_workers=block_workers)
        self.buf_p1 = fl.flatten_tree(init_params, self.layout)   # P^{t-1}
        self.buf_p2 = jnp.zeros_like(self.buf_p1)                 # P^{t-2}

    def flatten_locals(self, locals_: list[PyTree]) -> jax.Array:
        """Stack N worker pytrees into the (N, rows, 128) uplink input."""
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *locals_)
        return fl.flatten_stacked(stacked, self.layout)

    def run_round(self, bufs_q: jax.Array, k_star, p_shares: jax.Array,
                  t, *, betas=None, mask=None) -> PyTree:
        """Alg. 1 lines 5-8 for one round; returns the new global pytree and
        advances the engine's history. ``k_star`` may be traced."""
        w = self.wire.weights(p_shares, k_star, t, betas=betas, mask=mask)
        new_buf, _packed = self.wire.round_from_stacked(
            bufs_q, k_star, w, self.buf_p1, self.buf_p2, t=t, betas=betas)
        self.buf_p1, self.buf_p2 = new_buf, self.buf_p1
        return fl.unflatten_tree(new_buf, self.layout)
