"""FedPC on the TPU mesh: fed workers = slices of a mesh axis.

Mapping (DESIGN.md §2): each federated worker owns one index of the fed
mesh axis ('data' on a single pod → up to 16 workers; 'pod' across pods).
Within a worker slice the model is tensor-sharded over 'model' (kept as an
*auto* axis — XLA SPMD handles it; only the fed axis is manual).

The round sync flattens the whole model pytree into ONE padded
``FlatParams`` buffer (``repro.core.flat``) and runs a single ``shard_map``
over it, so the wire format is explicit in the HLO and there is exactly one
collective per round regardless of the number of leaves:

  fedpc:        all_gather(int8 ternary)           — faithful Eq. (3)-(5)
  fedpc_packed: all_gather(uint8 2-bit codes)      — beyond-paper: the
                paper packs for TCP; we pack *before the collective* so ICI
                moves 4× fewer bytes than int8 (16× fewer than fp32)
  fedavg:       psum(weighted params)              — baseline all-reduce

Pilot weights travel as a masked psum over the fed axis (the mesh analogue
of the star-topology upload+broadcast; see EXPERIMENTS.md for the honest
star-vs-all-reduce byte comparison).

Every shard_map instance runs the *same* master math on public inputs, so
the update stays consistent without a physical master — the master of the
paper is replicated control flow here.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import flat as fl
from repro.core.goodness import select_pilot as _select_pilot
from repro.core.packing import pack2bit, unpack2bit
from repro.core.ternary import ternarize, ternarize_round1
from repro.models.model import Model
from repro.utils import PyTree

from repro.sharding.specs import param_specs


def _shard_map(body, mesh, in_specs, out_specs, manual_axes):
    """Version-portable shard_map (jax≥0.5 `jax.shard_map` vs 0.4 API)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=frozenset(manual_axes),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    # 0.4's `auto` lowering chokes on axis_index under SPMD; the flat wire
    # buffers are replicated over every non-fed axis anyway, so running the
    # other axes manually too is equivalent.
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# ---------------------------------------------------------------------------
# Sync strategies (shard_map bodies over the fed axis, on the flat buffer)
# ---------------------------------------------------------------------------

def _sync_fedpc_flat(q_buf, p_prev, p_prev2, *, k_star, w, t, alpha0, beta,
                     alpha1, axis, mode):
    """One worker's slice of the round sync, entirely on flat vectors.

    q_buf: (1, n_pad) this worker's flattened weights; p_prev/p_prev2:
    (n_pad,) replicated flattened history. Returns the (n_pad,) new global
    flat model (identical on every instance).
    """
    idx = jax.lax.axis_index(axis)
    q = q_buf[0]
    # Eq. (4) at t == 1, Eq. (5) after — elementwise on the flat buffer.
    tern = jnp.where(t <= 1,
                     ternarize_round1(q, p_prev, alpha1),
                     ternarize(q, p_prev, p_prev2, beta))
    # pilot upload+broadcast == masked all-reduce over the fed axis
    q_pilot = jax.lax.psum(jnp.where(idx == k_star, q, 0.0), axis)
    wf = w.astype(jnp.float32)                        # (F,) masked p_k*beta_k

    if mode == "reduce":
        # Beyond-paper: Eq. (3) needs only Σ_k w_k T_k — reduce in-network
        # instead of gathering N ternary vectors. On an all-reduce fabric
        # this caps the sync at one f16 all-reduce regardless of N (the
        # gather grows linearly with N); every instance ends with the same
        # sum so the replicated-master math is unchanged.
        w_me = jnp.take(wf, idx)
        # f16 on the wire (bf16 triggers an XLA-CPU AllReducePromotion
        # crash in this container; on TPU use bf16 — same byte count)
        contrib = (w_me * tern.astype(jnp.float32)).astype(jnp.float16)
        coeff = jax.lax.psum(contrib, axis).astype(jnp.float32)
    elif mode == "packed":
        pk = pack2bit(tern)                               # uint8 on the wire
        pk_all = jax.lax.all_gather(pk, axis)             # (F, bytes)
        tern_all = jax.vmap(lambda b: unpack2bit(b, tern.shape[0]))(pk_all)
        coeff = jnp.tensordot(wf, tern_all.astype(jnp.float32), axes=1)
    else:
        tern_all = jax.lax.all_gather(tern, axis)         # (F, n_pad) int8
        coeff = jnp.tensordot(wf, tern_all.astype(jnp.float32), axes=1)

    step = (p_prev - p_prev2).astype(jnp.float32)
    r1 = q_pilot - alpha0 * coeff
    rt = q_pilot - coeff * step
    return jnp.where(t <= 1, r1, rt)


def build_fed_sync(model: Model, mesh: Mesh, fed_axis: str = "data",
                   strategy: str = "fedpc", alpha0: float = 0.01,
                   beta: float = 0.2, alpha1: float = 0.01) -> Callable:
    """Returns sync(params_F, state) -> (new_global_params, aux).

    params_F leaves are stacked (F, ...) over the fed axis; state carries
    the public history (params, params_prev — replicated) plus per-round
    costs (F,) and the 1-based round index.
    """
    F = mesh.shape[fed_axis]

    def sync(params_F: PyTree, costs: jax.Array, sizes: jax.Array,
             state: dict) -> tuple[PyTree, dict]:
        t = state["round"]
        k_star, scores = _select_pilot(costs, state["prev_costs"], sizes, t)
        p_shares = sizes.astype(jnp.float32) / jnp.sum(sizes)

        if strategy == "fedavg":
            def avg(x):
                wb = p_shares.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.sum(x.astype(jnp.float32) * wb, axis=0).astype(x.dtype)
            new_params = jax.tree_util.tree_map(avg, params_F)
        else:
            mask = (jnp.arange(F) != k_star).astype(jnp.float32)
            # Eq. (3): round 1 weighs workers by p_k alone (the alpha0 rule),
            # later rounds by p_k * beta_k — matching core.update and the
            # simulator ( `t` may be traced, hence the where).
            w = mask * p_shares * jnp.where(jnp.asarray(t) <= 1, 1.0, beta)

            # Flat wire path: the whole pytree becomes one padded buffer per
            # worker, so the sync is a single shard_map over flat vectors —
            # one collective per round, not one per leaf.
            layout = fl.layout_of(state["params"])
            q_flat_F = fl.flatten_stacked(params_F, layout).reshape(
                F, layout.padded)
            p1_flat = fl.flatten_tree(state["params"], layout).reshape(-1)
            p2_flat = fl.flatten_tree(state["params_prev"], layout).reshape(-1)

            body = partial(
                _sync_fedpc_flat, k_star=k_star, w=w, t=t, alpha0=alpha0,
                beta=beta, alpha1=alpha1, axis=fed_axis,
                mode={"fedpc_packed": "packed",
                      "fedpc_reduce": "reduce"}.get(strategy, "gather"))

            new_flat = _shard_map(
                body, mesh,
                in_specs=(P(fed_axis), P(), P()),
                out_specs=P(),
                manual_axes={fed_axis},
            )(q_flat_F, p1_flat, p2_flat)
            new_params = fl.unflatten_tree(
                new_flat.reshape(layout.rows, fl.LANES), layout)

        new_state = {
            "params": new_params,
            "params_prev": state["params"],
            "prev_costs": costs.astype(jnp.float32),
            "round": t + 1,
        }
        aux = {"k_star": k_star, "goodness": scores}
        return new_params, {"state": new_state, **aux}

    return sync


# ---------------------------------------------------------------------------
# Full federated step: local training (vmap over fed axis) + sync
# ---------------------------------------------------------------------------

def build_fed_step(model: Model, mesh: Mesh, fed_axis: str = "data",
                   strategy: str = "fedpc", local_steps: int = 1,
                   lr: float = 0.01) -> Callable:
    """fed_step(state, opt_states_F, batch_F, sizes) ->
       (state', opt_states_F', metrics)

    batch_F: pytree with leaves (F, local_steps, B_local, ...) — each fed
    worker's private micro-batches for this round. Worker k trains
    ``local_steps`` steps from the shared global params (its private
    optimizer state persists), reports its final loss as the round cost.
    """
    sync = build_fed_sync(model, mesh, fed_axis, strategy)

    def local_train(params, opt_state, batches):
        def step(carry, b):
            p, os = carry
            p, os, m = model.train_step(p, os, b, lr)
            return (p, os), m["loss"]
        (p, os), losses = jax.lax.scan(step, (params, opt_state), batches)
        return p, os, losses[-1]

    def fed_step(state: dict, opt_states_F: PyTree, batch_F: PyTree,
                 sizes: jax.Array):
        params_F, opt_F, costs = jax.vmap(
            local_train, in_axes=(None, 0, 0))(
                state["params"], opt_states_F, batch_F)
        new_params, aux = sync(params_F, costs, sizes, state)
        metrics = {"cost_mean": jnp.mean(costs), "k_star": aux["k_star"]}
        return aux["state"], opt_F, metrics

    return fed_step


def fed_state_init(params: PyTree, n_fed: int) -> dict:
    return {
        "params": params,
        "params_prev": jax.tree_util.tree_map(jnp.zeros_like, params),
        "prev_costs": jnp.full((n_fed,), jnp.inf, jnp.float32),
        "round": jnp.asarray(1, jnp.int32),
    }


def fed_shardings(model: Model, mesh: Mesh, fed_axis: str,
                  params: PyTree) -> dict:
    """NamedShardings for the fed-step arguments."""
    pspecs = param_specs(params, mesh)

    def prepend_fed(spec: P) -> P:
        return P(fed_axis, *spec)

    stacked = jax.tree_util.tree_map(prepend_fed, pspecs)
    return {
        "params": jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs),
        "params_F": jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), stacked),
    }
