"""FedPC on the TPU mesh: fed workers = slices of a mesh axis.

Mapping (DESIGN.md §2): each federated worker owns one index of the fed
mesh axis ('data' on a single pod → up to 16 workers; 'pod' across pods).
Within a worker slice the model is tensor-sharded over 'model' (kept as an
*auto* axis — XLA SPMD handles it; only the fed axis is manual).

The round sync is a ``shard_map`` over the fed axis so the wire format is
explicit in the HLO:

  fedpc:        all_gather(int8 ternary)           — faithful Eq. (3)-(5)
  fedpc_packed: all_gather(uint8 2-bit codes)      — beyond-paper: the
                paper packs for TCP; we pack *before the collective* so ICI
                moves 4× fewer bytes than int8 (16× fewer than fp32)
  fedavg:       psum(weighted params)              — baseline all-reduce

Pilot weights travel as a masked psum over the fed axis (the mesh analogue
of the star-topology upload+broadcast; see EXPERIMENTS.md for the honest
star-vs-all-reduce byte comparison).

Every shard_map instance runs the *same* master math on public inputs, so
the update stays consistent without a physical master — the master of the
paper is replicated control flow here.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.goodness import select_pilot as _select_pilot
from repro.core.packing import pack2bit, unpack2bit
from repro.core.ternary import ternarize, ternarize_round1
from repro.models.model import Model
from repro.utils import PyTree

from repro.sharding.specs import param_specs


# ---------------------------------------------------------------------------
# Sync strategies (shard_map bodies over the fed axis)
# ---------------------------------------------------------------------------

def _eq3_leaf(q_local, tern_all, w, k_star, p_prev, p_prev2, t, alpha0,
              axis: str):
    """Per-leaf Eq. (3) with fed-axis collectives.

    q_local: (1, *shape) this worker's weights; tern_all: (F, *shape) int8.
    """
    idx = jax.lax.axis_index(axis)
    # pilot upload+broadcast == masked all-reduce over the fed axis
    q_pilot = jax.lax.psum(
        jnp.where(idx == k_star, q_local[0].astype(jnp.float32), 0.0),
        axis)
    wf = w.astype(jnp.float32)                        # (F,) masked p_k*beta_k
    coeff = jnp.tensordot(wf, tern_all.astype(jnp.float32), axes=1)
    step = (p_prev - p_prev2).astype(jnp.float32)
    r1 = q_pilot - alpha0 * coeff
    rt = q_pilot - coeff * step
    return jnp.where(t <= 1, r1, rt).astype(q_local.dtype)


def _ternary_leaf(q_local, p_prev, p_prev2, t, beta, alpha1):
    t1 = ternarize_round1(q_local[0], p_prev, alpha1)
    tt = ternarize(q_local[0], p_prev, p_prev2, beta)
    return jnp.where(t <= 1, t1, tt)


def _sync_fedpc_body(q_leaf, p_prev_leaf, p_prev2_leaf, *, k_star, w, t,
                     alpha0, beta, alpha1, axis, mode):
    tern = _ternary_leaf(q_leaf, p_prev_leaf, p_prev2_leaf, t, beta, alpha1)
    if mode == "reduce":
        # Beyond-paper: Eq. (3) needs only Σ_k w_k T_k — reduce in-network
        # instead of gathering N ternary vectors. On an all-reduce fabric
        # this caps the sync at one bf16 all-reduce regardless of N (the
        # gather grows linearly with N); every instance ends with the same
        # sum so the replicated-master math is unchanged.
        idx = jax.lax.axis_index(axis)
        w_me = jnp.take(w, idx).astype(jnp.float32)
        # f16 on the wire (bf16 triggers an XLA-CPU AllReducePromotion
        # crash in this container; on TPU use bf16 — same byte count)
        contrib = (w_me * tern.astype(jnp.float32)).astype(jnp.float16)
        coeff = jax.lax.psum(contrib, axis).astype(jnp.float32)
        step = (p_prev_leaf - p_prev2_leaf).astype(jnp.float32)
        q_pilot = jax.lax.psum(
            jnp.where(idx == k_star, q_leaf[0].astype(jnp.float32), 0.0),
            axis)
        r1 = q_pilot - alpha0 * coeff
        rt = q_pilot - coeff * step
        return jnp.where(t <= 1, r1, rt).astype(q_leaf.dtype)
    if mode == "packed":
        flat = tern.reshape(-1)
        pk = pack2bit(flat)                               # uint8 on the wire
        pk_all = jax.lax.all_gather(pk, axis)             # (F, bytes)
        tern_all = jax.vmap(lambda b: unpack2bit(b, flat.shape[0]))(pk_all)
        tern_all = tern_all.reshape((-1,) + tern.shape)
    else:
        tern_all = jax.lax.all_gather(tern, axis)         # (F, *shape) int8
    return _eq3_leaf(q_leaf, tern_all, w, k_star, p_prev_leaf, p_prev2_leaf,
                     t, alpha0, axis)


def build_fed_sync(model: Model, mesh: Mesh, fed_axis: str = "data",
                   strategy: str = "fedpc", alpha0: float = 0.01,
                   beta: float = 0.2, alpha1: float = 0.01) -> Callable:
    """Returns sync(params_F, state) -> (new_global_params, aux).

    params_F leaves are stacked (F, ...) over the fed axis; state carries
    the public history (params, params_prev — replicated) plus per-round
    costs (F,) and the 1-based round index.
    """
    F = mesh.shape[fed_axis]

    def sync(params_F: PyTree, costs: jax.Array, sizes: jax.Array,
             state: dict) -> tuple[PyTree, dict]:
        t = state["round"]
        k_star, scores = _select_pilot(costs, state["prev_costs"], sizes, t)
        p_shares = sizes.astype(jnp.float32) / jnp.sum(sizes)

        if strategy == "fedavg":
            def avg(x):
                wb = p_shares.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.sum(x.astype(jnp.float32) * wb, axis=0).astype(x.dtype)
            new_params = jax.tree_util.tree_map(avg, params_F)
        else:
            mask = (jnp.arange(F) != k_star).astype(jnp.float32)
            w = mask * p_shares * beta

            # fed axis is the stacked leading dim; model axes stay auto.
            in_q = jax.tree_util.tree_map(lambda _: P(fed_axis), params_F)
            in_rep = jax.tree_util.tree_map(lambda _: P(), state["params"])
            out = jax.tree_util.tree_map(lambda _: P(), state["params"])

            body = partial(
                _sync_fedpc_body, k_star=k_star, w=w, t=t, alpha0=alpha0,
                beta=beta, alpha1=alpha1, axis=fed_axis,
                mode={"fedpc_packed": "packed",
                      "fedpc_reduce": "reduce"}.get(strategy, "gather"))

            def tree_body(q, p1, p2):
                return jax.tree_util.tree_map(body, q, p1, p2)

            new_params = jax.shard_map(
                tree_body,
                mesh=mesh,
                in_specs=(in_q, in_rep, in_rep),
                out_specs=out,
                axis_names=frozenset({fed_axis}),
                check_vma=False,
            )(params_F, state["params"], state["params_prev"])

        new_state = {
            "params": new_params,
            "params_prev": state["params"],
            "prev_costs": costs.astype(jnp.float32),
            "round": t + 1,
        }
        aux = {"k_star": k_star, "goodness": scores}
        return new_params, {"state": new_state, **aux}

    return sync


# ---------------------------------------------------------------------------
# Full federated step: local training (vmap over fed axis) + sync
# ---------------------------------------------------------------------------

def build_fed_step(model: Model, mesh: Mesh, fed_axis: str = "data",
                   strategy: str = "fedpc", local_steps: int = 1,
                   lr: float = 0.01) -> Callable:
    """fed_step(state, opt_states_F, batch_F, sizes) ->
       (state', opt_states_F', metrics)

    batch_F: pytree with leaves (F, local_steps, B_local, ...) — each fed
    worker's private micro-batches for this round. Worker k trains
    ``local_steps`` steps from the shared global params (its private
    optimizer state persists), reports its final loss as the round cost.
    """
    sync = build_fed_sync(model, mesh, fed_axis, strategy)

    def local_train(params, opt_state, batches):
        def step(carry, b):
            p, os = carry
            p, os, m = model.train_step(p, os, b, lr)
            return (p, os), m["loss"]
        (p, os), losses = jax.lax.scan(step, (params, opt_state), batches)
        return p, os, losses[-1]

    def fed_step(state: dict, opt_states_F: PyTree, batch_F: PyTree,
                 sizes: jax.Array):
        params_F, opt_F, costs = jax.vmap(
            local_train, in_axes=(None, 0, 0))(
                state["params"], opt_states_F, batch_F)
        new_params, aux = sync(params_F, costs, sizes, state)
        metrics = {"cost_mean": jnp.mean(costs), "k_star": aux["k_star"]}
        return aux["state"], opt_F, metrics

    return fed_step


def fed_state_init(params: PyTree, n_fed: int) -> dict:
    return {
        "params": params,
        "params_prev": jax.tree_util.tree_map(jnp.zeros_like, params),
        "prev_costs": jnp.full((n_fed,), jnp.inf, jnp.float32),
        "round": jnp.asarray(1, jnp.int32),
    }


def fed_shardings(model: Model, mesh: Mesh, fed_axis: str,
                  params: PyTree) -> dict:
    """NamedShardings for the fed-step arguments."""
    pspecs = param_specs(params, mesh)

    def prepend_fed(spec: P) -> P:
        return P(fed_axis, *spec)

    stacked = jax.tree_util.tree_map(prepend_fed, pspecs)
    return {
        "params": jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs),
        "params_F": jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), stacked),
    }
