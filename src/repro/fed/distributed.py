"""FedPC on the TPU mesh: fed workers = slices of a mesh axis.

Mapping (DESIGN.md §2): each federated worker owns one index of the fed
mesh axis ('data' on a single pod → up to 16 workers; 'pod' across pods).
Within a worker slice the model is tensor-sharded over 'model'.

The round sync flattens the whole model pytree into ONE padded
``FlatParams`` buffer (``repro.core.flat``) and runs a single 2-D
``shard_map`` over (fed, model): the buffer's rows are *sharded over the
model axis* (``layout_of(..., shards=M)``), so every device owns a
``(rows/M, 128)`` slab, runs the fused wire kernels on that slab only, and
the fed-axis collectives move ``1/M`` of the buffer per device instead of a
replicated copy. The protocol math itself lives in ``repro.fed.rounds``
(:class:`~repro.fed.rounds.WirePath`) — shared verbatim with the simulator —
and this module only decides which bytes move between its steps:

  fedpc:        all_gather(int8 ternary)           — faithful Eq. (3)-(5)
  fedpc_packed: all_gather(uint8 2-bit codes)      — beyond-paper: the
                paper packs for TCP; we pack *before the collective* so ICI
                moves 4× fewer bytes than int8 (16× fewer than fp32)
  fedpc_reduce: psum_scatter + all_gather(f16 Σ w_k T_k) — Eq. (3) needs
                only the weighted sum; the RS+AG pair is the bandwidth-
                optimal all-reduce and caps the payload regardless of N
  fedavg:       psum(weighted params)              — baseline all-reduce

Pilot weights travel as a masked psum over the fed axis (the mesh analogue
of the star-topology upload+broadcast; see EXPERIMENTS.md for the honest
star-vs-all-reduce byte comparison).

Every (fed) shard_map instance runs the *same* master math on public
inputs, so the update stays consistent without a physical master — the
master of the paper is replicated control flow here (replicated over fed,
sharded over model).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import flat as fl
from repro.core.goodness import select_pilot as _select_pilot
from repro.core.tree import TreeSpec
from repro.fed import rounds as rd
from repro.kernels import ops
from repro.models.model import Model
from repro.privacy import audit as pv_audit
from repro.privacy import dp as pdp
from repro.privacy import masking as pvm
from repro.privacy import recovery as pvr
from repro.privacy.spec import PrivacySpec
from repro.telemetry import record as tmr
from repro.utils import PyTree

from repro.sharding.specs import param_specs, wire_specs


def _shard_map(body, mesh, in_specs, out_specs, manual_axes):
    """Version-portable shard_map (jax≥0.5 `jax.shard_map` vs 0.4 API)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=frozenset(manual_axes),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    # 0.4's `auto` lowering chokes on axis_index under SPMD; the flat wire
    # buffers are replicated over every non-fed axis anyway, so running the
    # other axes manually too is equivalent.
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# ---------------------------------------------------------------------------
# Sync strategies (shard_map bodies over (fed, model), on flat buffer slabs)
# ---------------------------------------------------------------------------

def _tree_butterfly_reduce(y, *, spec, tree, idx, t, fed_axis, n_fed,
                           m_idx, pmask):
    """Tree-shaped masked all-reduce as XOR recursive doubling.

    Level l folds aligned sibling groups of ``fanout`` nodes with
    ``fanout``-spanning ppermute hops (group masks cancel in the modular
    sum — leaf signs are sibling-scoped), then only the group
    representatives (``idx % fanout**l == 0``) carry on: each adds its OWN
    level-salted sibling-scoped node mask (``net_mask_slab`` over the
    ``tree_level_seed`` stream) and every non-representative zeroes out, so
    the words on every subsequent hop stay masked and nothing is
    double-counted. After the last level a butterfly over the w_L
    representatives completes the root sum (their level-L masks cancel
    there), and an additive down-broadcast returns the identical public
    masked total to every instance — at each hop exactly one endpoint is
    nonzero, so addition is a broadcast. Modular addition is order-free:
    the result is bitwise equal to the flat psum of the flat-signed wire.

    Per level the number of information-bearing payloads drops fanout×
    (non-representatives ship all-zero slabs — SPMD cannot skip a
    permute, a physical tree runtime simply would not send them).
    """
    f = tree.fanout
    L = tree.n_levels(n_fed)
    seed = spec.mask_seed if spec.masking_on else 0
    act = None if pmask is None else jnp.asarray(pmask, jnp.float32)
    contrib = y

    def hop(x, d):
        perm = [(i, i ^ d) for i in range(n_fed)]
        return x + jax.lax.ppermute(x, fed_axis, perm=perm)

    for lvl in range(1, L + 1):
        for d in (f ** (lvl - 1) * (1 << k) for k in range(f.bit_length() - 1)):
            contrib = hop(contrib, d)
        if act is not None:
            act = pvm.tree_activity(act, f)
        stride = f ** lvl
        node = idx // stride
        w_l = n_fed // stride
        sib_l = f if lvl < L else w_l
        if spec.masking_on and w_l >= 2:
            slab = pvm.net_mask_slab(
                pvm.tree_level_seed(seed, lvl), node, w_l, t, y.shape,
                m_idx, word_bits=spec.modulus_bits,
                signs_row=pvm.tree_pair_signs_row(node, w_l, sib_l,
                                                  participation=act))
            contrib = contrib + slab
        is_rep = (idx % stride) == 0
        contrib = jnp.where(is_rep, contrib, jnp.zeros_like(contrib))
    d = f ** L
    while d < n_fed:            # root: fold the w_L last-level partials
        contrib = hop(contrib, d)
        d *= 2
    d = 1
    while d < f ** L:           # down-broadcast the public masked total
        contrib = hop(contrib, d)
        d *= 2
    return contrib


def _sync_body(q_buf, p_prev, p_prev2, *, wire: rd.WirePath, k_star, w,
               t, fed_axis, n_fed, mode, betas=None, model_axis=None,
               pmask=None, tree=None, alive=None):
    """One (fed, model) device's slice of the round sync — a thin driver
    over :class:`repro.fed.rounds.WirePath`.

    q_buf: (1, sr, 128) this worker's slab of its flattened weights;
    p_prev/p_prev2: (sr, 128) slabs of the public history (replicated over
    fed, sharded over model). ``betas`` is an optional (F,) per-worker
    beta_k vector (replicated): each fed instance ternarizes its own slab
    with its own threshold. Returns the (sr, 128) slab of the new global
    flat model (identical on every fed instance).
    """
    idx = jax.lax.axis_index(fed_axis)
    q = q_buf[0]
    beta_k = None if betas is None else jnp.take(betas, idx)
    # pilot upload+broadcast == masked all-reduce over the fed axis
    q_pilot = jax.lax.psum(jnp.where(idx == k_star, q, 0.0), fed_axis)
    wf = w.astype(jnp.float32)                    # (F,) masked Eq.(3) weights

    if mode == "masked":
        # Secure-aggregation wire: this instance masks its own fixed-point
        # weighted fields in-kernel (the uplink kernel regenerates only
        # this worker's row of pair streams from the (F,) key row — no
        # mask tensor exists in HBM, unlike the full F(F-1)/2 set the
        # simulator's oracle materializes), the fed collective sums mod
        # 2**modulus_bits (masks cancel EXACTLY, and modular addition is
        # order-free, so psum_scatter+all_gather is bit-identical to a
        # plain psum and to the replicated path), and every instance
        # unmasks the identical public sum. At the 16-bit modulus the
        # collective moves native uint16 words — HALF the bytes of the
        # uint32 wire for the same topology.
        spec = wire.privacy
        sr = q.shape[0]
        m_idx = (jax.lax.axis_index(model_axis) if model_axis is not None
                 else jnp.int32(0))
        wq = pvm.quantize_weights(wf, spec.fixpoint_bits)
        seed = spec.mask_seed if spec.masking_on else 0
        keys_row = pvm.pair_stream_keys_row(seed, idx, n_fed, t, m_idx)
        if tree is not None:        # leaf masks cancel within sibling groups
            signs_row = pvm.tree_pair_signs_row(idx, n_fed, tree.fanout,
                                                participation=pmask)
        else:
            signs_row = pvm.pair_signs_row(idx, n_fed, participation=pmask)
        rr_key = pdp.rr_stream_key(spec.dp_seed, t, idx, m_idx)
        y = wire.uplink_masked_slab(q, p_prev, p_prev2, t=t,
                                    wq_own=jnp.take(wq, idx),
                                    keys_row=keys_row,
                                    signs_row=signs_row, rr_key=rr_key,
                                    beta=beta_k)
        alive_eff = dead_eff = None
        if alive is not None:
            # Dropout recovery (repro.privacy.recovery): the uplink above
            # is what this worker COMMITTED; a post-fault death zeroes its
            # slab before the collective (nothing arrives from a dead
            # worker), its W_k leaves the de-bias, and the survivors'
            # uncancelled pair masks toward the dead are repaired on the
            # reduced total below — identically on every instance.
            alive_eff, dead_eff = pvr.effective_masks(
                pmask, alive, spec.recovery_threshold,
                tree.fanout if tree is not None else None, n_fed)
            y = jnp.where(jnp.take(alive_eff, idx) > 0, y,
                          jnp.zeros_like(y))
            wq = jnp.where(alive_eff > 0, wq, jnp.zeros_like(wq))
        if tree is not None:
            s = _tree_butterfly_reduce(y, spec=spec, tree=tree, idx=idx,
                                       t=t, fed_axis=fed_axis,
                                       n_fed=n_fed, m_idx=m_idx,
                                       pmask=pmask)
        elif y.shape[0] % n_fed == 0:
            part = jax.lax.psum_scatter(y, fed_axis, scatter_dimension=0,
                                        tiled=True)
            s = jax.lax.all_gather(part, fed_axis, axis=0, tiled=True)
        else:                       # slab rows not divisible by F
            s = jax.lax.psum(y, fed_axis)
        if alive is not None and spec.masking_on:
            i_idx, j_idx = pvr.repair_pair_index(
                n_fed, tree.fanout if tree is not None else None)
            keys_mat = pvm.pair_stream_keys(seed, n_fed, t, m_idx)
            if tree is not None:
                signs_mat = pvm.tree_pair_signs(n_fed, tree.fanout,
                                                participation=pmask)
            else:
                signs_mat = pvm.pair_signs(n_fed, participation=pmask)
            kf, cf = pvr.repair_coefficients(keys_mat, signs_mat,
                                             alive_eff, dead_eff,
                                             i_idx, j_idx)
            s = ops.flat_mask_repair(s, kf, cf, interpret=wire.interpret,
                                     block_rows=wire.block_rows)
        sw = jnp.sum(wq)
        if spec.modulus_bits == 16:
            sw = (sw & jnp.uint32(0xFFFF)).astype(jnp.uint16)
            ci = jax.lax.bitcast_convert_type(s - sw, jnp.int16)
        else:
            ci = jax.lax.bitcast_convert_type(s - sw, jnp.int32)
        coeff = ci.astype(jnp.float32) * jnp.float32(spec.scale_mult)
        return wire.combine(q_pilot, coeff.reshape(sr, fl.LANES), p_prev,
                            p_prev2, t)

    if mode == "packed":
        # Fused uplink on the slab → uint8 §3.3 codes on the wire → fused
        # master over the gathered stack (in-register decode, Eq. (3)).
        pk = wire.uplink_traced(q, p_prev, p_prev2, t=t, beta=beta_k)
        pk_all = jax.lax.all_gather(pk, fed_axis)     # (F, sr/4, 128)
        return wire.master(q_pilot, pk_all, wf, p_prev, p_prev2, t=t)

    tern = wire.codes(q, p_prev, p_prev2, t, beta=beta_k)  # int8 (sr, 128)
    if mode == "reduce":
        # Beyond-paper: Eq. (3) needs only Σ_k w_k T_k — reduce in-network
        # instead of gathering N ternary slabs. psum_scatter + all_gather is
        # the bandwidth-optimal all-reduce decomposition: each fed hop moves
        # sr/F rows, and the payload stays flat in N (the gather grows
        # linearly). Every instance ends with the same sum so the
        # replicated-master math is unchanged.
        w_me = jnp.take(wf, idx)
        # f16 on the wire (bf16 triggers an XLA-CPU AllReducePromotion
        # crash in this container; on TPU use bf16 — same byte count)
        contrib = (w_me * tern.astype(jnp.float32)).astype(jnp.float16)
        if contrib.shape[0] % n_fed == 0:
            part = jax.lax.psum_scatter(contrib, fed_axis,
                                        scatter_dimension=0, tiled=True)
            coeff = jax.lax.all_gather(part, fed_axis, axis=0,
                                       tiled=True).astype(jnp.float32)
        else:                       # slab rows not divisible by F: plain psum
            coeff = jax.lax.psum(contrib, fed_axis).astype(jnp.float32)
    else:
        tern_all = jax.lax.all_gather(tern, fed_axis)  # (F, sr, 128) int8
        coeff = jnp.tensordot(wf, tern_all.astype(jnp.float32), axes=1)

    return wire.combine(q_pilot, coeff, p_prev, p_prev2, t)


def build_fed_sync(model: Model, mesh: Mesh, fed_axis: str = "data",
                   strategy: str = "fedpc", alpha0: float = 0.01,
                   beta: float = 0.2, alpha1: float = 0.01, *,
                   model_axis: str = "model", shard_wire: bool = True,
                   wire_block_rows: int | None = None,
                   wire_block_workers: int | None = None,
                   betas=None, privacy: PrivacySpec | None = None,
                   renorm_shares: bool = False,
                   tree: TreeSpec | None = None,
                   faults=None, ledger=None) -> Callable:
    """Returns sync(params_F, costs, sizes, state, mask=None) ->
    (new_global_params, aux).

    params_F leaves are stacked (F, ...) over the fed axis; state carries
    the public history (params, params_prev — replicated) plus per-round
    costs (F,) and the 1-based round index.

    ``betas`` is an optional (F,) per-worker beta_k vector — each fed
    instance ternarizes with its own threshold and Eq. (3) weights carry
    p_k·beta_k. ``mask`` (optional (F,) 0/1, passed per call) is a
    partial-participation round: non-sampled workers are excluded from
    pilot selection, contribute zero Eq. (3) weight, and keep their
    previous cost in the carried state.

    With ``shard_wire=True`` (default) and a ``model_axis`` in the mesh, the
    flat wire buffers are sharded over the model axis: per-device wire
    memory and fed-collective payload are ``rows/M``. ``shard_wire=False``
    keeps the replicated wire path (used by the parity tests and meshes
    without a model axis — both paths produce identical global params).

    ``wire_block_rows``/``wire_block_workers`` pin the wire-kernel tiling on
    each device's slab (master VMEM per tile stays O(block) regardless of
    F); left as None they resolve through the ``kernels.tune`` table —
    tiling never changes bits.

    An active ``privacy`` spec puts the fedpc strategies on the masked
    secure-aggregation wire: each instance uploads masked fixed-point
    words mod ``2**privacy.modulus_bits`` (uint16 by default — half the
    collective bytes of the uint32 wire), the fed collective is the
    bandwidth-optimal psum_scatter+all_gather over the native wire word
    (modular addition is order-free,
    so mask cancellation — and bitwise parity with the replicated path —
    survives ANY reduction topology), and the master never sees a worker's
    plaintext codes. With ``privacy.enforce`` the traced sync program is
    audited against the §4.2 leakage policy on first call (shape-only
    trace) and the passing audit recorded in ``ledger`` when given.
    ``renorm_shares`` selects the renormalized-share Eq. (3) variant under
    partial participation.

    ``tree`` (masked wire only) replaces the flat fed all-reduce with the
    tree-shaped XOR-butterfly of :func:`_tree_butterfly_reduce`: sibling
    groups of ``tree.fanout`` fold level by level, per-level node masks
    keep every hop's payload masked, and the link into the root carries
    w_L ≤ fanout partials instead of F — bitwise identical to the flat
    path. Requires power-of-two ``fanout`` and fed axis size.

    ``faults`` attaches a deterministic :class:`repro.fed.faults.FaultPlan`:
    each round realizes per-worker fault codes from ``state["round"]`` and
    excludes faulted workers from pilot selection and the aggregate. On the
    masked wire the committed uplinks of dead workers are dropped and their
    residual pair masks repaired post-reduce (identically on every
    instance) — requires ``privacy.recovery_threshold``; a sibling group
    below it degrades to an exact-zero subtree.
    """
    F = mesh.shape[fed_axis]
    M = mesh.shape.get(model_axis, 1) if shard_wire else 1
    m_axis = model_axis if M > 1 else None
    wcfg = rd.WireConfig(alpha0=alpha0, beta=beta, alpha1=alpha1)
    betas_arr = None if betas is None else jnp.asarray(betas, jnp.float32)
    masked_wire = privacy is not None and privacy.active
    if masked_wire and strategy == "fedavg":
        # Silently running FedAvg's full-precision psum while the caller
        # believes secure aggregation is on would be the worst failure
        # mode a privacy layer can have.
        raise ValueError("privacy (secure-agg / DP wire) requires a fedpc "
                         "strategy; strategy='fedavg' moves full-precision "
                         "params over the fed axis")
    if tree is not None:
        # The XOR-butterfly tree reduce needs aligned power-of-two sibling
        # groups and full levels over the fed axis, and the masked wire
        # (partials crossing tree edges must be masked words).
        if not masked_wire:
            raise ValueError("tree aggregation on the mesh requires an "
                             "active privacy spec — every tree edge must "
                             "carry masked words")
        if tree.fanout & (tree.fanout - 1):
            raise ValueError(f"mesh tree fanout must be a power of two, "
                             f"got {tree.fanout}")
        if F & (F - 1):
            raise ValueError(f"mesh tree reduce needs a power-of-two fed "
                             f"axis, got {F}")
        if F % (tree.fanout ** tree.n_levels(F)):
            raise ValueError(
                f"fed axis ({F}) must hold whole sibling groups at every "
                f"level: not divisible by fanout**levels "
                f"({tree.fanout}**{tree.n_levels(F)})")
    fault_plan = faults if faults is not None and faults.active else None
    if (fault_plan is not None and masked_wire
            and privacy.recovery_threshold is None):
        raise ValueError(
            "fault injection on the masked wire requires "
            "privacy.recovery_threshold (the Shamir t of the "
            "dropout-recovery dealing) to be set")
    audit_state = {"done": False}

    def sync(params_F: PyTree, costs: jax.Array, sizes: jax.Array,
             state: dict, mask: jax.Array | None = None
             ) -> tuple[PyTree, dict]:
        t = state["round"]
        codes = dead_eff = None
        av = None
        if fault_plan is not None:
            codes = fault_plan.codes(t, F)
            av = (codes == tmr.FAULT_NONE).astype(jnp.float32)
        if av is None:
            sel_mask = mask
        elif masked_wire:
            # Survivors of a below-threshold sibling group contribute an
            # exact-zero subtree — exclude them from pilot selection and
            # the cost carry along with the dead (the threshold and fault
            # set are public, so every instance computes the same split).
            sel_mask, dead_eff = pvr.effective_masks(
                mask, av, privacy.recovery_threshold,
                tree.fanout if tree is not None else None, F)
        elif mask is None:
            sel_mask = av
        else:
            sel_mask = jnp.asarray(mask, jnp.float32) * av
        k_star, scores = _select_pilot(costs, state["prev_costs"], sizes, t,
                                       sel_mask)
        p_shares = sizes.astype(jnp.float32) / jnp.sum(sizes)

        if strategy == "fedavg":
            # C-fraction FedAvg: average over the sampled (and surviving)
            # workers only, shares renormalized over that set (>= 1
            # participant by construction).
            if sel_mask is None:
                wts = p_shares
            else:
                wm = p_shares * jnp.asarray(sel_mask, jnp.float32)
                wts = wm / jnp.sum(wm)

            def avg(x):
                wb = wts.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.sum(x.astype(jnp.float32) * wb, axis=0).astype(x.dtype)
            new_params = jax.tree_util.tree_map(avg, params_F)
        else:
            # Flat wire path: the whole pytree becomes one padded buffer per
            # worker (rows padded to M aligned slabs), so the sync is a
            # single shard_map over (fed, model) — one fed collective per
            # round, not one per leaf, each moving rows/M per device.
            layout = fl.layout_of(state["params"], shards=M)
            wire = rd.WirePath(wcfg, block_rows=wire_block_rows,
                               block_workers=wire_block_workers,
                               privacy=privacy if masked_wire else None,
                               renorm_shares=renorm_shares)
            # Masked wire: weights were committed BEFORE faults realized
            # (pre-fault participation); dead rows drop downstream and the
            # de-bias reweights by the surviving ΣW_k. Plain wire: faults
            # fold straight into the weights — survivors-only exactly.
            w = wire.weights(p_shares, k_star, t, betas=betas_arr,
                             mask=(mask if masked_wire else sel_mask))
            q_flat_F = fl.flatten_stacked(params_F, layout)
            p1_flat = fl.flatten_tree(state["params"], layout)
            p2_flat = fl.flatten_tree(state["params_prev"], layout)
            if M > 1:
                # Materialize the flat buffers on a sharding whose row axis
                # is NOT split before handing them to the shard_map: XLA's
                # SPMD partitioner (observed on CPU, jax 0.4) miscompiles
                # the concat+pad+reshape of flatten when its output is
                # resharded along the concat-derived row axis in the same
                # fusion — values arrive strided. The constraint forces a
                # clean boundary; the model-axis reshard then happens at
                # shard_map entry. Workers stay sharded over fed (no
                # cross-fed gather), history is replicated as it already is
                # semantically.
                q_flat_F = jax.lax.with_sharding_constraint(
                    q_flat_F, NamedSharding(mesh, P(fed_axis, None, None)))
                p1_flat, p2_flat = (
                    jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, P(None, None)))
                    for x in (p1_flat, p2_flat))

            mode = ("masked" if masked_wire else
                    {"fedpc_packed": "packed",
                     "fedpc_reduce": "reduce"}.get(strategy, "gather"))
            body = partial(
                _sync_body, wire=wire, k_star=k_star, w=w, t=t,
                fed_axis=fed_axis, n_fed=F, betas=betas_arr,
                model_axis=m_axis, pmask=mask, mode=mode, tree=tree,
                alive=(av if masked_wire else None))

            specs = wire_specs(fed_axis, m_axis)
            sharded_sync = _shard_map(
                body, mesh,
                in_specs=(specs["stacked"], specs["history"],
                          specs["history"]),
                out_specs=specs["out"],
                manual_axes={fed_axis} | ({m_axis} if m_axis else set()),
            )
            if (masked_wire and privacy.enforce
                    and not audit_state["done"]):
                # §4.2 enforcement hook: audit what actually crosses the
                # fed axis in this round's traced program (shape-only
                # trace — runs once, works under an outer jit too).
                report = pv_audit.check_fed_collectives(
                    sharded_sync, q_flat_F, p1_flat, p2_flat,
                    n_fed=F, masked=True)
                audit_state["done"] = True
                if ledger is not None:
                    ledger.record_audit("build_fed_sync", report)
            new_flat = sharded_sync(q_flat_F, p1_flat, p2_flat)
            new_params = fl.unflatten_tree(new_flat, layout)

        costs_eff = costs.astype(jnp.float32)
        if sel_mask is not None:  # non-participants / faulted: carry prev
            costs_eff = jnp.where(jnp.asarray(sel_mask) > 0, costs_eff,
                                  state["prev_costs"])
        new_state = {
            "params": new_params,
            "params_prev": state["params"],
            "prev_costs": costs_eff,
            "round": t + 1,
        }
        # The same device-resident round record the simulator drivers
        # emit — the mesh runtime's per-round observability rides aux (all
        # scalars; fetch-when-you-want, nothing syncs here).
        rec = tmr.build_round_record(
            t=t, k_star=k_star, n=F, costs=costs, sizes=sizes, mask=mask,
            codes=codes, sel_mask=sel_mask, dead_eff=dead_eff,
            modulus_bits=privacy.modulus_bits if masked_wire else 0,
            fanout=tree.fanout if tree is not None else 0,
            levels=tree.n_levels(F) if tree is not None else 0)
        aux = {"k_star": k_star, "goodness": scores, "telemetry": rec}
        return new_params, {"state": new_state, **aux}

    return sync


# ---------------------------------------------------------------------------
# Full federated step: local training (vmap over fed axis) + sync
# ---------------------------------------------------------------------------

def build_fed_step(model: Model, mesh: Mesh, fed_axis: str = "data",
                   strategy: str = "fedpc", local_steps: int = 1,
                   lr: float = 0.01, betas=None,
                   privacy: PrivacySpec | None = None,
                   renorm_shares: bool = False, faults=None,
                   ledger=None) -> Callable:
    """fed_step(state, opt_states_F, batch_F, sizes, mask=None) ->
       (state', opt_states_F', metrics)

    batch_F: pytree with leaves (F, local_steps, B_local, ...) — each fed
    worker's private micro-batches for this round. Worker k trains
    ``local_steps`` steps from the shared global params (its private
    optimizer state persists), reports its final loss as the round cost.
    ``betas``/``mask`` as in :func:`build_fed_sync` (under SPMD every
    worker still computes when masked — the mask drops its contribution
    from the aggregate, the federated semantics of a skipped round), and
    so are ``privacy``/``renorm_shares``/``ledger`` — the secure-agg wire
    is reachable from the end-to-end driver, not only from the raw sync.
    """
    sync = build_fed_sync(model, mesh, fed_axis, strategy, betas=betas,
                          privacy=privacy, renorm_shares=renorm_shares,
                          faults=faults, ledger=ledger)

    def local_train(params, opt_state, batches):
        def step(carry, b):
            p, os = carry
            p, os, m = model.train_step(p, os, b, lr)
            return (p, os), m["loss"]
        (p, os), losses = jax.lax.scan(step, (params, opt_state), batches)
        return p, os, losses[-1]

    def fed_step(state: dict, opt_states_F: PyTree, batch_F: PyTree,
                 sizes: jax.Array, mask: jax.Array | None = None):
        params_F, opt_F, costs = jax.vmap(
            local_train, in_axes=(None, 0, 0))(
                state["params"], opt_states_F, batch_F)
        if mask is not None:    # a skipped worker's private state is frozen
            opt_F = jax.tree_util.tree_map(
                lambda new, old: jnp.where(
                    (mask > 0).reshape((-1,) + (1,) * (new.ndim - 1)),
                    new, old),
                opt_F, opt_states_F)
        new_params, aux = sync(params_F, costs, sizes, state, mask)
        metrics = {"cost_mean": jnp.mean(costs), "k_star": aux["k_star"]}
        return aux["state"], opt_F, metrics

    return fed_step


def fed_state_init(params: PyTree, n_fed: int) -> dict:
    return {
        "params": params,
        "params_prev": jax.tree_util.tree_map(jnp.zeros_like, params),
        "prev_costs": jnp.full((n_fed,), jnp.inf, jnp.float32),
        "round": jnp.asarray(1, jnp.int32),
    }


def fed_shardings(model: Model, mesh: Mesh, fed_axis: str,
                  params: PyTree) -> dict:
    """NamedShardings for the fed-step arguments."""
    pspecs = param_specs(params, mesh)

    def prepend_fed(spec: P) -> P:
        return P(fed_axis, *spec)

    stacked = jax.tree_util.tree_map(prepend_fed, pspecs)
    return {
        "params": jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs),
        "params_F": jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), stacked),
    }
