"""Deterministic fault injection for the federated round.

A :class:`FaultPlan` is a seeded, stateless description of the failure
axis: each round, each worker independently draws one fault code from a
FAULT_DOMAIN counter stream (the same lowbias32 chain every other stream
in the system uses), so the schedule is a pure function of
``(plan.seed, round, worker)`` — both simulator drivers, ``scan_rounds``
and the distributed mesh realize bitwise the same faults, and a resumed
run replays its schedule exactly.

Three fault types, matching the cross-device failure model:

* ``DROP_BEFORE`` — the worker dies before its uplink: nothing arrives,
  no uplink bytes are spent.
* ``DROP_AFTER`` — the worker dies after committing its masked uplink:
  its words arrived but the protocol must discard them (the worker is
  gone; its contribution is excluded from the survivors-only aggregate).
  Uplink bytes were spent.
* ``STRAGGLER`` — the uplink exceeds the round timeout: discarded like a
  death, but the bytes were spent.

All three are identical to the AGGREGATION math — the worker's row leaves
the sum, and on the masked wire its uncancelled pairwise-mask residue is
repaired from reconstructed seeds (``repro.privacy.recovery``) — they
differ only in byte accounting. Fault codes are int32 on purpose: the
masked-wire audit forbids int8/uint8 tensors anywhere in the round
program, and fault codes are public control metadata, not wire payload.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.privacy import masking as pvm

FAULT_NONE = 0
DROP_BEFORE = 1     # died before uplink: no bytes spent, row excluded
DROP_AFTER = 2      # died after uplink: bytes spent, row excluded + repair
STRAGGLER = 3       # exceeded timeout: bytes spent, row excluded + repair


@dataclass(frozen=True)
class FaultPlan:
    """Per-round i.i.d. fault probabilities, realized deterministically.

    Probabilities are per worker per round; they must sum to at most 1
    (the remainder is the no-fault outcome). ``seed`` namespaces the
    fault stream — independent of mask/RR/recovery streams by domain
    separation even at equal seeds.
    """
    seed: int = 0
    drop_before_uplink: float = 0.0
    drop_after_uplink: float = 0.0
    straggler: float = 0.0

    def __post_init__(self):
        for name in ("drop_before_uplink", "drop_after_uplink", "straggler"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.total > 1.0:
            raise ValueError(
                f"fault probabilities sum to {self.total} > 1")

    @property
    def total(self) -> float:
        return (self.drop_before_uplink + self.drop_after_uplink
                + self.straggler)

    @property
    def active(self) -> bool:
        return self.total > 0.0

    def codes(self, t, n: int) -> jnp.ndarray:
        """The (n,) int32 fault codes of round ``t`` (``t`` may be traced).

        One uniform draw per worker from the FAULT_DOMAIN stream, split by
        cumulative thresholds — so lowering one probability to zero never
        reshuffles the draws of the remaining fault types.
        """
        u = pvm.stream_key(self.seed, jnp.arange(n), t,
                           domain=pvm.FAULT_DOMAIN)
        r = u.astype(jnp.float32) * jnp.float32(2.0 ** -32)
        p1 = jnp.float32(self.drop_before_uplink)
        p2 = p1 + jnp.float32(self.drop_after_uplink)
        p3 = p2 + jnp.float32(self.straggler)
        return jnp.where(
            r < p1, DROP_BEFORE,
            jnp.where(r < p2, DROP_AFTER,
                      jnp.where(r < p3, STRAGGLER,
                                FAULT_NONE))).astype(jnp.int32)

    def alive(self, t, n: int) -> jnp.ndarray:
        """(n,) float32 survival mask of round ``t``: 1 where no fault."""
        return (self.codes(t, n) == FAULT_NONE).astype(jnp.float32)
