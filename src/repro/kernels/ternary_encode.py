"""Pallas TPU kernel: Eq. (5) ternarization of parameter evolution.

This op runs over *every model parameter every round* — the per-round
compute hot-spot of the FedPC protocol (everything else in a round is the
local training itself). On TPU it is a pure VPU elementwise pass; the win
over the unfused jnp version is fusing threshold + sign + compare into one
VMEM-resident pass (4 HBM reads + 1 write per element → exactly 3 reads +
1 int8 write, no intermediates).

Layout: flat parameter vectors are viewed as (rows, 128) — lane-aligned —
and tiled (BLOCK_ROWS, 128) per grid step, 8-sublane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256          # (256, 128) fp32 tile = 128 KiB / input → fits VMEM


def _kernel(q_ref, p1_ref, p2_ref, beta_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    beta = beta_ref[0]
    step = p1 - p2
    delta = q - p1
    significant = jnp.abs(delta) >= beta * jnp.abs(step)
    out_ref[...] = jnp.where(
        significant, jnp.sign(delta * step), 0.0).astype(jnp.int8)


def _kernel_round1(q_ref, p0_ref, alpha_ref, out_ref):
    d = q_ref[...].astype(jnp.float32) - p0_ref[...].astype(jnp.float32)
    alpha = alpha_ref[0]
    out_ref[...] = ((d > alpha).astype(jnp.int8)
                    - (d < -alpha).astype(jnp.int8))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_encode_2d(q, p1, p2, beta, *, interpret: bool = True,
                      block_rows: int = BLOCK_ROWS):
    """q/p1/p2 (R, 128) with R % block_rows == 0 → int8 (R, 128)."""
    rows = q.shape[0]
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.int8),
        interpret=interpret,
    )(q, p1, p2, jnp.asarray([beta], jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_encode_round1_2d(q, p0, alpha, *, interpret: bool = True,
                             block_rows: int = BLOCK_ROWS):
    rows = q.shape[0]
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel_round1,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.int8),
        interpret=interpret,
    )(q, p0, jnp.asarray([alpha], jnp.float32))
