"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These share semantics with repro.core.{ternary,packing,update} but operate
on the flat, padded layouts the kernels use, so tests compare exactly.
The masked (secure-aggregation) wire's oracles live in
``repro.privacy.ref`` — they consume host-expanded mask/RR streams
(``privacy.masking.net_masks`` / ``privacy.dp.rr_bits``), which the
kernels of ``kernels.masked_wire`` must reproduce bit-for-bit from their
in-kernel counter PRNG at either modulus.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ternary_encode_ref(q: jax.Array, p1: jax.Array, p2: jax.Array,
                       beta: float) -> jax.Array:
    """Eq. (5) on flat fp32 arrays → int8 codes."""
    qf, p1f, p2f = (t.astype(jnp.float32) for t in (q, p1, p2))
    step = p1f - p2f
    delta = qf - p1f
    significant = jnp.abs(delta) >= beta * jnp.abs(step)
    return jnp.where(significant, jnp.sign(delta * step), 0.0).astype(jnp.int8)


def ternary_encode_round1_ref(q: jax.Array, p0: jax.Array,
                              alpha: float) -> jax.Array:
    """Eq. (4)."""
    d = (q - p0).astype(jnp.float32)
    return ((d > alpha).astype(jnp.int8) - (d < -alpha).astype(jnp.int8))


def pack2bit_ref(t: jax.Array) -> jax.Array:
    """int8 codes (..., 4k) → uint8 (..., k); biased 2-bit fields, LE."""
    codes = (t.astype(jnp.int32) + 1).astype(jnp.uint8)
    g = codes.reshape(t.shape[:-1] + (t.shape[-1] // 4, 4))
    shifts = jnp.array([0, 2, 4, 6], jnp.uint8)
    return jnp.sum(g << shifts, axis=-1).astype(jnp.uint8)


def unpack2bit_ref(b: jax.Array) -> jax.Array:
    """uint8 (..., k) → int8 codes (..., 4k)."""
    shifts = jnp.array([0, 2, 4, 6], jnp.uint8)
    fields = (b[..., None] >> shifts) & jnp.uint8(0x3)
    return (fields.astype(jnp.int8) - 1).reshape(b.shape[:-1] + (-1,))


def ternary_pack_ref(q: jax.Array, p1: jax.Array, p2: jax.Array,
                     beta: float) -> jax.Array:
    """Fused-uplink oracle: Eq. (5) then §3.3 pack on flat arrays whose size
    is a multiple of 4."""
    return pack2bit_ref(ternary_encode_ref(q, p1, p2, beta))


def ternary_pack_round1_ref(q: jax.Array, p0: jax.Array,
                            alpha: float) -> jax.Array:
    """Round-1 fused-uplink oracle (Eq. (4) then §3.3 pack)."""
    return pack2bit_ref(ternary_encode_round1_ref(q, p0, alpha))


def packed_master_update_ref(q_pilot: jax.Array, packed: jax.Array,
                             w: jax.Array, p1: jax.Array, p2: jax.Array,
                             t, alpha0: float) -> jax.Array:
    """Eq. (3) oracle over packed codes. packed (N, bytes) uint8; both round
    branches, selected on ``t`` like the kernel."""
    tern = unpack2bit_ref(packed)                     # (N, 4*bytes)
    coeff = jnp.einsum("n,nm->m", w.astype(jnp.float32),
                       tern.astype(jnp.float32))
    step = (p1 - p2).astype(jnp.float32)
    mult = jnp.where(jnp.asarray(t, jnp.float32) <= 1.0, alpha0, step)
    return (q_pilot.astype(jnp.float32) - coeff * mult).astype(q_pilot.dtype)


def packed_master_accum_ref(q_pilot: jax.Array, packed: jax.Array,
                            w: jax.Array, p1: jax.Array, p2: jax.Array,
                            t, alpha0: float) -> jax.Array:
    """Order-exact Eq. (3) oracle over packed codes.

    Accumulates worker contributions strictly sequentially (k = 0..N−1,
    each folded as ``w_k·field − w_k``) — the exact floating-point order of
    the grid-accumulated ``packed_master_update_2d`` kernel under EVERY
    (block_rows, block_workers) plan, so parity tests against this are
    bitwise, not allclose. Compare against the **jitted** oracle: the
    kernel always runs under jit, where XLA:CPU contracts mul+sub chains
    into FMAs that op-by-op eager execution does not (ulp-level drift
    between eager and jit of this very function). Semantically identical to
    :func:`packed_master_update_ref` (which reduces with einsum and is the
    allclose oracle).
    """
    coeff = jnp.zeros(packed.shape[1:-1] + (packed.shape[-1] * 4,),
                      jnp.float32)
    for k in range(packed.shape[0]):
        wk = w[k].astype(jnp.float32)
        fields = unpack2bit_ref(packed[k]).astype(jnp.float32) + 1.0
        coeff = coeff + (fields * wk - wk)
    step = (p1 - p2).astype(jnp.float32)
    mult = jnp.where(jnp.asarray(t, jnp.float32) <= 1.0, alpha0, step)
    return (q_pilot.astype(jnp.float32) - coeff * mult).astype(q_pilot.dtype)


def master_update_ref(q_pilot: jax.Array, tern: jax.Array, w: jax.Array,
                      p1: jax.Array, p2: jax.Array) -> jax.Array:
    """Eq. (3) t>1 on flat arrays. tern (N, M) int8, w (N,) already masked
    p_k * beta_k (pilot row zeroed)."""
    coeff = jnp.einsum("n,nm->m", w.astype(jnp.float32),
                       tern.astype(jnp.float32))
    step = (p1 - p2).astype(jnp.float32)
    return (q_pilot.astype(jnp.float32) - coeff * step).astype(q_pilot.dtype)
