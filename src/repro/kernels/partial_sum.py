"""Fused sub-aggregate kernels for hierarchical (tree) aggregation.

An internal tree node folds its (at most ``fanout``) children into one
*partial* accumulator block on the INTEGER wire — fixed-point-weighted
fields summed mod 2**word_bits — WITHOUT de-biasing or descaling: the
subtraction of the public ΣW_k and the fixed-point descale happen exactly
once, at the root (``masked_master_update_2d``). Modular accumulation is
order-free, so any tree shape produces bitwise the flat master's result.

``partial_sum_2d`` — the leaf-level sub-aggregate over the PLAIN packed
wire: decodes each child's §3.3 2-bit codes in-register (the
``fused_wire`` register decode, minus the de-bias) to fields {0, 1, 2},
weights by the public fixed-point ``W_c``, and sums children per sibling
group mod 2**word_bits. One launch turns (C, R, 128) packed uint8 leaves
into (C/fanout, R, 512) word partials.

``masked_partial_sum_2d`` — the interior sub-aggregate over masked (or
plain integer) word partials: sums each sibling group's children mod
2**word_bits and adds the EMITTING node's own net pairwise mask,
regenerated in-register from the level's (G, G) counter-key matrix (the
``masked_wire`` stream idiom — shared tile hash, pair dedup whenever the
whole level is resident, half-width lo/hi planes at the 16-bit modulus).
The children's masks — scoped to exactly this sibling group by
``masking.tree_pair_signs`` — cancel inside the group sum; the node's own
mask keeps the partial masked while it crosses the next tree edge, and
cancels one level up. Every tree edge therefore carries masked words;
nothing is unmasked below the root.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.masked_wire import _tile_hash
from repro.privacy import masking as pvm
from repro.telemetry import profile as tprof

LANES = 128
PACK = 4
BLOCK_ROWS = 64
BLOCK_GROUPS = 1


def _weighted_fields(b, w, br: int, word_bits: int):
    """One child's packed (br, 128) uint8 codes -> (br, 512) fixed-point-
    weighted fields ``W_c * field`` in the wire word dtype. The register
    2-bit decode of ``fused_wire._weighted_decode`` minus the de-bias:
    fields stay biased {0, 1, 2} so the ΣW_k subtraction can happen once,
    at the root. At the 16-bit modulus the product runs in uint16 lanes
    (W < 2**14, field <= 2 — exact, and mod-2**16 congruent regardless)."""
    bi = b.astype(jnp.int32)[:, :, None]
    e = jax.lax.broadcasted_iota(jnp.int32, (1, 1, PACK), 2)
    f = (bi // jax.lax.shift_left(jnp.int32(1), 2 * e)) % 4
    f = f.reshape(br, LANES * PACK).astype(jnp.uint32)
    if word_bits == 16:
        return w.astype(jnp.uint16) * f.astype(jnp.uint16)
    return w * f


def _partial_sum_kernel(pk_ref, wq_ref, out_ref, *, fanout: int,
                        word_bits: int):
    """One (row block, group block) tile: each resident sibling group's
    packed children decode + weight + modular sum. The same body serves
    the one-shot plan (whole operands, no grid) and the gridded plan —
    nothing here depends on absolute position."""
    cb, br, _ = pk_ref.shape
    bg = cb // fanout
    wide = LANES * PACK
    acc_dtype = jnp.uint16 if word_bits == 16 else jnp.uint32
    outs = []
    for k in range(bg):
        acc = jnp.zeros((br, wide), acc_dtype)
        for j in range(fanout):
            c = k * fanout + j
            acc = acc + _weighted_fields(pk_ref[c], wq_ref[c, 0], br,
                                         word_bits)
        outs.append(acc)
    out_ref[...] = jnp.stack(outs)


def _masked_partial_kernel(y_ref, keys_ref, signs_ref, out_ref, *,
                           fanout: int, word_bits: int, use_masks: bool,
                           sibling: int, gridded: bool):
    """One tile of the interior sub-aggregate: sum each sibling group's
    children words mod 2**word_bits, then add each emitting node's net
    mask from the level's counter keys (``use_masks=False`` — the plain
    integer tree wire, or an all-dropped level — skips stream generation
    entirely)."""
    cb, br, wide = y_ref.shape
    bg = cb // fanout
    g_total = keys_ref.shape[0]
    if gridded:
        base = (jnp.asarray(pl.program_id(0), jnp.uint32)
                * jnp.uint32(br * wide))
        g0 = pl.program_id(1) * bg
    else:
        base = jnp.uint32(0)
        g0 = 0
    sums = []
    for k in range(bg):
        acc = y_ref[k * fanout]
        for j in range(1, fanout):        # modular: order can't change bits
            acc = acc + y_ref[k * fanout + j]
        sums.append(acc)
    out = jnp.stack(sums)
    if not use_masks or g_total < 2:
        out_ref[...] = out
        return
    keys = keys_ref[...]                               # (G, G) uint32
    signs = signs_ref[...]                             # (G, G) int32
    h_m = _tile_hash(base, br, wide, word_bits)
    if word_bits == 16:
        # Half-width lo/hi planes, repacked once by shift|or + bitcast —
        # the masked_wire layout, so the jnp net_masks oracle matches
        # bitwise.
        nplanes, pw = 2, wide // 2

        def expand(key):
            u = pvm.mask_stream(key, h_m)
            return ((u & jnp.uint32(0xFFFF)).astype(jnp.int32),
                    (u >> jnp.uint32(16)).astype(jnp.int32))
    else:
        nplanes, pw = 1, wide

        def expand(key):
            v = pvm.mask_stream(key, h_m)
            return (jax.lax.bitcast_convert_type(v, jnp.int32),)
    zeros = functools.partial(jnp.zeros, (br, pw), jnp.int32)
    if bg == g_total:
        # Whole level resident: each unordered sibling pair's stream
        # expands ONCE and ±folds into both endpoints. Cross-group pairs
        # are structurally sign-zero (tree_pair_signs), so they are
        # skipped statically — sibling groups, not G(G-1)/2 pairs.
        nets = [[zeros() for _ in range(bg)] for _ in range(nplanes)]
        for i in range(bg):
            for j in range(i + 1, bg):
                if i // sibling != j // sibling:
                    continue
                s = signs[i, j]
                for plane, v in zip(nets, expand(keys[i, j])):
                    sv = s * v
                    plane[i] = plane[i] + sv
                    plane[j] = plane[j] - sv
    else:
        # Gridded group blocks: each resident node folds its key row
        # (cross-group/inactive pairs sign-zeroed — g0 + k is traced).
        nets = [[] for _ in range(nplanes)]
        for k in range(bg):
            accs = [zeros() for _ in range(nplanes)]
            for l in range(g_total):
                s = signs[g0 + k, l]
                accs = [p + s * v
                        for p, v in zip(accs, expand(keys[g0 + k, l]))]
            for plane, a in zip(nets, accs):
                plane.append(a)
    if word_bits == 32:
        net_words = jax.lax.bitcast_convert_type(jnp.stack(nets[0]),
                                                 jnp.uint32)
    else:
        los, his = nets
        words = []
        for k in range(bg):
            lo_u = (jax.lax.bitcast_convert_type(los[k], jnp.uint32)
                    & jnp.uint32(0xFFFF))
            hi_u = (jax.lax.bitcast_convert_type(his[k], jnp.uint32)
                    << jnp.uint32(16))
            words.append(jax.lax.bitcast_convert_type(
                lo_u | hi_u, jnp.uint16).reshape(br, wide))
        net_words = jnp.stack(words)
    out_ref[...] = out + net_words


@functools.partial(jax.jit, static_argnames=("fanout", "word_bits",
                                             "interpret", "block_rows",
                                             "block_groups"))
def partial_sum_2d(packed, wq, *, fanout: int, word_bits: int = 32,
                   interpret: bool = True, block_rows: int = BLOCK_ROWS,
                   block_groups: int = BLOCK_GROUPS):
    """Leaf-level sub-aggregate: (C, R, 128) packed uint8 + (C,) public
    fixed-point weights -> (C/fanout, R, 512) word partials, one launch.

    ``C`` must be a multiple of ``fanout`` (the ``ops`` wrapper pads the
    ragged last group with zero bytes and zero weight — an exact identity:
    0 * field == 0). Each output row g is ``Σ_{c in group g} W_c·field_c``
    mod 2**word_bits — no de-bias, no descale. Bitwise invariant under
    every (block_rows, block_groups) plan.
    """
    c, rows, _ = packed.shape
    if c % fanout:
        raise ValueError(f"children count {c} not a multiple of fanout "
                         f"{fanout} — pad before the kernel")
    g = c // fanout
    wide = LANES * PACK
    out_dtype = jnp.uint16 if word_bits == 16 else jnp.uint32
    wq2 = jnp.asarray(wq, jnp.uint32).reshape(c, 1)
    kern = functools.partial(_partial_sum_kernel, fanout=fanout,
                             word_bits=word_bits)
    with tprof.kernel_scope("partial_sum", rows, fanout, interpret):
        if block_rows >= rows and block_groups >= g:
            return pl.pallas_call(
                kern,
                in_specs=[pl.BlockSpec(packed.shape, None),
                          pl.BlockSpec(wq2.shape, None)],
                out_specs=pl.BlockSpec((g, rows, wide), None),
                out_shape=jax.ShapeDtypeStruct((g, rows, wide), out_dtype),
                interpret=interpret,
            )(packed, wq2)
        grid = (rows // block_rows, g // block_groups)
        pk_spec = pl.BlockSpec((block_groups * fanout, block_rows, LANES),
                               lambda i, k: (k, i, 0))
        wq_spec = pl.BlockSpec((block_groups * fanout, 1), lambda i, k: (k, 0))
        out_spec = pl.BlockSpec((block_groups, block_rows, wide),
                                lambda i, k: (k, i, 0))
        return pl.pallas_call(
            kern, grid=grid,
            in_specs=[pk_spec, wq_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((g, rows, wide), out_dtype),
            interpret=interpret,
        )(packed, wq2)


@functools.partial(jax.jit, static_argnames=("fanout", "sibling",
                                             "use_masks", "interpret",
                                             "block_rows", "block_groups"))
def masked_partial_sum_2d(words, keys, signs, *, fanout: int, sibling: int,
                          use_masks: bool = True, interpret: bool = True,
                          block_rows: int = BLOCK_ROWS,
                          block_groups: int = BLOCK_GROUPS):
    """Interior sub-aggregate: (C, R, 512) child word partials -> (C/fanout,
    R, 512) parent partials in the same wire dtype (modulus from dtype).

    ``keys``/``signs`` are the (G, G) pair stream-key / scoped sign
    matrices of the EMITTING level's nodes (``masking.pair_stream_keys``
    at the level seed, ``masking.tree_pair_signs`` at ``sibling``): each
    output adds its node's net mask so the partial crossing the next tree
    edge stays masked; the children's own masks cancel inside the group
    sum. ``C`` must be a multiple of ``fanout`` (zero-word padding is an
    exact identity). ``sibling`` is the static sibling-group size of the
    emitting level (``fanout`` below the last level, the whole level at
    it). ``t`` dependence rides inside ``keys``. Bitwise invariant under
    every plan.
    """
    c, rows, wide = words.shape
    if c % fanout:
        raise ValueError(f"children count {c} not a multiple of fanout "
                         f"{fanout} — pad before the kernel")
    g = c // fanout
    word_bits = 16 if words.dtype == jnp.uint16 else 32
    keys = jnp.asarray(keys, jnp.uint32)
    signs = jnp.asarray(signs, jnp.int32)
    kern_kw = dict(fanout=fanout, word_bits=word_bits, use_masks=use_masks,
                   sibling=sibling)
    kind = ("partial_sum_masked16" if word_bits == 16
            else "partial_sum_masked")
    with tprof.kernel_scope(kind, rows, fanout, interpret):
        if block_rows >= rows and block_groups >= g:
            return pl.pallas_call(
                functools.partial(_masked_partial_kernel, gridded=False,
                                  **kern_kw),
                in_specs=[pl.BlockSpec(words.shape, None),
                          pl.BlockSpec(memory_space=pl.ANY),
                          pl.BlockSpec(memory_space=pl.ANY)],
                out_specs=pl.BlockSpec((g, rows, wide), None),
                out_shape=jax.ShapeDtypeStruct((g, rows, wide), words.dtype),
                interpret=interpret,
            )(words, keys, signs)
        grid = (rows // block_rows, g // block_groups)
        y_spec = pl.BlockSpec((block_groups * fanout, block_rows, wide),
                              lambda i, k: (k, i, 0))
        out_spec = pl.BlockSpec((block_groups, block_rows, wide),
                                lambda i, k: (k, i, 0))
        return pl.pallas_call(
            functools.partial(_masked_partial_kernel, gridded=True, **kern_kw),
            grid=grid,
            in_specs=[y_spec,
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((g, rows, wide), words.dtype),
            interpret=interpret,
        )(words, keys, signs)
