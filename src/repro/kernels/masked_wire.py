"""Fused Pallas kernels for the secure-aggregation (masked) wire path.

Two kernels mirror the plaintext pair of ``fused_wire`` and keep the round
at exactly two launches when privacy is on:

``ternary_pack_masked_2d`` — the masked uplink. Fuses Eq. (4)/(5)
ternarization -> bias to fields {0, 1, 2} -> 3-ary randomized response
(local DP, threshold 0 = off) -> fixed-point weighting by the public
per-worker ``W_k`` -> pairwise-mask addition, all in-register: float
history views in, uint32 masked words out. The plaintext code NEVER exists
outside VMEM registers — what reaches HBM (and then the wire) is already
masked. Grid layout is identical to ``ternary_pack_stacked_2d``:
rows-major with the worker axis minor (shared history fetched once per row
block), a vectorized (block_workers, block_rows) block, and a grid-less
one-shot path when the plan collapses to one step.

``masked_master_update_2d`` — the sum-then-unmask master. Walks the same
2-D (rows, workers) grid as ``packed_master_update_2d``, accumulating the
masked uint32 words into a revisited uint32 accumulator block (a second
output whose block index ignores the worker axis; the caller discards it).
Because the accumulation is modular (mod 2**32), the pairwise masks cancel
EXACTLY once all workers are folded — the master never observes an
individual worker's ternary directions, only the sum — and the result is
bitwise invariant under every block plan *and* every reduction order (no
sequential-order discipline needed, unlike the float master). The last
worker step de-biases in the integer domain (subtract the public
``sum_k W_k``), reinterprets the residue as int32 (|coeff| < 2**31 by the
``sum w_k <= 1`` weight bound), descales by the fixed-point multiplier
(with the RR unbias folded in), and applies the Eq. (3) combine.

Wire cost: one uint32 word per parameter — 16x the 2-bit plaintext wire,
equal to fp32 FedAvg traffic. That is the classic secure-aggregation
price: the modulus must hold the cohort sum of fixed-point-weighted
fields. The overhead is benchmarked in ``benchmarks/kernels_bench.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_wire import _codes_any
from repro.privacy.dp import rr_fields

LANES = 128
PACK = 4
BLOCK_ROWS = 64
BLOCK_WORKERS = 1

def _masked_fields(q, p1, p2, beta, t, alpha1, wq, mask, rr, thr):
    """In-register masked-word math shared by both uplink launch paths.

    q (bw, br, 512) f32; p1/p2 (br, 512) f32 broadcast over workers; beta
    (bw, 1, 1); wq (bw, 1, 1) uint32; mask/rr (bw, br, 512) uint32; thr
    uint32 scalar. Returns uint32 (bw, br, 512).
    """
    code = _codes_any(q, p1[None], p2[None], t, beta, alpha1)
    field = (code + 1.0).astype(jnp.uint32)          # exact for {0, 1, 2}
    field = rr_fields(field, rr, thr)                # THE oracle expression
    return wq * field + mask                          # mod 2**32


def _masked_pack_kernel(q_ref, p1_ref, p2_ref, beta_ref, wq_ref, mask_ref,
                        rr_ref, scal_ref, thr_ref, out_ref):
    t, alpha1 = scal_ref[0], scal_ref[1]
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    beta = beta_ref[...].astype(jnp.float32)[:, :, None]
    wq = wq_ref[...][:, :, None]
    out_ref[...] = _masked_fields(q, p1, p2, beta, t, alpha1, wq,
                                  mask_ref[...], rr_ref[...], thr_ref[0])


def _masked_master_kernel(q_ref, y_ref, p1_ref, p2_ref, scal_ref, sumw_ref,
                          out_ref, acc_ref, *, block_workers: int,
                          last_k: int):
    """One (row block, worker block) step of the sum-then-unmask master.

    ``acc_ref`` is the revisited uint32 accumulator output (its block index
    ignores the worker axis; the wrapper discards it): step k == 0 zeroes
    it, every step folds its workers mod 2**32, the last step unmasks —
    integer de-bias, fixed-point descale — and writes the Eq. (3) combine
    into ``out_ref``.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    acc = acc_ref[...]
    for j in range(block_workers):        # modular: order can't change bits
        acc = acc + y_ref[j]
    acc_ref[...] = acc

    @pl.when(k == last_k)
    def _combine():
        t, alpha0, smult = scal_ref[0], scal_ref[1], scal_ref[2]
        ci = jax.lax.bitcast_convert_type(acc_ref[...] - sumw_ref[0],
                                          jnp.int32)
        coeff = ci.astype(jnp.float32) * smult
        step = (p1_ref[...].astype(jnp.float32)
                - p2_ref[...].astype(jnp.float32))
        mult = jnp.where(t <= 1.0, alpha0, step)
        q = q_ref[...].astype(jnp.float32)
        out_ref[...] = (q - coeff * mult).astype(out_ref.dtype)


def _masked_master_oneshot_kernel(q_ref, y_ref, p1_ref, p2_ref, scal_ref,
                                  sumw_ref, out_ref, *, n_workers: int):
    """Single-step plan (the cpu-interpret optimum): same modular math."""
    acc = jnp.zeros((q_ref.shape[0], LANES * PACK), jnp.uint32)
    for j in range(n_workers):
        acc = acc + y_ref[j]
    t, alpha0, smult = scal_ref[0], scal_ref[1], scal_ref[2]
    ci = jax.lax.bitcast_convert_type(acc - sumw_ref[0], jnp.int32)
    coeff = ci.astype(jnp.float32) * smult
    step = p1_ref[...].astype(jnp.float32) - p2_ref[...].astype(jnp.float32)
    mult = jnp.where(t <= 1.0, alpha0, step)
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = (q - coeff * mult).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "block_workers"))
def ternary_pack_masked_2d(q, p1, p2, t, beta, alpha1, wq, masks, rr_bits,
                           rr_threshold, *, interpret: bool = True,
                           block_rows: int = BLOCK_ROWS,
                           block_workers: int = BLOCK_WORKERS):
    """Masked uplink: all N workers' secure-agg wire words from ONE launch.

    q (N, R, 512) float history views; p1/p2 (R, 512) shared public
    history; ``beta`` a scalar or (N,) per-worker Eq. (5) threshold; wq
    (N,) uint32 fixed-point Eq. (3) weights (public); masks/rr_bits
    (N, R, 512) uint32 (pass the mask buffer for ``rr_bits`` when DP is
    off — threshold 0 ignores it, and no zero tensor is streamed twice);
    ``rr_threshold`` the uint16 flip threshold. ``t`` may be traced.
    Returns uint32 (N, R, 512) — already masked when it first touches HBM.
    """
    n, rows, _ = q.shape
    betas = jnp.broadcast_to(
        jnp.asarray(beta, jnp.float32).reshape(-1, 1), (n, 1))
    wq2 = jnp.asarray(wq, jnp.uint32).reshape(n, 1)
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha1, jnp.float32)])
    thr = jnp.asarray([rr_threshold], jnp.uint32)
    wide = LANES * PACK
    if block_rows >= rows and block_workers >= n:
        return pl.pallas_call(
            _masked_pack_kernel,
            in_specs=[pl.BlockSpec(q.shape, None),
                      pl.BlockSpec(p1.shape, None),
                      pl.BlockSpec(p2.shape, None),
                      pl.BlockSpec(betas.shape, None),
                      pl.BlockSpec(wq2.shape, None),
                      pl.BlockSpec(masks.shape, None),
                      pl.BlockSpec(rr_bits.shape, None),
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((n, rows, wide), None),
            out_shape=jax.ShapeDtypeStruct((n, rows, wide), jnp.uint32),
            interpret=interpret,
        )(q, p1, p2, betas, wq2, masks, rr_bits, scal, thr)
    grid = (rows // block_rows, n // block_workers)
    q_spec = pl.BlockSpec((block_workers, block_rows, wide),
                          lambda i, k: (k, i, 0))
    h_spec = pl.BlockSpec((block_rows, wide), lambda i, k: (i, 0))
    w_spec = pl.BlockSpec((block_workers, 1), lambda i, k: (k, 0))
    return pl.pallas_call(
        _masked_pack_kernel,
        grid=grid,
        in_specs=[q_spec, h_spec, h_spec, w_spec, w_spec, q_spec, q_spec,
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((n, rows, wide), jnp.uint32),
        interpret=interpret,
    )(q, p1, p2, betas, wq2, masks, rr_bits, scal, thr)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "block_workers"))
def masked_master_update_2d(q_pilot, masked, sum_wq, p1, p2, t, alpha0,
                            scale_mult, *, interpret: bool = True,
                            block_rows: int = BLOCK_ROWS,
                            block_workers: int = BLOCK_WORKERS):
    """Sum-then-unmask Eq. (3) over masked uint32 wire words.

    q_pilot/p1/p2 (R, 512) float; masked (N, R, 512) uint32; ``sum_wq``
    the public scalar ``sum_k W_k`` (uint32); ``scale_mult`` the fixed-
    point descale with the RR unbias folded in; ``t`` may be traced.
    Returns (R, 512) in q_pilot.dtype. Bitwise invariant under every
    (block_rows, block_workers) plan — modular accumulation is order-free.
    """
    n, rows, _ = masked.shape
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha0, jnp.float32),
                      jnp.asarray(scale_mult, jnp.float32)])
    sumw = jnp.asarray(sum_wq, jnp.uint32).reshape(1)
    if block_rows >= rows and block_workers >= n:
        return pl.pallas_call(
            functools.partial(_masked_master_oneshot_kernel, n_workers=n),
            in_specs=[pl.BlockSpec(q_pilot.shape, None),
                      pl.BlockSpec(masked.shape, None),
                      pl.BlockSpec(p1.shape, None),
                      pl.BlockSpec(p2.shape, None),
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(q_pilot.shape, None),
            out_shape=jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
            interpret=interpret,
        )(q_pilot, masked, p1, p2, scal, sumw)
    grid = (rows // block_rows, n // block_workers)
    spec_f = pl.BlockSpec((block_rows, LANES * PACK), lambda i, k: (i, 0))
    spec_y = pl.BlockSpec((block_workers, block_rows, LANES * PACK),
                          lambda i, k: (k, i, 0))
    out, _acc = pl.pallas_call(
        functools.partial(_masked_master_kernel,
                          block_workers=block_workers,
                          last_k=n // block_workers - 1),
        grid=grid,
        in_specs=[spec_f, spec_y, spec_f, spec_f,
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[spec_f, spec_f],
        out_shape=[jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
                   jax.ShapeDtypeStruct(q_pilot.shape, jnp.uint32)],
        interpret=interpret,
    )(q_pilot, masked, p1, p2, scal, sumw)
    return out
