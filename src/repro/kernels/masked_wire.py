"""Fused Pallas kernels for the secure-aggregation (masked) wire path.

Two kernels mirror the plaintext pair of ``fused_wire`` and keep the round
at exactly two launches when privacy is on:

``ternary_pack_masked_2d`` — the masked uplink. Fuses Eq. (4)/(5)
ternarization -> bias to fields {0, 1, 2} -> 3-ary randomized response
(local DP, threshold 0 = off) -> fixed-point weighting by the public
per-worker ``W_k`` -> pairwise-mask addition, all in-register: float
history views in, uint16/uint32 masked words out. The plaintext code NEVER
exists outside VMEM registers — what reaches HBM (and then the wire) is
already masked.

The pairwise mask and RR streams are generated INSIDE the kernel from the
counter PRNG of ``repro.privacy.masking``: the launch consumes only the
tiny per-pair key matrix ``(N, L)``, the antisymmetric sign matrix (with
participation folded in) and the ``(N,)`` RR key vector — never an
``(N, rows, 512)`` mask tensor. Each tile hashes its absolute element
counters once (``mix32(base + local index)``) and reuses that hash across
every pair stream of the tile (only the ``+ key`` finalizer differs per
stream — the worker-minor batching that hides PRNG cost). At the 16-bit
modulus one 32-bit stream word feeds two adjacent lanes, halving the
hashing work, with the two 16-bit halves accumulated in separate planes
and re-paired by a single shift|or + bitcast (never a per-stream lane
shuffle). Whenever the whole cohort is resident, each unordered pair's
stream is evaluated ONCE and ±accumulated into both endpoints —
n(n-1)/2 stream expansions instead of n^2 — and large tiles run the
whole pipeline as a cache-resident sweep over row chunks.

``masked_master_update_2d`` — the sum-then-unmask master. Walks the same
2-D (rows, workers) grid as ``packed_master_update_2d``, accumulating the
masked words into a revisited accumulator block in the WIRE dtype (native
modular wrap — mod 2**16 or 2**32). Because the accumulation is modular,
the pairwise masks cancel EXACTLY once all workers are folded — the master
never observes an individual worker's ternary directions, only the sum —
and the result is bitwise invariant under every block plan *and* every
reduction order. The last worker step de-biases in the integer domain
(subtract the public ``sum_k W_k`` mod the modulus), reinterprets the
residue as the same-width SIGNED int (exact by the ``sum w_k <= 1`` +
fixpoint-headroom bound), descales by the fixed-point multiplier (with the
RR unbias folded in), and applies the Eq. (3) combine.

Wire cost: one word per parameter — 8x the 2-bit plaintext wire at the
16-bit modulus (16x at 32). The overhead is benchmarked per modulus in
``benchmarks/kernels_bench.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_wire import _codes_any
from repro.privacy import masking as pvm
from repro.telemetry import profile as tprof
from repro.privacy.dp import rr_fields

LANES = 128
PACK = 4
BLOCK_ROWS = 64
BLOCK_WORKERS = 1
# Rows per mask-net accumulation chunk inside one uplink tile (keeps the
# full pair-stream working set cache-resident on CPU; a no-op for tiles
# at or under this size).
_NET_CHUNK_ROWS = 256


def _tile_hash(base_u32, rows: int, width: int, word_bits: int):
    """The shared counter hash of one (rows, width) tile whose first
    element sits at absolute flat index ``base_u32`` (tiles always span
    full rows, so the flat index is ``base + r*width + c``). At the
    16-bit modulus the hash covers element PAIRS — half the entries,
    expanded by ``halves16`` per stream."""
    if word_bits == 16:
        w2 = width // 2
        r = jax.lax.broadcasted_iota(jnp.uint32, (rows, w2), 0)
        c = jax.lax.broadcasted_iota(jnp.uint32, (rows, w2), 1)
        return pvm.mix32(base_u32 // jnp.uint32(2)
                         + r * jnp.uint32(w2) + c)
    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, width), 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, width), 1)
    return pvm.mix32(base_u32 + r * jnp.uint32(width) + c)


def _stream_i32(key, hashed, word_bits: int):
    """One pair stream over a tile as SIGNED values for the ± net
    accumulation: int32 words at 32 bits (bit pattern preserved), or
    zero-extended 16-bit values at 16 (mod-2**16 congruent either way)."""
    vals = pvm.stream_values(key, hashed, word_bits)
    if word_bits == 16:
        return vals.astype(jnp.int32)
    return jax.lax.bitcast_convert_type(vals, jnp.int32)


def _masked_pack_kernel(q_ref, p1_ref, p2_ref, beta_ref, wq_ref, keys_ref,
                        signs_ref, rrk_ref, scal_ref, out_ref, *,
                        cohort: int, word_bits: int, use_masks: bool,
                        rr_threshold: int, gridded: bool):
    """Masked-uplink tile: ternarize -> RR -> weight -> in-register mask
    streams -> truncate to the wire word. ``cohort`` is the total worker
    count L of the key matrix (== N in the simulator's stacked call, the
    fed size in the distributed N=1 slab call)."""
    bw, br, wide = q_ref.shape
    t, alpha1 = scal_ref[0], scal_ref[1]
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    beta = beta_ref[...].astype(jnp.float32)[:, :, None]
    wq = wq_ref[...][:, :, None]                       # (bw, 1, 1) uint32
    if gridded:
        base = (jnp.asarray(pl.program_id(0), jnp.uint32)
                * jnp.uint32(br * wide))
        w0 = pl.program_id(1) * bw
    else:
        base = jnp.uint32(0)
        w0 = 0
    keys = keys_ref[...]                               # (N, L) uint32
    signs = signs_ref[...]                             # (N, L) int32
    rrk = rrk_ref[...]                                 # (N,) uint32

    def slab(base_c, qc, p1c, p2c):
        """The full uplink pipeline over one row slab starting at absolute
        flat element ``base_c``: ternarize -> RR -> weight -> mask ->
        wire words (bw, rows_c, wide)."""
        rows_c = qc.shape[1]
        code = _codes_any(qc, p1c[None], p2c[None], t, beta, alpha1)
        field = (code + 1.0).astype(jnp.uint32)        # exact for {0, 1, 2}
        if rr_threshold:
            h_rr = _tile_hash(base_c, rows_c, wide, 32)   # RR: full words
            rr = jnp.stack([pvm.mask_stream(rrk[w0 + j], h_rr)
                            for j in range(bw)])
            field = rr_fields(field, rr, jnp.uint32(rr_threshold))
        if word_bits == 16:
            # 16-bit lane arithmetic throughout: wq < 2**fb <= 2**14 and
            # field <= 2 keep the product exact in uint16 (and mod-2**16
            # congruent regardless) — half-width SIMD lanes for free.
            accc = wq.astype(jnp.uint16) * field.astype(jnp.uint16)
        else:
            accc = wq * field                          # mod 2**32
        if use_masks:
            accc = accc + net_words(base_c, rows_c)
        return accc

    def net_words(base_c, rows_c):
        """All resident workers' net mask words over a ``rows_c``-row
        slab starting at absolute flat element ``base_c``, in the wire
        dtype: (bw, rows_c, wide)."""
        h_m = _tile_hash(base_c, rows_c, wide, word_bits)
        if word_bits == 16:
            # Half-width path: one 32-bit stream word covers two
            # adjacent 16-bit lanes, but the lanes are NEVER
            # interleaved per pair (a stride-2 shuffle per stream
            # kills vectorization — measured 10x on XLA:CPU). The
            # low/high halves accumulate in separate half-width
            # planes instead.
            nplanes, pw = 2, wide // 2

            def expand(key):
                u = pvm.mask_stream(key, h_m)
                return ((u & jnp.uint32(0xFFFF)).astype(jnp.int32),
                        (u >> jnp.uint32(16)).astype(jnp.int32))
        else:
            nplanes, pw = 1, wide

            def expand(key):
                v = pvm.mask_stream(key, h_m)
                return (jax.lax.bitcast_convert_type(v, jnp.int32),)
        zeros = functools.partial(jnp.zeros, (rows_c, pw), jnp.int32)
        if bw == cohort:
            # Whole cohort resident (any row blocking): each
            # unordered pair expands ONCE and ±folds into both
            # endpoints — n(n-1)/2 stream expansions instead of n^2.
            nets = [[zeros() for _ in range(bw)]
                    for _ in range(nplanes)]
            for i in range(bw):
                for j in range(i + 1, bw):
                    s = signs[i, j]
                    for plane, v in zip(nets, expand(keys[i, j])):
                        sv = s * v
                        plane[i] = plane[i] + sv
                        plane[j] = plane[j] - sv
        else:
            # Grid / slab path: each resident worker folds its row
            # of the key matrix (self/inactive pairs sign-zeroed —
            # w0 + j is traced, the cases cannot be pruned
            # statically).
            nets = [[] for _ in range(nplanes)]
            for j in range(bw):
                w_abs = w0 + j
                accs = [zeros() for _ in range(nplanes)]
                for l in range(cohort):
                    s = signs[w_abs, l]
                    accs = [p + s * v for p, v in
                            zip(accs, expand(keys[w_abs, l]))]
                for plane, a in zip(nets, accs):
                    plane.append(a)
        if word_bits == 32:
            return jax.lax.bitcast_convert_type(
                jnp.stack(nets[0]), jnp.uint32)
        # Pack the half-planes back into words with shift|or and
        # let bitcast split uint32 -> two uint16 lanes, least-
        # significant first — the interleaved lane order as a pure
        # reinterpret (a stride-2 stack/reshape shuffle here
        # measures ~5x slower on XLA:CPU). Low 16 bits of the int32
        # accumulators are exactly the mod-2**16 residues.
        los, his = nets
        words = []
        for k in range(bw):
            lo_u = (jax.lax.bitcast_convert_type(los[k], jnp.uint32)
                    & jnp.uint32(0xFFFF))
            hi_u = (jax.lax.bitcast_convert_type(his[k], jnp.uint32)
                    << jnp.uint32(16))
            words.append(jax.lax.bitcast_convert_type(
                lo_u | hi_u, jnp.uint16).reshape(rows_c, wide))
        return jnp.stack(words)

    # Row-chunked execution: XLA:CPU otherwise materializes every pair
    # stream (and the codes/fields) tile-size, ~2x the masked latency in
    # pure memory traffic at 1M params; a fori_loop over row chunks runs
    # the whole pipeline cache-resident in one sweep. Bitwise invariant —
    # each chunk hashes its own absolute counter range.
    if use_masks and br > _NET_CHUNK_ROWS and br % _NET_CHUNK_ROWS == 0:
        chunk = _NET_CHUNK_ROWS
        wdtype = jnp.uint16 if word_bits == 16 else jnp.uint32

        def fold(c, out):
            r0 = c * chunk
            base_c = base + (c * (chunk * wide)).astype(jnp.uint32)
            qc = jax.lax.dynamic_slice(q, (0, r0, 0), (bw, chunk, wide))
            p1c = jax.lax.dynamic_slice(p1, (r0, 0), (chunk, wide))
            p2c = jax.lax.dynamic_slice(p2, (r0, 0), (chunk, wide))
            return jax.lax.dynamic_update_slice(
                out, slab(base_c, qc, p1c, p2c), (0, r0, 0))

        out_ref[...] = jax.lax.fori_loop(
            0, br // chunk, fold, jnp.zeros((bw, br, wide), wdtype))
    else:
        out_ref[...] = slab(base, q, p1, p2)


def _masked_master_kernel(q_ref, y_ref, p1_ref, p2_ref, scal_ref, sumw_ref,
                          out_ref, acc_ref, *, block_workers: int,
                          last_k: int, word_bits: int):
    """One (row block, worker block) step of the sum-then-unmask master.

    ``acc_ref`` is the revisited accumulator output in the wire dtype (its
    block index ignores the worker axis; the wrapper discards it): step
    k == 0 zeroes it, every step folds its workers mod 2**word_bits, the
    last step unmasks — integer de-bias, signed reinterpretation,
    fixed-point descale — and writes the Eq. (3) combine into ``out_ref``.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    acc = acc_ref[...]
    for j in range(block_workers):        # modular: order can't change bits
        acc = acc + y_ref[j]
    acc_ref[...] = acc

    @pl.when(k == last_k)
    def _combine():
        t, alpha0, smult = scal_ref[0], scal_ref[1], scal_ref[2]
        signed = jnp.int16 if word_bits == 16 else jnp.int32
        ci = jax.lax.bitcast_convert_type(acc_ref[...] - sumw_ref[0],
                                          signed)
        coeff = ci.astype(jnp.float32) * smult
        step = (p1_ref[...].astype(jnp.float32)
                - p2_ref[...].astype(jnp.float32))
        mult = jnp.where(t <= 1.0, alpha0, step)
        q = q_ref[...].astype(jnp.float32)
        out_ref[...] = (q - coeff * mult).astype(out_ref.dtype)


def _masked_master_oneshot_kernel(q_ref, y_ref, p1_ref, p2_ref, scal_ref,
                                  sumw_ref, out_ref, *, n_workers: int,
                                  word_bits: int):
    """Single-step plan (the cpu-interpret optimum): same modular math."""
    acc = jnp.zeros((q_ref.shape[0], LANES * PACK), y_ref.dtype)
    for j in range(n_workers):
        acc = acc + y_ref[j]
    t, alpha0, smult = scal_ref[0], scal_ref[1], scal_ref[2]
    signed = jnp.int16 if word_bits == 16 else jnp.int32
    ci = jax.lax.bitcast_convert_type(acc - sumw_ref[0], signed)
    coeff = ci.astype(jnp.float32) * smult
    step = p1_ref[...].astype(jnp.float32) - p2_ref[...].astype(jnp.float32)
    mult = jnp.where(t <= 1.0, alpha0, step)
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = (q - coeff * mult).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rr_threshold", "word_bits",
                                             "use_masks", "interpret",
                                             "block_rows", "block_workers"))
def ternary_pack_masked_2d(q, p1, p2, t, beta, alpha1, wq, pair_keys,
                           pair_signs, rr_keys, *, rr_threshold: int = 0,
                           word_bits: int = 32, use_masks: bool = True,
                           interpret: bool = True,
                           block_rows: int = BLOCK_ROWS,
                           block_workers: int = BLOCK_WORKERS):
    """Masked uplink: all N workers' secure-agg wire words from ONE launch.

    q (N, R, 512) float history views; p1/p2 (R, 512) shared public
    history; ``beta`` a scalar or (N,) per-worker Eq. (5) threshold; wq
    (N,) uint32 fixed-point Eq. (3) weights (public); ``pair_keys``
    (N, L) uint32 pair stream keys (``masking.pair_stream_keys`` rows —
    L = cohort size, == N here or the fed size for a 1-row slab call);
    ``pair_signs`` (N, L) int32 antisymmetric signs with participation
    folded in; ``rr_keys`` (N,) uint32 per-worker RR stream keys;
    ``rr_threshold`` the STATIC uint16 flip threshold (0 = DP off — the
    RR stream is never generated); ``use_masks`` static (False skips mask
    generation entirely — the unmasked debug wire). ``t`` may be traced.
    Returns (N, R, 512) in the wire dtype (uint16 at ``word_bits=16``,
    else uint32) — already masked when it first touches HBM.
    """
    n, rows, _ = q.shape
    cohort = pair_keys.shape[1]
    out_dtype = jnp.uint16 if word_bits == 16 else jnp.uint32
    kind = "uplink_masked16" if word_bits == 16 else "uplink_masked"
    betas = jnp.broadcast_to(
        jnp.asarray(beta, jnp.float32).reshape(-1, 1), (n, 1))
    wq2 = jnp.asarray(wq, jnp.uint32).reshape(n, 1)
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha1, jnp.float32)])
    keys = jnp.asarray(pair_keys, jnp.uint32)
    signs = jnp.asarray(pair_signs, jnp.int32)
    rrk = jnp.asarray(rr_keys, jnp.uint32).reshape(n)
    with tprof.kernel_scope(kind, rows, n, interpret):
        return _masked_pack_call(
            q, p1, p2, betas, wq2, keys, signs, rrk, scal, n=n, rows=rows,
            cohort=cohort, word_bits=word_bits, use_masks=use_masks,
            rr_threshold=rr_threshold, out_dtype=out_dtype,
            interpret=interpret, block_rows=block_rows,
            block_workers=block_workers)


def _masked_pack_call(q, p1, p2, betas, wq2, keys, signs, rrk, scal, *, n,
                      rows, cohort, word_bits, use_masks, rr_threshold,
                      out_dtype, interpret, block_rows, block_workers):
    wide = LANES * PACK
    if block_rows >= rows and block_workers >= n:
        return pl.pallas_call(
            functools.partial(_masked_pack_kernel, cohort=cohort,
                              word_bits=word_bits, use_masks=use_masks,
                              rr_threshold=rr_threshold, gridded=False),
            in_specs=[pl.BlockSpec(q.shape, None),
                      pl.BlockSpec(p1.shape, None),
                      pl.BlockSpec(p2.shape, None),
                      pl.BlockSpec(betas.shape, None),
                      pl.BlockSpec(wq2.shape, None),
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((n, rows, wide), None),
            out_shape=jax.ShapeDtypeStruct((n, rows, wide), out_dtype),
            interpret=interpret,
        )(q, p1, p2, betas, wq2, keys, signs, rrk, scal)
    grid = (rows // block_rows, n // block_workers)
    q_spec = pl.BlockSpec((block_workers, block_rows, wide),
                          lambda i, k: (k, i, 0))
    h_spec = pl.BlockSpec((block_rows, wide), lambda i, k: (i, 0))
    w_spec = pl.BlockSpec((block_workers, 1), lambda i, k: (k, 0))
    return pl.pallas_call(
        functools.partial(_masked_pack_kernel, cohort=cohort,
                          word_bits=word_bits, use_masks=use_masks,
                          rr_threshold=rr_threshold, gridded=True),
        grid=grid,
        in_specs=[q_spec, h_spec, h_spec, w_spec, w_spec,
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((n, rows, wide), out_dtype),
        interpret=interpret,
    )(q, p1, p2, betas, wq2, keys, signs, rrk, scal)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "block_workers"))
def masked_master_update_2d(q_pilot, masked, sum_wq, p1, p2, t, alpha0,
                            scale_mult, *, interpret: bool = True,
                            block_rows: int = BLOCK_ROWS,
                            block_workers: int = BLOCK_WORKERS):
    """Sum-then-unmask Eq. (3) over masked wire words.

    q_pilot/p1/p2 (R, 512) float; masked (N, R, 512) uint16 or uint32 (the
    wire dtype picks the modulus); ``sum_wq`` the public scalar
    ``sum_k W_k`` (uint32 — truncated to the modulus here); ``scale_mult``
    the fixed-point descale with the RR unbias folded in; ``t`` may be
    traced. Returns (R, 512) in q_pilot.dtype. Bitwise invariant under
    every (block_rows, block_workers) plan — modular accumulation is
    order-free.
    """
    n, rows, _ = masked.shape
    word_bits = 16 if masked.dtype == jnp.uint16 else 32
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha0, jnp.float32),
                      jnp.asarray(scale_mult, jnp.float32)])
    sumw = jnp.asarray(sum_wq, jnp.uint32)
    if word_bits == 16:
        sumw = (sumw & jnp.uint32(0xFFFF)).astype(jnp.uint16)
    sumw = sumw.reshape(1)
    kind = "master_masked16" if word_bits == 16 else "master_masked"
    with tprof.kernel_scope(kind, rows, n, interpret):
        if block_rows >= rows and block_workers >= n:
            return pl.pallas_call(
                functools.partial(_masked_master_oneshot_kernel, n_workers=n,
                                  word_bits=word_bits),
                in_specs=[pl.BlockSpec(q_pilot.shape, None),
                          pl.BlockSpec(masked.shape, None),
                          pl.BlockSpec(p1.shape, None),
                          pl.BlockSpec(p2.shape, None),
                          pl.BlockSpec(memory_space=pl.ANY),
                          pl.BlockSpec(memory_space=pl.ANY)],
                out_specs=pl.BlockSpec(q_pilot.shape, None),
                out_shape=jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
                interpret=interpret,
            )(q_pilot, masked, p1, p2, scal, sumw)
        grid = (rows // block_rows, n // block_workers)
        spec_f = pl.BlockSpec((block_rows, LANES * PACK), lambda i, k: (i, 0))
        spec_y = pl.BlockSpec((block_workers, block_rows, LANES * PACK),
                              lambda i, k: (k, i, 0))
        out, _acc = pl.pallas_call(
            functools.partial(_masked_master_kernel,
                              block_workers=block_workers,
                              last_k=n // block_workers - 1,
                              word_bits=word_bits),
            grid=grid,
            in_specs=[spec_f, spec_y, spec_f, spec_f,
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=[spec_f, spec_f],
            out_shape=[jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
                       jax.ShapeDtypeStruct(q_pilot.shape, masked.dtype)],
            interpret=interpret,
        )(q_pilot, masked, p1, p2, scal, sumw)
        return out


def _mask_repair_kernel(y_ref, keys_ref, coeff_ref, out_ref, *,
                        n_pairs: int, word_bits: int, gridded: bool):
    """Dropout-repair tile: fold ``coeff[p] * stream(keys[p])`` for every
    repair pair into a (rows, wide) slab of masked wire words, mod
    2**word_bits. The accumulation planes START from the slab's own words,
    so the repaired output is one pass — no separate residue tensor.
    Zero-coefficient pairs (the common case: coeff is nonzero only for
    dead-live pairs) skip their stream expansion via ``lax.cond``."""
    br, wide = y_ref.shape
    base = (jnp.asarray(pl.program_id(0), jnp.uint32)
            * jnp.uint32(br * wide) if gridded else jnp.uint32(0))
    keys = keys_ref[...]                                   # (P,) uint32
    coeff = coeff_ref[...]                                 # (P,) int32
    h = _tile_hash(base, br, wide, word_bits)
    y = y_ref[...]
    if word_bits == 16:
        # Same half-plane layout as the uplink: reinterpret uint16 lane
        # pairs as uint32 words (low lane first), accumulate lo/hi in
        # separate int32 planes, repack with shift|or at the end.
        pw = wide // 2
        w0 = jax.lax.bitcast_convert_type(y.reshape(br, pw, 2), jnp.uint32)
        planes0 = ((w0 & jnp.uint32(0xFFFF)).astype(jnp.int32),
                   (w0 >> jnp.uint32(16)).astype(jnp.int32))

        def expand(key):
            u = pvm.mask_stream(key, h)
            return ((u & jnp.uint32(0xFFFF)).astype(jnp.int32),
                    (u >> jnp.uint32(16)).astype(jnp.int32))
    else:
        planes0 = (jax.lax.bitcast_convert_type(y, jnp.int32),)

        def expand(key):
            return (jax.lax.bitcast_convert_type(
                pvm.mask_stream(key, h), jnp.int32),)

    def fold(p, planes):
        c = coeff[p]
        return jax.lax.cond(
            c == 0, lambda ps: ps,
            lambda ps: tuple(a + c * v
                             for a, v in zip(ps, expand(keys[p]))),
            planes)

    planes = jax.lax.fori_loop(0, n_pairs, fold, planes0)
    if word_bits == 16:
        lo, hi = planes
        lo_u = (jax.lax.bitcast_convert_type(lo, jnp.uint32)
                & jnp.uint32(0xFFFF))
        hi_u = (jax.lax.bitcast_convert_type(hi, jnp.uint32)
                << jnp.uint32(16))
        out_ref[...] = jax.lax.bitcast_convert_type(
            lo_u | hi_u, jnp.uint16).reshape(br, wide)
    else:
        out_ref[...] = jax.lax.bitcast_convert_type(planes[0], jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def mask_repair_2d(y, pair_keys, pair_coeff, *, interpret: bool = True,
                   block_rows: int = BLOCK_ROWS):
    """Repair a masked-word slab after post-uplink deaths, in one launch.

    ``y`` (R, 512) wire words (uint16/uint32 picks the modulus);
    ``pair_keys`` (P,) uint32 stream keys and ``pair_coeff`` (P,) int32
    coefficients from ``privacy.recovery.repair_coefficients`` — the term
    ``sum_p coeff[p] * stream(keys[p])`` is added mod 2**modulus_bits.
    The stream geometry (flat element index ``r * 512 + c``, halved at the
    16-bit modulus) is exactly the uplink kernel's, so a dead worker's
    regenerated words are bitwise the ones it committed. Bitwise invariant
    under ``block_rows`` (modular addition; each tile hashes its own
    absolute counter range).
    """
    rows, wide = y.shape
    n_pairs = int(pair_keys.shape[0])
    if n_pairs == 0:
        return y
    word_bits = 16 if y.dtype == jnp.uint16 else 32
    keys = jnp.asarray(pair_keys, jnp.uint32)
    coeff = jnp.asarray(pair_coeff, jnp.int32)
    kern = functools.partial(_mask_repair_kernel, n_pairs=n_pairs,
                             word_bits=word_bits)
    kind = "mask_repair16" if word_bits == 16 else "mask_repair"
    with tprof.kernel_scope(kind, rows, 1, interpret):
        if block_rows >= rows:
            return pl.pallas_call(
                functools.partial(kern, gridded=False),
                in_specs=[pl.BlockSpec(y.shape, None),
                          pl.BlockSpec(memory_space=pl.ANY),
                          pl.BlockSpec(memory_space=pl.ANY)],
                out_specs=pl.BlockSpec(y.shape, None),
                out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
                interpret=interpret,
            )(y, keys, coeff)
        spec = pl.BlockSpec((block_rows, wide), lambda i: (i, 0))
        return pl.pallas_call(
            functools.partial(kern, gridded=True),
            grid=(rows // block_rows,),
            in_specs=[spec,
                      pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
            interpret=interpret,
        )(y, keys, coeff)
