"""Fused Pallas TPU kernels for the FedPC flat wire path.

Two kernel families cover the whole per-round wire cost over the
``FlatParams`` buffer (``repro.core.flat``):

``ternary_pack_2d`` / ``ternary_pack_round1_2d`` — worker uplink. Fuses
Eq. (5) (resp. Eq. (4)) ternarization *directly* into the §3.3 2-bit packed
wire format: float (R, 512) history views in, uint8 (R, 128) packed codes
out. The separate int8 code tensor of the two-kernel composition
(``ternary_encode`` → ``pack2bit``) — 4× the wire size, written to and
re-read from HBM — never exists: codes live only in VMEM registers.
``ternary_pack_any_2d`` carries the round index as a scalar operand so a
traced ``t`` selects the Eq. (4)/(5) branch in-register (for jit'd round
loops).

``ternary_pack_stacked_2d`` batches all N workers' uplinks into ONE launch
over a (N, R, 512) stack. The grid is **rows-major with the worker axis
minor**: for one row block the kernel steps through consecutive worker
blocks, so the shared ``p1``/``p2`` history blocks keep the same block index
across those steps and are fetched once per row block instead of once per
(worker, row) step — N× less history traffic than the old worker-major
order. ``block_workers`` workers ride in each block (vectorized, still
register-only); when the plan collapses to one step (``block_rows == R`` and
``block_workers == N`` — the cpu-interpret optimum, where per-step
machinery dominates) the launch drops the grid entirely. The Eq. (5)
threshold may be a per-worker ``(N,)`` beta vector (heterogeneous beta_k):
it rides as a (N, 1) operand blocked over the worker axis — no dynamic
in-kernel indexing.

``packed_master_update_2d`` — master downlink side of Eq. (3). Consumes the
*packed* uint8 codes of all N workers on a 2-D ``(rows, workers)`` grid and
**accumulates** the weighted ternary sum into a revisited output block: the
output's block index ignores the (minor) worker axis, so it stays resident
in VMEM while the grid walks the workers, collecting Σ_k w_k T_k in place;
the final worker step folds in the Eq. (3) combine (q − coeff·mult). VMEM
per step is O(block) — independent of N — so federations scale past the
paper's 10 nodes without growing the tile. The 2-bit decode is bit
arithmetic on the packed byte (broadcast divide by powers of four — no
``jnp.stack``, no (N, R, 128, 4) intermediate) with the per-worker ``w[k]``
multiply folded straight into the decoded field. Worker contributions are
accumulated strictly sequentially (k = 0..N−1), so the result is **bitwise
invariant across every (block_rows, block_workers) plan** — autotuning can
never change the math (``kernels.ref.packed_master_accum_ref`` is the
order-exact oracle). Both round branches of Eq. (3) (t == 1 uses
``alpha0``, t > 1 uses P^{t-1} − P^{t-2}) are computed from scalar operands
so the round index may be traced.

Layout: the flat (rows, 128) buffer is viewed as (rows/4, 512) so that the
four *consecutive* codes forming each wire byte sit in the last axis —
exactly the §3.3 / ``core.packing.pack2bit`` byte order. Shifts are
multiplies/divides by powers of two (VPU-safe, exact for 2-bit fields);
the pack runs in float (exact for the 0..170 byte range), one cast out.

Block sizes: callers normally leave ``block_rows``/``block_workers`` to the
``repro.kernels.tune`` autotuner (via the ``ops`` wrappers); the module
defaults here are the TPU-shaped fallbacks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
PACK = 4
BLOCK_ROWS = 64            # (64, 512) fp32 tile = 128 KiB per input
BLOCK_WORKERS = 1          # one worker per step → master VMEM is O(block)


def _codes_eq5(q, p1, p2, beta):
    """Eq. (5) codes in-register: float tiles → float {-1, 0, +1}."""
    step = p1 - p2
    delta = q - p1
    significant = jnp.abs(delta) >= beta * jnp.abs(step)
    return jnp.where(significant, jnp.sign(delta * step), 0.0)

def _codes_eq4(q, p0, alpha):
    """Eq. (4) round-1 codes in-register vs the public init P^0."""
    d = q - p0
    return (d > alpha).astype(jnp.float32) - (d < -alpha).astype(jnp.float32)


def _codes_any(q, p1, p2, t, beta, alpha1):
    """Round-branch select on a (possibly traced) round index: Eq. (4) at
    t <= 1 (p1 slot holds P^0), Eq. (5) after. Both branches share the
    ``q - p1`` evolution and are in-register VPU ops, so evaluating both
    costs no HBM traffic."""
    delta = q - p1
    step = p1 - p2
    c5 = jnp.where(jnp.abs(delta) >= beta * jnp.abs(step),
                   jnp.sign(delta * step), 0.0)
    c4 = ((delta > alpha1).astype(jnp.float32)
          - (delta < -alpha1).astype(jnp.float32))
    return jnp.where(t <= 1.0, c4, c5)


def _pack_tile(codes):
    """(..., 512) float codes → (..., 128) uint8, 4 consecutive codes/byte.

    Packed in float (biased fields 0..2, byte value ≤ 170 — exact in fp32)
    with a single cast out: one dtype conversion instead of the int32
    round-trip, measurably faster on XLA:CPU and identical bits.
    """
    lead = codes.shape[:-1]
    b = (codes + 1.0).reshape(*lead, LANES, PACK)
    byte = b[..., 0] + b[..., 1] * 4.0 + b[..., 2] * 16.0 + b[..., 3] * 64.0
    return byte.astype(jnp.uint8)


def _weighted_decode(b, w):
    """(R, 128) packed byte + scalar w → (R, 512) float32 ``w · code``.

    Pure bit arithmetic on the byte: a broadcast divide by [1, 4, 16, 64]
    (powers of four built from a shifted iota — VPU-safe, no closed-over
    array constant) isolates the four 2-bit fields, and the ``w`` multiply
    is folded into the de-bias (``w·field − w`` = ``w·(field − 1)``) so the
    bare {-1, 0, 1} code tensor never materializes.
    """
    bi = b.astype(jnp.int32)[:, :, None]                   # (R, 128, 1)
    e = jax.lax.broadcasted_iota(jnp.int32, (1, 1, PACK), 2)
    fields = (bi // jax.lax.shift_left(jnp.int32(1), 2 * e)) % 4
    wf = fields.astype(jnp.float32) * w - w                # w · (field − 1)
    return wf.reshape(b.shape[0], LANES * PACK)


def _ternary_pack_kernel(q_ref, p1_ref, p2_ref, beta_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    out_ref[...] = _pack_tile(_codes_eq5(q, p1, p2, beta_ref[0]))


def _ternary_pack_round1_kernel(q_ref, p0_ref, alpha_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p0 = p0_ref[...].astype(jnp.float32)
    out_ref[...] = _pack_tile(_codes_eq4(q, p0, alpha_ref[0]))


def _ternary_pack_any_kernel(q_ref, p1_ref, p2_ref, scal_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    t, beta, alpha1 = scal_ref[0], scal_ref[1], scal_ref[2]
    out_ref[...] = _pack_tile(_codes_any(q, p1, p2, t, beta, alpha1))


def _stacked_kernel(q_ref, p1_ref, p2_ref, beta_ref, scal_ref, out_ref):
    """One (block_workers, block_rows) step of the stacked uplink —
    vectorized over the worker-block axis, shared history broadcast."""
    t, alpha1 = scal_ref[0], scal_ref[1]
    q = q_ref[...].astype(jnp.float32)                 # (bw, br, 512)
    p1 = p1_ref[...].astype(jnp.float32)[None]         # shared history block
    p2 = p2_ref[...].astype(jnp.float32)[None]
    beta = beta_ref[...].astype(jnp.float32)[:, :, None]   # (bw, 1, 1)
    out_ref[...] = _pack_tile(_codes_any(q, p1, p2, t, beta, alpha1))


def _master_accum_kernel(q_ref, pk_ref, w_ref, p1_ref, p2_ref, scal_ref,
                         out_ref, *, block_workers: int, last_k: int):
    """One (row block, worker block) step of the accumulating master.

    The output block is revisited across the (minor) worker axis: step
    k == 0 zeroes it, every step folds its workers' weighted codes in
    strictly ascending order, and the last worker step applies Eq. (3).
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    acc = out_ref[...].astype(jnp.float32)
    for j in range(block_workers):                     # sequential: bitwise
        acc = acc + _weighted_decode(pk_ref[j], w_ref[j, 0])
    out_ref[...] = acc.astype(out_ref.dtype)

    @pl.when(k == last_k)
    def _combine():
        t, alpha0 = scal_ref[0], scal_ref[1]
        step = (p1_ref[...].astype(jnp.float32)
                - p2_ref[...].astype(jnp.float32))
        mult = jnp.where(t <= 1.0, alpha0, step)       # Eq. (3) branches
        coeff = out_ref[...].astype(jnp.float32)
        q = q_ref[...].astype(jnp.float32)
        out_ref[...] = (q - coeff * mult).astype(out_ref.dtype)


def _master_oneshot_kernel(q_ref, pk_ref, w_ref, p1_ref, p2_ref, scal_ref,
                           out_ref, *, n_workers: int):
    """Single-step master (cpu-interpret plan): same strictly-sequential
    worker accumulation as the grid kernel — bitwise identical output."""
    acc = jnp.zeros((q_ref.shape[0], LANES * PACK), jnp.float32)
    for j in range(n_workers):
        acc = acc + _weighted_decode(pk_ref[j], w_ref[j, 0])
    t, alpha0 = scal_ref[0], scal_ref[1]
    step = p1_ref[...].astype(jnp.float32) - p2_ref[...].astype(jnp.float32)
    mult = jnp.where(t <= 1.0, alpha0, step)
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = (q - acc * mult).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_2d(q, p1, p2, beta, *, interpret: bool = True,
                    block_rows: int = BLOCK_ROWS):
    """q/p1/p2 (R, 512) float, R % block_rows == 0 → uint8 (R, 128).

    Equals ``pack2bit_2d(ternary_encode_2d(q, p1, p2, beta))`` with zero
    int8 HBM intermediate and a single launch.
    """
    rows = q.shape[0]
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _ternary_pack_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p1, p2, jnp.asarray([beta], jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_round1_2d(q, p0, alpha, *, interpret: bool = True,
                           block_rows: int = BLOCK_ROWS):
    """Round-1 (Eq. (4)) variant of :func:`ternary_pack_2d`."""
    rows = q.shape[0]
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _ternary_pack_round1_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p0, jnp.asarray([alpha], jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_any_2d(q, p1, p2, t, beta, alpha1, *, interpret: bool = True,
                        block_rows: int = BLOCK_ROWS):
    """Traced-round fused uplink: Eq. (4) at t <= 1, Eq. (5) after.

    Same layout as :func:`ternary_pack_2d`, but the round index ``t`` (and
    both thresholds) travel as scalar operands so one compiled kernel serves
    every round — required inside jit'd round loops (the distributed sync)
    where ``t`` is traced.
    """
    rows = q.shape[0]
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(beta, jnp.float32),
                      jnp.asarray(alpha1, jnp.float32)])
    return pl.pallas_call(
        _ternary_pack_any_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p1, p2, scal)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "block_workers"))
def ternary_pack_stacked_2d(q, p1, p2, t, beta, alpha1, *,
                            interpret: bool = True,
                            block_rows: int = BLOCK_ROWS,
                            block_workers: int = BLOCK_WORKERS):
    """Batched uplink: all N workers' wire buffers from ONE launch.

    q (N, R, 512) — every worker's history view; p1/p2 (R, 512) — the shared
    public history passed once, not stacked N times. Grid is
    (R/block_rows, N/block_workers) — **rows-major, worker minor**, so the
    history blocks keep their block index across the consecutive worker
    steps of one row block and are re-fetched per *row block*, not per
    (worker, row) step. Blocks are vectorized over ``block_workers``
    workers; ``block_rows == R`` with ``block_workers == N`` collapses to a
    grid-less single-step launch (the cpu-interpret optimum — see
    ``repro.kernels.tune``). Every plan packs bitwise-identically (the math
    is elementwise).

    ``beta`` is either one scalar (shared threshold) or a ``(N,)`` vector of
    per-worker beta_k — worker k's blocks read ``beta[k]`` via the blocked
    (block_workers, 1) operand. Returns (N, R, 128) uint8.
    """
    n, rows, _ = q.shape
    betas = jnp.broadcast_to(
        jnp.asarray(beta, jnp.float32).reshape(-1, 1), (n, 1))
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha1, jnp.float32)])
    if block_rows >= rows and block_workers >= n:
        # One step: whole-operand blocks, no grid — skips the per-step
        # block machinery entirely (interpret mode pays it per step).
        return pl.pallas_call(
            _stacked_kernel,
            in_specs=[pl.BlockSpec(q.shape, None),
                      pl.BlockSpec(p1.shape, None),
                      pl.BlockSpec(p2.shape, None),
                      pl.BlockSpec(betas.shape, None),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((n, rows, LANES), None),
            out_shape=jax.ShapeDtypeStruct((n, rows, LANES), jnp.uint8),
            interpret=interpret,
        )(q, p1, p2, betas, scal)
    grid = (rows // block_rows, n // block_workers)
    q_spec = pl.BlockSpec((block_workers, block_rows, LANES * PACK),
                          lambda i, k: (k, i, 0))
    h_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i, k: (i, 0))
    beta_spec = pl.BlockSpec((block_workers, 1), lambda i, k: (k, 0))
    out_spec = pl.BlockSpec((block_workers, block_rows, LANES),
                            lambda i, k: (k, i, 0))
    return pl.pallas_call(
        _stacked_kernel,
        grid=grid,
        in_specs=[q_spec, h_spec, h_spec, beta_spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p1, p2, betas, scal)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "block_workers"))
def packed_master_update_2d(q_pilot, packed, w, p1, p2, t, alpha0, *,
                            interpret: bool = True,
                            block_rows: int = BLOCK_ROWS,
                            block_workers: int = BLOCK_WORKERS):
    """Fused Eq. (3) over packed wire codes, grid-accumulated over workers.

    q_pilot/p1/p2 (R, 512) float; packed (N, R, 128) uint8 — every worker's
    §3.3 wire buffer, pilot row masked by ``w``; w (N,) masked p_k·beta_k at
    t > 1 / p_k at t == 1; ``t`` may be traced. Returns (R, 512) in
    q_pilot.dtype.

    The 2-D (rows, workers) grid iterates workers minor and the output
    block's index ignores the worker axis, so the Σ_k w_k T_k accumulator
    *is* the resident output block: VMEM per step is
    ``(3 float + 1 out) · block_rows·512·4B + block_workers·block_rows·128B``
    — independent of N at the default ``block_workers = 1``, which is what
    lets N = 64+ federations run without growing the tile (the old kernel
    held all N packed blocks at once). Workers accumulate strictly
    sequentially regardless of the (block_rows, block_workers) plan, so
    every plan is bitwise-identical to
    ``kernels.ref.packed_master_accum_ref``.
    """
    n, rows, _ = packed.shape
    w2 = w.astype(jnp.float32).reshape(n, 1)
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha0, jnp.float32)])
    if block_rows >= rows and block_workers >= n:
        return pl.pallas_call(
            functools.partial(_master_oneshot_kernel, n_workers=n),
            in_specs=[pl.BlockSpec(q_pilot.shape, None),
                      pl.BlockSpec(packed.shape, None),
                      pl.BlockSpec(w2.shape, None),
                      pl.BlockSpec(p1.shape, None),
                      pl.BlockSpec(p2.shape, None),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(q_pilot.shape, None),
            out_shape=jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
            interpret=interpret,
        )(q_pilot, packed, w2, p1, p2, scal)
    grid = (rows // block_rows, n // block_workers)
    spec_f = pl.BlockSpec((block_rows, LANES * PACK), lambda i, k: (i, 0))
    spec_pk = pl.BlockSpec((block_workers, block_rows, LANES),
                           lambda i, k: (k, i, 0))
    spec_w = pl.BlockSpec((block_workers, 1), lambda i, k: (k, 0))
    out_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i, k: (i, 0))
    return pl.pallas_call(
        functools.partial(_master_accum_kernel, block_workers=block_workers,
                          last_k=n // block_workers - 1),
        grid=grid,
        in_specs=[spec_f, spec_pk, spec_w, spec_f, spec_f,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
        interpret=interpret,
    )(q_pilot, packed, w2, p1, p2, scal)
