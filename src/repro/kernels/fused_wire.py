"""Fused Pallas TPU kernels for the FedPC flat wire path.

Two kernels cover the whole per-round wire cost over the ``FlatParams``
buffer (``repro.core.flat``):

``ternary_pack_2d`` / ``ternary_pack_round1_2d`` — worker uplink. Fuses
Eq. (5) (resp. Eq. (4)) ternarization *directly* into the §3.3 2-bit packed
wire format: float (R, 512) history views in, uint8 (R, 128) packed codes
out. The separate int8 code tensor of the two-kernel composition
(``ternary_encode`` → ``pack2bit``) — 4× the wire size, written to and
re-read from HBM — never exists: codes live only in VMEM registers.
``ternary_pack_any_2d`` carries the round index as a scalar operand so a
traced ``t`` selects the Eq. (4)/(5) branch in-register (for jit'd round
loops); ``ternary_pack_stacked_2d`` batches all N workers' uplinks into ONE
launch over a (N, R, 512) stack sharing the public history blocks. The
stacked kernel's Eq. (5) threshold may be a per-worker ``(N,)`` beta vector
(heterogeneous beta_k): it rides as a (N, 1) operand blocked over the
worker grid axis, so each worker's block reads its own scalar — no dynamic
in-kernel indexing.

``packed_master_update_2d`` — master downlink side of Eq. (3). Consumes the
*packed* uint8 codes of all N workers, decodes the 2-bit fields in-register,
and fuses the masked weighted worker reduction, the history-step multiply
and the subtraction into one VMEM pass. Both round branches of Eq. (3)
(t == 1 uses ``alpha0``, t > 1 uses P^{t-1} − P^{t-2}) are computed from
scalar operands so the round index may be traced.

Layout: the flat (rows, 128) buffer is viewed as (rows/4, 512) so that the
four *consecutive* codes forming each wire byte sit in the last axis —
exactly the §3.3 / ``core.packing.pack2bit`` byte order. Shifts are
multiplies/divides by powers of two (VPU-safe, exact for 2-bit fields).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
PACK = 4
BLOCK_ROWS = 64            # (64, 512) fp32 tile = 128 KiB per input


def _codes_eq5(q, p1, p2, beta):
    """Eq. (5) codes in-register: float tiles → float {-1, 0, +1}."""
    step = p1 - p2
    delta = q - p1
    significant = jnp.abs(delta) >= beta * jnp.abs(step)
    return jnp.where(significant, jnp.sign(delta * step), 0.0)


def _codes_eq4(q, p0, alpha):
    """Eq. (4) round-1 codes in-register vs the public init P^0."""
    d = q - p0
    return (d > alpha).astype(jnp.float32) - (d < -alpha).astype(jnp.float32)


def _pack_tile(codes):
    """(R, 512) float codes → (R, 128) uint8, 4 consecutive codes per byte."""
    r = codes.shape[0]
    biased = (codes.astype(jnp.int32) + 1).reshape(r, LANES, PACK)
    byte = (biased[..., 0]
            + biased[..., 1] * 4
            + biased[..., 2] * 16
            + biased[..., 3] * 64)
    return byte.astype(jnp.uint8)


def _unpack_tile(b):
    """(N, R, 128) uint8 → (N, R, 512) float codes in {-1, 0, +1}."""
    bi = b.astype(jnp.int32)
    f0 = bi % 4
    f1 = (bi // 4) % 4
    f2 = (bi // 16) % 4
    f3 = (bi // 64) % 4
    fields = jnp.stack([f0, f1, f2, f3], axis=-1)      # (N, R, 128, 4)
    n, r = b.shape[0], b.shape[1]
    return (fields - 1).astype(jnp.float32).reshape(n, r, LANES * PACK)


def _ternary_pack_kernel(q_ref, p1_ref, p2_ref, beta_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    out_ref[...] = _pack_tile(_codes_eq5(q, p1, p2, beta_ref[0]))


def _ternary_pack_round1_kernel(q_ref, p0_ref, alpha_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p0 = p0_ref[...].astype(jnp.float32)
    out_ref[...] = _pack_tile(_codes_eq4(q, p0, alpha_ref[0]))


def _codes_any(q, p1, p2, t, beta, alpha1):
    """Round-branch select on a (possibly traced) round index: Eq. (4) at
    t <= 1 (p1 slot holds P^0), Eq. (5) after. Both branches are in-register
    VPU ops, so evaluating both costs no HBM traffic."""
    return jnp.where(t <= 1.0, _codes_eq4(q, p1, alpha1),
                     _codes_eq5(q, p1, p2, beta))


def _ternary_pack_any_kernel(q_ref, p1_ref, p2_ref, scal_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    t, beta, alpha1 = scal_ref[0], scal_ref[1], scal_ref[2]
    out_ref[...] = _pack_tile(_codes_any(q, p1, p2, t, beta, alpha1))


def _ternary_pack_stacked_kernel(q_ref, p1_ref, p2_ref, beta_ref, scal_ref,
                                 out_ref):
    q = q_ref[0].astype(jnp.float32)                   # block (1, R, 512)
    p1 = p1_ref[...].astype(jnp.float32)               # shared history block
    p2 = p2_ref[...].astype(jnp.float32)
    beta = beta_ref[0, 0]                              # this worker's beta_k
    t, alpha1 = scal_ref[0], scal_ref[1]
    out_ref[0] = _pack_tile(_codes_any(q, p1, p2, t, beta, alpha1))


def _master_kernel(q_ref, pk_ref, w_ref, p1_ref, p2_ref, scal_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)                 # (R, 512)
    tern = _unpack_tile(pk_ref[...])                   # (N, R, 512)
    w = w_ref[...].astype(jnp.float32)                 # (N,) masked p_k*beta_k
    coeff = jnp.tensordot(w, tern, axes=1)             # (R, 512)
    step = p1_ref[...].astype(jnp.float32) - p2_ref[...].astype(jnp.float32)
    t, alpha0 = scal_ref[0], scal_ref[1]
    # Eq. (3): t == 1 scales by alpha0, t > 1 by the history step.
    mult = jnp.where(t <= 1.0, alpha0, step)
    out_ref[...] = (q - coeff * mult).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_2d(q, p1, p2, beta, *, interpret: bool = True,
                    block_rows: int = BLOCK_ROWS):
    """q/p1/p2 (R, 512) float, R % block_rows == 0 → uint8 (R, 128).

    Equals ``pack2bit_2d(ternary_encode_2d(q, p1, p2, beta))`` with zero
    int8 HBM intermediate and a single launch.
    """
    rows = q.shape[0]
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _ternary_pack_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p1, p2, jnp.asarray([beta], jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_round1_2d(q, p0, alpha, *, interpret: bool = True,
                           block_rows: int = BLOCK_ROWS):
    """Round-1 (Eq. (4)) variant of :func:`ternary_pack_2d`."""
    rows = q.shape[0]
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _ternary_pack_round1_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p0, jnp.asarray([alpha], jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_any_2d(q, p1, p2, t, beta, alpha1, *, interpret: bool = True,
                        block_rows: int = BLOCK_ROWS):
    """Traced-round fused uplink: Eq. (4) at t <= 1, Eq. (5) after.

    Same layout as :func:`ternary_pack_2d`, but the round index ``t`` (and
    both thresholds) travel as scalar operands so one compiled kernel serves
    every round — required inside jit'd round loops (the distributed sync)
    where ``t`` is traced.
    """
    rows = q.shape[0]
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(beta, jnp.float32),
                      jnp.asarray(alpha1, jnp.float32)])
    return pl.pallas_call(
        _ternary_pack_any_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p1, p2, scal)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ternary_pack_stacked_2d(q, p1, p2, t, beta, alpha1, *,
                            interpret: bool = True,
                            block_rows: int = BLOCK_ROWS):
    """Batched uplink: all N workers' wire buffers from ONE launch.

    q (N, R, 512) — every worker's history view; p1/p2 (R, 512) — the shared
    public history, re-read per worker block (it is the same HBM buffer, not
    N copies). Grid is (N, R/block): worker-major, so the §3.3 byte order of
    each worker's buffer matches :func:`ternary_pack_2d` exactly.

    ``beta`` is either one scalar (shared threshold) or a ``(N,)`` vector of
    per-worker beta_k — worker k's blocks read ``beta[k]`` via the blocked
    (1, 1) operand. Returns (N, R, 128) uint8.
    """
    n, rows, _ = q.shape
    grid = (n, rows // block_rows)
    q_spec = pl.BlockSpec((1, block_rows, LANES * PACK),
                          lambda k, i: (k, i, 0))
    h_spec = pl.BlockSpec((block_rows, LANES * PACK), lambda k, i: (i, 0))
    out_spec = pl.BlockSpec((1, block_rows, LANES), lambda k, i: (k, i, 0))
    betas = jnp.broadcast_to(
        jnp.asarray(beta, jnp.float32).reshape(-1, 1), (n, 1))
    beta_spec = pl.BlockSpec((1, 1), lambda k, i: (k, 0))
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha1, jnp.float32)])
    return pl.pallas_call(
        _ternary_pack_stacked_kernel,
        grid=grid,
        in_specs=[q_spec, h_spec, h_spec, beta_spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q, p1, p2, betas, scal)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def packed_master_update_2d(q_pilot, packed, w, p1, p2, t, alpha0, *,
                            interpret: bool = True,
                            block_rows: int = BLOCK_ROWS):
    """Fused Eq. (3) over packed wire codes.

    q_pilot/p1/p2 (R, 512) float; packed (N, R, 128) uint8 — every worker's
    §3.3 wire buffer, pilot row masked by ``w``; w (N,) masked p_k·beta_k at
    t > 1 / p_k at t == 1; ``t`` may be traced. Returns (R, 512) in
    q_pilot.dtype.

    VMEM per tile at N=16, R=64: 3 × 128 KiB float inputs + 128 KiB packed —
    decoded codes exist only in registers.
    """
    n, rows, _ = packed.shape
    grid = (rows // block_rows,)
    spec_f = pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))
    spec_pk = pl.BlockSpec((n, block_rows, LANES), lambda i: (0, i, 0))
    scal = jnp.stack([jnp.asarray(t, jnp.float32),
                      jnp.asarray(alpha0, jnp.float32)])
    return pl.pallas_call(
        _master_kernel,
        grid=grid,
        in_specs=[spec_f, spec_pk, pl.BlockSpec(memory_space=pl.ANY),
                  spec_f, spec_f, pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=spec_f,
        out_shape=jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
        interpret=interpret,
    )(q_pilot, packed, w.astype(jnp.float32), p1, p2, scal)
