"""Pallas TPU kernel: 2-bit packing/unpacking of ternary codes (§3.3).

The wire format behind Eq. (8)'s 16× upload reduction: four {-1,0,+1}
codes per byte. Pack reads an int8 (R, 512) tile and writes a uint8
(R, 128) tile — the output stays lane-aligned (128 lanes) so the packed
buffer feeds collectives directly. Unpack is the inverse.

Shifts are implemented as multiplies/divides by powers of two: VPU-safe,
and exact for the 2-bit fields.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
PACK = 4
BLOCK_ROWS = 256


def _pack_kernel(t_ref, out_ref):
    t = t_ref[...]                                     # (R, 512) int8
    r = t.shape[0]
    codes = (t.astype(jnp.int32) + 1).reshape(r, LANES, PACK)
    byte = (codes[..., 0]
            + codes[..., 1] * 4
            + codes[..., 2] * 16
            + codes[..., 3] * 64)
    out_ref[...] = byte.astype(jnp.uint8)              # (R, 128)


def _unpack_kernel(b_ref, out_ref):
    b = b_ref[...].astype(jnp.int32)                   # (R, 128)
    r = b.shape[0]
    f0 = b % 4
    f1 = (b // 4) % 4
    f2 = (b // 16) % 4
    f3 = (b // 64) % 4
    codes = jnp.stack([f0, f1, f2, f3], axis=-1)       # (R, 128, 4)
    out_ref[...] = (codes - 1).astype(jnp.int8).reshape(r, LANES * PACK)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def pack2bit_2d(t, *, interpret: bool = True, block_rows: int = BLOCK_ROWS):
    """t int8 (R, 512), R % block_rows == 0 → uint8 (R, 128).

    Group layout matches ref.pack2bit_ref: four consecutive codes → 1 byte.
    """
    rows = t.shape[0]
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(t)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def unpack2bit_2d(b, *, interpret: bool = True, block_rows: int = BLOCK_ROWS):
    """b uint8 (R, 128) → int8 (R, 512)."""
    rows = b.shape[0]
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES * PACK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES * PACK), jnp.int8),
        interpret=interpret,
    )(b)
