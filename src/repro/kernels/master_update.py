"""Pallas TPU kernel: fused Eq. (3) master update (t > 1).

    P^t = Q_{k*} − (Σ_k w_k T_k) ⊙ (P^{t-1} − P^{t-2}),   w_k = p_k β_k, w_{k*}=0

Fuses the worker-axis reduction of int8 ternary codes with the history-step
multiply and the subtraction — one VMEM pass instead of materializing the
(N, M) float promotion and a separate elementwise chain in HBM.

Layout: M is viewed as (rows, 128); the grid tiles rows; the full worker
axis N (≤ 16 fed slices) rides along inside the tile: block (N, R, 128)
int8 = N·R·128 bytes — at N=16, R=256 that is 512 KiB, well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256


def _kernel(q_ref, t_ref, w_ref, p1_ref, p2_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)            # (R, 128)
    tern = t_ref[...].astype(jnp.float32)         # (N, R, 128)
    w = w_ref[...].astype(jnp.float32)            # (N,)
    coeff = jnp.tensordot(w, tern, axes=1)        # (R, 128)
    step = p1_ref[...].astype(jnp.float32) - p2_ref[...].astype(jnp.float32)
    out_ref[...] = (q - coeff * step).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def master_update_2d(q_pilot, tern, w, p1, p2, *, interpret: bool = True,
                     block_rows: int = BLOCK_ROWS):
    """q_pilot/p1/p2 (R, 128); tern (N, R, 128) int8; w (N,) fp32 (masked).

    R % block_rows == 0. Returns (R, 128) in q_pilot.dtype.
    """
    n, rows, _ = tern.shape
    grid = (rows // block_rows,)
    spec2d = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    spec3d = pl.BlockSpec((n, block_rows, LANES), lambda i: (0, i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec2d, spec3d, pl.BlockSpec(memory_space=pl.ANY),
                  spec2d, spec2d],
        out_specs=spec2d,
        out_shape=jax.ShapeDtypeStruct(q_pilot.shape, q_pilot.dtype),
        interpret=interpret,
    )(q_pilot, tern, w, p1, p2)
