"""Block-size autotuner for the wire kernels.

Which (block_rows, block_workers) plan wins is a property of the *backend*,
not the math: on TPU the grid must tile VMEM (small row blocks, one worker
per step so the master's memory stays O(block)); under cpu-interpret every
grid step pays the interpreter's full block machinery, so the fastest plan
is the one with the fewest steps (whole-operand blocks, no grid). Every
plan computes bitwise-identical results (the uplink is elementwise; the
master accumulates workers in a fixed sequential order), so tuning is free
to pick purely on time.

The table maps ``(kind, rows, n_workers, backend)`` → plan. ``lookup``
never times anything: it returns the tuned entry if one exists, else the
backend heuristic — so production paths (the ``ops`` wrappers call
``lookup`` whenever the caller leaves ``block_rows``/``block_workers`` as
None) pay a dict probe, nothing more. ``autotune_stacked`` /
``autotune_master`` run the actual timed sweep and fill the table; the
kernel benchmark (`benchmarks/kernels_bench.py`) runs them per shape so
per-size regressions (e.g. the old hand-tuned 16M fused-uplink loss) are
tuned away instead of patched.

``save_table``/``load_table`` persist the table as JSON; pointing the
``REPRO_TUNE_TABLE`` environment variable at such a file pre-loads it at
import (e.g. a table tuned once on real TPU hardware).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp

# TPU-shaped fallbacks (mirrors fused_wire; duplicated to avoid an import
# cycle with the kernels that consult this module through ops).
BLOCK_ROWS = 64
BLOCK_WORKERS = 1

KINDS = ("uplink", "uplink_stacked", "master", "uplink_masked",
         "master_masked", "uplink_masked16", "master_masked16",
         "partial_sum", "partial_sum_masked", "partial_sum_masked16",
         "mask_repair", "mask_repair16")

# Masked kernels share the grid geometry of their plaintext counterparts
# (same block shapes over the same (rows, N) iteration space), so an
# untuned masked kind borrows down a chain of geometry twins: the 16-bit
# modulus kinds fall back to the 32-bit masked plans, which fall back to
# the unmasked kinds, which fall back to the backend heuristic. The tree
# sub-aggregate kinds (keyed by fanout in the n_workers slot, block_workers
# meaning output groups per step) chain the same way.
MASKED_FALLBACK = {"uplink_masked16": "uplink_masked",
                   "master_masked16": "master_masked",
                   "uplink_masked": "uplink_stacked",
                   "master_masked": "master",
                   "partial_sum_masked16": "partial_sum_masked",
                   "partial_sum_masked": "partial_sum",
                   "mask_repair16": "mask_repair",
                   "mask_repair": "uplink"}

# (kind, rows, n_workers, backend) -> {"block_rows": int, "block_workers": int}
_TABLE: dict[tuple[str, int, int, str], dict] = {}

# Fallback-chain resolutions already reported, one line per (kind, rows, n,
# backend) — tuner gaps surface in bench output instead of silently
# borrowing another kind's plan forever.
_FALLBACK_LOGGED: set[tuple[str, int, int, str]] = set()

# Interpret-mode sweeps execute one Python-level step per grid tile; cap the
# plans a cpu sweep will even try so autotuning stays seconds, not minutes.
_MAX_SWEEP_STEPS_INTERPRET = 16

# Optional sweep-trace sink: hook(kind, rows, n, backend, timings, best)
# called once per completed sweep so BENCH_kernels.json provenance is
# reconstructable from a telemetry trace (telemetry.trace.plan_emitter
# adapts a TraceWriter into this signature). None = off.
_TRACE_HOOK = None


def set_trace_writer(hook) -> None:
    """Install (or clear, with None) the sweep-trace hook every autotune
    sweep reports through — one call per sweep with its full timing list."""
    global _TRACE_HOOK
    _TRACE_HOOK = hook


def _emit_sweep(kind, rows, n, backend, timings, best) -> None:
    if _TRACE_HOOK is not None:
        _TRACE_HOOK(kind, rows, n, backend, timings, best)


def backend_tag(interpret: bool | None = None) -> str:
    """The table's backend key: 'cpu-interpret' for interpret mode (the
    hermetic-container default), else the real jax backend ('tpu', ...)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return "cpu-interpret" if interpret else jax.default_backend()


def fit_block_rows(rows: int, want: int) -> int:
    """Largest multiple of gcd(rows, want) ≤ ``want`` that divides ``rows``.

    The gcd floors the probe (≤ want/g steps vs a unit-step scan) and —
    since padded rows and ``want`` are both multiples of 8 — guarantees the
    result stays 8-sublane aligned (e.g. rows=8400, want=64 → 48, not the
    unaligned 60 a plain divisor scan would pick). The single
    implementation behind ``ops._block_rows_for``."""
    if rows <= want:
        return rows
    g = math.gcd(rows, want)
    b = (want // g) * g
    while rows % b:
        b -= g
    return b


def fit_block_workers(n: int, want: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``want`` (worker blocks must tile
    the worker axis exactly — N=33 with want=8 gives 3, not 8)."""
    want = max(1, min(n, want))
    for b in range(want, 0, -1):
        if n % b == 0:
            return b
    return 1


# Group-axis "all of them" sentinel of the partial-sum default: the ops
# wrappers fit block_workers to the level width, so a huge want collapses
# the group grid to one step (the cpu-interpret optimum at any width).
_ALL_GROUPS = 1 << 30


def default_plan(kind: str, rows: int, n_workers: int = 1,
                 backend: str | None = None) -> dict:
    """The untimed heuristic: fewest steps on cpu-interpret (per-step
    machinery dominates), VMEM-sized O(block) tiles elsewhere. For the
    partial-sum kinds ``n_workers`` holds the fanout and ``block_workers``
    means output groups per grid step — the cpu one-shot wants ALL groups
    (the ops wrapper clamps to the level width)."""
    backend = backend or backend_tag()
    if backend == "cpu-interpret":
        if kind.startswith("partial_sum"):
            return {"block_rows": rows, "block_workers": _ALL_GROUPS}
        return {"block_rows": rows, "block_workers": max(1, n_workers)}
    if kind.startswith("partial_sum"):
        return {"block_rows": fit_block_rows(rows, BLOCK_ROWS),
                "block_workers": 1}
    return {"block_rows": fit_block_rows(rows, BLOCK_ROWS),
            "block_workers": fit_block_workers(max(1, n_workers),
                                               BLOCK_WORKERS)}


def lookup(kind: str, rows: int, n_workers: int = 1, *,
           interpret: bool | None = None) -> tuple[int, int]:
    """(block_rows, block_workers) for a shape — tuned entry or heuristic.

    Never times anything; this is the hot-path call the ``ops`` wrappers
    make when the caller leaves the block sizes unspecified. When the
    requested kind has no entry and resolution walks the
    ``MASKED_FALLBACK`` chain, the traversal is reported once per (kind,
    rows, n, backend) so tuner gaps are visible in bench output instead of
    silently borrowing another kind's plan.
    """
    backend = backend_tag(interpret)
    probe = kind
    chain = [kind]
    plan = _TABLE.get((probe, rows, max(1, n_workers), backend))
    while plan is None and probe in MASKED_FALLBACK:
        probe = MASKED_FALLBACK[probe]
        chain.append(probe)
        plan = _TABLE.get((probe, rows, max(1, n_workers), backend))
    if len(chain) > 1:
        key = (kind, rows, max(1, n_workers), backend)
        if key not in _FALLBACK_LOGGED:
            _FALLBACK_LOGGED.add(key)
            landed = (f"tuned '{probe}' plan" if plan is not None
                      else f"'{backend}' heuristic")
            print(f"[tune] no plan for {kind}@(rows={rows}, "
                  f"n={max(1, n_workers)}, {backend}); fell back "
                  f"{' -> '.join(chain)} to the {landed}")
    if plan is None:
        plan = default_plan(kind, rows, n_workers, backend)
    return plan["block_rows"], plan["block_workers"]


def set_plan(kind: str, rows: int, n_workers: int, plan: dict, *,
             backend: str | None = None) -> None:
    """Pin a plan (tests / externally-tuned tables)."""
    _TABLE[(kind, rows, max(1, n_workers), backend or backend_tag())] = dict(plan)


def clear_table() -> None:
    _TABLE.clear()


def master_vmem_tile_bytes(block_rows: int, block_workers: int) -> int:
    """VMEM footprint model of one accumulating-master grid step: the four
    resident (block_rows, 512) float32 blocks (q, p1, p2, and the
    output/accumulator) plus the (block_workers, block_rows, 128) packed
    uint8 sub-block. Independent of N at fixed ``block_workers`` — the
    property that lets federation size scale without growing the tile
    (the pre-accumulation kernel held all N packed blocks: N·block_rows·128
    bytes, linear in N)."""
    float_block = block_rows * 512 * 4
    return 4 * float_block + block_workers * block_rows * 128


def master_vmem_tile_bytes_preaccum(block_rows: int, n_workers: int) -> int:
    """Footprint of the OLD (pre-grid-accumulation) master tile, which
    blocked the full worker axis: scales linearly with N."""
    float_block = block_rows * 512 * 4
    return 4 * float_block + n_workers * block_rows * 128


def _time_us(fn: Callable, reps: int) -> float:
    jax.block_until_ready(fn())                       # compile/warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def _candidate_plans(rows: int, n: int, backend: str) -> list[dict]:
    """Small, deduplicated sweep: the one-shot plan, whole-row blocks with
    worker sub-blocks, and VMEM-tile plans."""
    cands = [
        {"block_rows": rows, "block_workers": n},            # one step
        {"block_rows": rows, "block_workers": 1},            # worker grid
        {"block_rows": fit_block_rows(rows, BLOCK_ROWS),
         "block_workers": 1},                                # TPU tile
        {"block_rows": fit_block_rows(rows, 256),
         "block_workers": fit_block_workers(n, 8)},
    ]
    seen, out = set(), []
    for c in cands:
        key = (c["block_rows"], c["block_workers"])
        steps = (rows // c["block_rows"]) * (n // c["block_workers"])
        if key in seen:
            continue
        if (backend == "cpu-interpret"
                and steps > _MAX_SWEEP_STEPS_INTERPRET):
            continue                       # interpret: each step is Python
        seen.add(key)
        out.append(c)
    return out


def _sweep(kind: str, rows: int, n: int, run_plan: Callable, *,
           interpret: bool | None, reps: int) -> dict:
    backend = backend_tag(interpret)
    timings = []
    for plan in _candidate_plans(rows, n, backend):
        us = _time_us(lambda p=plan: run_plan(p), reps)
        timings.append({**plan, "us": us})
    best = min(timings, key=lambda r: r["us"])
    _TABLE[(kind, rows, n, backend)] = {
        "block_rows": best["block_rows"],
        "block_workers": best["block_workers"]}
    _emit_sweep(kind, rows, n, backend, timings, best)
    return {"kind": kind, "rows": rows, "n_workers": n, "backend": backend,
            "best": {k: best[k] for k in ("block_rows", "block_workers")},
            "timings": timings}


def autotune_stacked(rows: int, n_workers: int, *,
                     interpret: bool | None = None, reps: int = 2,
                     seed: int = 0) -> dict:
    """Timed sweep of the stacked-uplink plans for (rows, N); stores the
    winner in the table and returns the full sweep record. ``rows`` is the
    kernel-view row count (flat rows / 4)."""
    from repro.kernels import fused_wire as fw
    itp = (jax.default_backend() != "tpu") if interpret is None else interpret
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (n_workers, rows, fw.LANES * fw.PACK))
    p1 = jax.random.normal(jax.random.fold_in(k, 1),
                           (rows, fw.LANES * fw.PACK))
    p2 = jax.random.normal(jax.random.fold_in(k, 2),
                           (rows, fw.LANES * fw.PACK))

    def run_plan(plan):
        return fw.ternary_pack_stacked_2d(
            q, p1, p2, 3, 0.2, 0.01, interpret=itp,
            block_rows=plan["block_rows"],
            block_workers=plan["block_workers"])

    return _sweep("uplink_stacked", rows, n_workers, run_plan,
                  interpret=itp, reps=reps)


def autotune_master(rows: int, n_workers: int, *,
                    interpret: bool | None = None, reps: int = 2,
                    seed: int = 0) -> dict:
    """Timed sweep of the accumulating-master plans for (rows, N)."""
    from repro.kernels import fused_wire as fw
    itp = (jax.default_backend() != "tpu") if interpret is None else interpret
    k = jax.random.PRNGKey(seed)
    wide = fw.LANES * fw.PACK
    q = jax.random.normal(k, (rows, wide))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, wide))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, wide))
    packed = jax.random.randint(jax.random.fold_in(k, 3),
                                (n_workers, rows, fw.LANES), 0,
                                256).astype(jnp.uint8)
    w = jnp.full((n_workers,), 0.02)

    def run_plan(plan):
        return fw.packed_master_update_2d(
            q, packed, w, p1, p2, 3, 0.01, interpret=itp,
            block_rows=plan["block_rows"],
            block_workers=plan["block_workers"])

    return _sweep("master", rows, n_workers, run_plan,
                  interpret=itp, reps=reps)


def _masked_inputs(rows: int, n_workers: int, seed: int, word_bits: int):
    """Shared operands of the masked-kernel sweeps: random history views
    plus the tiny per-pair key/sign matrices the in-kernel PRNG consumes
    (sweep timings therefore include the real mask-generation cost)."""
    from repro.kernels import fused_wire as fw
    from repro.privacy import dp as pdp
    from repro.privacy import masking as pvm
    k = jax.random.PRNGKey(seed)
    wide = fw.LANES * fw.PACK
    q = jax.random.normal(k, (n_workers, rows, wide))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, wide))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, wide))
    keys = pvm.pair_stream_keys(seed, n_workers, 3)
    signs = pvm.pair_signs(n_workers)
    rrk = pdp.rr_stream_keys(seed + 1, 3, n_workers)
    fb = 14 if word_bits == 16 else 24
    wq = jnp.full((n_workers,), (1 << fb) // max(n_workers, 1), jnp.uint32)
    return q, p1, p2, keys, signs, rrk, wq


def autotune_masked_uplink(rows: int, n_workers: int, *,
                           interpret: bool | None = None, reps: int = 2,
                           seed: int = 0, word_bits: int = 32) -> dict:
    """Timed sweep of the masked-uplink (secure-agg) plans for (rows, N) at
    one wire modulus; fills the ``uplink_masked16``/``uplink_masked`` kind
    by ``word_bits``."""
    from repro.kernels import masked_wire as mw
    itp = (jax.default_backend() != "tpu") if interpret is None else interpret
    q, p1, p2, keys, signs, rrk, wq = _masked_inputs(rows, n_workers, seed,
                                                     word_bits)

    def run_plan(plan):
        return mw.ternary_pack_masked_2d(
            q, p1, p2, 3, 0.2, 0.01, wq, keys, signs, rrk,
            rr_threshold=0, word_bits=word_bits, interpret=itp,
            block_rows=plan["block_rows"],
            block_workers=plan["block_workers"])

    kind = "uplink_masked16" if word_bits == 16 else "uplink_masked"
    return _sweep(kind, rows, n_workers, run_plan, interpret=itp, reps=reps)


def autotune_masked_master(rows: int, n_workers: int, *,
                           interpret: bool | None = None, reps: int = 2,
                           seed: int = 0, word_bits: int = 32) -> dict:
    """Timed sweep of the sum-then-unmask master plans for (rows, N) at one
    wire modulus."""
    from repro.kernels import masked_wire as mw
    itp = (jax.default_backend() != "tpu") if interpret is None else interpret
    q, p1, p2, keys, signs, rrk, wq = _masked_inputs(rows, n_workers, seed,
                                                     word_bits)
    word = jnp.uint16 if word_bits == 16 else jnp.uint32
    masked = jax.random.bits(jax.random.PRNGKey(seed + 3),
                             (n_workers, rows, q.shape[-1]),
                             jnp.uint32).astype(word)
    fb = 14 if word_bits == 16 else 24

    def run_plan(plan):
        return mw.masked_master_update_2d(
            q[0], masked, jnp.sum(wq), p1, p2, 3, 0.01, 2.0 ** -fb,
            interpret=itp, block_rows=plan["block_rows"],
            block_workers=plan["block_workers"])

    kind = "master_masked16" if word_bits == 16 else "master_masked"
    return _sweep(kind, rows, n_workers, run_plan, interpret=itp, reps=reps)


def autotune_partial_sum(rows: int, fanout: int, n_children: int, *,
                         interpret: bool | None = None, reps: int = 2,
                         seed: int = 0, word_bits: int = 32,
                         masked: bool = False) -> dict:
    """Timed sweep of the tree sub-aggregate plans for (rows, fanout) at
    one level width ``n_children``; fills the ``partial_sum*`` kind picked
    by ``masked``/``word_bits``. The table key holds the fanout in the
    n_workers slot and the winning ``block_workers`` means output groups
    per grid step (clamped to the level width by the ops wrappers)."""
    from repro.kernels import partial_sum as psk
    from repro.privacy import masking as pvm
    itp = (jax.default_backend() != "tpu") if interpret is None else interpret
    backend = backend_tag(itp)
    g = -(-n_children // fanout)
    pad_c = g * fanout
    wide = 512
    key = jax.random.PRNGKey(seed)
    if masked:
        kind = ("partial_sum_masked16" if word_bits == 16
                else "partial_sum_masked")
        word = jnp.uint16 if word_bits == 16 else jnp.uint32
        y = jax.random.bits(key, (pad_c, rows, wide),
                            jnp.uint32).astype(word)
        keys = pvm.pair_stream_keys(seed, g, 3)
        sib = max(1, min(g, fanout))
        signs = pvm.tree_pair_signs(g, sib)

        def run_plan(plan):
            return psk.masked_partial_sum_2d(
                y, keys, signs, fanout=fanout, sibling=sib, interpret=itp,
                block_rows=plan["block_rows"],
                block_groups=plan["block_workers"])
    else:
        kind = "partial_sum"
        packed = jax.random.bits(key, (pad_c, rows, 128),
                                 jnp.uint32).astype(jnp.uint8)
        fb = 14 if word_bits == 16 else 24
        wq = jnp.full((pad_c,), (1 << fb) // max(pad_c, 1), jnp.uint32)

        def run_plan(plan):
            return psk.partial_sum_2d(
                packed, wq, fanout=fanout, word_bits=word_bits,
                interpret=itp, block_rows=plan["block_rows"],
                block_groups=plan["block_workers"])

    cands, seen = [], set()
    for c in ({"block_rows": rows, "block_workers": g},
              {"block_rows": rows, "block_workers": 1},
              {"block_rows": fit_block_rows(rows, BLOCK_ROWS),
               "block_workers": 1}):
        ck = (c["block_rows"], c["block_workers"])
        steps = (rows // c["block_rows"]) * (g // c["block_workers"])
        if ck in seen or (backend == "cpu-interpret"
                          and steps > _MAX_SWEEP_STEPS_INTERPRET):
            continue
        seen.add(ck)
        cands.append(c)
    timings = [{**plan, "us": _time_us(lambda p=plan: run_plan(p), reps)}
               for plan in cands]
    best = min(timings, key=lambda r: r["us"])
    _TABLE[(kind, rows, fanout, backend)] = {
        "block_rows": best["block_rows"],
        "block_workers": best["block_workers"]}
    _emit_sweep(kind, rows, fanout, backend, timings, best)
    return {"kind": kind, "rows": rows, "n_workers": fanout,
            "n_children": n_children, "backend": backend,
            "best": {k: best[k] for k in ("block_rows", "block_workers")},
            "timings": timings}


def autotune_mask_repair(rows: int, n_pairs: int, *,
                         interpret: bool | None = None, reps: int = 2,
                         seed: int = 0, word_bits: int = 32) -> dict:
    """Timed sweep of the dropout-repair kernel plans for (rows, P repair
    pairs) at one wire modulus; fills ``mask_repair16``/``mask_repair``
    keyed with n_workers=1 (the kernel has no worker axis — only a row
    grid). Half the coefficients are zero so the sweep times the in-kernel
    zero-skip path a real faulted round exercises."""
    from repro.kernels import masked_wire as mw
    from repro.privacy import masking as pvm
    itp = (jax.default_backend() != "tpu") if interpret is None else interpret
    backend = backend_tag(itp)
    word = jnp.uint16 if word_bits == 16 else jnp.uint32
    y = jax.random.bits(jax.random.PRNGKey(seed), (rows, 512),
                        jnp.uint32).astype(word)
    keys = pvm.stream_key(seed, jnp.arange(max(1, n_pairs)), 3)
    coeff = jnp.where(jnp.arange(max(1, n_pairs)) % 2 == 0, 1, 0
                      ).astype(jnp.int32)

    def run_plan(plan):
        return mw.mask_repair_2d(y, keys, coeff, interpret=itp,
                                 block_rows=plan["block_rows"])

    kind = "mask_repair16" if word_bits == 16 else "mask_repair"
    cands, seen = [], set()
    for c in ({"block_rows": rows, "block_workers": 1},
              {"block_rows": fit_block_rows(rows, 256), "block_workers": 1},
              {"block_rows": fit_block_rows(rows, BLOCK_ROWS),
               "block_workers": 1}):
        ck = c["block_rows"]
        steps = rows // c["block_rows"]
        if ck in seen or (backend == "cpu-interpret"
                          and steps > _MAX_SWEEP_STEPS_INTERPRET):
            continue
        seen.add(ck)
        cands.append(c)
    timings = [{**plan, "us": _time_us(lambda p=plan: run_plan(p), reps)}
               for plan in cands]
    best = min(timings, key=lambda r: r["us"])
    _TABLE[(kind, rows, 1, backend)] = {
        "block_rows": best["block_rows"],
        "block_workers": best["block_workers"]}
    _emit_sweep(kind, rows, 1, backend, timings, best)
    return {"kind": kind, "rows": rows, "n_workers": 1,
            "n_pairs": n_pairs, "backend": backend,
            "best": {k: best[k] for k in ("block_rows", "block_workers")},
            "timings": timings}


def save_table(path: str) -> None:
    """Persist the tuned table as JSON ({'kind|rows|n|backend': plan})."""
    data = {"|".join(map(str, k)): v for k, v in sorted(_TABLE.items())}
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)


def load_table(path: str, *, replace: bool = False) -> int:
    """Merge (or replace) the table from a ``save_table`` JSON; returns the
    number of entries loaded."""
    with open(path) as f:
        data = json.load(f)
    if replace:
        _TABLE.clear()
    for key, plan in data.items():
        kind, rows, n, backend = key.split("|")
        _TABLE[(kind, int(rows), int(n), backend)] = {
            "block_rows": int(plan["block_rows"]),
            "block_workers": int(plan["block_workers"])}
    return len(data)


_env_table = os.environ.get("REPRO_TUNE_TABLE")
if _env_table and os.path.exists(_env_table):
    load_table(_env_table)
