"""Public jit'd wrappers over the Pallas kernels.

Handles: flat (or pytree) → padded (rows, 128) layout, interpret-mode
selection (Python execution on CPU, compiled on TPU), block-plan selection
(callers that leave ``block_rows``/``block_workers`` unset get the
``repro.kernels.tune`` plan for their shape and backend), and un-padding.
These are drop-in replacements for the core/* reference functions and are
what the distributed sync uses when ``use_kernels=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import fused_wire as fw
from repro.kernels import masked_wire as mw
from repro.kernels import pack2bit as pk
from repro.kernels import master_update as mu
from repro.kernels import partial_sum as ps
from repro.kernels import ternary_encode as te
from repro.kernels import tune
from repro.telemetry import profile as tprof
from repro.utils import round_up

LANES = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(x: jax.Array, row_multiple: int, lane_multiple: int = LANES):
    """Flatten + zero-pad to (rows, lane_multiple), rows % row_multiple == 0."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_row = lane_multiple
    rows = round_up(max(-(-n // per_row), 1), row_multiple)
    padded = rows * per_row
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(rows, per_row), n


# Canonical gcd-snapping lives in tune (one implementation; its docstring
# carries the alignment argument).
_block_rows_for = tune.fit_block_rows


def _stacked_plan(kind: str, rows: int, n: int, block_rows: int | None,
                  block_workers: int | None, interpret: bool) -> tuple[int,
                                                                       int]:
    """Resolve a worker-batched kernel's (block_rows, block_workers): any
    axis the caller left as None comes from the tuner table / heuristic;
    explicit requests are snapped to legal tilings (divisors)."""
    tuned_br, tuned_bw = tune.lookup(kind, rows, n, interpret=interpret)
    br = _block_rows_for(rows, block_rows or tuned_br)
    bw = tune.fit_block_workers(n, block_workers or tuned_bw)
    return br, bw


def ternary_encode(q, p1, p2, beta: float, interpret: bool | None = None):
    """Eq. (5) over an arbitrary-shape array; returns int8 of q.shape."""
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8)
    p12, _ = _to_2d(p1, 8)
    p22, _ = _to_2d(p2, 8)
    br = _block_rows_for(q2.shape[0], te.BLOCK_ROWS)
    out = te.ternary_encode_2d(q2, p12, p22, beta, interpret=interpret,
                               block_rows=br)
    return out.reshape(-1)[:n].reshape(q.shape)


def ternary_encode_round1(q, p0, alpha: float, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8)
    p02, _ = _to_2d(p0, 8)
    br = _block_rows_for(q2.shape[0], te.BLOCK_ROWS)
    out = te.ternary_encode_round1_2d(q2, p02, alpha, interpret=interpret,
                                      block_rows=br)
    return out.reshape(-1)[:n].reshape(q.shape)


def pack2bit(t, interpret: bool | None = None):
    """int8 codes any shape → uint8 (ceil(n/4),) flat packed buffer."""
    interpret = _default_interpret() if interpret is None else interpret
    t2, n = _to_2d(t, 8, LANES * pk.PACK)
    br = _block_rows_for(t2.shape[0], pk.BLOCK_ROWS)
    out = pk.pack2bit_2d(t2, interpret=interpret, block_rows=br)
    n_bytes = -(-n // pk.PACK)
    return out.reshape(-1)[:n_bytes]


def unpack2bit(b, n: int, interpret: bool | None = None):
    """uint8 packed buffer → int8 (n,) codes."""
    interpret = _default_interpret() if interpret is None else interpret
    b2, nb = _to_2d(b, 8, LANES)
    br = _block_rows_for(b2.shape[0], pk.BLOCK_ROWS)
    out = pk.unpack2bit_2d(b2, interpret=interpret, block_rows=br)
    return out.reshape(-1)[:n]


def ternary_pack(q, p1, p2, beta: float, interpret: bool | None = None):
    """Fused Eq. (5) → §3.3 uplink over an arbitrary-shape array.

    Equals ``pack2bit(ternary_encode(q, p1, p2, beta))`` in one launch with
    no int8 intermediate. Returns uint8 (ceil(n/4),) packed wire bytes.
    """
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8, LANES * fw.PACK)
    p12, _ = _to_2d(p1, 8, LANES * fw.PACK)
    p22, _ = _to_2d(p2, 8, LANES * fw.PACK)
    br = _block_rows_for(
        q2.shape[0], tune.lookup("uplink", q2.shape[0],
                                 interpret=interpret)[0])
    out = fw.ternary_pack_2d(q2, p12, p22, beta, interpret=interpret,
                             block_rows=br)
    n_bytes = -(-n // fw.PACK)
    return out.reshape(-1)[:n_bytes]


def ternary_pack_round1(q, p0, alpha: float, interpret: bool | None = None):
    """Round-1 (Eq. (4)) variant of :func:`ternary_pack`."""
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8, LANES * fw.PACK)
    p02, _ = _to_2d(p0, 8, LANES * fw.PACK)
    br = _block_rows_for(
        q2.shape[0], tune.lookup("uplink", q2.shape[0],
                                 interpret=interpret)[0])
    out = fw.ternary_pack_round1_2d(q2, p02, alpha, interpret=interpret,
                                    block_rows=br)
    n_bytes = -(-n // fw.PACK)
    return out.reshape(-1)[:n_bytes]


def flat_ternary_pack(buf_q, buf_p1, buf_p2, *, t: int, beta: float,
                      alpha1: float, interpret: bool | None = None,
                      block_rows: int | None = None):
    """Fused uplink over FlatParams buffers: (rows, 128) → (rows//4, 128).

    ``t`` is the (static) 1-based round index: round 1 uses the Eq. (4)
    threshold ``alpha1`` against ``buf_p1`` (= P^0), later rounds Eq. (5)
    with ``beta`` against the (P^{t-1}, P^{t-2}) history.
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q.shape[0]
    r4 = rows // fw.PACK
    q4 = buf_q.reshape(r4, LANES * fw.PACK)
    br = _block_rows_for(
        r4, block_rows or tune.lookup("uplink", r4, interpret=interpret)[0])
    with tprof.kernel_scope("uplink", r4, 1, interpret):
        if t <= 1:
            return fw.ternary_pack_round1_2d(
                q4, buf_p1.reshape(r4, LANES * fw.PACK), alpha1,
                interpret=interpret, block_rows=br)
        return fw.ternary_pack_2d(
            q4, buf_p1.reshape(r4, LANES * fw.PACK),
            buf_p2.reshape(r4, LANES * fw.PACK), beta,
            interpret=interpret, block_rows=br)


def flat_ternary_pack_traced(buf_q, buf_p1, buf_p2, *, t, beta,
                             alpha1: float, interpret: bool | None = None,
                             block_rows: int | None = None):
    """Fused uplink over FlatParams buffers with a *traced* round index.

    Same contract as :func:`flat_ternary_pack` but ``t`` may be a traced
    scalar (the Eq. (4)/(5) branch is selected in-register), so it can live
    inside a jit'd round loop such as the distributed sync body. ``beta``
    may also be traced — e.g. this fed instance's own beta_k gathered from a
    heterogeneous per-worker vector.
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q.shape[0]
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    br = _block_rows_for(
        r4, block_rows or tune.lookup("uplink", r4, interpret=interpret)[0])
    with tprof.kernel_scope("uplink", r4, 1, interpret):
        return fw.ternary_pack_any_2d(
            buf_q.reshape(r4, wide), buf_p1.reshape(r4, wide),
            buf_p2.reshape(r4, wide), t, beta, alpha1,
            interpret=interpret, block_rows=br)


def flat_ternary_pack_stacked(bufs_q, buf_p1, buf_p2, *, t, beta,
                              alpha1: float, interpret: bool | None = None,
                              block_rows: int | None = None,
                              block_workers: int | None = None):
    """Batched uplink: (N, rows, 128) worker buffers → (N, rows//4, 128)
    packed wire buffers in ONE kernel launch.

    The shared public history ``buf_p1``/``buf_p2`` is passed once, not
    stacked N times; the rows-major grid re-reads it once per row block,
    not once per worker. ``t`` may be traced (scalar-operand branch
    select); ``beta`` is a shared scalar or a per-worker ``(N,)`` vector of
    beta_k. ``block_rows``/``block_workers`` default to the tuned plan for
    (rows, N, backend) — see ``repro.kernels.tune``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n, rows, _ = bufs_q.shape
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    br, bw = _stacked_plan("uplink_stacked", r4, n, block_rows,
                           block_workers, interpret)
    with tprof.kernel_scope("uplink_stacked", r4, n, interpret):
        return fw.ternary_pack_stacked_2d(
            bufs_q.reshape(n, r4, wide), buf_p1.reshape(r4, wide),
            buf_p2.reshape(r4, wide), t, beta, alpha1,
            interpret=interpret, block_rows=br, block_workers=bw)


def flat_master_update(buf_q_pilot, packed_stacked, w, buf_p1, buf_p2, *,
                       t, alpha0: float, interpret: bool | None = None,
                       block_rows: int | None = None,
                       block_workers: int | None = None):
    """Fused Eq. (3) over the packed wire buffers of all N workers.

    buf_* (rows, 128) float; packed_stacked (N, rows//4, 128) uint8; w (N,)
    masked per-worker coefficients (pilot zeroed). ``t`` may be traced.
    Returns the new global buffer, (rows, 128) in buf_q_pilot.dtype.

    The kernel walks a (rows, workers) grid accumulating into the resident
    output block, so its VMEM is O(block) — independent of N — and the
    result is bitwise-identical under every (block_rows, block_workers)
    plan (strictly sequential worker accumulation; the oracle is
    ``ref.packed_master_accum_ref``). Block sizes default to the tuned
    plan for (rows, N, backend).
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q_pilot.shape[0]
    n = packed_stacked.shape[0]
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    br, bw = _stacked_plan("master", r4, n, block_rows, block_workers,
                           interpret)
    with tprof.kernel_scope("master", r4, n, interpret):
        out = fw.packed_master_update_2d(
            buf_q_pilot.reshape(r4, wide), packed_stacked,
            w.astype(jnp.float32), buf_p1.reshape(r4, wide),
            buf_p2.reshape(r4, wide), t, alpha0,
            interpret=interpret, block_rows=br, block_workers=bw)
    return out.reshape(rows, LANES)


def flat_ternary_pack_masked(bufs_q, buf_p1, buf_p2, *, t, beta,
                             alpha1: float, wq, pair_keys, pair_signs,
                             rr_keys, rr_threshold: int = 0,
                             word_bits: int = 32, use_masks: bool = True,
                             interpret: bool | None = None,
                             block_rows: int | None = None,
                             block_workers: int | None = None):
    """Masked (secure-agg) uplink over FlatParams buffers: (N, rows, 128)
    float -> (N, rows//4, 512) wire words (uint16 at ``word_bits=16``,
    else uint32) in ONE launch.

    ``wq`` (N,) uint32 fixed-point Eq. (3) weights; ``pair_keys`` (N, L)
    uint32 / ``pair_signs`` (N, L) int32 the per-pair counter keys and
    participation-folded signs (``privacy.masking.pair_stream_keys`` /
    ``pair_signs``); ``rr_keys`` (N,) uint32 per-worker RR keys;
    ``rr_threshold`` the STATIC uint16 flip threshold (0 = DP off);
    ``use_masks`` static (False = unmasked debug wire — no streams are
    generated at all). The mask/RR planes are generated INSIDE the kernel
    from these keys; no (N, rows, 512) tensor ever reaches HBM. ``t`` may
    be traced; ``beta`` a scalar or per-worker (N,) vector. Block plans
    resolve through the ``kernels.tune`` table (kind ``uplink_masked16`` /
    ``uplink_masked`` by modulus, chaining down to the ``uplink_stacked``
    plan when untuned) — every plan produces identical bits.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n, rows, _ = bufs_q.shape
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    kind = "uplink_masked16" if word_bits == 16 else "uplink_masked"
    br, bw = _stacked_plan(kind, r4, n, block_rows, block_workers,
                           interpret)
    with tprof.kernel_scope(kind, r4, n, interpret):
        return mw.ternary_pack_masked_2d(
            bufs_q.reshape(n, r4, wide), buf_p1.reshape(r4, wide),
            buf_p2.reshape(r4, wide), t, beta, alpha1, wq, pair_keys,
            pair_signs, rr_keys, rr_threshold=int(rr_threshold),
            word_bits=word_bits, use_masks=use_masks, interpret=interpret,
            block_rows=br, block_workers=bw)


def flat_masked_master_update(buf_q_pilot, masked, sum_wq, buf_p1, buf_p2,
                              *, t, alpha0: float, scale_mult: float,
                              interpret: bool | None = None,
                              block_rows: int | None = None,
                              block_workers: int | None = None):
    """Sum-then-unmask Eq. (3) over the masked wire words.

    buf_* (rows, 128) float; masked (N, rows//4, 512) uint16 or uint32
    (the dtype picks the modulus); ``sum_wq`` the public scalar sum of the
    fixed-point weights; ``scale_mult`` the fixed-point descale with the
    RR unbias folded in. ``t`` may be traced. Returns the new global
    buffer, (rows, 128) in buf_q_pilot.dtype — bitwise invariant under
    every block plan (modular accumulation is order-free; the oracle is
    ``repro.privacy.ref.masked_master_ref``).
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q_pilot.shape[0]
    n = masked.shape[0]
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    kind = ("master_masked16" if masked.dtype == jnp.uint16
            else "master_masked")
    br, bw = _stacked_plan(kind, r4, n, block_rows, block_workers,
                           interpret)
    with tprof.kernel_scope(kind, r4, n, interpret):
        out = mw.masked_master_update_2d(
            buf_q_pilot.reshape(r4, wide), masked, sum_wq,
            buf_p1.reshape(r4, wide), buf_p2.reshape(r4, wide), t, alpha0,
            scale_mult, interpret=interpret, block_rows=br,
            block_workers=bw)
    return out.reshape(rows, LANES)


def flat_mask_repair(words, pair_keys, pair_coeff, *,
                     interpret: bool | None = None,
                     block_rows: int | None = None):
    """Dropout repair over one masked-word slab (kernel view): add
    ``sum_p coeff[p] * stream(keys[p])`` mod 2**modulus_bits to a
    (rows//4, 512) wire-word buffer in one launch.

    ``pair_keys``/``pair_coeff`` come from
    ``privacy.recovery.repair_coefficients`` — coefficients are nonzero
    only for dead-live pairs, and zero-coefficient streams are skipped
    in-kernel, so a fault-free round's repair is a near-no-op. Plans
    resolve under kind ``mask_repair16``/``mask_repair`` (by dtype) and
    chain down to the ``uplink`` row plan when untuned; every plan
    produces identical bits (modular addition is order-free).
    """
    interpret = _default_interpret() if interpret is None else interpret
    r4 = words.shape[0]
    kind = "mask_repair16" if words.dtype == jnp.uint16 else "mask_repair"
    tuned_br, _ = tune.lookup(kind, r4, 1, interpret=interpret)
    br = _block_rows_for(r4, block_rows or tuned_br)
    with tprof.kernel_scope(kind, r4, 1, interpret):
        return mw.mask_repair_2d(words, pair_keys, pair_coeff,
                                 interpret=interpret, block_rows=br)


def flat_partial_sum(packed, wq, *, fanout: int, word_bits: int = 32,
                     interpret: bool | None = None,
                     block_rows: int | None = None,
                     block_groups: int | None = None):
    """Leaf-level tree sub-aggregate over the packed wire: (C, rows//4,
    128) uint8 children + (C,) fixed-point weights -> (ceil(C/fanout),
    rows//4, 512) word partials, one launch per level.

    The ragged last sibling group (C not a multiple of ``fanout``) is
    padded with zero bytes and zero weight — an exact identity (0·field ==
    0 mod 2**word_bits). Block plans resolve through the tune table under
    kind ``partial_sum`` keyed by (rows, fanout, backend); every plan
    produces identical bits (modular accumulation is order-free).
    """
    interpret = _default_interpret() if interpret is None else interpret
    c, r4, _ = packed.shape
    g = -(-c // fanout)
    # Plans are keyed by fanout (the per-node working set); the group-axis
    # block is fitted to this level's width, not to fanout.
    tuned_br, tuned_bg = tune.lookup("partial_sum", r4, fanout,
                                     interpret=interpret)
    br = _block_rows_for(r4, block_rows or tuned_br)
    bg = tune.fit_block_workers(g, block_groups or tuned_bg)
    pad = g * fanout - c
    wq = jnp.asarray(wq, jnp.uint32)
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
        wq = jnp.pad(wq, (0, pad))
    with tprof.kernel_scope("partial_sum", r4, fanout, interpret):
        return ps.partial_sum_2d(packed, wq, fanout=fanout,
                                 word_bits=word_bits, interpret=interpret,
                                 block_rows=br, block_groups=bg)


def flat_masked_partial_sum(words, keys, signs, *, fanout: int,
                            sibling: int, use_masks: bool = True,
                            interpret: bool | None = None,
                            block_rows: int | None = None,
                            block_groups: int | None = None):
    """Interior tree sub-aggregate over word partials: (C, rows//4, 512)
    children -> (ceil(C/fanout), rows//4, 512) parents in the same wire
    dtype, each parent's own sibling-scoped net mask added in-kernel from
    the level's (G, G) ``keys``/``signs`` matrices.

    Zero-word padding of the ragged last group is an exact identity.
    Plans resolve under kind ``partial_sum_masked16``/``partial_sum_masked``
    (by dtype) keyed by (rows, fanout, backend), chaining down to the
    plain ``partial_sum`` plan when untuned.
    """
    interpret = _default_interpret() if interpret is None else interpret
    c, r4, _ = words.shape
    g = -(-c // fanout)
    kind = ("partial_sum_masked16" if words.dtype == jnp.uint16
            else "partial_sum_masked")
    tuned_br, tuned_bg = tune.lookup(kind, r4, fanout, interpret=interpret)
    br = _block_rows_for(r4, block_rows or tuned_br)
    bg = tune.fit_block_workers(g, block_groups or tuned_bg)
    pad = g * fanout - c
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0), (0, 0)))
    with tprof.kernel_scope(kind, r4, fanout, interpret):
        return ps.masked_partial_sum_2d(
            words, keys, signs, fanout=fanout, sibling=sibling,
            use_masks=use_masks, interpret=interpret, block_rows=br,
            block_groups=bg)


def master_update(q_pilot, tern_stacked, w, p1, p2,
                  interpret: bool | None = None):
    """Fused Eq. (3), t>1. tern_stacked (N, *shape) int8; w (N,) masked.

    Returns array of q_pilot.shape/dtype.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n_workers = tern_stacked.shape[0]
    q2, n = _to_2d(q_pilot, 8)
    p12, _ = _to_2d(p1, 8)
    p22, _ = _to_2d(p2, 8)
    rows = q2.shape[0]
    # Pad/reshape all N workers in ONE traced op (the worker axis rides
    # along), not a Python loop of N per-worker _to_2d + stack.
    flat = tern_stacked.reshape(n_workers, -1)
    t2 = jnp.pad(flat, ((0, 0), (0, rows * LANES - flat.shape[1]))
                 ).reshape(n_workers, rows, LANES)
    br = _block_rows_for(rows, mu.BLOCK_ROWS)
    out = mu.master_update_2d(q2, t2, w.astype(jnp.float32), p12, p22,
                              interpret=interpret, block_rows=br)
    return out.reshape(-1)[:n].reshape(q_pilot.shape)
