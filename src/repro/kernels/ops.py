"""Public jit'd wrappers over the Pallas kernels.

Handles: flat (or pytree) → padded (rows, 128) layout, interpret-mode
selection (Python execution on CPU, compiled on TPU), and un-padding.
These are drop-in replacements for the core/* reference functions and are
what the distributed sync uses when ``use_kernels=True``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import fused_wire as fw
from repro.kernels import pack2bit as pk
from repro.kernels import master_update as mu
from repro.kernels import ternary_encode as te
from repro.utils import round_up

LANES = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(x: jax.Array, row_multiple: int, lane_multiple: int = LANES):
    """Flatten + zero-pad to (rows, lane_multiple), rows % row_multiple == 0."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_row = lane_multiple
    rows = round_up(max(-(-n // per_row), 1), row_multiple)
    padded = rows * per_row
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(rows, per_row), n


def _block_rows_for(rows: int, want: int) -> int:
    """Largest multiple of gcd(rows, want) that divides ``rows`` and is
    ≤ ``want``.

    The gcd floors the probe (≤ want/g steps vs the old unit-step scan) and
    — since padded rows and ``want`` are both multiples of 8 — guarantees
    the result stays 8-sublane aligned, which the old probe did not (e.g.
    rows=8400, want=64 → 48 here vs the unaligned 60 before).
    """
    if rows <= want:
        return rows
    g = math.gcd(rows, want)
    b = (want // g) * g
    while rows % b:
        b -= g
    return b


def ternary_encode(q, p1, p2, beta: float, interpret: bool | None = None):
    """Eq. (5) over an arbitrary-shape array; returns int8 of q.shape."""
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8)
    p12, _ = _to_2d(p1, 8)
    p22, _ = _to_2d(p2, 8)
    br = _block_rows_for(q2.shape[0], te.BLOCK_ROWS)
    out = te.ternary_encode_2d(q2, p12, p22, beta, interpret=interpret,
                               block_rows=br)
    return out.reshape(-1)[:n].reshape(q.shape)


def ternary_encode_round1(q, p0, alpha: float, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8)
    p02, _ = _to_2d(p0, 8)
    br = _block_rows_for(q2.shape[0], te.BLOCK_ROWS)
    out = te.ternary_encode_round1_2d(q2, p02, alpha, interpret=interpret,
                                      block_rows=br)
    return out.reshape(-1)[:n].reshape(q.shape)


def pack2bit(t, interpret: bool | None = None):
    """int8 codes any shape → uint8 (ceil(n/4),) flat packed buffer."""
    interpret = _default_interpret() if interpret is None else interpret
    t2, n = _to_2d(t, 8, LANES * pk.PACK)
    br = _block_rows_for(t2.shape[0], pk.BLOCK_ROWS)
    out = pk.pack2bit_2d(t2, interpret=interpret, block_rows=br)
    n_bytes = -(-n // pk.PACK)
    return out.reshape(-1)[:n_bytes]


def unpack2bit(b, n: int, interpret: bool | None = None):
    """uint8 packed buffer → int8 (n,) codes."""
    interpret = _default_interpret() if interpret is None else interpret
    b2, nb = _to_2d(b, 8, LANES)
    br = _block_rows_for(b2.shape[0], pk.BLOCK_ROWS)
    out = pk.unpack2bit_2d(b2, interpret=interpret, block_rows=br)
    return out.reshape(-1)[:n]


def ternary_pack(q, p1, p2, beta: float, interpret: bool | None = None):
    """Fused Eq. (5) → §3.3 uplink over an arbitrary-shape array.

    Equals ``pack2bit(ternary_encode(q, p1, p2, beta))`` in one launch with
    no int8 intermediate. Returns uint8 (ceil(n/4),) packed wire bytes.
    """
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8, LANES * fw.PACK)
    p12, _ = _to_2d(p1, 8, LANES * fw.PACK)
    p22, _ = _to_2d(p2, 8, LANES * fw.PACK)
    br = _block_rows_for(q2.shape[0], fw.BLOCK_ROWS)
    out = fw.ternary_pack_2d(q2, p12, p22, beta, interpret=interpret,
                             block_rows=br)
    n_bytes = -(-n // fw.PACK)
    return out.reshape(-1)[:n_bytes]


def ternary_pack_round1(q, p0, alpha: float, interpret: bool | None = None):
    """Round-1 (Eq. (4)) variant of :func:`ternary_pack`."""
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8, LANES * fw.PACK)
    p02, _ = _to_2d(p0, 8, LANES * fw.PACK)
    br = _block_rows_for(q2.shape[0], fw.BLOCK_ROWS)
    out = fw.ternary_pack_round1_2d(q2, p02, alpha, interpret=interpret,
                                    block_rows=br)
    n_bytes = -(-n // fw.PACK)
    return out.reshape(-1)[:n_bytes]


def flat_ternary_pack(buf_q, buf_p1, buf_p2, *, t: int, beta: float,
                      alpha1: float, interpret: bool | None = None,
                      block_rows: int | None = None):
    """Fused uplink over FlatParams buffers: (rows, 128) → (rows//4, 128).

    ``t`` is the (static) 1-based round index: round 1 uses the Eq. (4)
    threshold ``alpha1`` against ``buf_p1`` (= P^0), later rounds Eq. (5)
    with ``beta`` against the (P^{t-1}, P^{t-2}) history.
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q.shape[0]
    r4 = rows // fw.PACK
    q4 = buf_q.reshape(r4, LANES * fw.PACK)
    br = _block_rows_for(r4, block_rows or fw.BLOCK_ROWS)
    if t <= 1:
        return fw.ternary_pack_round1_2d(
            q4, buf_p1.reshape(r4, LANES * fw.PACK), alpha1,
            interpret=interpret, block_rows=br)
    return fw.ternary_pack_2d(
        q4, buf_p1.reshape(r4, LANES * fw.PACK),
        buf_p2.reshape(r4, LANES * fw.PACK), beta,
        interpret=interpret, block_rows=br)


def flat_ternary_pack_traced(buf_q, buf_p1, buf_p2, *, t, beta,
                             alpha1: float, interpret: bool | None = None,
                             block_rows: int | None = None):
    """Fused uplink over FlatParams buffers with a *traced* round index.

    Same contract as :func:`flat_ternary_pack` but ``t`` may be a traced
    scalar (the Eq. (4)/(5) branch is selected in-register), so it can live
    inside a jit'd round loop such as the distributed sync body. ``beta``
    may also be traced — e.g. this fed instance's own beta_k gathered from a
    heterogeneous per-worker vector.
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q.shape[0]
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    br = _block_rows_for(r4, block_rows or fw.BLOCK_ROWS)
    return fw.ternary_pack_any_2d(
        buf_q.reshape(r4, wide), buf_p1.reshape(r4, wide),
        buf_p2.reshape(r4, wide), t, beta, alpha1,
        interpret=interpret, block_rows=br)


def flat_ternary_pack_stacked(bufs_q, buf_p1, buf_p2, *, t, beta,
                              alpha1: float, interpret: bool | None = None,
                              block_rows: int | None = None):
    """Batched uplink: (N, rows, 128) worker buffers → (N, rows//4, 128)
    packed wire buffers in ONE kernel launch.

    The shared public history ``buf_p1``/``buf_p2`` is passed once, not
    stacked N times. ``t`` may be traced (scalar-operand branch select);
    ``beta`` is a shared scalar or a per-worker ``(N,)`` vector of beta_k.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n, rows, _ = bufs_q.shape
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    br = _block_rows_for(r4, block_rows or fw.BLOCK_ROWS)
    return fw.ternary_pack_stacked_2d(
        bufs_q.reshape(n, r4, wide), buf_p1.reshape(r4, wide),
        buf_p2.reshape(r4, wide), t, beta, alpha1,
        interpret=interpret, block_rows=br)


def flat_master_update(buf_q_pilot, packed_stacked, w, buf_p1, buf_p2, *,
                       t, alpha0: float, interpret: bool | None = None,
                       block_rows: int | None = None):
    """Fused Eq. (3) over the packed wire buffers of all N workers.

    buf_* (rows, 128) float; packed_stacked (N, rows//4, 128) uint8; w (N,)
    masked per-worker coefficients (pilot zeroed). ``t`` may be traced.
    Returns the new global buffer, (rows, 128) in buf_q_pilot.dtype.
    """
    interpret = _default_interpret() if interpret is None else interpret
    rows = buf_q_pilot.shape[0]
    r4 = rows // fw.PACK
    wide = LANES * fw.PACK
    br = _block_rows_for(r4, block_rows or fw.BLOCK_ROWS)
    out = fw.packed_master_update_2d(
        buf_q_pilot.reshape(r4, wide), packed_stacked,
        w.astype(jnp.float32), buf_p1.reshape(r4, wide),
        buf_p2.reshape(r4, wide), t, alpha0,
        interpret=interpret, block_rows=br)
    return out.reshape(rows, LANES)


def master_update(q_pilot, tern_stacked, w, p1, p2,
                  interpret: bool | None = None):
    """Fused Eq. (3), t>1. tern_stacked (N, *shape) int8; w (N,) masked.

    Returns array of q_pilot.shape/dtype.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n_workers = tern_stacked.shape[0]
    q2, n = _to_2d(q_pilot, 8)
    p12, _ = _to_2d(p1, 8)
    p22, _ = _to_2d(p2, 8)
    rows = q2.shape[0]
    t2 = jnp.stack([_to_2d(tern_stacked[k], 8)[0]
                    for k in range(n_workers)])
    br = _block_rows_for(rows, mu.BLOCK_ROWS)
    out = mu.master_update_2d(q2, t2, w.astype(jnp.float32), p12, p22,
                              interpret=interpret, block_rows=br)
    return out.reshape(-1)[:n].reshape(q_pilot.shape)
