"""Public jit'd wrappers over the Pallas kernels.

Handles: flat (or pytree) → padded (rows, 128) layout, interpret-mode
selection (Python execution on CPU, compiled on TPU), and un-padding.
These are drop-in replacements for the core/* reference functions and are
what the distributed sync uses when ``use_kernels=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import pack2bit as pk
from repro.kernels import master_update as mu
from repro.kernels import ternary_encode as te
from repro.utils import round_up

LANES = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(x: jax.Array, row_multiple: int, lane_multiple: int = LANES):
    """Flatten + zero-pad to (rows, lane_multiple), rows % row_multiple == 0."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_row = lane_multiple
    rows = round_up(max(-(-n // per_row), 1), row_multiple)
    padded = rows * per_row
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(rows, per_row), n


def _block_rows_for(rows: int, want: int) -> int:
    b = min(want, rows)
    while rows % b:
        b -= 1
    return max(b, 1)


def ternary_encode(q, p1, p2, beta: float, interpret: bool | None = None):
    """Eq. (5) over an arbitrary-shape array; returns int8 of q.shape."""
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8)
    p12, _ = _to_2d(p1, 8)
    p22, _ = _to_2d(p2, 8)
    br = _block_rows_for(q2.shape[0], te.BLOCK_ROWS)
    out = te.ternary_encode_2d(q2, p12, p22, beta, interpret=interpret,
                               block_rows=br)
    return out.reshape(-1)[:n].reshape(q.shape)


def ternary_encode_round1(q, p0, alpha: float, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    q2, n = _to_2d(q, 8)
    p02, _ = _to_2d(p0, 8)
    br = _block_rows_for(q2.shape[0], te.BLOCK_ROWS)
    out = te.ternary_encode_round1_2d(q2, p02, alpha, interpret=interpret,
                                      block_rows=br)
    return out.reshape(-1)[:n].reshape(q.shape)


def pack2bit(t, interpret: bool | None = None):
    """int8 codes any shape → uint8 (ceil(n/4),) flat packed buffer."""
    interpret = _default_interpret() if interpret is None else interpret
    t2, n = _to_2d(t, 8, LANES * pk.PACK)
    br = _block_rows_for(t2.shape[0], pk.BLOCK_ROWS)
    out = pk.pack2bit_2d(t2, interpret=interpret, block_rows=br)
    n_bytes = -(-n // pk.PACK)
    return out.reshape(-1)[:n_bytes]


def unpack2bit(b, n: int, interpret: bool | None = None):
    """uint8 packed buffer → int8 (n,) codes."""
    interpret = _default_interpret() if interpret is None else interpret
    b2, nb = _to_2d(b, 8, LANES)
    br = _block_rows_for(b2.shape[0], pk.BLOCK_ROWS)
    out = pk.unpack2bit_2d(b2, interpret=interpret, block_rows=br)
    return out.reshape(-1)[:n]


def master_update(q_pilot, tern_stacked, w, p1, p2,
                  interpret: bool | None = None):
    """Fused Eq. (3), t>1. tern_stacked (N, *shape) int8; w (N,) masked.

    Returns array of q_pilot.shape/dtype.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n_workers = tern_stacked.shape[0]
    q2, n = _to_2d(q_pilot, 8)
    p12, _ = _to_2d(p1, 8)
    p22, _ = _to_2d(p2, 8)
    rows = q2.shape[0]
    t2 = jnp.stack([_to_2d(tern_stacked[k], 8)[0]
                    for k in range(n_workers)])
    br = _block_rows_for(rows, mu.BLOCK_ROWS)
    out = mu.master_update_2d(q2, t2, w.astype(jnp.float32), p12, p22,
                              interpret=interpret, block_rows=br)
    return out.reshape(-1)[:n].reshape(q_pilot.shape)
