"""Mixture-of-Experts: top-k routing with capacity-based scatter dispatch.

TPU-native design notes (DESIGN.md §2): dispatch is a *scatter/gather*, not
a one-hot matmul — the Mesh-TF-style `einsum('te,td->etd')` dispatch inflates
HLO FLOPs by the full T×E×C×D product and would corrupt the roofline
analysis. Here:

  1. router logits (T, E) in fp32, softmax, top-k, renormalize;
  2. position-in-expert via cumsum over the flat (T·k,) assignment stream;
  3. tokens scattered into (E, C, D) expert buffers (overflow dropped — the
     classic capacity-factor discipline);
  4. per-expert SwiGLU via batched einsum over the E axis (expert-parallel
     sharding over 'model' when E divides it — sharding/specs.py);
  5. gather back, combine with gate weights, add shared-expert output.

Aux losses: switch-style load balance + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.ffn import init_mlp, mlp
from repro.models.layers import dense_init, dtype_of, silu
from repro.sharding import activations as act


def init_moe(cfg: ArchConfig, key) -> dict:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_expert_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    import numpy as np
    std = 1.0 / np.sqrt(D)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "experts_gate": (std * jax.random.truncated_normal(
            ks[1], -2, 2, (E, D, Fe), jnp.float32)).astype(dt),
        "experts_up": (std * jax.random.truncated_normal(
            ks[2], -2, 2, (E, D, Fe), jnp.float32)).astype(dt),
        "experts_down": ((1.0 / np.sqrt(Fe)) * jax.random.truncated_normal(
            ks[3], -2, 2, (E, Fe, D), jnp.float32)).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4],
                               d_ff=cfg.n_shared_experts * cfg.d_expert_ff)
    return p


# §Perf toggle: force the paper-standard global-capacity dispatch even on a
# mesh (the "before" of the shard-local dispatch hillclimb).
FORCE_GLOBAL_DISPATCH = [False]


def capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / max(cfg.n_experts, 1))
    return max(c, cfg.top_k)


def moe(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, dict]:
    """x (B, S, D) → (out, aux). aux: load_balance_loss, z_loss, drop_frac.

    Dispatch is SHARD-LOCAL (§Perf): the token stream is viewed as
    (s, T/s) blocks matching the data-parallel shards and
    position-in-expert is computed *within each block*, so the scatter into
    (E, s, C_loc, D) buffers never crosses shards — the global-cumsum
    scatter otherwise forces (E, C, D)-sized all-reduces on every MoE layer
    (observed ~1.9 TB/device on grok train_4k). Per-device capacity is also
    what production routers implement. Off-mesh (unit tests) s == 1 and the
    semantics are the paper-standard global capacity.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    s_blk = act.dp_size()
    # Block-local dispatch pays off for tensor-parallel experts (E does not
    # divide 'model'); with expert-parallel buffers the (model×data) 2-D
    # resharding of blocked buffers regressed 10× on deepseek — measured,
    # see EXPERIMENTS.md §Perf — so expert-parallel keeps global dispatch.
    if T % s_blk or FORCE_GLOBAL_DISPATCH[0] \
            or (act.dp_size() > 1 and E % act.model_size() == 0):
        s_blk = 1
    Tl = T // s_blk
    C = capacity(cfg, Tl)                                    # per-block
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, e_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- block-local position-in-expert --------------------------------
    flat_e = e_idx.reshape(s_blk, Tl * K)                    # (s, Tl*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (s, Tl*K, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot            # within block
    pos = jnp.take_along_axis(
        pos_all, flat_e[..., None], axis=2)[..., 0]          # (s, Tl*K)
    keep = pos < C
    gate_flat = gate_vals.reshape(s_blk, Tl * K) * keep.astype(jnp.float32)

    # ---- block-local scatter into expert buffers ------------------------
    token_idx = jnp.repeat(jnp.arange(Tl), K)                # within block
    blk_idx = jnp.broadcast_to(jnp.arange(s_blk)[:, None], (s_blk, Tl * K))
    buf = jnp.zeros((E, s_blk, C, D), x.dtype)
    e_safe = jnp.where(keep, flat_e, 0)
    pos_safe = jnp.where(keep, pos, C - 1)
    xb = xf.reshape(s_blk, Tl, D)
    contrib = jnp.where(keep[..., None], xb[:, token_idx], 0).astype(x.dtype)
    buf = act.expert_block_buf(
        buf.at[e_safe, blk_idx, pos_safe].add(contrib, mode="drop"))

    # ---- expert SwiGLU over the E axis ----------------------------------
    w_gate = act.expert_weights(p["experts_gate"])
    w_up = act.expert_weights(p["experts_up"])
    w_down = act.expert_weights(p["experts_down"], transposed=True)
    h = silu(jnp.einsum("escd,edf->escf", buf, w_gate)) * \
        jnp.einsum("escd,edf->escf", buf, w_up)
    h = act.expert_block_hidden(h)
    out_buf = act.expert_block_buf(
        jnp.einsum("escf,efd->escd", h, w_down))             # (E, s, C, D)

    # ---- block-local gather + combine -----------------------------------
    y_flat = out_buf[e_safe, blk_idx, pos_safe]              # (s, Tl*K, D)
    y = jnp.sum(
        (y_flat.astype(jnp.float32)
         * gate_flat[..., None]).reshape(T, K, D),
        axis=1,
    ).astype(x.dtype)

    if "shared" in p:
        y = y + mlp(p["shared"], cfg, xf)

    # ---- aux losses ----------------------------------------------------
    # Switch load balance: E * sum_e (token_frac_e * prob_frac_e)
    assign_frac = jnp.mean(
        jax.nn.one_hot(e_idx, E, dtype=jnp.float32).sum(1), axis=0)  # (E,)
    prob_frac = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(assign_frac / K * prob_frac)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"load_balance": lb, "z_loss": z, "drop_frac": drop_frac}
    return y.reshape(B, S, D), aux
