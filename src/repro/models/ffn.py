"""Feed-forward blocks: SwiGLU (llama-family) and GELU (whisper)."""
from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, dtype_of, silu
from repro.sharding import activations as act


def init_mlp(cfg: ArchConfig, key, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    if cfg.ffn_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, D, F, dt),
            "w_up": dense_init(k2, D, F, dt),
            "w_down": dense_init(k3, F, D, dt),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": dense_init(k1, D, F, dt),
        "w_down": dense_init(k2, F, D, dt),
    }


def mlp(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if "w_gate" in p:
        h = silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    if h.ndim == 3:
        h = act.ffn_hidden(h)
    return h @ p["w_down"]
