"""Model facade: build any assigned architecture from its ArchConfig.

API (all pure functions, pjit/shard_map friendly):

    m = build_model(get_config("qwen3-14b"))
    params = m.init(key)
    loss, aux = m.loss(params, batch)
    params, opt_state, metrics = m.train_step(params, opt_state, batch, lr)
    cache = m.init_decode_state(batch, max_len)
    cache = m.prefill(params, batch, cache)         # (audio/vlm set up here)
    logits, cache = m.decode_step(params, cache, batch_step)

Batch conventions:
  LM:    {"tokens": (B, S) i32}
  VLM:   + {"vision_embed": (B, P, D), "positions": (3, B, S) i32}
  audio: {"tokens": (B, S) i32, "audio_embed": (B, F, D)}
Decode step: {"token": (B, 1) i32, "pos": () i32} (+ "positions" (3,B,1) vlm).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.layers import dtype_of, rms_norm, rope_cos_sin, mrope_cos_sin, \
    sinusoidal_positions
from repro.optim.optimizers import Optimizer, apply_updates, momentum
from repro.sharding import activations as act

PyTree = Any


def _needs_rope(cfg: ArchConfig) -> bool:
    return not cfg.is_encdec  # whisper uses sinusoidal tables instead


def _rope_for(cfg: ArchConfig, batch: dict, S: int):
    if not _needs_rope(cfg):
        return None, None
    dh = cfg.resolved_head_dim
    if cfg.mrope and "positions" in batch:
        cos, sin = mrope_cos_sin(batch["positions"], dh, cfg.rope_theta,
                                 cfg.mrope_sections)
        return cos, sin  # (B, S, dh//2)
    pos = jnp.arange(S, dtype=jnp.int32)[None]              # (1, S)
    return rope_cos_sin(pos, dh, cfg.rope_theta)


def _embed(cfg: ArchConfig, params: PyTree, batch: dict) -> jax.Array:
    x = params["embed"][batch["tokens"]]
    if cfg.arch_type == "vlm" and "vision_embed" in batch:
        patches = batch["vision_embed"] @ params["patch_proj"]
        n_p = patches.shape[1]
        x = jnp.concatenate(
            [x[:, :n_p] + patches.astype(x.dtype), x[:, n_p:]], axis=1)
    if cfg.is_encdec:
        pe = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model), x.dtype)
        x = x + pe
    return act.residual(x)


def _logits(cfg: ArchConfig, params: PyTree, x) -> jax.Array:
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return act.logits(x @ head)


def _xent(logits, labels) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    train_step: Callable
    init_decode_state: Callable
    prefill: Callable
    prefill_sequential: Callable
    decode_step: Callable
    optimizer: Optimizer


def build_model(cfg: ArchConfig, optimizer: Optional[Optimizer] = None) -> Model:
    opt = optimizer or momentum()
    act_dtype = dtype_of(cfg.param_dtype)

    def init(key) -> PyTree:
        return tf.init_stack(cfg, key)

    # ---------------- forward / loss ----------------
    def forward(params: PyTree, batch: dict):
        S = batch["tokens"].shape[1]
        cos, sin = _rope_for(cfg, batch, S)
        x = _embed(cfg, params, batch)
        cross_kvs = None
        if cfg.is_encdec:
            enc = tf.apply_encoder(cfg, params, batch["audio_embed"])
            cross_kvs = tf.encoder_cross_kvs(cfg, params, enc)
        x = tf.apply_dense_prefix_train(cfg, params, x, cos, sin)
        x, aux = tf.apply_units_train(cfg, params, x, cos, sin,
                                      cross_kvs=cross_kvs)
        return _logits(cfg, params, x), aux

    def loss(params: PyTree, batch: dict):
        logits, aux = forward(params, batch)
        labels = batch["tokens"][:, 1:]
        l = _xent(logits[:, :-1], labels)
        n_moe = sum(1 for _, f in cfg.pattern if f == "moe") * cfg.n_units
        if n_moe:
            l = l + cfg.router_aux_weight * aux["load_balance"] / n_moe \
                  + 1e-3 * aux["z_loss"] / n_moe
        return l, aux

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params: PyTree, opt_state: PyTree, batch: dict, lr):
        (l, aux), grads = grad_fn(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return params, opt_state, {"loss": l, "grad_norm": gnorm, **aux}

    # ---------------- serving ----------------
    def init_decode_state(batch: int, max_len: int) -> dict:
        state = {
            "units": tf.init_unit_caches(cfg, batch, max_len, act_dtype),
        }
        dp = tf.init_dense_prefix_caches(cfg, batch, max_len, act_dtype)
        if dp is not None:
            state["dense"] = dp
        if cfg.is_encdec:
            dh = cfg.resolved_head_dim
            kv = {
                "k": jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, dh),
                               act_dtype),
                "v": jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, dh),
                               act_dtype),
            }
            state["cross"] = {
                f"b{j}": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(
                        a, (cfg.n_units,) + a.shape), kv)
                for j in range(len(cfg.pattern))
            }
        return state

    def prefill(params: PyTree, batch: dict, state: dict):
        """Parallel prefill: full-sequence forward that fills the decode
        caches in one pass (what production serving lowers for prefill_32k).
        Returns (last_logits (B,1,V), state)."""
        S = batch["tokens"].shape[1]
        cos, sin = _rope_for(cfg, batch, S)
        x = _embed(cfg, params, batch)
        new_state = dict(state)
        if cfg.is_encdec:
            enc = tf.apply_encoder(cfg, params, batch["audio_embed"])
            new_state["cross"] = tf.encoder_cross_kvs(cfg, params, enc)
        if "dense" in state:
            x, new_state["dense"] = tf.apply_dense_prefix_prefill(
                cfg, params, x, cos, sin, state["dense"])
        x, new_state["units"], _aux = tf.apply_units_prefill(
            cfg, params, x, cos, sin, state["units"],
            cross_kvs=new_state.get("cross"))
        logits = _logits(cfg, params, x[:, -1:])
        return logits, new_state

    def prefill_sequential(params: PyTree, batch: dict, state: dict):
        """Prompt processing as a scan of decode steps — kept as the exact
        cache-parity oracle for tests (slow; O(S) sequential)."""
        if cfg.is_encdec:
            enc = tf.apply_encoder(cfg, params, batch["audio_embed"])
            state = dict(state)
            state["cross"] = tf.encoder_cross_kvs(cfg, params, enc)

        S = batch["tokens"].shape[1]

        def step(carry, i):
            st, _ = carry
            tok = jax.lax.dynamic_slice_in_dim(batch["tokens"], i, 1, axis=1)
            step_batch = {"token": tok, "pos": i}
            if cfg.mrope and "positions" in batch:
                step_batch["positions"] = jax.lax.dynamic_slice_in_dim(
                    batch["positions"], i, 1, axis=2)
            logits, st = _decode_core(params, st, step_batch)
            return (st, logits), None

        zero_logits = jnp.zeros(
            (batch["tokens"].shape[0], 1, cfg.vocab), act_dtype)
        (state, logits), _ = jax.lax.scan(
            step, (state, zero_logits), jnp.arange(S))
        return logits, state

    def _decode_core(params: PyTree, state: dict, step_batch: dict):
        tok = step_batch["token"]            # (B, 1)
        pos = step_batch["pos"]              # scalar
        x = params["embed"][tok]
        if cfg.is_encdec:
            from repro.models.layers import sinusoidal_at
            pe = sinusoidal_at(jnp.asarray(pos), cfg.d_model).astype(x.dtype)
            x = x + pe[None, None]
            cos = sin = None
        elif cfg.mrope and "positions" in step_batch:
            cos, sin = mrope_cos_sin(step_batch["positions"],
                                     cfg.resolved_head_dim, cfg.rope_theta,
                                     cfg.mrope_sections)
        else:
            cos, sin = rope_cos_sin(
                jnp.full((1, 1), pos, jnp.int32),
                cfg.resolved_head_dim, cfg.rope_theta)

        new_state = dict(state)
        if "dense" in state:
            x, new_state["dense"] = tf.apply_dense_prefix_decode(
                cfg, params, x, pos, state["dense"], cos, sin)
        x, new_state["units"] = tf.apply_units_decode(
            cfg, params, x, pos, state["units"], cos, sin,
            cross_kvs=state.get("cross"))
        return _logits(cfg, params, x), new_state

    def decode_step(params: PyTree, state: dict, step_batch: dict):
        return _decode_core(params, state, step_batch)

    return Model(
        cfg=cfg,
        init=init,
        loss=loss,
        train_step=train_step,
        init_decode_state=init_decode_state,
        prefill=prefill,
        prefill_sequential=prefill_sequential,
        decode_step=decode_step,
        optimizer=opt,
    )
