"""Recurrent mixers: Mamba (selective SSM) and xLSTM (mLSTM / sLSTM).

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel becomes a
*chunked* scan — an outer ``lax.scan`` over sequence chunks carrying the
(B, d_inner, d_state) state, with an ``associative_scan`` inside each chunk.
Only one chunk's (B, Q, d_inner, d_state) tensor is ever materialized, which
is the VMEM-friendly analogue of the kernel's SRAM blocking, and the inner
scan exposes MXU-parallel work instead of a 1-step-at-a-time recurrence.

mLSTM keeps its exact recurrence (exponential gating with the max-stabilizer
from the xLSTM paper) under a time-step scan whose carry is the matrix
memory (B, H, dh, dh); q/k/v/gate projections are hoisted out of the scan so
the sequential part is only the rank-1 state update. sLSTM is inherently
sequential (h_{t-1} feeds the gates) — a time-step scan is the architecture,
not an implementation shortcut.

Decode paths update the same states one token at a time — O(1) in context,
which is what qualifies these archs for ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, dtype_of, rms_norm, silu
from repro.models.scan_config import unroll as _unroll
from repro.sharding import activations as act


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

def init_mamba(cfg: ArchConfig, key) -> dict:
    D, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    dtr = cfg.resolved_dt_rank
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # S4-style A init: A_log = log(1..ds) per channel.
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], D, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   / np.sqrt(dc)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dt),
        "dt_proj": dense_init(ks[3], dtr, di, dt),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[4], (di,), jnp.float32,
                np.log(1e-3), np.log(1e-1))), 1e-4, None))).astype(dt),
        "A_log": jnp.log(a),          # fp32 (di, ds)
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, D, dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B, S, di), w (dc, di)."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(pad[:, j : j + x.shape[1]] * w[j] for j in range(dc))
    return out + b


def _ssm_scan_chunk(a, b, h0):
    """One chunk of the diagonal SSM recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (B, Q, di, ds); h0 (B, di, ds). Uses an associative scan for the
    homogeneous part and a stable cumulative-decay term for the carry-in
    (a ∈ (0,1] so cumprod never overflows). Returns (h_all, h_last).
    """
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    a_cum, h_zero = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = h_zero + a_cum * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_train(p: dict, cfg: ArchConfig, x: jax.Array,
                chunk: int = 256) -> jax.Array:
    """Full-sequence Mamba mixer. x (B, S, D) → (B, S, D)."""
    y, _ = _mamba_forward(p, cfg, x, chunk, return_state=False)
    return y


def mamba_prefill(p: dict, cfg: ArchConfig, x: jax.Array,
                  chunk: int = 256) -> tuple[jax.Array, dict]:
    """Full-sequence Mamba that also returns the decode state."""
    return _mamba_forward(p, cfg, x, chunk, return_state=True)


def _mamba_forward(p: dict, cfg: ArchConfig, x: jax.Array,
                   chunk: int = 256, return_state: bool = False):
    B, S, D = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    dtr = cfg.resolved_dt_rank
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"

    xz = act.ffn_hidden(x @ p["in_proj"])
    xp, z = jnp.split(xz, 2, axis=-1)                       # (B,S,di) each
    xc = silu(_causal_conv(xp, p["conv_w"], p["conv_b"]))
    proj = xc @ p["x_proj"]                                 # (B,S,dtr+2ds)
    dt_r = proj[..., :dtr]
    Bm = proj[..., dtr : dtr + ds].astype(jnp.float32)      # (B,S,ds)
    Cm = proj[..., dtr + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]).astype(jnp.float32) +
        p["dt_bias"].astype(jnp.float32))                   # (B,S,di)
    A = -jnp.exp(p["A_log"])                                # (di, ds) fp32

    nc = S // Q
    xcf = xc.astype(jnp.float32)

    def chunk_body(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * Q, Q, axis=1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(Bm), sl(Cm), sl(xcf)
        a = jnp.exp(dt_c[..., None] * A)                    # (B,Q,di,ds)
        binc = (dt_c * x_c)[..., None] * b_c[:, :, None, :]  # (B,Q,di,ds)
        h_all, h_last = _ssm_scan_chunk(a, binc, h)
        y = jnp.einsum("bqns,bqs->bqn", h_all, c_c)         # (B,Q,di)
        return h_last, y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_body, h0, jnp.arange(nc),
                               unroll=_unroll())
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)            # (B,S,di)
    y = y + p["D_skip"] * xcf
    y = (y * silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    if not return_state:
        return out, None
    dc = cfg.d_conv
    conv_state = xp[:, -(dc - 1):].astype(x.dtype) if dc > 1 else \
        jnp.zeros((B, 0, di), x.dtype)
    if S < dc - 1:
        conv_state = jnp.concatenate(
            [jnp.zeros((B, dc - 1 - S, di), x.dtype), xp.astype(x.dtype)], axis=1)
    return out, {"h": h_last, "conv": conv_state}


def init_mamba_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    di, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
    }


def mamba_decode(p: dict, cfg: ArchConfig, x: jax.Array,
                 state: dict) -> tuple[jax.Array, dict]:
    """One-token Mamba step. x (B, 1, D)."""
    di, ds = cfg.d_inner, cfg.d_state
    dtr = cfg.resolved_dt_rank
    xz = x[:, 0] @ p["in_proj"]
    xp, z = jnp.split(xz, 2, axis=-1)                       # (B, di)
    window = jnp.concatenate([state["conv"],
                              xp[:, None].astype(state["conv"].dtype)], axis=1)
    xc = jnp.einsum("bci,ci->bi", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    xc = silu(xc)
    proj = xc.astype(x.dtype) @ p["x_proj"]
    dt_r = proj[..., :dtr]
    Bm = proj[..., dtr : dtr + ds].astype(jnp.float32)
    Cm = proj[..., dtr + ds :].astype(jnp.float32)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                          # (B,di,ds)
    h = a * state["h"] + (dt * xc)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bns,bs->bn", h, Cm) + p["D_skip"] * xc
    y = (y * silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, exponential gating with stabilizer)
# ---------------------------------------------------------------------------

def init_mlstm(cfg: ArchConfig, key) -> dict:
    D = cfg.d_model
    di = int(cfg.lstm_proj_factor * D)
    H = cfg.n_heads
    di = (di // H) * H
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], D, 2 * di, dt),
        "wq": dense_init(ks[1], di, di, dt),
        "wk": dense_init(ks[2], di, di, dt),
        "wv": dense_init(ks[3], di, di, dt),
        "gates_w": dense_init(ks[4], di, 2 * H, jnp.float32),
        "gates_b": jnp.concatenate([
            jnp.zeros((H,), jnp.float32),             # input gate bias
            3.0 * jnp.ones((H,), jnp.float32),        # forget gate bias (open)
        ]),
        "norm": jnp.ones((di,), dt),                  # per-head output norm
        "out_proj": dense_init(ks[5], di, D, dt),
    }


def _mlstm_qkvg(p, cfg, x):
    """Hoisted projections. x (B,S,D) → q,k,v (B,S,H,dh), li/lf (B,S,H), z."""
    di = p["wq"].shape[0]
    H = cfg.n_heads
    dh = di // H
    xz = x @ p["in_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)
    q = (xm @ p["wq"]).reshape(*xm.shape[:-1], H, dh)
    k = (xm @ p["wk"]).reshape(*xm.shape[:-1], H, dh) / np.sqrt(dh)
    v = (xm @ p["wv"]).reshape(*xm.shape[:-1], H, dh)
    gates = xm.astype(jnp.float32) @ p["gates_w"] + p["gates_b"]
    li, lf_raw = jnp.split(gates, 2, axis=-1)               # (B,S,H)
    lf = jax.nn.log_sigmoid(lf_raw)
    return q, k, v, li, lf, z


def _mlstm_step(carry, inp):
    """One stabilized mLSTM cell step.

    carry: C (B,H,dhv,dhk), n (B,H,dhk), m (B,H)
    inp:   q,k,v (B,H,dh), li,lf (B,H)
    """
    C, n, m, = carry
    q, k, v, li, lf = inp
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(lf + m, li)
    i_g = jnp.exp(li - m_new)[..., None]                    # (B,H,1)
    f_g = jnp.exp(lf + m - m_new)[..., None]
    C = f_g[..., None] * C + i_g[..., None] * (vf[..., :, None] * kf[..., None, :])
    n = f_g * n + i_g * kf
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return (C, n, m_new), h


# Chunked-remat switch for the recurrent time scans (§Perf hillclimb):
# chunk size C checkpoints the carry every C steps — backward residual
# memory drops from O(S · state) to O(S/C · state) at the cost of one
# in-chunk forward recompute. None = naive (residuals at every step).
LSTM_CHUNK = [64]


def set_lstm_chunk(c):
    LSTM_CHUNK[0] = c


def mlstm_train(p: dict, cfg: ArchConfig, x: jax.Array,
                return_state: bool = False):
    B, S, D = x.shape
    H = cfg.n_heads
    q, k, v, li, lf, z = _mlstm_qkvg(p, cfg, x)
    di = q.shape[-1] * H

    def step(carry, inp):
        return _mlstm_step(carry, inp)

    dh = q.shape[-1]
    carry = (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    chunk = LSTM_CHUNK[0]
    if chunk and S % min(chunk, S) == 0 and S > min(chunk, S):
        Q = min(chunk, S)
        nc = S // Q

        def chunk_body(c, idx):
            sl = tuple(
                jnp.moveaxis(
                    jax.lax.dynamic_slice_in_dim(t, idx * Q, Q, axis=1),
                    1, 0)
                for t in (q, k, v, li, lf))
            c2, hs_c = jax.lax.scan(step, c, sl)
            return c2, hs_c                                  # (Q,B,H,dh)

        final, hs = jax.lax.scan(jax.checkpoint(chunk_body), carry,
                                 jnp.arange(nc))             # (nc,Q,B,H,dh)
        hs = hs.reshape(S, B, H, dh)
    else:
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, li, lf))
        final, hs = jax.lax.scan(step, carry, xs)            # (S,B,H,dh)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di)
    h = rms_norm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    h = h * silu(z)
    out = h @ p["out_proj"]
    if return_state:
        C, n, m = final
        return out, {"C": C, "n": n, "m": m}
    return out


def init_mlstm_state(cfg: ArchConfig, batch: int) -> dict:
    di = int(cfg.lstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    di = (di // H) * H
    dh = di // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(p: dict, cfg: ArchConfig, x: jax.Array,
                 state: dict) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    H = cfg.n_heads
    q, k, v, li, lf, z = _mlstm_qkvg(p, cfg, x)             # S == 1
    carry = (state["C"], state["n"], state["m"])
    inp = tuple(t[:, 0] for t in (q, k, v, li, lf))
    (C, n, m), h = _mlstm_step(carry, inp)                  # h (B,H,dh)
    di = h.shape[-1] * H
    h = h.reshape(B, 1, di)
    h = rms_norm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    h = h * silu(z)
    return h @ p["out_proj"], {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, true recurrence)
# ---------------------------------------------------------------------------

def init_slstm(cfg: ArchConfig, key) -> dict:
    D = cfg.d_model
    di = D
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "gates_w": dense_init(ks[0], D, 4 * di, jnp.float32),
        "r_gates_w": (dense_init(ks[1], di, 4 * di, jnp.float32)
                      / np.sqrt(di)),
        "gates_b": jnp.concatenate([
            jnp.zeros((di,), jnp.float32),
            3.0 * jnp.ones((di,), jnp.float32),       # forget bias
            jnp.zeros((2 * di,), jnp.float32),
        ]),
        "out_proj": dense_init(ks[2], di, D, dt),
    }


def _slstm_step(p, carry, x_t):
    """x_t (B, 4di) pre-projected input contribution."""
    c, n, h, m = carry
    raw = x_t + h @ p["r_gates_w"] + p["gates_b"]
    di = raw.shape[-1] // 4
    li = raw[..., :di]
    lf = raw[..., di : 2 * di]                   # exp forget gate (log-space)
    z_raw = raw[..., 2 * di : 3 * di]
    o_raw = raw[..., 3 * di :]
    m_new = jnp.maximum(lf + m, li)
    i_g = jnp.exp(li - m_new)
    f_g = jnp.exp(lf + m - m_new)
    c = f_g * c + i_g * jnp.tanh(z_raw)
    n = jnp.maximum(f_g * n + i_g, jnp.exp(-m_new))
    h = jax.nn.sigmoid(o_raw) * c / n
    return (c, n, h, m_new)


def slstm_train(p: dict, cfg: ArchConfig, x: jax.Array,
                return_state: bool = False):
    B, S, D = x.shape
    di = D
    xg = x.astype(jnp.float32) @ p["gates_w"]               # (B,S,4di)

    def step(carry, x_t):
        new = _slstm_step(p, carry, x_t)
        return new, new[2]

    carry = (jnp.zeros((B, di), jnp.float32),
             jnp.ones((B, di), jnp.float32),
             jnp.zeros((B, di), jnp.float32),
             jnp.zeros((B, di), jnp.float32))
    chunk = LSTM_CHUNK[0]
    if chunk and S % min(chunk, S) == 0 and S > min(chunk, S):
        Q = min(chunk, S)
        nc = S // Q

        def chunk_body(c, idx):
            xs_c = jnp.moveaxis(
                jax.lax.dynamic_slice_in_dim(xg, idx * Q, Q, axis=1), 1, 0)
            c2, hs_c = jax.lax.scan(step, c, xs_c)
            return c2, hs_c

        final, hs = jax.lax.scan(jax.checkpoint(chunk_body), carry,
                                 jnp.arange(nc))
        hs = hs.reshape(S, B, di)
    else:
        final, hs = jax.lax.scan(step, carry, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # (B,S,di)
    out = h @ p["out_proj"]
    if return_state:
        c, n, hh, m = final
        return out, {"c": c, "n": n, "h": hh, "m": m}
    return out


def init_slstm_state(cfg: ArchConfig, batch: int) -> dict:
    di = cfg.d_model
    return {
        "c": jnp.zeros((batch, di), jnp.float32),
        "n": jnp.ones((batch, di), jnp.float32),
        "h": jnp.zeros((batch, di), jnp.float32),
        "m": jnp.zeros((batch, di), jnp.float32),
    }


def slstm_decode(p: dict, cfg: ArchConfig, x: jax.Array,
                 state: dict) -> tuple[jax.Array, dict]:
    xg = x[:, 0].astype(jnp.float32) @ p["gates_w"]
    carry = (state["c"], state["n"], state["h"], state["m"])
    c, n, h, m = _slstm_step(p, carry, xg)
    out = (h.astype(x.dtype) @ p["out_proj"])[:, None]
    return out, {"c": c, "n": n, "h": h, "m": m}
