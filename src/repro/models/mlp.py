"""Compact MLP classifier — the paper-analog model for Tables 1–4.

The paper's CIFAR-10/ResNet50-FIXUP experiment is reproduced structurally on
synthetic classification (see data/synthetic.py); this model plays the role
of the network being federated. Deliberately BatchNorm-free, like the
paper's §5.2.1 choice (BatchNorm statistics would leak data distribution).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mlp_classifier(key, n_features: int, n_classes: int,
                        hidden: Sequence[int] = (64, 64)) -> dict:
    dims = [n_features, *hidden, n_classes]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"layer{i}": {
            "w": dense_init(ks[i], dims[i], dims[i + 1], jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
        for i in range(len(dims) - 1)
    }


def mlp_logits(params: dict, x: jax.Array) -> jax.Array:
    n = len(params)
    for i in range(n):
        p = params[f"layer{i}"]
        x = x @ p["w"] + p["b"]
        if i < n - 1:
            x = jnp.tanh(x)
    return x


def mlp_loss(params: dict, batch: tuple) -> tuple[jax.Array, dict]:
    x, y = batch
    logits = mlp_logits(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return jnp.mean(lse - gold), {}


def mlp_accuracy(params: dict, x, y) -> float:
    pred = jnp.argmax(mlp_logits(params, jnp.asarray(x)), axis=-1)
    return float(jnp.mean((pred == jnp.asarray(y)).astype(jnp.float32)))


mlp_loss_and_grad = jax.jit(jax.value_and_grad(mlp_loss, has_aux=True))
