"""Config-driven block stack: init + apply for train / prefill / decode.

Layers are grouped into repeating *units* (one period of ``cfg.pattern``);
unit parameters are stacked along a leading axis and the stack is applied
with ``lax.scan`` + ``jax.checkpoint`` — small HLO, remat'd activations.
Heterogeneous hybrids (Jamba's 7:1 mamba:attn, xLSTM's mLSTM/sLSTM
alternation) are handled by the per-position sub-block types inside a unit.

Caches mirror the unit structure: ``cache['units']['b<j>']`` holds the
per-unit-stacked state for pattern position j (KV rings for attention,
SSM/LSTM states for recurrent mixers), so decode is also one scan.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import dtype_of, rms_norm
from repro.models.scan_config import unroll as _unroll
from repro.sharding import activations as act

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, key, mixer: str, ffn: str,
                cross: bool = False, d_ff: Optional[int] = None) -> dict:
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg.param_dtype)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if mixer in ("attn", "swa"):
        p["mixer"] = attn.init_attention(cfg, ks[0])
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(cfg, ks[0])
    elif mixer == "mlstm":
        p["mixer"] = ssm.init_mlstm(cfg, ks[0])
    elif mixer == "slstm":
        p["mixer"] = ssm.init_slstm(cfg, ks[0])
    else:
        raise ValueError(f"unknown mixer {mixer}")
    if cross:
        p["norm_x"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = attn.init_attention(cfg, ks[3], cross=True)
    if ffn == "mlp":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = ffn_mod.init_mlp(cfg, ks[1], d_ff=d_ff)
    elif ffn == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = moe_mod.init_moe(cfg, ks[1])
    return p


def _stack_init(fn, key, n: int) -> PyTree:
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_stack(cfg: ArchConfig, key) -> PyTree:
    from repro.models.layers import embed_init, dense_init
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
        "norm_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab, dt)

    if cfg.first_k_dense:
        d_ff = cfg.d_ff_dense or cfg.d_ff
        params["dense_blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "attn", "mlp", d_ff=d_ff),
            ks[2], cfg.first_k_dense)

    units: dict = {}
    for j, (mixer, f) in enumerate(cfg.pattern):
        units[f"b{j}"] = _stack_init(
            lambda k, m=mixer, f_=f: _init_block(
                cfg, k, m, f_, cross=cfg.is_encdec),
            jax.random.fold_in(ks[3], j), cfg.n_units)
    params["units"] = units

    if cfg.is_encdec:
        params["audio_proj"] = dense_init(ks[4], cfg.d_model, cfg.d_model, dt)
        params["encoder_blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "attn", "mlp"),
            ks[5], cfg.n_encoder_layers)
        params["enc_norm_f"] = jnp.ones((cfg.d_model,), dt)
    if cfg.arch_type == "vlm":
        params["patch_proj"] = dense_init(ks[6], cfg.d_model, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _apply_block_train(cfg: ArchConfig, bp: dict, mixer: str, f: str, x,
                       cos, sin, cross_kv=None, causal=True):
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    if mixer in ("attn", "swa"):
        h = attn.attn_train(bp["mixer"], cfg, h, cos, sin, causal=causal)
    elif mixer == "mamba":
        h = ssm.mamba_train(bp["mixer"], cfg, h)
    elif mixer == "mlstm":
        h = ssm.mlstm_train(bp["mixer"], cfg, h)
    elif mixer == "slstm":
        h = ssm.slstm_train(bp["mixer"], cfg, h)
    x = act.residual(x + h)
    aux = {}
    if cross_kv is not None:
        h = rms_norm(x, bp["norm_x"], cfg.norm_eps)
        x = x + attn.cross_attn(bp["cross"], cfg, h, cross_kv)
    if "ffn" in bp:
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        if "router" in bp["ffn"]:
            h, aux = moe_mod.moe(bp["ffn"], cfg, h)
        else:
            h = ffn_mod.mlp(bp["ffn"], cfg, h)
        x = act.residual(x + h)
    return x, aux


def _apply_block_prefill(cfg: ArchConfig, bp: dict, mixer: str, f: str, x,
                         cos, sin, cache, cross_kv=None):
    """Full-sequence pass that also produces the decode cache entry."""
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    if mixer in ("attn", "swa"):
        h, new_cache = attn.attn_prefill(bp["mixer"], cfg, h, cos, sin, cache)
    elif mixer == "mamba":
        h, new_cache = ssm.mamba_prefill(bp["mixer"], cfg, h)
    elif mixer == "mlstm":
        h, new_cache = ssm.mlstm_train(bp["mixer"], cfg, h, return_state=True)
    elif mixer == "slstm":
        h, new_cache = ssm.slstm_train(bp["mixer"], cfg, h, return_state=True)
    else:
        raise ValueError(mixer)
    x = act.residual(x + h)
    aux = {}
    if cross_kv is not None:
        h = rms_norm(x, bp["norm_x"], cfg.norm_eps)
        x = x + attn.cross_attn(bp["cross"], cfg, h, cross_kv)
    if "ffn" in bp:
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        if "router" in bp["ffn"]:
            h, aux = moe_mod.moe(bp["ffn"], cfg, h)
        else:
            h = ffn_mod.mlp(bp["ffn"], cfg, h)
        x = act.residual(x + h)
    return x, new_cache, aux


def _apply_block_decode(cfg: ArchConfig, bp: dict, mixer: str, f: str, x,
                        pos, cache, cos, sin, cross_kv=None):
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    if mixer in ("attn", "swa"):
        h, cache = attn.attn_decode(bp["mixer"], cfg, h, pos, cache, cos, sin)
    elif mixer == "mamba":
        h, cache = ssm.mamba_decode(bp["mixer"], cfg, h, cache)
    elif mixer == "mlstm":
        h, cache = ssm.mlstm_decode(bp["mixer"], cfg, h, cache)
    elif mixer == "slstm":
        h, cache = ssm.slstm_decode(bp["mixer"], cfg, h, cache)
    x = act.residual(x + h)
    if cross_kv is not None:
        h = rms_norm(x, bp["norm_x"], cfg.norm_eps)
        x = x + attn.cross_attn(bp["cross"], cfg, h, cross_kv)
    if "ffn" in bp:
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        if "router" in bp["ffn"]:
            h, _ = moe_mod.moe(bp["ffn"], cfg, h)
        else:
            h = ffn_mod.mlp(bp["ffn"], cfg, h)
        x = act.residual(x + h)
    return x, cache


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def _zero_aux():
    return {"load_balance": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "drop_frac": jnp.zeros((), jnp.float32)}


def _acc_aux(acc, aux):
    if not aux:
        return acc
    return {k: acc[k] + aux.get(k, 0.0) for k in acc}


def apply_units_train(cfg: ArchConfig, params: PyTree, x, cos, sin,
                      cross_kvs=None, causal=True):
    """Scan the unit stack in train/prefill (no cache) mode."""
    aux0 = _zero_aux()

    def unit_body(carry, xs):
        x, acc = carry
        unit_params, unit_cross = xs
        for j, (mixer, f) in enumerate(cfg.pattern):
            ckv = None if unit_cross is None else unit_cross[f"b{j}"]
            x, aux = _apply_block_train(
                cfg, unit_params[f"b{j}"], mixer, f, x, cos, sin,
                cross_kv=ckv, causal=causal)
            acc = _acc_aux(acc, aux)
        return (x, acc), None

    body = jax.checkpoint(unit_body)
    xs = (params["units"],
          cross_kvs if cross_kvs is not None
          else _none_like_units(cfg))
    (x, acc), _ = jax.lax.scan(body, (x, aux0), xs, unroll=_unroll())
    return x, acc


def _none_like_units(cfg: ArchConfig):
    # scan requires a pytree with a leading axis; use per-unit None markers
    return {f"b{j}": None for j in range(len(cfg.pattern))}


def apply_units_prefill(cfg: ArchConfig, params: PyTree, x, cos, sin,
                        caches, cross_kvs=None):
    """Scan the unit stack in parallel-prefill mode: full-sequence compute
    plus cache fill. Returns (x, new_caches, aux)."""
    aux0 = _zero_aux()

    def unit_body(carry, xs):
        x, acc = carry
        unit_params, unit_cache, unit_cross = xs
        new_cache = {}
        for j, (mixer, f) in enumerate(cfg.pattern):
            ckv = None if unit_cross is None else unit_cross[f"b{j}"]
            x, c, aux = _apply_block_prefill(
                cfg, unit_params[f"b{j}"], mixer, f, x, cos, sin,
                unit_cache[f"b{j}"], cross_kv=ckv)
            new_cache[f"b{j}"] = c
            acc = _acc_aux(acc, aux)
        return (x, acc), new_cache

    xs = (params["units"], caches,
          cross_kvs if cross_kvs is not None else _none_like_units(cfg))
    (x, acc), new_caches = jax.lax.scan(
        jax.checkpoint(unit_body), (x, aux0), xs, unroll=_unroll())
    return x, new_caches, acc


def apply_dense_prefix_prefill(cfg: ArchConfig, params: PyTree, x, cos, sin,
                               caches):
    if "dense_blocks" not in params:
        return x, caches
    def body(x, xs):
        bp, c = xs
        x, c2, _ = _apply_block_prefill(cfg, bp, "attn", "mlp", x, cos, sin, c)
        return x, c2
    x, new = jax.lax.scan(jax.checkpoint(body), x,
                          (params["dense_blocks"], caches), unroll=_unroll())
    return x, new


def apply_units_decode(cfg: ArchConfig, params: PyTree, x, pos, caches,
                       cos, sin, cross_kvs=None):
    def unit_body(x, xs):
        unit_params, unit_cache, unit_cross = xs
        new_cache = {}
        for j, (mixer, f) in enumerate(cfg.pattern):
            ckv = None if unit_cross is None else unit_cross[f"b{j}"]
            x, c = _apply_block_decode(
                cfg, unit_params[f"b{j}"], mixer, f, x, pos,
                unit_cache[f"b{j}"], cos, sin, cross_kv=ckv)
            new_cache[f"b{j}"] = c
        return x, new_cache

    xs = (params["units"], caches,
          cross_kvs if cross_kvs is not None else _none_like_units(cfg))
    x, new_caches = jax.lax.scan(unit_body, x, xs, unroll=_unroll())
    return x, new_caches


def init_unit_caches(cfg: ArchConfig, batch: int, max_len: int,
                     dtype) -> PyTree:
    """Stacked (n_units, ...) cache pytree for the decode scan."""
    def one(mixer):
        if mixer in ("attn", "swa"):
            return attn.init_cache(cfg, batch, max_len, dtype)
        if mixer == "mamba":
            return ssm.init_mamba_state(cfg, batch, dtype)
        if mixer == "mlstm":
            return ssm.init_mlstm_state(cfg, batch)
        if mixer == "slstm":
            return ssm.init_slstm_state(cfg, batch)
        raise ValueError(mixer)

    caches = {}
    for j, (mixer, _) in enumerate(cfg.pattern):
        c = one(mixer)
        caches[f"b{j}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape), c)
    return caches


# ---------------------------------------------------------------------------
# Dense prefix (deepseek first_k_dense) — tiny loop, not worth a scan
# ---------------------------------------------------------------------------

def apply_dense_prefix_train(cfg: ArchConfig, params: PyTree, x, cos, sin):
    if "dense_blocks" not in params:
        return x
    def body(x, bp):
        x, _ = _apply_block_train(cfg, bp, "attn", "mlp", x, cos, sin)
        return x, None
    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dense_blocks"],
                        unroll=_unroll())
    return x


def apply_dense_prefix_decode(cfg: ArchConfig, params: PyTree, x, pos,
                              caches, cos, sin):
    if "dense_blocks" not in params:
        return x, caches
    def body(x, xs):
        bp, c = xs
        x, c2 = _apply_block_decode(cfg, bp, "attn", "mlp", x, pos, c,
                                    cos, sin)
        return x, c2
    x, new = jax.lax.scan(body, x, (params["dense_blocks"], caches),
                        unroll=_unroll())
    return x, new


def init_dense_prefix_caches(cfg: ArchConfig, batch: int, max_len: int,
                             dtype):
    if not cfg.first_k_dense:
        return None
    c = attn.init_cache(cfg, batch, max_len, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.first_k_dense,) + a.shape), c)


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------

def apply_encoder(cfg: ArchConfig, params: PyTree, audio_embed):
    """audio_embed (B, F, D) — stub frontend output → encoder hidden."""
    from repro.models.layers import sinusoidal_positions
    x = audio_embed @ params["audio_proj"]
    pe = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model),
                     x.dtype)
    x = x + pe

    def body(x, bp):
        x, _ = _apply_block_train(cfg, bp, "attn", "mlp", x, None, None,
                                  causal=False)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder_blocks"],
                        unroll=_unroll())
    return rms_norm(x, params["enc_norm_f"], cfg.norm_eps)


def encoder_cross_kvs(cfg: ArchConfig, params: PyTree, enc_out):
    """Per-unit, per-position cross K/V stacks (computed once per request)."""
    def per_stacked(block_stack):
        return jax.vmap(
            lambda bp: attn.cross_kv(bp["cross"], cfg, enc_out)
        )(block_stack)

    return {f"b{j}": per_stacked(params["units"][f"b{j}"])
            for j in range(len(cfg.pattern))}
