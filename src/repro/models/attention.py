"""Attention: GQA, optional qk-norm, sliding window, KV caches, cross-attn.

Three entry points:
  * ``attn_train``   — full-sequence causal (or bidirectional) attention;
  * ``attn_decode``  — one-token step against a (possibly ring) KV cache;
  * ``cross_attn``   — decoder→encoder attention with precomputed K/V.

Caches are plain dicts of arrays so they shard/scan cleanly:
  self-attn cache: {'k': (B, S_cache, Hk, dh), 'v': ..., 'pos': (B,) int32}
For sliding-window archs S_cache == window and writes wrap (ring buffer);
RoPE is applied to keys at insert time so ring eviction is safe.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding import activations as act

NEG_INF = -1e30


def init_attention(cfg: ArchConfig, key, cross: bool = False) -> dict:
    dh = cfg.resolved_head_dim
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    from repro.models.layers import dtype_of
    dt = dtype_of(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], D, cfg.n_heads * dh, dt),
        "wk": dense_init(ks[1], D, cfg.n_kv_heads * dh, dt),
        "wv": dense_init(ks[2], D, cfg.n_kv_heads * dh, dt),
        "wo": dense_init(ks[3], cfg.n_heads * dh, D, dt),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def _repeat_kv(k, n_heads):
    """(B, S, Hk, dh) -> (B, S, H, dh) by group repetition."""
    hk = k.shape[2]
    if hk == n_heads:
        return k
    return jnp.repeat(k, n_heads // hk, axis=2)


def _qkv(p, cfg: ArchConfig, x, cos, sin):
    dh = cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"], cfg.n_heads, dh)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, dh)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return act.heads(q), act.heads(k), act.heads(v)


def _sdpa(q, k, v, mask, dh):
    """GQA attention. q (B,Sq,H,dh); k/v (B,Sk,Hk,dh) UN-repeated.

    Sharding-aware path choice (§Perf):
      * Hk divides 'model' → grouped (Hk,G) einsum: K/V read once, heads
        sharded (deepseek, whisper).
      * only H divides 'model' → repeat K/V to H heads *after which the
        head dim shards cleanly*; without this, a head-sharded Q meets a
        sequence-sharded K and the partitioner falls into "involuntary full
        rematerialization" (measured: replicated 96-head Q projections on
        mistral-large prefill_32k).
      * neither divides → grouped einsum; the act.heads fallback
        sequence-shards Q and K consistently (qwen3, phi4, qwen2-vl).
    Matmuls take bf16 operands with fp32 accumulation — MXU-native, no f32
    cache copies. mask: (B|1, 1, Sq, Sk) bool keep.
    """
    b, sq, h, _ = q.shape
    hk = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    msize = act.model_size()
    if msize > 1 and hk % msize != 0 and h % msize == 0:
        k = act.heads(_repeat_kv(k, h))
        v = act.heads(_repeat_kv(v, h))
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
        if mask is not None:
            logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return act.heads(out.astype(v.dtype))
    g = h // hk
    qg = q.reshape(b, sq, hk, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.astype(v.dtype).reshape(b, sq, h, dh)
    return act.heads(out)


# §Perf toggles: blocked (flash-style) attention for full-sequence passes.
# Measured (EXPERIMENTS.md §Perf 5): 6× memory win on PREFILL for
# head-sharded archs (mistral-large prefill_32k 58.6 s → 9.7 s, fits HBM),
# but a large REGRESSION on the gradient path (scan residuals store every
# tile) and for sequence-sharded-Q archs (per-tile resharding). Hence:
# blocked is applied to inference prefill of head-sharded archs only.
ATTN_BLOCK = [None]           # train path (grad): None = baseline
ATTN_BLOCK_PREFILL = [512]    # inference prefill


def set_attn_block(b):
    ATTN_BLOCK[0] = b


def set_attn_block_prefill(b):
    ATTN_BLOCK_PREFILL[0] = b


def _sdpa_blocked(q, k, v, dh, causal: bool, window: Optional[int],
                  block: int):
    """Two-level blocked online-softmax attention (flash-style).

    Outer scan over QUERY tiles (outputs collected as ys — no big carry),
    inner scan over KEY blocks with a (…, q_tile, dh) accumulator. §Perf
    note: a single-level key scan carrying the full-Sq accumulator was
    measured WORSE than materialized scores (the lax.scan carry round-trips
    HBM every block — the reason flash attention is a fused kernel);
    q-tiling shrinks the spilled carry by Sq/q_tile.
    """
    b, sq, h, _ = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qg = q.reshape(b, sq, hk, g, dh)
    qt = min(block, sq)
    if sq % qt:
        qt = sq
    nq = sq // qt
    nb = sk // block

    def q_tile_body(_, iq):
        q_tile = jax.lax.dynamic_slice_in_dim(qg, iq * qt, qt, axis=1)
        q_idx = iq * qt + jnp.arange(qt)

        def kb_body(carry, ib):
            m_run, l_run, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ib * block, block,
                                                 axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ib * block, block,
                                                 axis=1)
            logits = jnp.einsum("bqkgd,bskd->bkgqs", q_tile, k_blk,
                                preferred_element_type=jnp.float32) * scale
            if causal:
                k_idx = ib * block + jnp.arange(block)
                keep = k_idx[None, :] <= q_idx[:, None]
                if window is not None:
                    keep &= (q_idx[:, None] - k_idx[None, :]) < window
                logits = jnp.where(keep[None, None, None], logits, NEG_INF)
            m_blk = jnp.max(logits, axis=-1)               # (b,hk,g,qt)
            m_new = jnp.maximum(m_run, m_blk)
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hk, g, qt), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hk, g, qt), jnp.float32)
        a0 = jnp.zeros((b, hk, g, qt, dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kb_body, (m0, l0, a0),
                                          jnp.arange(nb))
        out_t = acc / jnp.maximum(l_f, 1e-30)[..., None]   # (b,hk,g,qt,dh)
        return None, out_t.astype(v.dtype)

    _, outs = jax.lax.scan(q_tile_body, None, jnp.arange(nq))
    # outs (nq, b, hk, g, qt, dh) → (b, sq, h, dh)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hk, g, sq, dh)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dh)
    return act.heads(out)


def _sdpa_full_seq(q, k, v, dh, causal: bool, window: Optional[int],
                   grad_path: bool = True):
    """Full-sequence attention dispatcher: blocked when enabled, the key
    length divides the block, and the heads shard (see toggle notes);
    else the materialized-score baseline."""
    s = k.shape[1]
    blk = ATTN_BLOCK[0] if grad_path else ATTN_BLOCK_PREFILL[0]
    msize = act.model_size()
    heads_shard = (msize == 1 or k.shape[2] % msize == 0
                   or q.shape[2] % msize == 0)
    if blk and s % blk == 0 and s > blk and heads_shard:
        if not grad_path and msize > 1 and k.shape[2] % msize != 0 \
                and q.shape[2] % msize == 0:
            # repeat so the head dim shards inside the blocked scan too
            k = act.heads(_repeat_kv(k, q.shape[2]))
            v = act.heads(_repeat_kv(v, q.shape[2]))
        return _sdpa_blocked(q, k, v, dh, causal, window, blk)
    mask = causal_mask(s, window) if causal else None
    return _sdpa(q, k, v, mask, dh)


def causal_mask(s: int, window: Optional[int] = None) -> jax.Array:
    """(1, 1, S, S) keep-mask: causal, optionally sliding-window."""
    qi = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    keep = ki <= qi
    if window is not None:
        keep &= (qi - ki) < window
    return keep[None, None]


def attn_train(p, cfg: ArchConfig, x, cos, sin, causal: bool = True) -> jax.Array:
    """Full-sequence attention. x (B, S, D)."""
    dh = cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x, cos, sin)
    out = _sdpa_full_seq(q, k, v, dh, causal, cfg.sliding_window)
    return out.reshape(x.shape[:-1] + (-1,)) @ p["wo"]


def attn_prefill(p, cfg: ArchConfig, x, cos, sin, cache: dict
                 ) -> tuple[jax.Array, dict]:
    """Full-sequence causal attention that also fills the KV cache.

    The cache ring layout matches :func:`attn_decode`: slot j holds position
    p with p % S_cache == j, so for S <= S_cache this is a plain prefix
    write; for SWA prompts longer than the window, the last `window`
    positions land in their ring slots.
    """
    dh = cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x, cos, sin)
    s = x.shape[1]
    out = _sdpa_full_seq(q, k, v, dh, True, cfg.sliding_window,
                         grad_path=False)
    y = out.reshape(x.shape[:-1] + (-1,)) @ p["wo"]

    s_cache = cache["k"].shape[1]
    kd = k.astype(cache["k"].dtype)
    vd = v.astype(cache["v"].dtype)
    if s <= s_cache:
        ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, 0, 0, 0))
    else:
        # keep the last window, placed at their ring slots
        tail_k, tail_v = kd[:, -s_cache:], vd[:, -s_cache:]
        shift = s % s_cache
        ck = jnp.roll(tail_k, shift, axis=1)
        cv = jnp.roll(tail_v, shift, axis=1)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    """Self-attention cache; for sliding-window archs the cache is the ring
    of the last `min(window, max_len)` positions."""
    s_cache = max_len if cfg.sliding_window is None \
        else min(cfg.sliding_window, max_len)
    dh = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s_cache, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, s_cache, cfg.n_kv_heads, dh), dtype),
    }


def attn_decode(p, cfg: ArchConfig, x, pos, cache: dict,
                cos, sin) -> tuple[jax.Array, dict]:
    """One-token decode. x (B, 1, D); pos scalar int32 (uniform across batch
    in our serving step); cos/sin (B, 1, dh//2) at absolute position.

    Keys are stored post-RoPE; the ring write index is pos % S_cache.
    """
    dh = cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x, cos, sin)
    s_cache = cache["k"].shape[1]
    slot = (pos % s_cache).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    # keep-mask over cache slots: slot index valid iff it holds a position
    # <= pos and (for SWA) within the window. With ring writes, a slot j
    # holds position: the largest p' <= pos with p' % S == j.
    ki = jnp.arange(s_cache)
    filled = ki <= jnp.minimum(pos, s_cache - 1)  # before wrap: only <= pos
    wrapped = pos >= s_cache
    keep = jnp.where(wrapped, jnp.ones_like(filled, bool), filled)
    mask = keep[None, None, None, :]             # (1,1,1,S_cache)

    out = _sdpa(q, ck, cv, mask, dh)
    y = out.reshape(x.shape[:-1] + (-1,)) @ p["wo"]
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_kv(p, cfg: ArchConfig, enc_out) -> dict:
    """Precompute encoder K/V once per request (prefill)."""
    dh = cfg.resolved_head_dim
    k = _split_heads(enc_out @ p["wk"], cfg.n_kv_heads, dh)
    v = _split_heads(enc_out @ p["wv"], cfg.n_kv_heads, dh)
    return {"k": k, "v": v}


def cross_attn(p, cfg: ArchConfig, x, kv: dict) -> jax.Array:
    """x (B, Sq, D) attends over encoder memory (no mask, no rope)."""
    dh = cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"], cfg.n_heads, dh)
    out = _sdpa(q, kv["k"], kv["v"], None, dh)
    return out.reshape(x.shape[:-1] + (-1,)) @ p["wo"]
