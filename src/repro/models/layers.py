"""Primitive layers: init helpers, norms, rotary embeddings (incl. M-RoPE)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init."""
    std = 1.0 / np.sqrt(d_in)
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, (d_in, d_out), jnp.float32)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (0.02 * jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) int → cos/sin (..., head_dim//2) fp32."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, dh); cos/sin (..., S, dh//2) broadcast over heads.

    Rotate-half convention: pairs are (x[..., :half], x[..., half:]).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1
    ).astype(x.dtype)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                  sections: tuple[int, ...]):
    """Qwen2-VL M-RoPE. positions (3, B, S) — temporal/height/width ids.

    The head_dim//2 frequency slots are partitioned into ``sections``
    (t, h, w); each partition rotates by its own position component.
    Returns cos/sin (B, S, head_dim//2).
    """
    assert positions.shape[0] == 3 and sum(sections) == head_dim // 2
    inv = rope_freqs(head_dim, theta)                     # (half,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (3, B, S, half)
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=head_dim // 2)  # (half,)
    picked = sum(
        jnp.where(sec_ids == c, ang[c], 0.0) for c in range(3)
    )                                                      # (B, S, half)
    return jnp.cos(picked), jnp.sin(picked)


def sinusoidal_at(pos, d: int) -> jax.Array:
    """Sinusoidal embedding at a (traced) scalar position → (d,) fp32."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000.0 ** (2 * dim / d))
    out = jnp.zeros((d,), jnp.float32)
    out = out.at[0::2].set(jnp.sin(ang))
    out = out.at[1::2].set(jnp.cos(ang))
    return out


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal table (n, d)."""
    pos = np.arange(n)[:, None].astype(np.float64)
    dim = np.arange(d // 2)[None, :].astype(np.float64)
    ang = pos / (10000.0 ** (2 * dim / d))
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out
