"""Global scan-unroll switch.

XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
count, so a scanned layer stack under-reports FLOPs/bytes/collectives by
~n_layers×. The dry-run therefore lowers with structural scans (layer
stacks, mamba chunk loops) fully unrolled — exact counting at the price of
compile time. Training/serving runs keep scans rolled (small HLO).

Time-step recurrences (mLSTM/sLSTM) stay rolled even when this flag is on —
unrolling S=32k steps is infeasible; their roofline rows carry an analytic
correction instead (see launch/analysis.py + EXPERIMENTS.md notes).
"""

_UNROLL = [False]


def set_unroll(value: bool) -> None:
    _UNROLL[0] = bool(value)


def unroll() -> bool:
    return _UNROLL[0]
