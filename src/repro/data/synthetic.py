"""Synthetic datasets + the paper's federated splitting schemes (§5.2).

No external datasets exist in this offline environment, so the paper's
CIFAR-10 / LGGS experiments are reproduced *structurally* on synthetic tasks
whose Bayes-optimal solution is known:

* ``SyntheticClassification`` — a teacher-MLP labelling problem (stands in
  for CIFAR-10 image classification): class-balanced, learnable, and the gap
  between centralized and federated training is measurable exactly as in
  Tables 2/4.
* ``SyntheticLM`` — token sequences from a sampled Markov teacher for the
  transformer-family architectures (next-token cross-entropy).

Splitters:
* ``random_share_split`` — the paper's IID protocol: random percentage shares
  (bounded away from extremes), class-stratified per worker (Fig. 2).
* ``dirichlet_split`` — the non-IID protocol of Table 4 (Fig. 5): per-class
  Dirichlet(alpha) allocation across workers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


# ---------------------------------------------------------------------------
# Tasks
# ---------------------------------------------------------------------------

@dataclass
class SyntheticClassification:
    """Teacher-generated classification: x ~ N(0, I_d), y = argmax(teacher(x))."""
    n_samples: int = 4096
    n_features: int = 32
    n_classes: int = 10
    hidden: int = 64
    seed: int = 0

    def generate(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        w1 = rng.normal(0, 1.0 / np.sqrt(self.n_features),
                        (self.n_features, self.hidden))
        w2 = rng.normal(0, 1.0 / np.sqrt(self.hidden),
                        (self.hidden, self.n_classes))
        x = rng.normal(0, 1, (self.n_samples, self.n_features)).astype(np.float32)
        logits = np.tanh(x @ w1) @ w2
        y = np.argmax(logits + 0.1 * rng.normal(size=logits.shape), axis=-1)
        return x, y.astype(np.int32)


@dataclass
class SyntheticLM:
    """Markov-teacher token streams for LM training."""
    n_sequences: int = 512
    seq_len: int = 128
    vocab: int = 256
    seed: int = 0

    def generate(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        # Sparse row-stochastic transition matrix → learnable structure.
        trans = rng.gamma(0.3, 1.0, (self.vocab, self.vocab)).astype(np.float64)
        trans /= trans.sum(axis=1, keepdims=True)
        cum = np.cumsum(trans, axis=1)
        toks = np.zeros((self.n_sequences, self.seq_len), np.int32)
        state = rng.integers(0, self.vocab, self.n_sequences)
        for t in range(self.seq_len):
            toks[:, t] = state
            u = rng.random(self.n_sequences)
            state = np.array(
                [np.searchsorted(cum[s], uu) for s, uu in zip(state, u)],
                dtype=np.int64,
            ).clip(0, self.vocab - 1)
        return toks


# ---------------------------------------------------------------------------
# Federated splits
# ---------------------------------------------------------------------------

def _bounded_shares(n_workers: int, rng, lo_frac: float = 0.3) -> np.ndarray:
    """Random shares summing to 1 with min share >= lo_frac/n — the paper's
    'avoid the extreme imbalance' control (§5.2.2)."""
    raw = rng.random(n_workers) + lo_frac
    return raw / raw.sum()


def random_share_split(
    y: np.ndarray, n_workers: int, seed: int = 0
) -> list[np.ndarray]:
    """IID/stratified split (Fig. 2): heterogeneous sizes, per-class balance
    inside each worker."""
    rng = np.random.default_rng(seed)
    shares = _bounded_shares(n_workers, rng)
    classes = np.unique(y)
    worker_idx: list[list[int]] = [[] for _ in range(n_workers)]
    for c in classes:
        idx = np.flatnonzero(y == c)
        rng.shuffle(idx)
        bounds = np.floor(np.cumsum(shares) * len(idx)).astype(int)
        prev = 0
        for k, b in enumerate(bounds):
            worker_idx[k].extend(idx[prev:b].tolist())
            prev = b
    return [np.asarray(sorted(w), dtype=np.int64) for w in worker_idx]


def dirichlet_split(
    y: np.ndarray, n_workers: int, alpha: float = 0.5, seed: int = 0,
    min_per_worker: int = 2,
) -> list[np.ndarray]:
    """Non-IID split of Table 4 (Fig. 5): per-class Dirichlet(alpha) shares."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    worker_idx: list[list[int]] = [[] for _ in range(n_workers)]
    for c in classes:
        idx = np.flatnonzero(y == c)
        rng.shuffle(idx)
        p = rng.dirichlet([alpha] * n_workers)
        bounds = np.floor(np.cumsum(p) * len(idx)).astype(int)
        prev = 0
        for k, b in enumerate(bounds):
            worker_idx[k].extend(idx[prev:b].tolist())
            prev = b
    out = []
    for k, w in enumerate(worker_idx):
        if len(w) < min_per_worker:  # keep every worker trainable
            donor = int(np.argmax([len(v) for v in worker_idx]))
            need = min_per_worker - len(w)
            w = w + worker_idx[donor][:need]
            worker_idx[donor] = worker_idx[donor][need:]
        out.append(np.asarray(sorted(w), dtype=np.int64))
    return out


def sequence_split(n_sequences: int, n_workers: int, seed: int = 0,
                   iid: bool = True, alpha: float = 0.5) -> list[np.ndarray]:
    """Split LM sequences (no labels to stratify on)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_sequences)
    shares = (_bounded_shares(n_workers, rng) if iid
              else rng.dirichlet([alpha] * n_workers))
    shares = np.maximum(shares, 2.0 / n_sequences)
    shares = shares / shares.sum()
    bounds = np.floor(np.cumsum(shares) * n_sequences).astype(int)
    out, prev = [], 0
    for b in bounds:
        out.append(np.sort(idx[prev:max(b, prev + 1)]))
        prev = max(b, prev + 1)
    return out
