"""Batching pipeline: per-worker iterators with private batch sizes.

The paper's workers privately choose batch size from a menu (e.g. 128/64/32)
and shuffle locally each epoch; ``federated_loaders`` reproduces that."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class BatchIterator:
    """Epoch-based shuffling batch iterator over numpy arrays."""
    arrays: tuple            # tuple of arrays sharing dim 0
    batch_size: int
    seed: int = 0
    drop_remainder: bool = False

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.n = self.arrays[0].shape[0]
        for a in self.arrays:
            assert a.shape[0] == self.n

    def epoch_indices(self) -> Iterator[np.ndarray]:
        """One epoch's batch index arrays (same rng draw as :meth:`epoch` —
        the two are interchangeable schedule-wise). Lets gather-style
        consumers (the simulator's scan driver) keep one resident copy of
        the shard instead of materialized batches."""
        order = self._rng.permutation(self.n)
        end = (self.n // self.batch_size) * self.batch_size \
            if self.drop_remainder else self.n
        for s in range(0, max(end, 1), self.batch_size):
            sel = order[s : s + self.batch_size]
            if len(sel) == 0:
                break
            yield sel

    def epoch(self) -> Iterator[tuple]:
        for sel in self.epoch_indices():
            yield tuple(a[sel] for a in self.arrays)

    def steps_per_epoch(self) -> int:
        if self.drop_remainder:
            return max(self.n // self.batch_size, 1)
        return -(-self.n // self.batch_size)


BATCH_MENU = (128, 64, 32)          # paper §5.1 (CIFAR-10)
BATCH_MENU_SMALL = (16, 8, 4)       # paper §5.1 (LGGS)


def federated_loaders(
    arrays: tuple,
    splits: list[np.ndarray],
    seed: int = 0,
    batch_menu: tuple = BATCH_MENU,
    max_batch: Optional[int] = None,
) -> list[BatchIterator]:
    """One private loader per worker; batch size drawn from the paper's menu."""
    rng = np.random.default_rng(seed + 7919)
    loaders = []
    for k, idx in enumerate(splits):
        bs = int(rng.choice(batch_menu))
        if max_batch is not None:
            bs = min(bs, max_batch)
        bs = min(bs, max(len(idx), 1))
        loaders.append(
            BatchIterator(tuple(a[idx] for a in arrays), bs, seed=seed + k)
        )
    return loaders
