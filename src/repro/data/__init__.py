from repro.data.synthetic import (  # noqa: F401
    SyntheticClassification,
    SyntheticLM,
    dirichlet_split,
    random_share_split,
)
from repro.data.pipeline import BatchIterator, federated_loaders  # noqa: F401
