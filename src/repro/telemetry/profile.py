"""Profiler integration: named kernel scopes + opt-in trace sessions.

Every kernel wrapper in ``repro.kernels.ops`` (and the tree/masked entry
points it fronts) launches inside a :func:`kernel_scope` named after the
tuner's table key — ``wire/<kind>/r<rows>n<N>/<backend>`` — so a real-TPU
``jax.profiler`` capture attributes device time to the same identities the
autotuner plans and ``BENCH_kernels.json`` reports. ``jax.named_scope``
annotates metadata only: it adds no jaxpr equations, so the round program
still counts exactly two pallas launches and zero host syncs with scopes
on (pinned by tests/test_telemetry.py).

:func:`profile_session` wraps ``jax.profiler.start_trace/stop_trace`` as a
context manager; ``benchmarks/kernels_bench.py --profile DIR`` drives it.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax


def scope_name(kind: str, rows: int, n: int = 1,
               interpret: bool | None = None) -> str:
    """The profiler label of one launch site, keyed like the tune table."""
    from repro.kernels import tune
    return f"wire/{kind}/r{int(rows)}n{max(1, int(n))}/" \
           f"{tune.backend_tag(interpret)}"


def kernel_scope(kind: str, rows: int, n: int = 1,
                 interpret: bool | None = None):
    """``jax.named_scope`` over a kernel launch, named by its tuner key."""
    return jax.named_scope(scope_name(kind, rows, n, interpret))


@contextmanager
def profile_session(logdir: str):
    """Opt-in ``jax.profiler`` capture: every named kernel scope inside the
    block lands in the trace under ``logdir`` (TensorBoard/Perfetto)."""
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()
