"""Render a telemetry trace: round table, rollups, byte cross-check.

``python -m repro.telemetry.report trace.jsonl`` reads a JSONL trace
(federation or tuner-sweep), re-verifies its byte accounting against the
``core.protocol`` models (:func:`repro.telemetry.trace.summarize` raises
:class:`~repro.telemetry.trace.TelemetryMismatch` on any divergence), and
prints a round-by-round table plus per-kind rollups. CI greps the final
``byte cross-check OK`` line.
"""
from __future__ import annotations

import argparse
import sys

from repro.telemetry import trace as tmt


def _fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.2f}MB"
    if b >= 1e3:
        return f"{b / 1e3:.1f}kB"
    return f"{b:.0f}B"


def _table(rows: list[list[str]], header: list[str]) -> str:
    widths = [max(len(str(c)) for c in col)
              for col in zip(*([header] + rows))]
    def line(cells):
        return "  ".join(str(c).rjust(w) for c, w in zip(cells, widths))
    return "\n".join([line(header), line(["-" * w for w in widths])]
                     + [line(r) for r in rows])


def _meta_lines(meta: dict) -> list[str]:
    skip = {"ev", "schema"}
    return [f"  {k}: {meta[k]}" for k in meta if k not in skip]


def _round_table(summary: tmt.TraceSummary) -> str:
    header = ["t", "pilot", "sampled", "used", "dead", "pre", "recov",
              "degr", "cost", "wire", "recovery"]
    rows = [[r["t"], r["pilot"], r["n_sampled"], r["n_used"], r["n_dead"],
             r["n_pre_uplink"], r["n_recovered"], r["n_degraded"],
             f"{r['cost']:.4f}", _fmt_bytes(r["wire_bytes"]),
             _fmt_bytes(r["recovery_bytes"])]
            for r in summary.rounds]
    return _table(rows, header)


def _worker_rollup(summary: tmt.TraceSummary) -> str:
    counts: dict[str, int] = {}
    for w in summary.workers:
        counts[w["sent"]] = counts.get(w["sent"], 0) + 1
    parts = [f"{k}={counts[k]}" for k in tmt.SENT_KINDS if k in counts]
    return "uplink events: " + ", ".join(parts)


def _edge_rollup(summary: tmt.TraceSummary) -> str:
    per_level: dict[int, float] = {}
    for e in summary.edges:
        per_level[e["level"]] = per_level.get(e["level"], 0.0) + e["bytes"]
    parts = [f"L{lvl}={_fmt_bytes(b)}"
             for lvl, b in sorted(per_level.items())]
    return "interior tree-edge bytes: " + ", ".join(parts)


def _plan_table(summary: tmt.TraceSummary) -> str:
    by_key: dict[tuple, list[dict]] = {}
    for p in summary.plans:
        by_key.setdefault(
            (p["kind"], p["rows"], p["n"], p["backend"]), []).append(p)
    header = ["kind", "rows", "n", "backend", "plans", "best plan",
              "best us", "worst us"]
    rows = []
    for (kind, r, n, backend), plans in sorted(by_key.items()):
        best = min(plans, key=lambda p: p["us"])
        rows.append([kind, r, n, backend, len(plans),
                     f"{best['block_rows']}x{best['block_workers']}",
                     f"{best['us']:.1f}",
                     f"{max(p['us'] for p in plans):.1f}"])
    return _table(rows, header)


def render(summary: tmt.TraceSummary) -> str:
    out = [f"trace: {summary.meta.get('source', '?')} "
           f"(schema v{summary.meta['schema']})"]
    out += _meta_lines(summary.meta)
    if summary.rounds:
        out += ["", _round_table(summary)]
        out += ["", f"total wire bytes: "
                    f"{sum(summary.bytes_per_round):.0f}  "
                    f"recovery: {sum(summary.recovery_bytes_per_round):.0f}"]
    if summary.workers:
        out += ["", _worker_rollup(summary)]
    if summary.edges:
        out += [_edge_rollup(summary)]
    if summary.plans:
        out += ["", "tuner sweeps:", _plan_table(summary)]
    if summary.rounds:
        out += ["", summary.crosscheck_line()]
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a repro telemetry JSONL trace.")
    ap.add_argument("trace", help="path to a trace .jsonl file")
    args = ap.parse_args(argv)
    try:
        summary = tmt.summarize(tmt.read_trace(args.trace))
    except tmt.TelemetryMismatch as e:
        print(e, file=sys.stderr)
        return 1
    print(render(summary))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
