"""Observability layer: device-resident round records, structured JSONL
traces with protocol-model byte cross-checks, and profiler hooks.

* ``telemetry.record`` — :class:`RoundTelemetry` / :class:`TelemetryCarry`
  pytrees that ride ``round_step``'s info dict and the scan carry (zero
  host syncs; one post-run fetch).
* ``telemetry.trace`` — stable JSONL event schema, :func:`build_trace`
  assembly with loud :class:`TelemetryMismatch` on any divergence from the
  ``core.protocol`` byte models, :func:`summarize` rollups, streaming
  :class:`TraceWriter` for tuner sweeps.
* ``telemetry.profile`` — ``jax.named_scope`` kernel labels keyed like the
  autotune table + an opt-in ``jax.profiler`` session helper.
* ``telemetry.report`` — CLI rendering round tables and per-kind rollups
  from a trace file (``python -m repro.telemetry.report trace.jsonl``).
* ``telemetry.smoke`` — the CI smoke: a tiny traced federation written,
  validated and cross-checked end to end.
"""
from repro.telemetry.record import (  # noqa: F401
    RoundTelemetry, TelemetryCarry, build_round_record,
)
from repro.telemetry.trace import (  # noqa: F401
    SCHEMA_VERSION, TelemetryMismatch, TraceSummary, TraceWriter,
    build_trace, read_trace, round_bytes, summarize, trace_meta,
    validate_event, validate_trace, write_trace,
)
from repro.telemetry.profile import (  # noqa: F401
    kernel_scope, profile_session, scope_name,
)
