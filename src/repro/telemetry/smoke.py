"""CI telemetry smoke: a tiny traced federation, end to end.

``python -m repro.telemetry.smoke --out /tmp/fed_trace.jsonl`` runs a
4-worker, 3-round masked tree federation WITH faults through the scan
driver, writes its telemetry as a JSONL trace, re-reads and re-validates
it (``summarize`` re-derives every round's bytes through the
``core.protocol`` models), and prints the ``byte cross-check OK`` line CI
greps. Exit is nonzero on any schema or byte divergence.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.fedpc import FedPCConfig
from repro.core.tree import TreeSpec
from repro.data.pipeline import federated_loaders
from repro.data.synthetic import SyntheticClassification
from repro.fed.faults import FaultPlan
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad
from repro.privacy.spec import PrivacySpec
from repro.telemetry import trace as tmt

N = 4
PER = 64                 # samples per worker; 32-batch menu divides it


def make_sim(seed: int = 0) -> FedSimulator:
    """The smoke federation: masked 16-bit wire, fanout-2 tree, dropout
    faults and seed-share recovery all on at once."""
    task = SyntheticClassification(n_samples=N * PER, n_features=16,
                                   n_classes=5, seed=0)
    x, y = task.generate()
    splits = [np.arange(k * PER, (k + 1) * PER) for k in range(N)]
    loaders = federated_loaders((x, y), splits, seed=seed,
                                batch_menu=(32,))
    cfgs = make_worker_configs(N, [PER] * N, seed=seed, batch_menu=(32,))
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(N)]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 16, 5,
                                 hidden=(32,))
    cfg = FedPCConfig(
        n_workers=N,
        privacy=PrivacySpec(mask_seed=5, modulus_bits=16,
                            recovery_threshold=2),
        tree=TreeSpec(fanout=2),
        faults=FaultPlan(seed=5, drop_before_uplink=0.1,
                         drop_after_uplink=0.15, straggler=0.05))
    return FedSimulator(workers, params, fed_cfg=cfg)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description="Traced-federation smoke.")
    ap.add_argument("--out", default="/tmp/fed_trace.jsonl",
                    help="trace output path")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args(argv)
    res = make_sim().run_fedpc_scan(rounds=args.rounds)
    assert res.telemetry is not None, "scan driver produced no telemetry"
    n_events = res.telemetry.write(args.out)
    # Re-read from disk: summarize() re-derives each round's bytes from
    # its counts and raises TelemetryMismatch on divergence.
    summary = tmt.summarize(tmt.read_trace(args.out))
    assert summary.bytes_per_round == res.telemetry.bytes_per_round
    assert (summary.recovery_bytes_per_round
            == res.telemetry.recovery_bytes_per_round)
    print(f"telemetry smoke: {n_events} events -> {args.out}")
    print(summary.crosscheck_line())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
