"""Device-resident round telemetry — the records that ride the scan carry.

Two pytrees, both built from the same template as
``repro.privacy.accountant.PrivacyAccountant`` (NamedTuples of device
scalars with a ``zero()`` constructor and traceable update), so a traced
federation observes itself without a single extra host sync:

* :class:`RoundTelemetry` — ONE round's record: pilot id, participation /
  fault / degradation counts, the cost numerator+denominator the master
  actually averaged, and the public wire tags (modulus, fanout, levels).
  ``WirePath.round_step`` emits it in ``info["telemetry"]``; ``lax.scan``
  stacks it like every other info leaf and the driver fetches ALL rounds in
  the one post-run transfer it already performs.
* :class:`TelemetryCarry` — cumulative totals riding
  ``RoundState.telemetry``: checkpointed with the history buffers, so a
  resumed run continues its counters exactly where the interrupted run
  stopped.

Counts, not bytes, on purpose: float32 holds integers exactly only up to
2**24 and the wire totals (``model_bytes * (N+1)``-shaped quantities)
blow through that for any real model. The device records exact int32
counts; ``repro.telemetry.trace`` derives byte totals on the host through
``repro.core.protocol`` — where they are cross-checked against the
simulator's independent ledger math and any divergence raises
:class:`~repro.telemetry.trace.TelemetryMismatch`.

Everything here is plain ``jnp`` reductions over (N,) operands the round
already computed — no new kernel launches, no host syncs, and the jaxpr
the leakage audit sees gains only scalar outputs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Fault-code constants mirrored from repro.fed.faults (importing it here
# would cycle through repro.fed.__init__ back into rounds); the identity is
# pinned by tests/test_telemetry.py.
FAULT_NONE = 0
DROP_BEFORE = 1


class RoundTelemetry(NamedTuple):
    """One round's device-resident record — int32/float32 scalars only.

    ``cost_sum``/``weight_sum`` are the numerator and denominator of the
    size-weighted cost average over the workers whose report the master
    USED (sampled, surviving, in a viable sibling group) — the host divides
    and applies the all-reports-lost carry rule, so the trace reports the
    exact average the protocol acted on.
    """
    round: jax.Array          # absolute 1-based round index
    pilot: jax.Array          # k* of this round
    n_sampled: jax.Array      # participation-mask popcount
    n_used: jax.Array         # reports the master used (post fault/viability)
    n_dead: jax.Array         # sampled workers that faulted this round
    n_pre_uplink: jax.Array   # dead BEFORE uplink (bytes never spent)
    n_recovered: jax.Array    # dead in viable groups (seeds reconstructable)
    n_degraded: jax.Array     # live survivors excluded by group viability
    cost_sum: jax.Array       # sum(size_k * cost_k) over used workers
    weight_sum: jax.Array     # sum(size_k) over used workers
    modulus_bits: jax.Array   # wire modulus tag (0 = plain wire)
    fanout: jax.Array         # tree fanout tag (0 = flat aggregation)
    levels: jax.Array         # resolved tree depth tag (0 = flat)


class TelemetryCarry(NamedTuple):
    """Cumulative totals riding ``RoundState.telemetry`` (scan carry +
    checkpoint): a resumed federation's counters continue bitwise."""
    rounds: jax.Array
    sampled: jax.Array
    used: jax.Array
    dead: jax.Array
    pre_uplink: jax.Array
    recovered: jax.Array
    degraded: jax.Array
    cost_sum: jax.Array

    @classmethod
    def zero(cls) -> "TelemetryCarry":
        z = jnp.asarray(0, jnp.int32)
        return cls(rounds=z, sampled=z, used=z, dead=z, pre_uplink=z,
                   recovered=z, degraded=z,
                   cost_sum=jnp.asarray(0.0, jnp.float32))

    def add(self, rec: RoundTelemetry) -> "TelemetryCarry":
        """Fold one round's record into the running totals (traceable)."""
        return TelemetryCarry(
            rounds=self.rounds + 1,
            sampled=self.sampled + rec.n_sampled,
            used=self.used + rec.n_used,
            dead=self.dead + rec.n_dead,
            pre_uplink=self.pre_uplink + rec.n_pre_uplink,
            recovered=self.recovered + rec.n_recovered,
            degraded=self.degraded + rec.n_degraded,
            cost_sum=self.cost_sum + rec.cost_sum)


def _count(x) -> jax.Array:
    return jnp.sum(x.astype(jnp.int32)).astype(jnp.int32)


def build_round_record(*, t, k_star, n: int, costs, sizes, mask=None,
                       codes=None, sel_mask=None, dead_eff=None,
                       modulus_bits: int = 0, fanout: int = 0,
                       levels: int = 0) -> RoundTelemetry:
    """Assemble one round's :class:`RoundTelemetry` from operands the round
    computed anyway.

    ``mask`` — the (N,) participation row (None = all sampled); ``codes`` —
    the round's int32 fault codes (None = no fault plan); ``sel_mask`` —
    the post-fault/viability selection mask the pilot and cost carry used
    (None = everyone sampled is used); ``dead_eff`` — the masked wire's
    recoverable-dead mask from ``recovery.effective_masks`` (None off the
    recovery path). All may be traced; the result is scalars only.
    """
    costs = jnp.asarray(costs, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    pm = (jnp.ones((n,), jnp.float32) if mask is None
          else (jnp.asarray(mask, jnp.float32) > 0).astype(jnp.float32))
    if codes is None:
        live = pm
        n_dead = jnp.asarray(0, jnp.int32)
        n_pre = jnp.asarray(0, jnp.int32)
    else:
        codes = jnp.asarray(codes, jnp.int32)
        ok = (codes == FAULT_NONE).astype(jnp.float32)
        live = pm * ok
        n_dead = _count(pm * (1.0 - ok))
        n_pre = _count(pm * (codes == DROP_BEFORE).astype(jnp.float32))
    used = (live if sel_mask is None
            else (jnp.asarray(sel_mask, jnp.float32) > 0
                  ).astype(jnp.float32))
    n_used = _count(used)
    n_recovered = (jnp.asarray(0, jnp.int32) if dead_eff is None
                   else _count(jnp.asarray(dead_eff) > 0))
    return RoundTelemetry(
        round=jnp.asarray(t, jnp.int32),
        pilot=jnp.asarray(k_star, jnp.int32),
        n_sampled=_count(pm),
        n_used=n_used,
        n_dead=n_dead,
        n_pre_uplink=n_pre,
        n_recovered=n_recovered,
        n_degraded=_count(live) - n_used,
        cost_sum=jnp.sum(costs * sizes * used),
        weight_sum=jnp.sum(sizes * used),
        modulus_bits=jnp.asarray(modulus_bits, jnp.int32),
        fanout=jnp.asarray(fanout, jnp.int32),
        levels=jnp.asarray(levels, jnp.int32))
