"""Structured JSONL traces of a federation — export, schema, cross-check.

One trace is a JSON-Lines file whose first event is a ``meta`` record and
whose remaining events are flat dicts, one per round / worker / tree edge /
tuner-timed plan, each tagged with its event kind under ``"ev"``. The
schema is stable and validated (:func:`validate_trace`) — hand-rolled
field/type checks, no schema dependency — so downstream tooling
(``telemetry/report.py``, dashboards, regression diffs) can rely on it.

Byte accounting flows ONE way: the device records exact participation /
fault / recovery COUNTS (``repro.telemetry.record`` — float32 cannot hold
wire-scale byte totals exactly), and :func:`round_bytes` derives the byte
totals from those counts through the ``repro.core.protocol`` models. The
simulator still computes its ledger bytes independently from the host-side
mask/fault schedules; :func:`build_trace` compares the two paths —
count-by-count and byte-by-byte, exact equality — and any divergence
raises :class:`TelemetryMismatch` instead of silently exporting a wrong
ledger. :func:`summarize` re-runs the byte derivation on a trace read back
from disk, so a stored trace proves its own consistency.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

import numpy as np

from repro.core import protocol as proto
from repro.core.tree import TreeSpec

SCHEMA_VERSION = 1

#: ``sent`` values of a worker event — what crossed the uplink this round.
SENT_KINDS = ("pilot_params", "masked_words", "packed_ternary", "none")


class TelemetryMismatch(RuntimeError):
    """Device-recorded telemetry disagrees with the host byte/ledger model.

    This is a loud failure on purpose: the trace is the system's account of
    its own wire traffic, and a divergence means either the protocol byte
    model or the round program drifted — never something to average away.
    """


_NUM = (int, float)

#: Event schemas: ev -> {field: allowed python types}. Every field is
#: required; unknown fields reject (meta excepted — its run-config tail is
#: source-specific and carried verbatim).
_SCHEMAS: dict[str, dict[str, tuple]] = {
    "meta": {"ev": (str,), "schema": (int,), "source": (str,)},
    "round": {"ev": (str,), "t": (int,), "pilot": (int,),
              "n_sampled": (int,), "n_used": (int,), "n_dead": (int,),
              "n_pre_uplink": (int,), "n_recovered": (int,),
              "n_degraded": (int,), "cost": _NUM,
              "wire_bytes": _NUM, "recovery_bytes": _NUM},
    "worker": {"ev": (str,), "t": (int,), "worker": (int,),
               "sampled": (bool,), "fault": (int,), "pilot": (bool,),
               "sent": (str,)},
    "edge": {"ev": (str,), "t": (int,), "level": (int,), "width": (int,),
             "word_bits": (int,), "bytes": _NUM},
    "plan": {"ev": (str,), "kind": (str,), "rows": (int,), "n": (int,),
             "backend": (str,), "block_rows": (int,),
             "block_workers": (int,), "us": _NUM, "best": (bool,)},
}


def validate_event(event: dict) -> None:
    """Raise ``ValueError`` unless ``event`` matches its kind's schema."""
    ev = event.get("ev")
    if ev not in _SCHEMAS:
        raise ValueError(f"unknown trace event kind: {ev!r}")
    schema = _SCHEMAS[ev]
    for name, types in schema.items():
        if name not in event:
            raise ValueError(f"{ev} event missing field {name!r}: {event}")
        val = event[name]
        # bool is an int subclass; only fields typed bool accept it.
        if isinstance(val, bool) and bool not in types:
            raise ValueError(
                f"{ev} event field {name!r} has bool where "
                f"{types} expected: {event}")
        if not isinstance(val, types):
            raise ValueError(
                f"{ev} event field {name!r} = {val!r} is not of "
                f"{types}: {event}")
    if ev != "meta":
        extra = set(event) - set(schema)
        if extra:
            raise ValueError(f"{ev} event has unknown fields {extra}")
    if ev == "worker" and event["sent"] not in SENT_KINDS:
        raise ValueError(f"worker event sent={event['sent']!r} not in "
                         f"{SENT_KINDS}")


def validate_trace(events: Iterable[dict]) -> int:
    """Validate a whole event stream (first event must be ``meta`` at the
    current schema version); returns the number of events."""
    n = 0
    for i, event in enumerate(events):
        if i == 0:
            if event.get("ev") != "meta":
                raise ValueError("trace must start with a meta event")
            if event.get("schema") != SCHEMA_VERSION:
                raise ValueError(
                    f"trace schema {event.get('schema')!r} != "
                    f"{SCHEMA_VERSION}")
        validate_event(event)
        n += 1
    if n == 0:
        raise ValueError("empty trace")
    return n


def write_trace(path: str, events: Iterable[dict]) -> int:
    """Write events as JSONL (validated); returns the event count."""
    events = list(events)
    validate_trace(events)
    with open(path, "w") as f:
        for event in events:
            f.write(json.dumps(event) + "\n")
    return len(events)


def read_trace(path: str) -> list[dict]:
    """Read + validate a JSONL trace."""
    with open(path) as f:
        events = [json.loads(line) for line in f if line.strip()]
    validate_trace(events)
    return events


class TraceWriter:
    """Streaming JSONL writer (tuner sweeps, long benches): validates and
    flushes each event as it is emitted, so a crashed run keeps its trace
    prefix. Usable as a context manager; ``emit`` is the plain callable
    hook ``kernels.tune.set_trace_writer`` expects."""

    def __init__(self, path: str, *, source: str, meta: dict | None = None):
        self._f = open(path, "w")
        self.path = path
        self.count = 0
        self.emit({"ev": "meta", "schema": SCHEMA_VERSION,
                   "source": source, **(meta or {})})

    def emit(self, event: dict) -> None:
        validate_event(event)
        self._f.write(json.dumps(event) + "\n")
        self._f.flush()
        self.count += 1

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Byte derivation from device counts (the protocol models are the oracle)
# ---------------------------------------------------------------------------

def trace_meta(*, source: str, algorithm: str, driver: str, n_workers: int,
               t0: int, rounds: int, model_bytes: int, wire: str,
               masking: bool, modulus_bits: int, fanout: int, levels: int,
               recovery_threshold: int, faults_active: bool) -> dict:
    """The federation meta event — everything :func:`round_bytes` needs to
    turn a round event's counts into exact byte totals."""
    return {"ev": "meta", "schema": SCHEMA_VERSION, "source": source,
            "algorithm": algorithm, "driver": driver,
            "n_workers": int(n_workers), "t0": int(t0),
            "rounds": int(rounds), "model_bytes": int(model_bytes),
            "wire": wire, "masking": bool(masking),
            "modulus_bits": int(modulus_bits), "fanout": int(fanout),
            "levels": int(levels),
            "recovery_threshold": int(recovery_threshold),
            "faults_active": bool(faults_active)}


def round_bytes(meta: dict, rec: dict) -> tuple[float, float]:
    """(wire_bytes, recovery_bytes) of one round, derived from the round's
    device counts through the ``core.protocol`` models — the single byte
    path every consumer (SimResult views, report CLI, CI greps) reads."""
    masked = meta["wire"] == "masked"
    mb = meta["model_bytes"]
    n_part = rec["n_sampled"]
    if meta["fanout"]:
        wire = proto.fedpc_tree_bytes_per_round(
            mb, n_part, meta["fanout"], levels=meta["levels"] or None,
            word_bits=meta["modulus_bits"] if masked else None)
    elif masked:
        wire = proto.fedpc_masked_bytes_per_round(
            mb, n_part, word_bits=meta["modulus_bits"])
    else:
        wire = proto.fedpc_bytes_per_round(mb, n_part)
    rec_bytes = 0.0
    if meta["faults_active"]:
        # Pre-uplink deaths never spent their uplink bytes.
        leaf_bits = float(meta["modulus_bits"]) if masked else 2.0
        wire -= mb * rec["n_pre_uplink"] * leaf_bits / 32.0
        if meta["masking"] and meta["recovery_threshold"]:
            g = meta["fanout"] or None
            rec_bytes = (
                proto.recovery_dealing_bytes_per_round(meta["n_workers"], g)
                + proto.recovery_reconstruction_bytes(
                    rec["n_recovered"], meta["recovery_threshold"], g,
                    n_workers=meta["n_workers"]))
    return float(wire), float(rec_bytes)


# ---------------------------------------------------------------------------
# Trace assembly + cross-check
# ---------------------------------------------------------------------------

@dataclass
class TraceSummary:
    """A parsed/assembled trace: the meta event plus events grouped by
    kind, with the derived per-round views ``SimResult`` exposes."""
    meta: dict
    rounds: list = field(default_factory=list)
    workers: list = field(default_factory=list)
    edges: list = field(default_factory=list)
    plans: list = field(default_factory=list)

    @property
    def bytes_per_round(self) -> list:
        return [float(r["wire_bytes"]) for r in self.rounds]

    @property
    def recovery_bytes_per_round(self) -> list:
        return [float(r["recovery_bytes"]) for r in self.rounds]

    @property
    def total_bytes(self) -> float:
        return float(np.sum(self.bytes_per_round)
                     + np.sum(self.recovery_bytes_per_round))

    @property
    def costs(self) -> list:
        return [float(r["cost"]) for r in self.rounds]

    @property
    def pilots(self) -> list:
        return [int(r["pilot"]) for r in self.rounds]

    def events(self) -> list[dict]:
        return [self.meta] + self.rounds + self.workers + self.edges \
            + self.plans

    def write(self, path: str) -> int:
        return write_trace(path, self.events())

    def crosscheck_line(self) -> str:
        """The one-line attestation CI greps for."""
        return (f"byte cross-check OK: {len(self.rounds)} rounds, "
                f"{self.total_bytes:.0f} trace bytes == core/protocol "
                f"models")


def _require(ok: bool, what: str, t: int, device, host) -> None:
    if not ok:
        raise TelemetryMismatch(
            f"TELEMETRY MISMATCH at round {t}: {what} — device-recorded "
            f"{device!r} vs host ledger model {host!r}. The trace would "
            f"not match core/protocol byte accounting; refusing to "
            f"export it.")


def build_trace(meta: dict, records, host_rounds: list[dict], *,
                check_costs: bool = True) -> TraceSummary:
    """Assemble the federation trace from the stacked device records and
    cross-check every round against the host's independent ledger math.

    ``records`` is a ``RoundTelemetry`` of (R,)-stacked host arrays (the
    one post-run fetch); ``host_rounds[i]`` carries what the simulator
    computed from its own host-side schedules: ``row`` (participation
    bools), ``codes`` (fault codes or None), ``used`` (effective-report
    bools), ``n_recoverable``, ``pilot``, ``cost``, ``wire_bytes``,
    ``recovery_bytes``. Counts must match exactly, derived bytes must
    equal the host bytes exactly; costs compare within float32 tolerance
    (``check_costs=False`` for the evasion defence, where the device
    averages the *reported* costs and the host ledger the measured ones).
    """
    recs = {k: np.asarray(v) for k, v in records._asdict().items()}
    n_rounds = len(host_rounds)
    validate_event(meta)
    rounds_ev: list[dict] = []
    workers_ev: list[dict] = []
    edges_ev: list[dict] = []
    prev_cost = float("inf")
    for i, host in enumerate(host_rounds):
        t = int(recs["round"][i])
        _require(t == int(meta["t0"]) + i, "round index", t,
                 t, int(meta["t0"]) + i)
        rec = {k: int(recs[k][i]) for k in
               ("pilot", "n_sampled", "n_used", "n_dead", "n_pre_uplink",
                "n_recovered", "n_degraded")}
        row = np.asarray(host["row"]) > 0
        used = np.asarray(host["used"]) > 0
        codes = host.get("codes")
        _require(rec["pilot"] == int(host["pilot"]), "pilot id", t,
                 rec["pilot"], int(host["pilot"]))
        _require(rec["n_sampled"] == int(row.sum()), "sampled count", t,
                 rec["n_sampled"], int(row.sum()))
        _require(rec["n_used"] == int(used.sum()), "used-report count", t,
                 rec["n_used"], int(used.sum()))
        if codes is None:
            host_dead = host_pre = 0
        else:
            codes = np.asarray(codes)
            host_dead = int((row & (codes != 0)).sum())
            host_pre = int((row & (codes == 1)).sum())
        _require(rec["n_dead"] == host_dead, "fault count", t,
                 rec["n_dead"], host_dead)
        _require(rec["n_pre_uplink"] == host_pre, "pre-uplink-death count",
                 t, rec["n_pre_uplink"], host_pre)
        _require(rec["n_recovered"] == int(host["n_recoverable"]),
                 "recoverable-death count", t, rec["n_recovered"],
                 int(host["n_recoverable"]))
        wire_b, rec_b = round_bytes(meta, rec)
        _require(wire_b == float(host["wire_bytes"]), "wire bytes", t,
                 wire_b, float(host["wire_bytes"]))
        _require(rec_b == float(host["recovery_bytes"]), "recovery bytes",
                 t, rec_b, float(host["recovery_bytes"]))
        ws = float(recs["weight_sum"][i])
        cost = (float(recs["cost_sum"][i]) / ws if ws > 0 else prev_cost)
        prev_cost = cost
        if check_costs:
            hc = float(host["cost"])
            close = (cost == hc or (np.isinf(cost) and np.isinf(hc))
                     or abs(cost - hc) <= 1e-4 * max(abs(hc), 1e-6))
            _require(close, "round cost", t, cost, hc)
        rounds_ev.append({"ev": "round", "t": t, **rec, "cost": cost,
                          "wire_bytes": wire_b, "recovery_bytes": rec_b})
        for k in range(meta["n_workers"]):
            sampled = bool(row[k])
            fault = 0 if codes is None else int(codes[k])
            if not sampled or fault == 1:
                sent = "none"
            elif k == rec["pilot"]:
                sent = "pilot_params"
            elif meta["wire"] == "masked":
                sent = "masked_words"
            else:
                sent = "packed_ternary"
            workers_ev.append({"ev": "worker", "t": t, "worker": k,
                               "sampled": sampled, "fault": fault,
                               "pilot": k == rec["pilot"], "sent": sent})
        if meta["fanout"]:
            ts = TreeSpec(fanout=meta["fanout"],
                          levels=meta["levels"] or None)
            word_bits = (meta["modulus_bits"] if meta["wire"] == "masked"
                         else 32)
            n_part = rec["n_sampled"]
            for lvl, w_l in enumerate(ts.level_widths(n_part)[1:], 1):
                edges_ev.append({
                    "ev": "edge", "t": t, "level": lvl, "width": int(w_l),
                    "word_bits": int(word_bits),
                    "bytes": meta["model_bytes"] * w_l * word_bits / 32.0})
    _require(n_rounds == len(rounds_ev), "round count", -1,
             len(rounds_ev), n_rounds)
    return TraceSummary(meta=meta, rounds=rounds_ev, workers=workers_ev,
                        edges=edges_ev)


def summarize(events: list[dict]) -> TraceSummary:
    """Group a (validated) event stream and re-verify its byte accounting.

    For federation traces every round event's recorded bytes are re-derived
    from its counts through :func:`round_bytes`; divergence raises
    :class:`TelemetryMismatch` — a stored trace re-proves itself on read.
    """
    validate_trace(events)
    meta = events[0]
    summary = TraceSummary(meta=meta)
    buckets = {"round": summary.rounds, "worker": summary.workers,
               "edge": summary.edges, "plan": summary.plans}
    for event in events[1:]:
        buckets[event["ev"]].append(event)
    if "model_bytes" in meta:
        for r in summary.rounds:
            wire_b, rec_b = round_bytes(meta, r)
            _require(wire_b == float(r["wire_bytes"]),
                     "stored wire bytes", r["t"], wire_b, r["wire_bytes"])
            _require(rec_b == float(r["recovery_bytes"]),
                     "stored recovery bytes", r["t"], rec_b,
                     r["recovery_bytes"])
    return summary


def plan_emitter(emit: Callable[[dict], None]) -> Callable[..., None]:
    """Adapt a raw event sink into the ``kernels.tune`` plan hook: one
    validated plan event per timed candidate."""
    def hook(kind: str, rows: int, n: int, backend: str,
             timings: list[dict], best: dict) -> None:
        for tm in timings:
            emit({"ev": "plan", "kind": kind, "rows": int(rows),
                  "n": int(n), "backend": backend,
                  "block_rows": int(tm["block_rows"]),
                  "block_workers": int(tm["block_workers"]),
                  "us": float(tm["us"]),
                  "best": (tm["block_rows"] == best["block_rows"]
                           and tm["block_workers"] == best["block_workers"])
                  })
    return hook


def events_of(obj: "TraceSummary | list[dict] | Any") -> list[dict]:
    """Events of a TraceSummary, an event list, or a trace file path."""
    if isinstance(obj, TraceSummary):
        return obj.events()
    if isinstance(obj, str):
        return read_trace(obj)
    return list(obj)
