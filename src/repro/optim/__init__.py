from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    momentum,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, step_decay  # noqa: F401
