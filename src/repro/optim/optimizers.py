"""Minimal pytree optimizers (no optax dependency).

The paper's workers use Momentum (ResNet50-FIXUP) and Adam (U-Net) with
per-worker private hyper-parameters; the fed runtime instantiates one of
these per worker. API mirrors optax: ``init(params) -> state``,
``update(grads, state, params, lr) -> (updates, state)`` with updates to be
*added* to params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import PyTree


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]


class MomentumState(NamedTuple):
    velocity: PyTree


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, state

    return Optimizer("sgd", init, update)


def momentum(decay: float = 0.9, nesterov: bool = False,
             accum_dtype=jnp.float32) -> Optimizer:
    """Heavy-ball momentum (Qian 1999) — the paper's ResNet optimizer."""

    def init(params):
        return MomentumState(
            velocity=jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
        )

    def update(grads, state, params, lr):
        vel = jax.tree_util.tree_map(
            lambda v, g: decay * v + g.astype(accum_dtype), state.velocity, grads
        )
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda v, g: -lr * (decay * v + g.astype(accum_dtype)), vel, grads
            )
        else:
            upd = jax.tree_util.tree_map(lambda v: -lr * v, vel)
        upd = jax.tree_util.tree_map(_cast_like, upd, params)
        return upd, MomentumState(velocity=vel)

    return Optimizer("momentum", init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         accum_dtype=jnp.float32) -> Optimizer:
    """Adam (Kingma & Ba 2015) — the paper's U-Net optimizer."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, accum_dtype)
        return AdamState(
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, lr):
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(accum_dtype),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(accum_dtype)),
            state.nu, grads)
        c = count.astype(accum_dtype)
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)
        upd = jax.tree_util.tree_map(
            lambda m, n: -lr * (m * mu_hat_scale)
            / (jnp.sqrt(n * nu_hat_scale) + eps),
            mu, nu)
        upd = jax.tree_util.tree_map(_cast_like, upd, params)
        return upd, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer("adam", init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def get(name: str, **kw) -> Optimizer:
    table = {"sgd": sgd, "momentum": momentum, "adam": adam}
    return table[name](**kw)
