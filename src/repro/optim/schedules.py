"""Learning-rate schedules.

The paper (§5.1): initial lr 0.01 for all workers with *step-based decay
driven by the local dataset size* — which is what makes worker lrs
heterogeneous (and private) after a few epochs.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr0: float, decay: float = 0.5, every: int = 1000):
    """lr0 * decay^(step // every) — the paper's per-worker decay; ``every``
    is derived from the worker's local dataset size so it differs per worker."""
    def fn(step):
        return jnp.asarray(lr0, jnp.float32) * (decay ** (step // every))
    return fn


def cosine_decay(lr0: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (lr0 - floor) * (1 + jnp.cos(jnp.pi * frac))
    return fn


def warmup_cosine(lr0: float, warmup: int, total_steps: int, floor: float = 0.0):
    cos = cosine_decay(lr0, max(total_steps - warmup, 1), floor)
    def fn(step):
        w = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0) * lr0
        return jnp.where(step < warmup, w, cos(step - warmup))
    return fn
