"""Fig. 6 reproduction: bytes exchanged per epoch, FedPC vs FedAvg/Phong.

Prints the Eq. (8) table for the paper's two model sizes and the ASCII bar
chart of the reduction curve.

Run:  PYTHONPATH=src python examples/communication_comparison.py
"""
from repro.core.protocol import (fedavg_bytes_per_round,
                                 fedpc_bytes_per_round, reduction_vs_fedavg)

MODELS = {"ResNet50-FIXUP (35 MB)": 35e6, "U-Net (119 MB)": 119e6}


def main():
    for name, v in MODELS.items():
        print(f"\n=== {name} ===")
        print(f"{'N':>3} {'FedPC MB':>10} {'FedAvg/Phong MB':>16} "
              f"{'reduction':>10}")
        for n in range(3, 11):
            pc = fedpc_bytes_per_round(v, n) / 1e6
            avg = fedavg_bytes_per_round(v, n) / 1e6
            red = reduction_vs_fedavg(v, n)
            bar = "#" * int(red * 60)
            print(f"{n:>3} {pc:>10.1f} {avg:>16.1f} {red*100:>9.2f}% {bar}")
    print("\npaper claims: >=31.25% (N=3) ... 42.20% (N=10)")
    print(f"ours:         {reduction_vs_fedavg(35e6,3)*100:.2f}% (N=3) ... "
          f"{reduction_vs_fedavg(35e6,10)*100:.2f}% (N=10)")


if __name__ == "__main__":
    main()
