"""Quickstart: the FedPC protocol in ~60 lines.

Three hospitals jointly train a classifier without any of them revealing
weights or gradients — only the pilot-of-the-round uploads a model; everyone
else uploads 2-bit evolution codes (Eqs. 1, 3, 4, 5 of the paper).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.data.pipeline import federated_loaders
from repro.data.synthetic import SyntheticClassification, random_share_split
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, \
    mlp_loss_and_grad


def main():
    # --- private data: three silos of different size ----------------------
    x, y = SyntheticClassification(n_samples=1800, n_features=24,
                                   n_classes=6, seed=0).generate()
    xtr, ytr, xte, yte = x[:1500], y[:1500], x[1500:], y[1500:]
    splits = random_share_split(ytr, n_workers=3, seed=1)
    print("silo sizes:", [len(s) for s in splits])

    # --- workers with PRIVATE hyper-parameters (batch size, lr decay, ...) -
    loaders = federated_loaders((xtr, ytr), splits, seed=2)
    cfgs = make_worker_configs(3, [len(s) for s in splits], seed=3)
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(3)]

    # --- federated training ----------------------------------------------
    params = init_mlp_classifier(jax.random.PRNGKey(0), 24, 6)
    sim = FedSimulator(workers, params,
                       eval_fn=lambda p: mlp_accuracy(p, xte, yte))
    res = sim.run_fedpc(rounds=15, eval_every=5)

    print("\nround costs:", [f"{c:.3f}" for c in res.costs])
    print("pilot per round:", res.pilot_history)
    print("eval accuracy:", [(t, f"{a:.3f}") for t, a in res.eval_history])
    print(f"bytes/round: {res.bytes_per_round[0]/1e3:.1f} KB "
          f"(FedAvg would be {2 * 3 * res.bytes_per_round[0] / (3 + 1 + 2/16) / 1e3:.1f} KB)")
    print("\nuplink kinds seen by the master:",
          sorted({k for (_, _, k, _) in sim.ledger.events}))


if __name__ == "__main__":
    main()
