"""End-to-end driver: federated training of a transformer LM with FedPC.

Trains a reduced-config model from the assigned-architecture zoo (default:
qwen3-14b family, ~1.4M params at reduced size; pass --arch/--steps to scale
up to the ~100M class on real hardware) for a few hundred steps across N
simulated workers on synthetic LM data, comparing FedPC vs FedAvg cost and
bytes.

Run:  PYTHONPATH=src python examples/federated_llm_training.py \
          --arch qwen3-14b --workers 4 --rounds 30
"""
import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import SyntheticLM, sequence_split
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--sequences", type=int, default=256)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full (not reduced) config — needs a TPU")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    m = build_model(cfg)
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"vocab={cfg.vocab}")

    toks = SyntheticLM(n_sequences=args.sequences, seq_len=args.seq_len,
                       vocab=cfg.vocab, seed=0).generate()
    splits = sequence_split(len(toks), args.workers, seed=1)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p, b: m.loss(p, {"tokens": jnp.asarray(b[0])}), has_aux=True))

    cfgs = make_worker_configs(args.workers, [len(s) for s in splits],
                               seed=2, batch_menu=(16, 8))
    workers = [Worker(cfg=cfgs[k],
                      loader=BatchIterator((toks[splits[k]],),
                                           cfgs[k].batch_size, seed=k),
                      loss_and_grad=loss_fn)
               for k in range(args.workers)]

    params = m.init(jax.random.PRNGKey(0))
    sim = FedSimulator(workers, params)
    res = sim.run_fedpc(rounds=args.rounds)

    print(f"cost: {res.costs[0]:.4f} -> {res.costs[-1]:.4f} over "
          f"{args.rounds} rounds")
    print(f"total bytes (FedPC): {res.total_bytes/1e6:.1f} MB")
    steps = sum(w.step for w in workers)
    print(f"total local train steps across workers: {steps}")

    # baseline comparison on fresh workers
    workers2 = [Worker(cfg=cfgs[k],
                       loader=BatchIterator((toks[splits[k]],),
                                            cfgs[k].batch_size, seed=k),
                       loss_and_grad=loss_fn)
                for k in range(args.workers)]
    sim2 = FedSimulator(workers2, params)
    res_avg = sim2.run_fedavg(rounds=args.rounds)
    print(f"FedAvg cost: {res_avg.costs[0]:.4f} -> {res_avg.costs[-1]:.4f}; "
          f"bytes {res_avg.total_bytes/1e6:.1f} MB "
          f"({100*(1 - res.total_bytes/res_avg.total_bytes):.1f}% saved by FedPC)")

    if args.ckpt:
        path = save_checkpoint(args.ckpt, res.params, step=args.rounds,
                               metadata={"arch": cfg.name, "algo": "fedpc"})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
