"""Batched serving driver: prefill a prompt batch, then greedy-decode.

Exercises the production serving path (parallel prefill → KV/state caches →
one-token decode steps) for any assigned architecture, including the
recurrent ones (xLSTM/Jamba run with O(1) state).

Run:  PYTHONPATH=src python examples/serve_llm.py --arch xlstm-350m \
          --batch 4 --prompt-len 32 --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt_len

    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.is_encdec:
        batch["audio_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["vision_embed"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_patches, cfg.d_model))

    state = m.init_decode_state(B, S + args.new_tokens)

    t0 = time.time()
    prefill = jax.jit(m.prefill)
    logits, state = prefill(params, batch, state)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"[serve] {cfg.name}: prefill {B}×{S} in {t_prefill*1e3:.0f} ms")

    decode = jax.jit(m.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        sb = {"token": tok, "pos": jnp.asarray(S + i, jnp.int32)}
        if cfg.mrope:
            sb["positions"] = jnp.full((3, B, 1), S + i, jnp.int32)
        logits, state = decode(params, state, sb)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    per_tok = t_decode / max(args.new_tokens - 1, 1) * 1e3
    print(f"[serve] decoded {args.new_tokens} tokens "
          f"({per_tok:.1f} ms/token incl. first-call compile)")
    seqs = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] sample continuation (batch 0): "
          f"{[int(t) for t in seqs[0][:12]]} ...")


if __name__ == "__main__":
    main()
