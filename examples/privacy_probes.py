"""Privacy probes: what can each party actually see on the secure wire?

Probes the ``repro.privacy`` subsystem end-to-end:

  1. the master's view of a non-pilot uplink is uniform-looking masked
     uint32 words — a mask-removal attack (correlating the masked stream
     with the true codes, or summing any strict subset of workers)
     recovers nothing, while the FULL cohort sum recovers exactly the
     aggregate Eq. (3) needs;
  2. the local-DP randomized response flips codes at the rate the
     configured epsilon implies, and the master's unbias correction keeps
     the expected update on target;
  3. the PrivacyAccountant composes per-round epsilon across a simulated
     federation (basic vs advanced composition read-outs);
  4. the §4.2 enforcement hook: the simulator audits its traced round
     program at setup and the ledger records the passed audit;
  5. hierarchical tree aggregation: the partial sums crossing every tree
     edge below the root are still masked (a tapped edge leaks nothing),
     and the level-scoped masks cancel exactly once — at the root;
  6. dropout recovery: a dead worker's mask seeds reconstruct exactly
     from t Shamir share-holders, while the server colluding with t-1
     holders recovers 0% of a LIVE worker's mask words — and the audit
     layer refuses live-target reconstruction outright;
  7. the telemetry boundary: the observability layer's round records ride
     the scan carry off-device, so the §4.2 audit also scans the exported
     info/trace payloads — the real telemetry (counts + public scalars)
     passes, while a round program smuggling a per-worker float buffer
     into its trace record is refused outright.

Run:  PYTHONPATH=src python examples/privacy_probes.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedpc import FedPCConfig
from repro.core.tree import TreeSpec
from repro.data.pipeline import federated_loaders
from repro.data.synthetic import SyntheticClassification, random_share_split
from repro.fed import rounds as rd
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.kernels import ops
from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad
from repro.core.privacy import LeakageError
from repro.privacy import (PrivacySpec, deal_worker_shares, pair_signs,
                           pair_stream_keys, quantize_weights,
                           reconstruct, recover_worker_keys, rr_fields,
                           rr_stream_keys)
from repro.privacy.masking import index_hash, stream_values


def probe_mask_removal(word_bits: int):
    """Probe 1: the masked uplink leaks nothing short of the full sum —
    at either wire modulus (16-bit halves the wire bytes; the pairwise
    cancellation and the attack's failure are modulus-independent)."""
    n, rows = 4, 96
    k = jax.random.PRNGKey(0)
    bufs = jax.random.normal(k, (n, rows, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, 128))
    w = jnp.full((n,), 1.0 / n).at[0].set(0.0)
    wq = quantize_weights(w, 14 if word_bits == 16 else 24)
    keys = pair_stream_keys(0, n, 5)
    signs = pair_signs(n)
    rrk = rr_stream_keys(1, 5, n)

    def uplink(use_masks):
        return ops.flat_ternary_pack_masked(
            bufs, p1, p2, t=5, beta=0.2, alpha1=0.01, wq=wq,
            pair_keys=keys, pair_signs=signs, rr_keys=rrk, rr_threshold=0,
            word_bits=word_bits, use_masks=use_masks, interpret=True)

    masked, clear = uplink(True), uplink(False)
    print(f"probe 1 — pairwise-masked secure aggregation "
          f"(modulus 2**{word_bits}, in-kernel mask streams)")
    print(f"  wire words of worker 1 (masked):   "
          f"{np.asarray(masked[1].reshape(-1)[:4])}")
    print(f"  same words without the mask:       "
          f"{np.asarray(clear[1].reshape(-1)[:4])}")
    corr = np.corrcoef(
        np.asarray(masked[1], np.float64).reshape(-1),
        np.asarray(clear[1], np.float64).reshape(-1))[0, 1]
    print(f"  corr(masked stream, true codes) = {corr:+.4f}  (~0: the "
          f"master learns nothing per-worker)")
    # subset sums keep mask residue; the full sum cancels it exactly
    full = jnp.sum(masked, axis=0, dtype=masked.dtype)
    want = jnp.sum(clear, axis=0, dtype=clear.dtype)
    sub = jnp.sum(masked[:-1], axis=0, dtype=masked.dtype)
    sub_want = jnp.sum(clear[:-1], axis=0, dtype=clear.dtype)
    recovered = float(jnp.mean((sub == sub_want).astype(jnp.float32)))
    # a 16-bit residue can collide on ~2**-16 of words by chance; anything
    # below 1% is indistinguishable from guessing
    verdict = "fails" if recovered < 0.01 else "SUCCEEDS"
    print(f"  modulus {word_bits}: full-cohort sum == unmasked sum: "
          f"{bool(jnp.all(full == want))}")
    print(f"  modulus {word_bits}: drop-one subset sum recovers "
          f"{recovered:.3%} of words -> the attack {verdict}\n")


def probe_subtree_masks(word_bits: int = 16):
    """Probe 5: hierarchical tree aggregation keeps every edge masked.

    With a fan-in tree, interior nodes forward PARTIAL sums up the tree.
    Each level's partial is formed by summing its children (whose
    sibling-scoped masks cancel) and adding the node's OWN net mask from
    the level-salted stream — so a party tapping any single tree edge sees
    a still-masked word stream, and a node's children learn nothing about
    sibling subtrees. The masks have all cancelled exactly once: at the
    root's sum of the last level's partials."""
    n, rows, fanout, t = 8, 32, 2, 5
    k = jax.random.PRNGKey(7)
    bufs = jax.random.normal(k, (n, rows, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, 128))
    w = jnp.full((n,), 1.0 / n)
    ts = TreeSpec(fanout=fanout)
    mk = {"interpret": True, "tree": ts}
    wire = rd.WirePath(rd.WireConfig(),
                       privacy=PrivacySpec(modulus_bits=word_bits), **mk)
    clear_wire = rd.WirePath(rd.WireConfig(), privacy=PrivacySpec(
        modulus_bits=word_bits, mask_seed=None, enforce=False), **mk)

    y, _ = wire.uplink_masked(bufs, p1, p2, t=t, w=w)
    y_clear, _ = clear_wire.uplink_masked(bufs, p1, p2, t=t, w=w)
    top = wire._tree_fold_masked(y, t=t)          # (w_L, r4, 512) masked
    top_clear = clear_wire._tree_fold_masked(y_clear, t=t)

    print(f"probe 5 — tree aggregation (fanout {fanout}, "
          f"{ts.n_levels(n)} levels, modulus 2**{word_bits})")
    # tap one tree edge below the root: the level-L partial of node 0 —
    # a full subtree's sum, yet it still carries that node's own net mask
    match = float(jnp.mean((top[0] == top_clear[0]).astype(jnp.float32)))
    verdict = "fails" if match < 0.01 else "SUCCEEDS"
    print(f"  tapping a below-root edge recovers {match:.3%} of the "
          f"subtree's words -> the tree-edge attack {verdict}")
    root = jnp.sum(top, axis=0, dtype=top.dtype)
    root_clear = jnp.sum(top_clear, axis=0, dtype=top_clear.dtype)
    print(f"  tree level masks: subtree sums cancel at the root: "
          f"{bool(jnp.all(root == root_clear))}\n")


def probe_randomized_response():
    """Probe 2: RR flip rate matches epsilon; unbias keeps E[update]."""
    spec = PrivacySpec(dp_epsilon=2.0)
    p = spec.flip_prob
    fields = jnp.ones((1 << 18,), jnp.uint32)
    bits = jax.random.bits(jax.random.PRNGKey(3), fields.shape, jnp.uint32)
    out = rr_fields(fields, bits, spec.rr_threshold)
    flipped = float(jnp.mean((out != fields).astype(jnp.float32)))
    print("probe 2 — local-DP ternary randomized response")
    print(f"  eps = {spec.dp_epsilon}  ->  flip prob p = {p:.4f} "
          f"(realized eps/round = {spec.eps_round:.4f})")
    print(f"  measured flip rate = {flipped:.4f}  "
          f"(expected p*2/3 = {p * 2 / 3:.4f})")
    print(f"  master unbias multiplier 1/(1-p) folded into the de-bias: "
          f"{1.0 / (1.0 - p):.4f}\n")


def probe_accountant_and_enforcement():
    """Probes 3+4: a DP federation — accountant + setup-time audit."""
    x, y = SyntheticClassification(n_samples=600, n_features=12,
                                  n_classes=3, seed=0).generate()
    splits = random_share_split(y, 4, seed=1)
    loaders = federated_loaders((x, y), splits, seed=2)
    cfgs = make_worker_configs(4, [len(s) for s in splits], seed=3,
                               batch_menu=(25,))
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(4)]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 12, 3, hidden=(16,))

    spec = PrivacySpec(dp_epsilon=2.0)
    sim = FedSimulator(workers, params,
                       FedPCConfig(n_workers=4, privacy=spec))
    res = sim.run_fedpc(rounds=8)
    acc = res.round_state.accountant
    print("probe 3 — privacy accountant across a federation")
    print(f"  rounds composed: {int(acc.spent_rounds)}")
    print(f"  eps (basic composition):           "
          f"{float(acc.epsilon()):.3f}")
    print(f"  eps (advanced, delta={spec.delta:g}): "
          f"{float(acc.epsilon(spec.delta)):.3f}")
    print(f"  best of both: {float(acc.best_epsilon(spec.delta)):.3f}\n")

    print("probe 4 — §4.2 enforcement hook")
    for audit in sim.ledger.audits:
        print(f"  audit passed: runtime={audit['runtime']} "
              f"boundary={audit['boundary']} masked={audit['masked']}")
    kinds = {k for (_, _, k, _) in sim.ledger.events}
    print(f"  uplink fields recorded on the masked wire: {sorted(kinds)}")
    print("  -> no weight value, no gradient value, no per-worker ternary "
          "direction reaches the master.")


def probe_dropout_recovery():
    """Probe 6: the dropout-recovery control plane — t-of-n seed shares.

    Each worker's per-pair mask seeds are Shamir-shared (GF(2^16),
    threshold t) across its sibling group so the cohort can repair the
    masked sum after a post-uplink death. The probe plays both sides:
    the server colluding with t-1 share-holders against a LIVE worker
    (must learn nothing), and a legitimate >= t reconstruction of a
    DECLARED-DEAD worker's stream (must be exact)."""
    n, thr, victim = 8, 3, 2
    t = jnp.asarray(5, jnp.int32)
    members, xs, shares = deal_worker_shares(5, victim, n, t, thr)
    true_keys = np.asarray(pair_stream_keys(5, n, t))[victim][members]
    h = index_hash(512, 16)
    true_words = np.stack([np.asarray(stream_values(jnp.uint32(k), h, 16))
                           for k in true_keys])

    print(f"probe 6 — dropout recovery: {thr}-of-{len(members)} seed "
          f"shares (GF(2^16) Shamir)")
    # --- the collusion attack: server + t-1 holders, victim still live
    holders = [j for j in range(len(members))
               if int(members[j]) != victim][:thr - 1]
    part = reconstruct(shares[holders], xs[holders])   # t-1 points only
    guess_keys = (part[..., 0].astype(np.uint32)
                  | (part[..., 1].astype(np.uint32) << 16))
    guess_words = np.stack(
        [np.asarray(stream_values(jnp.uint32(k), h, 16))
         for k in guess_keys])
    hit = float(np.mean(guess_words == true_words))
    verdict = "fails" if hit < 0.01 else "SUCCEEDS"
    print(f"  server + {thr - 1} colluding share-holders vs a LIVE "
          f"worker: recover {hit:.3%} of its mask words -> the collusion "
          f"attack {verdict}")
    try:
        recover_worker_keys(5, victim, n, t, thr, alive=np.ones(n))
        refused = False
    except LeakageError:
        refused = True
    print(f"  control plane refuses a live-target reconstruction "
          f"(LeakageError): {refused}")
    # --- the legitimate path: victim declared dead, >= t holders
    alive = np.ones(n)
    alive[victim] = 0.0
    _, rec_keys = recover_worker_keys(5, victim, n, t, thr, alive=alive)
    rec_words = np.stack(
        [np.asarray(stream_values(jnp.uint32(k), h, 16))
         for k in rec_keys])
    exact = bool(np.array_equal(rec_words, true_words))
    print(f"  declared-dead worker, {thr} surviving share-holders: "
          f"recovered mask stream exact: {exact}\n")


def probe_telemetry_trace():
    """Probe 7: telemetry rides the carry; the trace leaks no payloads.

    The observability layer threads a ``RoundTelemetry`` record through
    every ``round_step`` — device-resident counts and public scalars,
    fetched once post-run and exported as a JSONL trace. The audit's
    masked policy shape-evaluates the round program and scans its
    dict-carried outputs (exactly what a driver exports off-device): the
    real telemetry record passes, and a round program that smuggles a
    per-worker float buffer into its trace record raises LeakageError."""
    from repro.core import flat as fl
    from repro.privacy import check_round_program

    n = 4
    k = jax.random.PRNGKey(11)
    tree = {"w": jax.random.normal(k, (41, 23)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (23,))}
    layout = fl.layout_of(tree)
    spec = PrivacySpec()
    state = rd.init_round_state(tree, n, layout, privacy=spec)
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    sizes = jnp.linspace(20.0, 80.0, n)
    bufs = jax.ShapeDtypeStruct((n,) + state.buf_p1.shape, jnp.float32)
    costs = jax.ShapeDtypeStruct((n,), jnp.float32)

    def step(s, b, c):
        return wire.round_step(s, b, c, sizes)

    report = check_round_program(step, state, bufs, costs,
                                 n_workers=n, masked=True)
    rec = jax.eval_shape(step, state, bufs, costs)[2]["telemetry"]
    print("probe 7 — telemetry boundary: the trace leaks nothing")
    print(f"  telemetry-carrying round program passes the masked audit "
          f"({report['n_launches']} launches, counts + public scalars "
          f"only): True")
    print(f"  per-round record fields exported off-device: "
          f"{sorted(rec._fields)}")

    def leaky(s, b, c):
        new_s, new_buf, info = step(s, b, c)
        info = dict(info)
        # a (N, rows*128) float export — per-worker parameter payload
        info["trace_payload"] = b.reshape(n, -1)
        return new_s, new_buf, info

    try:
        check_round_program(leaky, state, bufs, costs,
                            n_workers=n, masked=True)
        refused = False
    except LeakageError:
        refused = True
    print(f"  a per-worker float payload smuggled into the trace record "
          f"is refused (LeakageError): {refused}\n")


def main():
    probe_mask_removal(16)
    probe_mask_removal(32)
    probe_subtree_masks()
    probe_randomized_response()
    probe_accountant_and_enforcement()
    probe_dropout_recovery()
    probe_telemetry_trace()


if __name__ == "__main__":
    main()
