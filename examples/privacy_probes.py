"""Privacy probes (§4.2): what can each party actually see?

Demonstrates: (1) the master's view of a non-pilot worker is only 2-bit
codes; (2) the gradient-inversion system is underdetermined; (3) the
collusion scenario of Thm 4 and the worker-side evasion defence.

Run:  PYTHONPATH=src python examples/privacy_probes.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_tree
from repro.core.privacy import gradient_inversion_hardness
from repro.core.ternary import ternarize_tree
from repro.data.pipeline import federated_loaders
from repro.data.synthetic import SyntheticClassification, random_share_split
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad
from repro.utils import tree_bytes, tree_size


def main():
    x, y = SyntheticClassification(n_samples=900, n_features=16,
                                   n_classes=4, seed=0).generate()
    splits = random_share_split(y, 4, seed=1)
    loaders = federated_loaders((x, y), splits, seed=2)
    cfgs = make_worker_configs(4, [len(s) for s in splits], seed=3)
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(4)]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 16, 4)

    # ---- probe 1: the uplink of a non-pilot worker -----------------------
    q, _cost = workers[0].train_round(params)
    tern = ternarize_tree(q, params,
                          jax.tree_util.tree_map(jnp.zeros_like, params), 0.2)
    packed, layout = pack_tree(tern)
    print(f"model instance: {tree_size(params)} params "
          f"({tree_bytes(params)} B fp32)")
    print(f"non-pilot uplink: {packed.nbytes} B of 2-bit codes "
          f"({tree_bytes(params)/packed.nbytes:.1f}x smaller)")
    print("first bytes on the wire:", np.asarray(packed[:12]))
    print("→ no weight value, no gradient value leaves the worker.\n")

    # ---- probe 2: inversion hardness (Thm 2) ------------------------------
    h = gradient_inversion_hardness(
        n_batches=len(splits[0]) // cfgs[0].batch_size, known_lr=False)
    print(f"inversion system per epoch pair: {h['unknowns_per_epoch']} "
          f"unknowns vs {h['equations_per_pair']} equation "
          f"→ underdetermined={h['underdetermined']}\n")

    # ---- probe 3: collusion pressure + evasion defence (Thm 4) -----------
    sim = FedSimulator(workers, params, evade_streak=2)
    res = sim.run_fedpc(rounds=10)
    print("pilot history with evasion defence on:", res.pilot_history)
    streaks = {k: sim.ledger.consecutive_pilot_streak(k) for k in range(4)}
    print("longest consecutive-pilot streak per worker:", streaks)
    print("→ no worker can be farmed for weights round after round.")


if __name__ == "__main__":
    main()
