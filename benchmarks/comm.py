"""Fig. 6 / Eq. (8) analog: bytes exchanged per training epoch.

Reports the analytic Eq. (8) curve for the paper's own model sizes
(ResNet50-FIXUP 35 MB, U-Net 119 MB) and the *measured* ledger bytes from
the simulator, plus the headline reductions (31.25% … 42.20%).

Extended with the per-round byte accounting of the three wires the repo
actually implements — plaintext 2-bit, masked-16, masked-32 — under flat
vs hierarchical-tree aggregation vs FedAvg, at cohort sizes N ∈ {16, 64,
256}. The rows land in a ``comm`` section of the kernels-bench JSON
(``BENCH_kernels.json``, or the smoke variant under ``--smoke``) so
``check_bench_regression.py`` gates them: any change that grows a wire's
per-round bytes >25% fails CI the same way a kernel slowdown does.

Reading the tree columns: the tree does NOT shrink TOTAL bytes — every
interior level adds ``w_l`` word-wide partial links — it shrinks the bytes
over any single link. The flat master ingests N-1 buffers over one link;
the tree root ingests ``w_L <= fanout``, a ``(N-1)/w_L`` reduction, and
every interior node ingests exactly ``fanout``.
"""
from __future__ import annotations

import argparse
import json
from dataclasses import replace

from benchmarks.common import emit, make_sim, make_task, timed
from benchmarks.kernels_bench import BENCH_JSON, BENCH_SMOKE_JSON
from repro.core.protocol import (fedavg_bytes_per_round,
                                 fedpc_bytes_per_round,
                                 fedpc_masked_bytes_per_round,
                                 fedpc_tree_bytes_per_round,
                                 reduction_vs_fedavg)
from repro.core.tree import TreeSpec

PAPER_MODELS = {"resnet50_fixup": 35e6, "unet": 119e6}
TREE_COHORTS = (16, 64, 256)
TREE_FANOUT = 4


def _wire_rows(name: str, v: float) -> list[dict]:
    """Analytic per-round bytes for one model size across cohorts: flat vs
    tree at every wire, plus the FedAvg yardstick."""
    rows = []
    for n in TREE_COHORTS:
        ts = TreeSpec(fanout=TREE_FANOUT)
        w_last = ts.level_widths(n)[-1]
        rows.append({
            "model": name,
            "model_bytes": v,
            "n_workers": n,
            "fanout": TREE_FANOUT,
            "levels": ts.n_levels(n),
            "fedavg_bytes": fedavg_bytes_per_round(v, n),
            "flat_plain_bytes": fedpc_bytes_per_round(v, n),
            "tree_plain_bytes": fedpc_tree_bytes_per_round(v, n,
                                                           TREE_FANOUT),
            "flat_masked16_bytes": fedpc_masked_bytes_per_round(v, n, 16),
            "tree_masked16_bytes": fedpc_tree_bytes_per_round(
                v, n, TREE_FANOUT, word_bits=16),
            "flat_masked32_bytes": fedpc_masked_bytes_per_round(v, n, 32),
            "tree_masked32_bytes": fedpc_tree_bytes_per_round(
                v, n, TREE_FANOUT, word_bits=32),
            # ingress of the aggregation bottleneck link (masked-16):
            # N-1 word buffers into the flat master vs w_L tree partials
            "flat_root_link16_bytes": (n - 1) * v * 16 / 32,
            "tree_root_link16_bytes": w_last * v * 16 / 32,
            "root_link_reduction": (n - 1) / max(w_last, 1),
        })
    return rows


def _merge_section(json_path: str, section: dict) -> None:
    """Read-modify-write the kernels-bench JSON: comm rows ride in the same
    file the CI regression gate already diffs."""
    try:
        with open(json_path) as f:
            payload = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        payload = {}
    payload["comm"] = section
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)


def run(smoke: bool = False) -> dict:
    results = {}
    for name, v in PAPER_MODELS.items():
        for n in (3, 4, 5, 6, 7, 8, 9, 10):
            d_pc = fedpc_bytes_per_round(v, n)
            d_avg = fedavg_bytes_per_round(v, n)
            red = reduction_vs_fedavg(v, n)
            if n in (3, 10):
                emit(f"fig6_{name}_N{n}", 0.0,
                     f"fedpc={d_pc/1e6:.1f}MB fedavg={d_avg/1e6:.1f}MB "
                     f"reduction={red*100:.2f}%")
            results[(name, n)] = red
    # paper's headline claims
    emit("fig6_claim_min_reduction", 0.0,
         f"{reduction_vs_fedavg(35e6, 3)*100:.2f}% (paper: >=31.25%)")
    emit("fig6_claim_max_reduction", 0.0,
         f"{reduction_vs_fedavg(35e6, 10)*100:.2f}% (paper: 42.20%)")

    # ---- flat vs tree vs FedAvg at Eq. (8) accounting, three wires ------
    wire_rows = []
    for name, v in PAPER_MODELS.items():
        rows = _wire_rows(name, v)
        wire_rows.extend(rows)
        for r in rows:
            if r["n_workers"] != max(TREE_COHORTS):
                continue
            emit(f"comm_tree_{name}_N{r['n_workers']}_f{r['fanout']}", 0.0,
                 f"root_link16={r['tree_root_link16_bytes']/1e6:.0f}MB "
                 f"(flat {r['flat_root_link16_bytes']/1e6:.0f}MB, "
                 f"{r['root_link_reduction']:.1f}x) "
                 f"total16={r['tree_masked16_bytes']/1e6:.0f}MB "
                 f"fedavg={r['fedavg_bytes']/1e6:.0f}MB")

    # measured through the simulator ledger
    n_sim = 6 if smoke else 10
    rounds = 2
    task = make_task(seed=3)
    sim, _ = make_sim(task, n_sim, seed=3)
    res_pc, us = timed(lambda: sim.run_fedpc(rounds=rounds))
    res_avg = sim.run_fedavg(rounds=rounds)
    meas = 1.0 - res_pc.bytes_per_round[0] / res_avg.bytes_per_round[0]
    emit(f"fig6_measured_reduction_N{n_sim}", us, f"{meas*100:.2f}%")

    # measured on the tree path: the ledger's per-round accounting follows
    # the configured topology, and must agree with the analytic model
    sim_tree, _ = make_sim(task, n_sim, seed=3)
    sim_tree.fed_cfg = replace(sim_tree.fed_cfg, tree=TreeSpec(fanout=2))
    res_tree, us_t = timed(lambda: sim_tree.run_fedpc(rounds=rounds))
    want = fedpc_tree_bytes_per_round(
        res_avg.bytes_per_round[0] / (2 * n_sim), n_sim, 2)
    got = res_tree.bytes_per_round[0]
    assert got == want, (got, want)
    emit(f"comm_measured_tree_N{n_sim}_f2", us_t,
         f"ledger={got/1e3:.1f}KB matches Eq.(8)-tree model: True")

    section = {
        "paper_models": wire_rows,
        "measured": {
            "n_workers": n_sim,
            "rounds": rounds,
            "fedpc_flat_bytes": res_pc.bytes_per_round[0],
            "fedpc_tree_f2_bytes": got,
            "fedavg_bytes": res_avg.bytes_per_round[0],
        },
    }
    _merge_section(BENCH_SMOKE_JSON if smoke else BENCH_JSON, section)
    emit("bench_comm_section", 0.0,
         "merged into " + ("smoke" if smoke else "full") + " bench JSON")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small measured sim for CI; merges the comm "
                         "section into BENCH_kernels_smoke.json")
    run(smoke=ap.parse_args().smoke)
