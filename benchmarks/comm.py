"""Fig. 6 / Eq. (8) analog: bytes exchanged per training epoch.

Reports the analytic Eq. (8) curve for the paper's own model sizes
(ResNet50-FIXUP 35 MB, U-Net 119 MB) and the *measured* ledger bytes from
the simulator, plus the headline reductions (31.25% … 42.20%)."""
from __future__ import annotations

from benchmarks.common import emit, make_sim, make_task, timed
from repro.core.protocol import (fedavg_bytes_per_round,
                                 fedpc_bytes_per_round, reduction_vs_fedavg)

PAPER_MODELS = {"resnet50_fixup": 35e6, "unet": 119e6}


def run() -> dict:
    results = {}
    for name, v in PAPER_MODELS.items():
        for n in (3, 4, 5, 6, 7, 8, 9, 10):
            d_pc = fedpc_bytes_per_round(v, n)
            d_avg = fedavg_bytes_per_round(v, n)
            red = reduction_vs_fedavg(v, n)
            if n in (3, 10):
                emit(f"fig6_{name}_N{n}", 0.0,
                     f"fedpc={d_pc/1e6:.1f}MB fedavg={d_avg/1e6:.1f}MB "
                     f"reduction={red*100:.2f}%")
            results[(name, n)] = red
    # paper's headline claims
    emit("fig6_claim_min_reduction", 0.0,
         f"{reduction_vs_fedavg(35e6, 3)*100:.2f}% (paper: >=31.25%)")
    emit("fig6_claim_max_reduction", 0.0,
         f"{reduction_vs_fedavg(35e6, 10)*100:.2f}% (paper: 42.20%)")

    # measured through the simulator ledger
    task = make_task(seed=3)
    sim, _ = make_sim(task, 10, seed=3)
    res_pc, us = timed(lambda: sim.run_fedpc(rounds=2))
    res_avg = sim.run_fedavg(rounds=2)
    meas = 1.0 - res_pc.bytes_per_round[0] / res_avg.bytes_per_round[0]
    emit("fig6_measured_reduction_N10", us, f"{meas*100:.2f}%")
    return results


if __name__ == "__main__":
    run()
