"""Fig. 4 analog: training-cost evolution under FedPC.

Checks the paper's two observations: (1) cost decreases and stabilizes;
(2) the first couple of rounds improve slowly because ternary direction
information only becomes meaningful from round 3 (§5.2.2)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_sim, make_task, timed
from repro.core.convergence import CostHistory


def run() -> dict:
    task = make_task(seed=2)
    sim, _ = make_sim(task, 5, seed=2)
    res, us = timed(lambda: sim.run_fedpc(rounds=25))
    hist = CostHistory(costs=res.costs)
    total_drop = hist.total_reduction()
    emit("fig4_fedpc_cost_drop", us, f"{total_drop:.4f}")
    emit("fig4_fedpc_final_cost", 0.0, f"{res.costs[-1]:.4f}")
    emit("fig4_monotone_fraction", 0.0, f"{hist.monotone_fraction():.3f}")
    late = np.asarray(res.costs[-5:])
    emit("fig4_late_stability_std", 0.0, f"{late.std():.5f}")
    return {"costs": res.costs}


if __name__ == "__main__":
    run()
