"""Tables 1–3 analog: accuracy approximation of FedPC vs FedAvg vs Phong
vs the centralized upper bound, across worker counts (synthetic task)."""
from __future__ import annotations

from benchmarks.common import central_worker, emit, make_sim, make_task, timed

ROUNDS = 12
WORKER_COUNTS = (3, 5, 10)


def run() -> dict:
    task = make_task()
    results: dict = {}

    # Table 1: centralized upper bound
    sim, _ = make_sim(task, 3, seed=0)
    (res_c, us) = timed(lambda: sim.run_centralized(
        ROUNDS, central_worker(task), eval_every=ROUNDS))
    acc_central = res_c.eval_history[-1][1]
    results["central"] = acc_central
    emit("table1_central_acc", us, f"{acc_central:.4f}")

    # Tables 2/3: per algorithm × N
    for n in WORKER_COUNTS:
        row = {}
        for algo in ("fedpc", "fedavg", "phong"):
            sim, _ = make_sim(task, n, seed=n)
            runner = getattr(sim, f"run_{algo}")
            res, us = timed(lambda r=runner: r(ROUNDS, eval_every=ROUNDS))
            acc = res.eval_history[-1][1]
            row[algo] = acc
            approx = acc / max(acc_central, 1e-9)
            emit(f"table2_{algo}_N{n}_acc", us,
                 f"{acc:.4f} (approx {approx:.3f} of central)")
        results[n] = row
    return results


if __name__ == "__main__":
    run()
