"""Kernel micro-bench: latency of the FedPC round ops (interpret mode on
CPU — correctness-weighted; TPU timings come from real hardware) and the
equivalent jnp reference, plus fused-vs-unfused flat wire path timings
emitted to BENCH_kernels.json so the perf trajectory is tracked across PRs.

NOTE on CPU numbers: interpret mode executes one Python step per grid tile,
so wall time measures launch overhead, not HBM traffic — the fused win there
shows up as HALF the grid steps (one kernel instead of two) rather than
bandwidth. The no-int8-intermediate property is asserted structurally in
tests/test_flat_wire.py via jaxpr inspection.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import flat as fl
from repro.core import protocol as proto
from repro.core.tree import TreeSpec
from repro.fed import rounds as rd
from repro.kernels import fused_wire as fw
from repro.kernels import ops, ref, tune
from repro.kernels import pack2bit as pk
from repro.kernels import ternary_encode as te
from repro.utils import HOST_SYNC_PRIMITIVES, jaxpr_primitive_counts

M = 1 << 20            # 1M params
N_WORKERS = 8
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")
BENCH_SMOKE_JSON = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_kernels_smoke.json")


def _bench(fn, *args, reps=3):
    """Best-of-reps wall time (us). Min, not mean: on a shared machine the
    distribution is one-sided (interference only adds time), so the minimum
    is the noise-robust estimator of true cost — applied uniformly to both
    sides of every comparison."""
    fn(*args)  # compile/warm
    best = float("inf")
    for _ in range(max(reps, 2)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _wire_inputs(m: int, key=0):
    k = jax.random.PRNGKey(key)
    q = jax.random.normal(k, (m,))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (m,))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (m,))
    return q, p1, p2


def _fused_vs_unfused(m: int, reps: int) -> dict:
    """Flat wire path at m params: old two-kernel uplink vs ternary_pack,
    old loop-and-stack master vs packed_master_update.

    Block sizes come from the ``kernels.tune`` plan for this (shape,
    backend) — on cpu-interpret that is the fewest-step plan (every grid
    step pays the interpreter's full block machinery), on TPU the
    VMEM-sized tiles. Nothing is hand-pinned per size any more.
    """
    q, p1, p2 = _wire_inputs(m)
    rows = m // 128
    r4 = rows // 4
    br4 = tune.lookup("uplink", r4, interpret=True)[0]
    br = br4 * 4
    q2, p12, p22 = (x.reshape(rows, 128) for x in (q, p1, p2))
    q4, p14, p24 = (x.reshape(r4, 512) for x in (q, p1, p2))

    def unfused():
        codes = te.ternary_encode_2d(q2, p12, p22, 0.2, interpret=True,
                                     block_rows=br)
        return pk.pack2bit_2d(codes.reshape(r4, 512), interpret=True,
                              block_rows=br4)

    def fused():
        return fw.ternary_pack_2d(q4, p14, p24, 0.2, interpret=True,
                                  block_rows=br4)

    np.testing.assert_array_equal(np.asarray(unfused()), np.asarray(fused()))
    up_unfused = _bench(unfused, reps=reps)
    up_fused = _bench(fused, reps=reps)

    # master side: N workers' wire buffers
    tern = jax.random.randint(jax.random.PRNGKey(9), (N_WORKERS, m),
                              -1, 2).astype(jnp.int8)
    w = jnp.full((N_WORKERS,), 0.02)
    packed = jnp.stack([ops.pack2bit(tern[k], interpret=True)
                        for k in range(N_WORKERS)]).reshape(
                            N_WORKERS, r4, 128)

    def master_unfused():
        # the old path: stacked-pad + int8 promotion inside master_update_2d
        return ops.master_update(q, tern, w, p1, p2, interpret=True)

    def master_fused():
        return ops.flat_master_update(q2, packed, w, p12, p22, t=3,
                                      alpha0=0.01, interpret=True)

    got = np.asarray(master_fused()).reshape(-1)
    want = np.asarray(master_unfused())
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    ms_unfused = _bench(master_unfused, reps=reps)
    ms_fused = _bench(master_fused, reps=reps)

    return {
        "params": m,
        "uplink_unfused_us": up_unfused,
        "uplink_fused_us": up_fused,
        "uplink_speedup": up_unfused / up_fused,
        "uplink_launches": {"unfused": 2, "fused": 1},
        "uplink_block_rows": br4,
        "master_unfused_us": ms_unfused,
        "master_fused_us": ms_fused,
        "master_speedup": ms_unfused / ms_fused,
        "n_workers": N_WORKERS,
        "mode": "cpu-interpret",
    }


def _batched_uplink(m: int, n_workers: int, reps: int,
                    autotune: bool = True) -> dict:
    """Simulator uplink at m params × N workers: a per-worker loop of N
    fused traced-t launches (both real drivers trace the round index, so
    this is the launch the loop alternative would actually dispatch) vs ONE
    stacked launch at its autotuned (block_rows, block_workers) plan.

    The stacked win on cpu-interpret comes from touching every operand
    once (the interpreter pays per-step block machinery ∝ operand bytes,
    and the loop re-reads the shared history N times); on TPU the same
    rows-major plan turns that into one history fetch per row block. All
    plans pack bitwise-identically."""
    rows = m // 128
    r4 = rows // 4
    k = jax.random.PRNGKey(11)
    bufs_q = jax.random.normal(k, (n_workers, rows, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, 128))
    if autotune:
        tune.autotune_stacked(r4, n_workers, interpret=True, reps=1)
    plan = tune.lookup("uplink_stacked", r4, n_workers, interpret=True)

    def loop():
        return jnp.stack([ops.flat_ternary_pack_traced(
            bufs_q[i], p1, p2, t=3, beta=0.2, alpha1=0.01,
            interpret=True) for i in range(n_workers)])

    def stacked():
        return ops.flat_ternary_pack_stacked(
            bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, interpret=True)

    np.testing.assert_array_equal(np.asarray(loop()).reshape(n_workers, r4,
                                                             128),
                                  np.asarray(stacked()))
    us_loop = _bench(loop, reps=reps)
    us_stacked = _bench(stacked, reps=reps)
    return {
        "params": m,
        "n_workers": n_workers,
        "uplink_loop_us": us_loop,
        "uplink_stacked_us": us_stacked,
        "stacked_speedup": us_loop / us_stacked,
        "launches": {"loop": n_workers, "stacked": 1},
        "plan": {"block_rows": plan[0], "block_workers": plan[1]},
        "mode": "cpu-interpret",
    }


def _worker_scaling(m: int, n_list: tuple, reps: int) -> list:
    """Federation-size sweep: tuned stacked-uplink + accumulating-master
    latency at N workers, with the §3.3 wire payload and the master
    kernel's per-tile VMEM model (new: O(block), constant in N; old
    pre-accumulation kernel: linear in N — the term that capped federation
    size)."""
    rows = m // 128
    r4 = rows // 4
    out = []
    for n in n_list:
        k = jax.random.PRNGKey(n)
        bufs_q = jax.random.normal(k, (n, rows, 128))
        p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, 128))
        p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, 128))
        w = jnp.full((n,), 1.0 / max(n - 1, 1)).at[0].set(0.0)
        tune.autotune_stacked(r4, n, interpret=True, reps=1)
        tune.autotune_master(r4, n, interpret=True, reps=1)

        def uplink():
            return ops.flat_ternary_pack_stacked(
                bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, interpret=True)

        packed = uplink()

        def master():
            return ops.flat_master_update(
                bufs_q[0], packed, w, p1, p2, t=3, alpha0=0.01,
                interpret=True)

        us_up = _bench(uplink, reps=reps)
        us_ms = _bench(master, reps=reps)
        # VMEM model at the compiled-backend (TPU) plan: the accumulating
        # master's tile is independent of N; the old kernel blocked the
        # full worker axis, so its tile grew linearly with N.
        tpu_plan = tune.default_plan("master", r4, n, "tpu")
        vmem_new = tune.master_vmem_tile_bytes(tpu_plan["block_rows"],
                                               tpu_plan["block_workers"])
        vmem_old = tune.master_vmem_tile_bytes_preaccum(
            tpu_plan["block_rows"], n)
        out.append({
            "params": m,
            "n_workers": n,
            "uplink_stacked_us": us_up,
            "master_us": us_ms,
            "wire_bytes_per_round": n * r4 * 128,   # uint8 uplink payload
            "master_vmem_tile_bytes": vmem_new,     # constant in N
            "master_vmem_tile_bytes_preaccum": vmem_old,  # linear in N
            "mode": "cpu-interpret",
        })
    return out


def _tree_scaling(m: int, n_list: tuple, fanout: int, reps: int) -> list:
    """Cohort-scale sweep of hierarchical fan-in aggregation: a full plain
    round through the tree (packed leaves → fixed-point level partials →
    root sum-and-descale, ``n_levels + 2`` launches) vs the flat two-launch
    round, at each N.

    The tree rides the integer wire, so its result is invariant to tree
    shape — the parity assert against the flat float master is bounded only
    by Eq. (3) weight quantization at ``TREE_PLAIN_FIXPOINT_BITS``. The
    structural claims are asserted on the jaxpr before timing: launch count
    grows with DEPTH (log_fanout N), not N, and zero host syncs.

    Byte columns come from the analytic Eq. (8) models at all three wires
    (plaintext 2-bit, masked-16, masked-32): the link INTO the root carries
    ``w_L <= fanout`` partials instead of the flat master's N-1 uplinks, and
    the root's grid/VMEM tile is O(fanout), not O(N)."""
    rows = m // 128
    r4 = rows // 4
    ts = TreeSpec(fanout=fanout)
    # one timed sweep fills the partial_sum plan for (r4, fanout) — the
    # table is keyed by fanout, not level width, so every level shares it
    tune.autotune_partial_sum(r4, fanout, fanout * fanout, interpret=True,
                              reps=1)
    out = []
    for n in n_list:
        levels = ts.n_levels(n)
        widths = ts.level_widths(n)
        k = jax.random.PRNGKey(100 + n)
        bufs_q = jax.random.normal(k, (n, rows, 128))
        p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, 128))
        p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, 128))
        w = jnp.full((n,), 1.0 / max(n - 1, 1)).at[0].set(0.0)
        if n <= 64:
            # at larger N the cpu-interpret default (one-shot) is already
            # the plan the sweep would pick; skip the expensive timing
            tune.autotune_stacked(r4, n, interpret=True, reps=1)
            tune.autotune_master(r4, n, interpret=True, reps=1)
        wire_flat = rd.WirePath(rd.WireConfig(), interpret=True)
        wire_tree = rd.WirePath(rd.WireConfig(), interpret=True, tree=ts)

        def flat():
            return wire_flat.round_from_stacked(bufs_q, 0, w, p1, p2,
                                                t=3)[0]

        def tree():
            return wire_tree.round_from_stacked(bufs_q, 0, w, p1, p2,
                                                t=3)[0]

        np.testing.assert_allclose(np.asarray(tree()), np.asarray(flat()),
                                   rtol=1e-4, atol=1e-4)
        counts_tree = jaxpr_primitive_counts(tree)
        counts_flat = jaxpr_primitive_counts(flat)
        assert counts_tree.get("pallas_call") == levels + 2, counts_tree
        assert counts_flat.get("pallas_call") == 2, counts_flat
        host_syncs = sum(counts_tree.get(p, 0)
                         for p in HOST_SYNC_PRIMITIVES)
        assert host_syncs == 0, counts_tree

        us_flat = _bench(flat, reps=reps)
        us_tree = _bench(tree, reps=reps)

        mb = m * 4.0                       # float32 model bytes
        w_last = widths[-1]
        tpu_root = tune.default_plan("master", r4, w_last, "tpu")
        tpu_flat = tune.default_plan("master", r4, n, "tpu")
        out.append({
            "params": m,
            "n_workers": n,
            "fanout": fanout,
            "levels": levels,
            "level_widths": widths,
            "flat_round_us": us_flat,
            "tree_round_us": us_tree,
            "launches": {"flat": 2, "tree": levels + 2},
            "host_syncs": 0,
            # the root sums w_L <= fanout partials, not N-1 uplinks — its
            # worker-axis grid and VMEM tile stop growing with cohort size
            "root_fan_in": {"flat": n, "tree": w_last},
            "root_link_reduction": (n - 1) / max(w_last, 1),
            # bytes over the link INTO the root per round (the flat
            # master's ingress bottleneck), masked-16 wire: N-1 word
            # buffers flat vs the last level's w_L partials on the tree
            "flat_root_link16_bytes": (n - 1) * mb * 16 / 32,
            "tree_root_link16_bytes": w_last * mb * 16 / 32,
            "root_vmem_tile_bytes": tune.master_vmem_tile_bytes(
                tpu_root["block_rows"], tpu_root["block_workers"]),
            "flat_master_vmem_tile_bytes": tune.master_vmem_tile_bytes(
                tpu_flat["block_rows"], tpu_flat["block_workers"]),
            "flat_plain_bytes": proto.fedpc_bytes_per_round(mb, n),
            "tree_plain_bytes": proto.fedpc_tree_bytes_per_round(
                mb, n, fanout),
            "flat_masked16_bytes": proto.fedpc_masked_bytes_per_round(
                mb, n, 16),
            "tree_masked16_bytes": proto.fedpc_tree_bytes_per_round(
                mb, n, fanout, word_bits=16),
            "flat_masked32_bytes": proto.fedpc_masked_bytes_per_round(
                mb, n, 32),
            "tree_masked32_bytes": proto.fedpc_tree_bytes_per_round(
                mb, n, fanout, word_bits=32),
            "fedavg_bytes": proto.fedavg_bytes_per_round(mb, n),
            "mode": "cpu-interpret",
        })
    return out


def _masked_wire(m: int, n_workers: int, reps: int) -> list:
    """Secure-aggregation wire overhead at m params x N workers, at BOTH
    wire moduli (2**16 default / 2**32 conservative): the masked uplink
    (ternarize -> RR -> fixed-point weight -> pairwise mask, one modular
    word out per parameter) vs the plaintext 2-bit stacked uplink, and the
    sum-then-unmask master vs the accumulating plaintext master — both at
    their autotuned plans. Mask and RR streams are generated IN-KERNEL
    from per-pair/per-worker counter keys, so no (N, rows, 128) mask
    tensor exists in HBM and no host-side incidence matmul runs per round
    — asserted structurally on the uplink jaxpr before timing. The
    wire-byte price per modulus is recorded so the trade is a number, not
    a vibe: 16-bit words are 8x the 2-bit codes (half the 32-bit path's
    fp32-FedAvg-sized uplinks)."""
    from repro.privacy import (pair_signs, pair_stream_keys,
                               quantize_weights, rr_stream_keys)
    rows = m // 128
    r4 = rows // 4
    k = jax.random.PRNGKey(23)
    bufs_q = jax.random.normal(k, (n_workers, rows, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows, 128))
    w = jnp.full((n_workers,), 1.0 / max(n_workers - 1, 1)).at[0].set(0.0)
    keys = pair_stream_keys(0, n_workers, 3)
    signs = pair_signs(n_workers)
    rrk = rr_stream_keys(1, 3, n_workers)
    tune.autotune_stacked(r4, n_workers, interpret=True, reps=1)
    tune.autotune_master(r4, n_workers, interpret=True, reps=1)

    def uplink_plain():
        return ops.flat_ternary_pack_stacked(
            bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, interpret=True)

    packed = uplink_plain()

    def master_plain():
        return ops.flat_master_update(bufs_q[0], packed, w, p1, p2, t=3,
                                      alpha0=0.01, interpret=True)

    us_up_plain = _bench(uplink_plain, reps=reps)
    us_ms_plain = _bench(master_plain, reps=reps)

    out = []
    for wb in (16, 32):
        fb = 14 if wb == 16 else 24
        wq = quantize_weights(w, fb)
        tune.autotune_masked_uplink(r4, n_workers, interpret=True, reps=1,
                                    word_bits=wb)
        tune.autotune_masked_master(r4, n_workers, interpret=True, reps=1,
                                    word_bits=wb)
        kind = "uplink_masked16" if wb == 16 else "uplink_masked"
        plan = tune.lookup(kind, r4, n_workers, interpret=True)

        def uplink_masked():
            return ops.flat_ternary_pack_masked(
                bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, wq=wq,
                pair_keys=keys, pair_signs=signs, rr_keys=rrk,
                rr_threshold=0, word_bits=wb, interpret=True)

        # structural guarantee before timing: ONE launch whose only
        # unsigned operands are the tiny O(N^2) counter keys — the mask
        # streams never round-trip through HBM and no threefry PRNG runs
        counts = jaxpr_primitive_counts(uplink_masked)
        assert counts.get("pallas_call") == 1, counts
        assert not any("threefry" in p for p in counts), counts
        from repro.utils import iter_jaxpr_eqns
        jaxpr = jax.make_jaxpr(uplink_masked)()
        [eqn] = [e for e in iter_jaxpr_eqns(jaxpr.jaxpr, into_pallas=False)
                 if e.primitive.name == "pallas_call"]
        for v in eqn.invars:
            if np.issubdtype(v.aval.dtype, np.unsignedinteger):
                assert int(np.prod(v.aval.shape)) <= n_workers * n_workers, (
                    v.aval, "mask tensor operand leaked into the uplink")

        y = uplink_masked()

        def master_masked():
            return ops.flat_masked_master_update(
                bufs_q[0], y, jnp.sum(wq), p1, p2, t=3, alpha0=0.01,
                scale_mult=2.0 ** -fb, interpret=True)

        # correctness rides along: masked == plain up to weight
        # quantization (coarser at fb=14, hence the looser 16-bit bound)
        np.testing.assert_allclose(
            np.asarray(master_masked()), np.asarray(master_plain()),
            rtol=1e-5 if wb == 32 else 1e-3,
            atol=1e-5 if wb == 32 else 2e-3)
        us_up_masked = _bench(uplink_masked, reps=reps)
        us_ms_masked = _bench(master_masked, reps=reps)
        out.append({
            "params": m,
            "n_workers": n_workers,
            "modulus_bits": wb,
            "uplink_plain_us": us_up_plain,
            "uplink_masked_us": us_up_masked,
            "masked_uplink_overhead": us_up_masked / us_up_plain,
            "master_plain_us": us_ms_plain,
            "master_masked_us": us_ms_masked,
            "masked_master_overhead": us_ms_masked / us_ms_plain,
            "wire_bytes_plain": n_workers * r4 * 128,           # 2-bit codes
            "wire_bytes_masked": n_workers * r4 * 512 * (wb // 8),
            "plan": {"block_rows": plan[0], "block_workers": plan[1]},
            "launches": {"uplink": 1, "master": 1},
            "mode": "cpu-interpret",
        })
    return out


def _dropout_recovery(m: int, n_list: tuple, reps: int) -> list:
    """Dropout-recovery price at m params x N workers vs dropout rate.

    Times the fused ``mask_repair_2d`` launch that subtracts the dead
    workers' mask residue from the aggregated slab (rate 0 exercises the
    in-kernel zero-coefficient skip — a fault-free round's repair is a
    near-no-op) and records the analytic control-plane wire overhead:
    per-round Shamir dealing (every worker shares its key row with its
    siblings) plus per-death reconstruction traffic — so the robustness
    premium is a number next to the masked-wire numbers it rides on."""
    from repro.core import protocol as proto
    from repro.privacy import masking as pvm
    from repro.privacy import recovery as pvr
    rows = m // 128
    r4 = rows // 4
    thr = 2
    k = jax.random.PRNGKey(31)
    out = []
    for n in n_list:
        keys_mat = pvm.pair_stream_keys(0, n, 3)
        signs = pvm.pair_signs(n)
        i_idx, j_idx = pvr.repair_pair_index(n)
        dealing = proto.recovery_dealing_bytes_per_round(n)
        for rate in (0.0, 1.0 / n, 0.10):
            n_dead = int(round(rate * n))
            alive = np.ones(n)
            alive[:n_dead] = 0.0
            ae, de = pvr.effective_masks(None, jnp.asarray(alive), thr,
                                         None, n)
            for wb in (16, 32):
                kf, cf = pvr.repair_coefficients(keys_mat, signs, ae, de,
                                                 i_idx, j_idx)
                word = jnp.uint16 if wb == 16 else jnp.uint32
                y = jax.random.bits(k, (r4, 512), jnp.uint32).astype(word)
                tune.autotune_mask_repair(r4, len(i_idx), interpret=True,
                                          reps=1, word_bits=wb)

                def repair():
                    return ops.flat_mask_repair(y, kf, cf, interpret=True)

                us = _bench(repair, reps=reps)
                recon = proto.recovery_reconstruction_bytes(
                    n_dead, thr, n_workers=n)
                out.append({
                    "params": m,
                    "n_workers": n,
                    "modulus_bits": wb,
                    "dropout": round(rate, 4),
                    "n_dead": n_dead,
                    "repair_pairs": int(len(i_idx)),
                    "active_pairs": int(np.sum(np.asarray(cf) != 0)),
                    "repair_us": us,
                    "dealing_bytes_per_round": dealing,
                    "reconstruction_bytes": recon,
                    "recovery_bytes_total": dealing + recon,
                    "mode": "cpu-interpret",
                })
    return out


def _scan_rounds_bench(m: int, n_workers: int, rounds: int,
                       reps: int) -> dict:
    """Multi-round FedPC: a Python loop re-dispatching ONE jitted round body
    (local models + round_step — what the real Python driver compiles) vs
    the same body under a single jitted lax.scan (the scan driver). Both
    sides jit identical work, so the delta is pure per-round dispatch +
    host-return overhead.

    The structural win is asserted at jaxpr level before timing: the scan
    program contains exactly TWO pallas_call eqns total (uplink + master,
    amortized over every round by the scan) and ZERO host-sync primitives —
    the Python loop re-dispatches both launches and returns control to the
    host every round.

    NOTE on CPU wall time: since the tuned one-shot wire kernels landed,
    the jitted round body is ~4x faster, which leaves the interpret-mode
    scan's fixed carry overhead (the pallas while_loop buffers threaded
    through the lax.scan carry) as the visible cost — the scan can time
    BELOW 1x here. The claim that matters (one dispatch, zero per-round
    host syncs) is the asserted structure; wall-clock wins are a compiled-
    TPU property.
    """
    rows = m // 128
    wire = rd.WirePath(rd.WireConfig(), interpret=True,
                       block_rows=rows // fl.PACK)
    key = jax.random.PRNGKey(17)
    buf = jax.random.normal(key, (rows, 128))
    state = rd.RoundState(
        buf_p1=buf, buf_p2=0.9 * buf,
        prev_costs=jnp.ones((n_workers,)),
        round=jnp.asarray(3, jnp.int32))
    deltas = 0.01 * jax.random.normal(
        jax.random.fold_in(key, 1), (rounds, n_workers, rows, 128))
    sizes = jnp.linspace(50.0, 200.0, n_workers)

    def worker_fn(wc, gbuf, t):
        d = jnp.take(deltas, t - 3, axis=0)
        costs = 1.0 / (t.astype(jnp.float32)
                       + jnp.arange(n_workers, dtype=jnp.float32) + 1.0)
        return wc, gbuf[None] + d, costs

    def scan_fn(st):
        st, _, infos = rd.scan_rounds(wire, st, worker_fn, 0, rounds, sizes)
        return st, infos["k_star"]

    counts = jaxpr_primitive_counts(scan_fn, state)
    assert counts.get("pallas_call") == 2, counts
    host_syncs = sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES)
    assert host_syncs == 0, counts

    scan_jit = jax.jit(scan_fn)

    def round_body(st):
        _, bufs, costs = worker_fn(0, st.buf_p1, st.round)
        st, _, _ = wire.round_step(st, bufs, costs, sizes)
        return st

    body_jit = jax.jit(round_body)

    def loop():
        st = state
        for _ in range(rounds):
            st = body_jit(st)
        return st.buf_p1

    def scan():
        st, _ = scan_jit(state)
        return st.buf_p1

    np.testing.assert_array_equal(np.asarray(loop()), np.asarray(scan()))
    us_loop = _bench(loop, reps=reps)
    us_scan = _bench(scan, reps=reps)
    return {
        "params": m,
        "n_workers": n_workers,
        "rounds": rounds,
        "loop_us": us_loop,
        "scan_us": us_scan,
        "scan_speedup": us_loop / us_scan,
        "pallas_calls_in_scan_program": counts.get("pallas_call"),
        "host_sync_primitives_in_scan_program": host_syncs,
        "mode": "cpu-interpret",
    }


_SYNC_BENCH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import jax, jax.numpy as jnp
from repro.core import flat as fl
from repro.fed.distributed import build_fed_sync, fed_state_init

m = int(sys.argv[1])
reps = int(sys.argv[2])
mesh = jax.make_mesh((4, 2), ("data", "model"))
F, MOD = 4, 2
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (m,))}
sizes = jnp.linspace(50.0, 200.0, F)
costs = jnp.linspace(0.9, 0.5, F)
params_F = jax.tree_util.tree_map(
    lambda x: jnp.stack([x + 0.05 * (i + 1) for i in range(F)]), params)
state = fed_state_init(params, F)
state["round"] = jnp.asarray(3, jnp.int32)
state["params_prev"] = jax.tree_util.tree_map(lambda x: x + 0.01, params)
state["prev_costs"] = jnp.ones((F,))

out = {"params": m, "fed": F, "model": MOD, "mode": "cpu-interpret"}
with mesh:
    for strat in ("fedpc_packed", "fedpc_reduce"):
        for shard in (True, False):
            layout = fl.layout_of(params, shards=MOD if shard else 1)
            # single interpret tile per device (see kernels_bench NOTE)
            sync = jax.jit(build_fed_sync(
                None, mesh, "data", strat, shard_wire=shard,
                wire_block_rows=layout.shard_rows // fl.PACK))
            new_params, _ = sync(params_F, costs, sizes, state)   # compile
            jax.block_until_ready(new_params)
            t0 = time.time()
            for _ in range(reps):
                new_params, _ = sync(params_F, costs, sizes, state)
                jax.block_until_ready(new_params)
            us = (time.time() - t0) / reps * 1e6
            key = f"{strat}_{'sharded' if shard else 'replicated'}"
            out[key + "_us"] = us
            if strat == "fedpc_packed":
                # uint8 §3.3 payload each device contributes to the fed
                # all_gather per round
                out[key + "_wire_bytes_per_device"] = (
                    layout.packed_shard_rows * fl.LANES)
print("SYNC " + json.dumps(out))
"""


def _sharded_sync(m: int, reps: int) -> dict | None:
    """Sharded vs replicated fed sync on an 8-host-device subprocess mesh
    (4 fed × 2 model): wall time per jitted sync + per-device wire bytes."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    proc = subprocess.run(
        [sys.executable, "-c", _SYNC_BENCH_SCRIPT, str(m), str(reps)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        emit("sync_bench_failed", 0.0, proc.stderr[-200:].replace("\n", " "))
        return None
    line = [l for l in proc.stdout.splitlines() if l.startswith("SYNC ")][-1]
    return json.loads(line[len("SYNC "):])


def run(smoke: bool = False) -> dict:
    # --smoke: tiny sizes for CI — exercises every bench path in seconds
    # and does NOT overwrite BENCH_kernels.json (whose numbers are real).
    # Smoke reps are high (cheap at 16K params) so the best-of-reps
    # estimator stays stable under CI-runner load — the regression gate
    # compares these numbers across runs.
    m0 = (1 << 14) if smoke else M
    q, p1, p2 = _wire_inputs(m0)
    tern = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(0), 3),
                              (N_WORKERS, m0), -1, 2).astype(jnp.int8)
    w = jnp.full((N_WORKERS,), 0.02)

    tag0 = f"{m0 // (1 << 20)}M" if m0 >= (1 << 20) else f"{m0 // 1024}K"
    us = _bench(lambda: ops.ternary_encode(q, p1, p2, 0.2, interpret=True))
    us_ref = _bench(lambda: jax.jit(
        lambda a, b, c: ref.ternary_encode_ref(a, b, c, 0.2))(q, p1, p2))
    emit(f"kernel_ternary_encode_{tag0}", us, f"ref_jnp={us_ref:.0f}us")

    t = ops.ternary_encode(q, p1, p2, 0.2, interpret=True)
    us = _bench(lambda: ops.pack2bit(t, interpret=True))
    us_ref = _bench(jax.jit(ref.pack2bit_ref), t.reshape(-1, 4).reshape(-1))
    emit(f"kernel_pack2bit_{tag0}", us,
         f"ref_jnp={us_ref:.0f}us bytes_out={m0 // 4}")

    us = _bench(lambda: ops.master_update(q, tern, w, p1, p2, interpret=True))
    us_ref = _bench(jax.jit(ref.master_update_ref), q, tern, w, p1, p2)
    emit(f"kernel_master_update_{tag0}_8w", us, f"ref_jnp={us_ref:.0f}us")

    # correctness spot check rides along
    out = ops.master_update(q, tern, w, p1, p2, interpret=True)
    want = ref.master_update_ref(q, tern, w, p1, p2)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernel_master_update_maxerr", 0.0, f"{err:.2e}")

    # ---- fused flat wire path vs the old composition, 1M and 16M --------
    sizes = (((1 << 14), 6),) if smoke else ((1 << 20, 3), (1 << 24, 1))
    results = []
    uplink_results = []
    for m, reps in sizes:
        tag = (f"{m // (1 << 20)}M" if m >= (1 << 20) else f"{m // 1024}K")
        r = _fused_vs_unfused(m, reps)
        results.append(r)
        emit(f"fused_uplink_{tag}", r["uplink_fused_us"],
             f"unfused={r['uplink_unfused_us']:.0f}us "
             f"speedup={r['uplink_speedup']:.2f}x launches=1v2")
        emit(f"fused_master_{tag}_{N_WORKERS}w", r["master_fused_us"],
             f"unfused={r['master_unfused_us']:.0f}us "
             f"speedup={r['master_speedup']:.2f}x")

        # ---- batched N-worker uplink: loop of N launches vs ONE ---------
        b = _batched_uplink(m, N_WORKERS, reps)
        uplink_results.append(b)
        emit(f"batched_uplink_{tag}_{N_WORKERS}w", b["uplink_stacked_us"],
             f"loop={b['uplink_loop_us']:.0f}us "
             f"speedup={b['stacked_speedup']:.2f}x launches=1v{N_WORKERS} "
             f"plan={b['plan']['block_rows']}x{b['plan']['block_workers']}")

    # ---- federation-size sweep: latency + wire bytes + master VMEM ------
    ws_m = (1 << 14) if smoke else (1 << 18)
    ws_n = (4, 8) if smoke else (8, 32, 64)
    ws_tag = (f"{ws_m // (1 << 20)}M" if ws_m >= (1 << 20)
              else f"{ws_m // 1024}K")
    scaling_results = _worker_scaling(ws_m, ws_n, max(r for _, r in sizes))
    for s in scaling_results:
        emit(f"worker_scaling_{ws_tag}_{s['n_workers']}w",
             s["uplink_stacked_us"],
             f"master={s['master_us']:.0f}us "
             f"wire={s['wire_bytes_per_round']}B "
             f"master_vmem_tile={s['master_vmem_tile_bytes']}B "
             f"(preaccum={s['master_vmem_tile_bytes_preaccum']}B)")

    # ---- hierarchical tree aggregation: cohort-scale sweep --------------
    tr_m = (1 << 14) if smoke else (1 << 18)
    tr_n = (4, 8) if smoke else (16, 64, 256)
    tr_fanout = 2 if smoke else 4
    tr_tag = (f"{tr_m // (1 << 20)}M" if tr_m >= (1 << 20)
              else f"{tr_m // 1024}K")
    tree_results = _tree_scaling(tr_m, tr_n, tr_fanout, 1)
    for s in tree_results:
        emit(f"tree_scaling_{tr_tag}_{s['n_workers']}w_f{s['fanout']}",
             s["tree_round_us"],
             f"flat={s['flat_round_us']:.0f}us levels={s['levels']} "
             f"launches={s['launches']['tree']}v2 "
             f"root_fanin={s['root_fan_in']['tree']}v"
             f"{s['root_fan_in']['flat']} "
             f"root_vmem={s['root_vmem_tile_bytes']}B "
             f"m16_wire={s['tree_masked16_bytes']:.3g}B "
             f"(flat {s['flat_masked16_bytes']:.3g}B)")

    # ---- secure-aggregation wire: masked vs plaintext kernels -----------
    mk_m = (1 << 14) if smoke else (1 << 20)
    mk_tag = (f"{mk_m // (1 << 20)}M" if mk_m >= (1 << 20)
              else f"{mk_m // 1024}K")
    masked_results = _masked_wire(mk_m, N_WORKERS, max(r for _, r in sizes))
    for s in masked_results:
        mb = s["modulus_bits"]
        emit(f"masked_uplink_{mk_tag}_{s['n_workers']}w_m{mb}",
             s["uplink_masked_us"],
             f"plain={s['uplink_plain_us']:.0f}us "
             f"overhead={s['masked_uplink_overhead']:.2f}x "
             f"wire={s['wire_bytes_masked']}B "
             f"(plain {s['wire_bytes_plain']}B)")
        emit(f"masked_master_{mk_tag}_{s['n_workers']}w_m{mb}",
             s["master_masked_us"],
             f"plain={s['master_plain_us']:.0f}us "
             f"overhead={s['masked_master_overhead']:.2f}x")

    # ---- dropout recovery: repair latency + control-plane bytes ---------
    dr_m = (1 << 14) if smoke else (1 << 18)
    dr_n = (4, 8) if smoke else (16, 64)
    dr_tag = (f"{dr_m // (1 << 20)}M" if dr_m >= (1 << 20)
              else f"{dr_m // 1024}K")
    recovery_results = _dropout_recovery(dr_m, dr_n, 1 if not smoke else 3)
    for s in recovery_results:
        if s["modulus_bits"] != 16:
            continue                       # one emit per (n, rate) is enough
        emit(f"dropout_recovery_{dr_tag}_{s['n_workers']}w"
             f"_d{s['dropout']}",
             s["repair_us"],
             f"dead={s['n_dead']} pairs={s['active_pairs']}/"
             f"{s['repair_pairs']} dealing={s['dealing_bytes_per_round']:.0f}B "
             f"recon={s['reconstruction_bytes']:.0f}B")

    # ---- multi-round scan driver vs per-round Python loop ---------------
    scan_results = []
    scan_sizes = (((1 << 14), 4, 4),) if smoke else ((1 << 20, 4, 3),)
    for m, n_rounds, reps in scan_sizes:
        tag = (f"{m // (1 << 20)}M" if m >= (1 << 20) else f"{m // 1024}K")
        sc = _scan_rounds_bench(m, 4, n_rounds, reps)
        scan_results.append(sc)
        emit(f"scan_rounds_{tag}_{n_rounds}r", sc["scan_us"],
             f"loop={sc['loop_us']:.0f}us "
             f"speedup={sc['scan_speedup']:.2f}x "
             f"launches_in_program=2 host_syncs=0")

    # ---- sharded vs replicated fed sync (8-device subprocess mesh) ------
    sync_results = []
    for m, reps in sizes:
        tag = (f"{m // (1 << 20)}M" if m >= (1 << 20) else f"{m // 1024}K")
        s = _sharded_sync(m, reps)
        if s is None:
            continue
        sync_results.append(s)
        for strat in ("fedpc_packed", "fedpc_reduce"):
            sh = s[f"{strat}_sharded_us"]
            rp = s[f"{strat}_replicated_us"]
            emit(f"sync_{strat}_{tag}", sh,
                 f"replicated={rp:.0f}us speedup={rp / sh:.2f}x "
                 f"mesh={s['fed']}x{s['model']}")
        emit(f"sync_wire_bytes_{tag}",
             float(s["fedpc_packed_sharded_wire_bytes_per_device"]),
             f"replicated={s['fedpc_packed_replicated_wire_bytes_per_device']}"
             f" ({s['model']}x fewer per device)")

    payload = {"bench": "fedpc_flat_wire_kernels",
               "backend": jax.default_backend(),
               "results": results,
               "batched_uplink": uplink_results,
               "worker_scaling": scaling_results,
               "tree_scaling": tree_results,
               "masked_wire": masked_results,
               "dropout_recovery": recovery_results,
               "scan_rounds": scan_results,
               "sharded_sync": sync_results}
    if smoke:
        # tiny-size smoke numbers land in their own JSON — committed as the
        # CI regression-gate baseline (benchmarks/check_bench_regression.py
        # fails the build on >25% slowdown of any entry) and uploaded as an
        # artifact; BENCH_kernels.json keeps only real-size runs.
        with open(BENCH_SMOKE_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        emit("bench_kernels_smoke_json", 0.0,
             os.path.abspath(BENCH_SMOKE_JSON))
    else:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        emit("bench_kernels_json", 0.0, os.path.abspath(BENCH_JSON))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; writes BENCH_kernels_smoke.json "
                         "(artifact) instead of BENCH_kernels.json")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of the whole bench "
                         "under DIR (kernel launches are named after their "
                         "tuner keys via telemetry.profile.kernel_scope)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="stream every autotune sweep's timed plans to a "
                         "telemetry JSONL trace at PATH (one plan event per "
                         "candidate — BENCH_kernels.json provenance)")
    cli = ap.parse_args()
    from contextlib import ExitStack

    from repro.kernels import tune as _tune
    from repro.telemetry import profile as _tprof
    from repro.telemetry import trace as _tmt
    with ExitStack() as stack:
        if cli.trace:
            writer = stack.enter_context(
                _tmt.TraceWriter(cli.trace, source="kernels_bench"))
            _tune.set_trace_writer(_tmt.plan_emitter(writer.emit))
            stack.callback(_tune.set_trace_writer, None)
        if cli.profile:
            stack.enter_context(_tprof.profile_session(cli.profile))
        run(smoke=cli.smoke)
