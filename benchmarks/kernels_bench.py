"""Kernel micro-bench: latency of the FedPC round ops (interpret mode on
CPU — correctness-weighted; TPU timings come from real hardware) and the
equivalent jnp reference, plus fused-vs-unfused flat wire path timings
emitted to BENCH_kernels.json so the perf trajectory is tracked across PRs.

NOTE on CPU numbers: interpret mode executes one Python step per grid tile,
so wall time measures launch overhead, not HBM traffic — the fused win there
shows up as HALF the grid steps (one kernel instead of two) rather than
bandwidth. The no-int8-intermediate property is asserted structurally in
tests/test_flat_wire.py via jaxpr inspection.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import fused_wire as fw
from repro.kernels import ops, ref
from repro.kernels import pack2bit as pk
from repro.kernels import ternary_encode as te

M = 1 << 20            # 1M params
N_WORKERS = 8
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")


def _bench(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _wire_inputs(m: int, key=0):
    k = jax.random.PRNGKey(key)
    q = jax.random.normal(k, (m,))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (m,))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (m,))
    return q, p1, p2


def _fused_vs_unfused(m: int, reps: int) -> dict:
    """Flat wire path at m params: old two-kernel uplink vs ternary_pack,
    old loop-and-stack master vs packed_master_update."""
    q, p1, p2 = _wire_inputs(m)
    rows = m // 128
    r4 = rows // 4
    # Single-tile launches: in interpret mode each grid step is a Python
    # invocation, so per-step overhead swamps the memory-traffic signal at
    # realistic (VMEM-sized) tiles. One tile per launch is the closest CPU
    # analogue of compiled behaviour; TPU runs use the VMEM-sized defaults.
    br = rows
    br4 = r4
    q2, p12, p22 = (x.reshape(rows, 128) for x in (q, p1, p2))
    q4, p14, p24 = (x.reshape(r4, 512) for x in (q, p1, p2))

    def unfused():
        codes = te.ternary_encode_2d(q2, p12, p22, 0.2, interpret=True,
                                     block_rows=br)
        return pk.pack2bit_2d(codes.reshape(r4, 512), interpret=True,
                              block_rows=br4)

    def fused():
        return fw.ternary_pack_2d(q4, p14, p24, 0.2, interpret=True,
                                  block_rows=br4)

    np.testing.assert_array_equal(np.asarray(unfused()), np.asarray(fused()))
    up_unfused = _bench(unfused, reps=reps)
    up_fused = _bench(fused, reps=reps)

    # master side: N workers' wire buffers
    tern = jax.random.randint(jax.random.PRNGKey(9), (N_WORKERS, m),
                              -1, 2).astype(jnp.int8)
    w = jnp.full((N_WORKERS,), 0.02)
    packed = jnp.stack([ops.pack2bit(tern[k], interpret=True)
                        for k in range(N_WORKERS)]).reshape(
                            N_WORKERS, r4, 128)

    def master_unfused():
        # the old path: python loop of _to_2d per worker + stack + int8
        # promotion inside master_update_2d
        return ops.master_update(q, tern, w, p1, p2, interpret=True)

    def master_fused():
        return ops.flat_master_update(q2, packed, w, p12, p22, t=3,
                                      alpha0=0.01, interpret=True,
                                      block_rows=br4)

    got = np.asarray(master_fused()).reshape(-1)
    want = np.asarray(master_unfused())
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    ms_unfused = _bench(master_unfused, reps=reps)
    ms_fused = _bench(master_fused, reps=reps)

    return {
        "params": m,
        "uplink_unfused_us": up_unfused,
        "uplink_fused_us": up_fused,
        "uplink_speedup": up_unfused / up_fused,
        "uplink_launches": {"unfused": 2, "fused": 1},
        "master_unfused_us": ms_unfused,
        "master_fused_us": ms_fused,
        "master_speedup": ms_unfused / ms_fused,
        "n_workers": N_WORKERS,
        "mode": "cpu-interpret",
    }


def run() -> dict:
    q, p1, p2 = _wire_inputs(M)
    tern = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(0), 3),
                              (N_WORKERS, M), -1, 2).astype(jnp.int8)
    w = jnp.full((N_WORKERS,), 0.02)

    us = _bench(lambda: ops.ternary_encode(q, p1, p2, 0.2, interpret=True))
    us_ref = _bench(lambda: jax.jit(
        lambda a, b, c: ref.ternary_encode_ref(a, b, c, 0.2))(q, p1, p2))
    emit("kernel_ternary_encode_1M", us, f"ref_jnp={us_ref:.0f}us")

    t = ops.ternary_encode(q, p1, p2, 0.2, interpret=True)
    us = _bench(lambda: ops.pack2bit(t, interpret=True))
    us_ref = _bench(jax.jit(ref.pack2bit_ref), t.reshape(-1, 4).reshape(-1))
    emit("kernel_pack2bit_1M", us,
         f"ref_jnp={us_ref:.0f}us bytes_out={M // 4}")

    us = _bench(lambda: ops.master_update(q, tern, w, p1, p2, interpret=True))
    us_ref = _bench(jax.jit(ref.master_update_ref), q, tern, w, p1, p2)
    emit("kernel_master_update_1M_8w", us, f"ref_jnp={us_ref:.0f}us")

    # correctness spot check rides along
    out = ops.master_update(q, tern, w, p1, p2, interpret=True)
    want = ref.master_update_ref(q, tern, w, p1, p2)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernel_master_update_maxerr", 0.0, f"{err:.2e}")

    # ---- fused flat wire path vs the old composition, 1M and 16M --------
    results = []
    for m, reps in ((1 << 20, 3), (1 << 24, 1)):
        r = _fused_vs_unfused(m, reps)
        results.append(r)
        tag = f"{m // (1 << 20)}M"
        emit(f"fused_uplink_{tag}", r["uplink_fused_us"],
             f"unfused={r['uplink_unfused_us']:.0f}us "
             f"speedup={r['uplink_speedup']:.2f}x launches=1v2")
        emit(f"fused_master_{tag}_{N_WORKERS}w", r["master_fused_us"],
             f"unfused={r['master_unfused_us']:.0f}us "
             f"speedup={r['master_speedup']:.2f}x")

    payload = {"bench": "fedpc_flat_wire_kernels",
               "backend": jax.default_backend(),
               "results": results}
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    emit("bench_kernels_json", 0.0, os.path.abspath(BENCH_JSON))
    return payload


if __name__ == "__main__":
    run()
