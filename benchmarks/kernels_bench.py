"""Kernel micro-bench: latency of the FedPC round ops (interpret mode on
CPU — correctness-weighted; TPU timings come from real hardware) and the
equivalent jnp reference, plus per-parameter byte costs."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref

M = 1 << 20            # 1M params
N_WORKERS = 8


def _bench(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> dict:
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (M,))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (M,))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (M,))
    tern = jax.random.randint(jax.random.fold_in(k, 3),
                              (N_WORKERS, M), -1, 2).astype(jnp.int8)
    w = jnp.full((N_WORKERS,), 0.02)

    us = _bench(lambda: ops.ternary_encode(q, p1, p2, 0.2, interpret=True))
    us_ref = _bench(lambda: jax.jit(
        lambda a, b, c: ref.ternary_encode_ref(a, b, c, 0.2))(q, p1, p2))
    emit("kernel_ternary_encode_1M", us, f"ref_jnp={us_ref:.0f}us")

    t = ops.ternary_encode(q, p1, p2, 0.2, interpret=True)
    us = _bench(lambda: ops.pack2bit(t, interpret=True))
    us_ref = _bench(jax.jit(ref.pack2bit_ref), t.reshape(-1, 4).reshape(-1))
    emit("kernel_pack2bit_1M", us,
         f"ref_jnp={us_ref:.0f}us bytes_out={M // 4}")

    us = _bench(lambda: ops.master_update(q, tern, w, p1, p2, interpret=True))
    us_ref = _bench(jax.jit(ref.master_update_ref), q, tern, w, p1, p2)
    emit("kernel_master_update_1M_8w", us, f"ref_jnp={us_ref:.0f}us")

    # correctness spot check rides along
    out = ops.master_update(q, tern, w, p1, p2, interpret=True)
    want = ref.master_update_ref(q, tern, w, p1, p2)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernel_master_update_maxerr", 0.0, f"{err:.2e}")
    return {}


if __name__ == "__main__":
    run()
