"""Shared benchmark scaffolding: simulator setup + CSV emission."""
from __future__ import annotations

import time

import jax

from repro.data.pipeline import BatchIterator, federated_loaders
from repro.data.synthetic import (SyntheticClassification, dirichlet_split,
                                  random_share_split)
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, \
    mlp_loss_and_grad


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def make_task(n_samples=2400, n_features=24, n_classes=8, seed=0):
    t = SyntheticClassification(n_samples=n_samples, n_features=n_features,
                                n_classes=n_classes, seed=seed)
    x, y = t.generate()
    n_tr = int(0.8 * n_samples)
    return (x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:])


def make_sim(task, n_workers, seed=0, dirichlet=None):
    xtr, ytr, xte, yte = task
    if dirichlet is None:
        splits = random_share_split(ytr, n_workers, seed=seed)
    else:
        splits = dirichlet_split(ytr, n_workers, alpha=dirichlet, seed=seed)
    loaders = federated_loaders((xtr, ytr), splits, seed=seed,
                                batch_menu=(64, 32))
    cfgs = make_worker_configs(n_workers, [len(s) for s in splits],
                               seed=seed, batch_menu=(64, 32))
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad)
               for k in range(n_workers)]
    params = init_mlp_classifier(jax.random.PRNGKey(0),
                                 xtr.shape[1], int(ytr.max()) + 1,
                                 hidden=(48, 48))
    sim = FedSimulator(workers, params,
                       eval_fn=lambda p: mlp_accuracy(p, xte, yte))
    return sim, params


def central_worker(task, seed=0):
    xtr, ytr, _, _ = task
    cfgs = make_worker_configs(1, [len(ytr)], seed=seed, batch_menu=(64,))
    return Worker(cfg=cfgs[0], loader=BatchIterator((xtr, ytr), 64, seed=seed),
                  loss_and_grad=mlp_loss_and_grad)
