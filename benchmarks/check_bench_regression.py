"""Bench-smoke regression gate.

Compares a freshly produced ``BENCH_kernels_smoke.json`` against the
committed baseline and fails (exit 1) when any kernel timing entry got more
than ``--threshold`` slower. Used by CI: the baseline is the file as
committed on the branch, the candidate is what ``kernels_bench --smoke``
just wrote on the runner.

Three comparison classes, all keyed by JSON path:

* ``*_speedup`` ratios (fused-vs-unfused, stacked-vs-loop, ...). Both
  sides of a speedup are measured in the SAME bench run on the SAME
  machine, so the ratio survives the committed-baseline-vs-CI-runner
  hardware gap — but only when the thing being timed is big enough to
  time: a speedup is gated only if its record's slowest ``_us`` sibling
  clears the noise floor (sub-millisecond smoke timings swing 2-4x
  run-to-run, measured, so their ratios are noise too). At today's smoke
  sizes this arms for nothing; grow the smoke sizes (or gate a real-size
  run) and the same script gets real teeth with no changes.
* absolute ``*_us`` entries — the gross-blowup guard, clamped to the
  noise floor before the ratio. Nothing a healthy smoke run produces
  clears the floor, so ordinary jitter (or a slower CI host) can never
  trip it; an interpret-mode structural regression of the class this
  repo has actually had (the 0.20x worker-major stacked uplink — ~23ms
  at smoke sizes) lands past floor×threshold and fails.
* ``*_bytes`` entries — deterministic wire/VMEM accounting models (Eq. (8)
  flat/tree/FedAvg bytes per round, master tile footprints). These carry
  no measurement noise, so they are compared exactly with no floor: a
  >threshold growth means the byte accounting itself regressed.

Entries new in the candidate pass (no baseline to regress from); entries
that disappeared fail (a silently dropped bench is as bad as a slow one —
this exact-match axis is the gate's always-on value). The
``sharded_sync`` section is excluded by default: it times an 8-process
host-device mesh whose wall clock is scheduler-bound (observed 4x+
run-to-run on a loaded box), not a kernel property.

Usage:
    python -m benchmarks.check_bench_regression BASELINE CANDIDATE \
        [--threshold 1.25] [--floor-us 20000] [--exclude sharded_sync]
"""
from __future__ import annotations

import argparse
import json
import sys


def iter_entries(node, path=""):
    """Yield (json_path, value, record) for every numeric ``*_us``,
    ``*_speedup`` or ``*_bytes`` leaf; ``record`` is the enclosing dict, so
    a speedup can be weighed by the size of its sibling timings. Byte
    entries are deterministic wire/VMEM models, so any growth past the
    threshold is a real accounting regression, never noise."""
    if isinstance(node, dict):
        for key, val in node.items():
            sub = f"{path}.{key}" if path else key
            if (isinstance(val, (int, float))
                    and (key.endswith("_us") or key.endswith("_speedup")
                         or key.endswith("_bytes"))):
                yield sub, float(val), node
            else:
                yield from iter_entries(val, sub)
    elif isinstance(node, list):
        for item in node:
            # Lists of bench records: key rows by their identifying fields
            # so reordering does not misalign the comparison.
            if isinstance(item, dict):
                tag = "/".join(
                    str(item[k]) for k in ("params", "n_workers",
                                           "modulus_bits", "rounds",
                                           "fed", "model", "fanout",
                                           "dropout")
                    if k in item)
                yield from iter_entries(item, f"{path}[{tag}]")
            else:
                yield from iter_entries(item, path)


def _record_scale_us(record: dict) -> float:
    """The slowest timing in a record — how 'big' its measurements are."""
    vals = [v for k, v in record.items()
            if k.endswith("_us") and isinstance(v, (int, float))]
    return max(vals, default=0.0)


def compare(baseline: dict, candidate: dict, threshold: float,
            floor_us: float, exclude: tuple = ()) -> list[str]:
    def keep(key):
        return not any(key.startswith(p) for p in exclude)
    base = {k: (v, rec) for k, v, rec in iter_entries(baseline) if keep(k)}
    cand = {k: (v, rec) for k, v, rec in iter_entries(candidate) if keep(k)}
    failures = []
    for key, (base_v, base_rec) in sorted(base.items()):
        if key not in cand:
            failures.append(f"MISSING  {key} (baseline {base_v:.0f})")
            continue
        cand_v, cand_rec = cand[key]
        if key.endswith("_speedup"):
            # Same-run ratio — machine-independent, but only meaningful
            # when the record's slow side clears the noise floor in BOTH
            # runs (sub-floor timings swing 2-4x, so do their ratios).
            armed = (min(_record_scale_us(base_rec),
                         _record_scale_us(cand_rec)) >= floor_us)
            bad = armed and cand_v < base_v / threshold
            note = "" if armed else " (below noise floor, not gated)"
            print(f"{'SLOWDOWN' if bad else 'ok':9s}{key}: "
                  f"{base_v:.2f}x -> {cand_v:.2f}x{note}")
            if bad:
                failures.append(
                    f"SLOWDOWN {key}: {base_v:.2f}x -> {cand_v:.2f}x "
                    f"(lost >{threshold:.2f}x ground vs same-run "
                    f"counterpart)")
        else:
            if key.endswith("_bytes"):
                # deterministic wire/VMEM byte models: no noise floor —
                # compare exactly
                unit = "B"
                ratio = (cand_v / base_v if base_v
                         else (1.0 if cand_v == 0 else float("inf")))
            else:
                unit = "us"
                ratio = max(cand_v, floor_us) / max(base_v, floor_us)
            bad = ratio > threshold
            print(f"{'SLOWDOWN' if bad else 'ok':9s}{key}: "
                  f"{base_v:.0f}{unit} -> {cand_v:.0f}{unit} "
                  f"({ratio:.2f}x)")
            if bad:
                failures.append(f"SLOWDOWN {key}: {base_v:.0f}{unit} -> "
                                f"{cand_v:.0f}{unit} ({ratio:.2f}x)")
    for key in sorted(set(cand) - set(base)):
        print(f"new      {key}: {cand[key][0]:.2f} (no baseline)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_kernels_smoke.json")
    ap.add_argument("candidate", help="freshly produced smoke JSON")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="max allowed candidate/baseline ratio (1.25 = "
                         "fail on >25%% slowdown)")
    ap.add_argument("--floor-us", type=float, default=20000.0,
                    help="noise floor: absolute entries are clamped up to "
                         "this before the ratio, and speedups only gate "
                         "when their record's slow side clears it — "
                         "sub-floor timings (and their ratios) never trip "
                         "the gate")
    ap.add_argument("--exclude", nargs="*", default=["sharded_sync"],
                    help="JSON-path prefixes to skip (default: the "
                         "scheduler-bound multi-process sync bench)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    failures = compare(baseline, candidate, args.threshold, args.floor_us,
                       tuple(args.exclude))
    if failures:
        print(f"\nFAIL: {len(failures)} kernel entr"
              f"{'y' if len(failures) == 1 else 'ies'} regressed "
              f">{(args.threshold - 1) * 100:.0f}%:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"\nOK: no kernel entry regressed >{(args.threshold - 1) * 100:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
