"""Ablations beyond the paper's tables:

* β (significance threshold of Eq. 5 / update scale of Eq. 3) — the paper
  fixes β=0.2 with a one-line justification; we sweep it.
* DP-noise defence (§4.2 discussion, option 1): privacy-utility trade-off
  when the pilot adds Gaussian noise to its upload.
"""
from __future__ import annotations


from benchmarks.common import emit, make_sim, make_task, timed
from repro.core.fedpc import FedPCConfig

ROUNDS = 10


def run() -> dict:
    task = make_task(seed=11)
    results = {}

    # --- beta sweep ------------------------------------------------------
    for beta in (0.05, 0.2, 0.5, 0.9):
        sim, _ = make_sim(task, 5, seed=11)
        sim.fed_cfg = FedPCConfig(n_workers=5, beta=beta)
        res, us = timed(lambda: sim.run_fedpc(ROUNDS, eval_every=ROUNDS))
        acc = res.eval_history[-1][1]
        results[("beta", beta)] = acc
        emit(f"ablate_beta_{beta}", us,
             f"acc={acc:.4f} final_cost={res.costs[-1]:.4f}")

    # --- DP noise on the pilot upload (worker defence 1) -------------------
    import jax
    from repro.core.privacy import dp_noise_tree

    for sigma in (0.0, 0.01, 0.05, 0.2):
        sim, _ = make_sim(task, 5, seed=12)

        # wrap each worker's train_round to noise its (potential) upload
        for k, w in enumerate(sim.workers):
            orig = w.train_round

            def noisy(params, _orig=orig, _k=k, _s=sigma):
                q, c = _orig(params)
                if _s > 0:
                    q = dp_noise_tree(q, jax.random.PRNGKey(_k + 1), _s)
                return q, c
            w.train_round = noisy

        res, us = timed(lambda: sim.run_fedpc(ROUNDS, eval_every=ROUNDS))
        acc = res.eval_history[-1][1]
        results[("dp", sigma)] = acc
        emit(f"ablate_dp_sigma_{sigma}", us, f"acc={acc:.4f}")
    return results


if __name__ == "__main__":
    run()
