"""Roofline report from the dry-run JSON (launch/dryrun.py output).

Prints, per (arch × shape × mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS, and the useful-compute ratio — the §Roofline table
of EXPERIMENTS.md is generated from this."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def load(path: str | None = None) -> list:
    path = path or RESULTS
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def fmt_row(r: dict) -> str:
    rl = r["roofline"]
    return (f"compute={rl['compute_s']:.3f}s memory={rl['memory_s']:.3f}s "
            f"collective={rl['collective_s']:.3f}s dom={rl['dominant']} "
            f"useful={rl['useful_ratio']:.2f}")


def run() -> dict:
    records = load()
    ok = [r for r in records if r["status"] == "ok"]
    fails = [r for r in records if r["status"] == "fail"]
    skips = [r for r in records if r["status"] == "skipped"]
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             r.get("compile_s", 0) * 1e6, fmt_row(r))
    emit("roofline_summary", 0.0,
         f"{len(ok)} ok / {len(fails)} fail / {len(skips)} skipped")
    if not records:
        emit("roofline_summary", 0.0,
             "no dryrun.json — run: PYTHONPATH=src python -m "
             "repro.launch.dryrun --all")
    return {"ok": len(ok), "fail": len(fails), "skip": len(skips)}


def table_markdown(mesh: str = "16x16") -> str:
    """EXPERIMENTS.md §Roofline table."""
    rows = [r for r in load() if r["mesh"] == mesh]
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | |")
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"{rl['dominant']} | {rl['model_flops_total']:.2e} | "
            f"{rl['useful_ratio']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    run()
