"""Table 4 analog: non-IID (Dirichlet) splits — FedPC vs baselines."""
from __future__ import annotations

from benchmarks.common import emit, make_sim, make_task, timed

ROUNDS = 12
ALPHA = 0.5


def run() -> dict:
    task = make_task(seed=4)
    results = {}
    for n in (3, 5, 10):
        row = {}
        for algo in ("fedpc", "fedavg", "phong"):
            sim, _ = make_sim(task, n, seed=100 + n, dirichlet=ALPHA)
            runner = getattr(sim, f"run_{algo}")
            res, us = timed(lambda r=runner: r(ROUNDS, eval_every=ROUNDS))
            acc = res.eval_history[-1][1]
            row[algo] = acc
            emit(f"table4_noniid_{algo}_N{n}_acc", us, f"{acc:.4f}")
        results[n] = row
        # Table 4 trade-off: privacy-first FedPC may trail FedAvg on
        # very skewed splits — report the gap explicitly.
        emit(f"table4_gap_N{n}", 0.0,
             f"fedavg-fedpc={row['fedavg'] - row['fedpc']:+.4f}")
    return results


if __name__ == "__main__":
    run()
