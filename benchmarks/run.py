"""Benchmark harness — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines:
  accuracy.py     → Table 1 (centralized) + Tables 2/3 (FedPC/FedAvg/Phong)
  noniid.py       → Table 4 (Dirichlet non-IID)
  convergence.py  → Fig. 4 (cost evolution)
  comm.py         → Fig. 6 / Eq. (8) (bytes per epoch + headline reductions)
  kernels_bench.py→ FedPC round-op kernels vs jnp reference
  roofline.py     → §Roofline rows from the dry-run JSON
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (ablation, accuracy, comm, convergence,
                            kernels_bench, noniid, roofline)
    modules = [
        ("comm", comm),
        ("convergence", convergence),
        ("accuracy", accuracy),
        ("noniid", noniid),
        ("ablation", ablation),
        ("kernels", kernels_bench),
        ("roofline", roofline),
    ]
    failures = 0
    t0 = time.time()
    for name, mod in modules:
        print(f"# --- {name} ---")
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0.0,{traceback.format_exc(limit=3)!r}")
    print(f"# done in {time.time() - t0:.1f}s, {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
