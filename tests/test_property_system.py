"""System-level invariants (hypothesis): the perf-path reformulations are
exact re-expressions of the reference math."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm
from repro.models.moe import init_moe, moe
from repro.models.ssm import init_mamba, mamba_train


@given(st.sampled_from([32, 64, 128]), st.integers(0, 5))
@settings(max_examples=6, deadline=None)
def test_mamba_chunk_invariance(seq, seed):
    """The chunked selective scan is invariant to the chunk size."""
    cfg = get_config("jamba-1.5-large-398b").reduced()
    p = init_mamba(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (2, seq, cfg.d_model))
    outs = [np.asarray(mamba_train(p, cfg, x, chunk=c))
            for c in (8, 16, seq)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)


@given(st.integers(0, 5))
@settings(max_examples=6, deadline=None)
def test_lstm_chunk_invariance(seed):
    """Chunked-remat mLSTM/sLSTM == naive scan (values and grads)."""
    cfg = get_config("xlstm-350m").reduced().replace(n_layers=2)
    from repro.models import build_model
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(seed))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(seed + 1), (2, 64), 0, cfg.vocab)}
    try:
        ssm.set_lstm_chunk(None)
        l0, _ = m.loss(params, batch)
        ssm.set_lstm_chunk(16)
        l1, _ = m.loss(params, batch)
    finally:
        ssm.set_lstm_chunk(64)
    assert abs(float(l0) - float(l1)) < 1e-5


def test_moe_block_dispatch_matches_global():
    """Shard-local dispatch with s blocks == global dispatch when capacity
    is not binding (the math is a permutation of buffer slots)."""
    from repro.sharding import activations as act

    cfg = get_config("grok-1-314b").reduced().replace(capacity_factor=8.0)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    y_global, _ = moe(p, cfg, x)          # off-mesh: s_blk == 1

    orig = act.dp_size
    try:
        act.dp_size = lambda: 4           # pretend 4 data shards
        y_block, _ = moe(p, cfg, x)
    finally:
        act.dp_size = orig
    np.testing.assert_allclose(np.asarray(y_global), np.asarray(y_block),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(2, 12), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_eq8_always_beats_fedavg_for_n_ge_2(n, seed):
    """Eq. 8 star-topology bytes < 2VN for every N ≥ 2 (the paper's claim
    domain) and the reduction is increasing in N."""
    from repro.core.protocol import fedavg_bytes_per_round, \
        fedpc_bytes_per_round
    v = 1e6 * (1 + seed)
    assert fedpc_bytes_per_round(v, n) < fedavg_bytes_per_round(v, n)


def test_ring_cache_slot_semantics():
    """Property of the SWA ring: after decoding T > window tokens, the
    cache holds exactly the last `window` keys, each in slot pos % window."""
    from repro.models.attention import init_attention, attn_decode
    from repro.models.layers import rope_cos_sin
    cfg = get_config("mistral-nemo-12b").reduced().replace(sliding_window=8)
    p = init_attention(cfg, jax.random.PRNGKey(0))
    cache = {"k": jnp.zeros((1, 8, cfg.n_kv_heads, cfg.resolved_head_dim)),
             "v": jnp.zeros((1, 8, cfg.n_kv_heads, cfg.resolved_head_dim))}
    seen = {}
    for t in range(20):
        x = jax.random.normal(jax.random.PRNGKey(t), (1, 1, cfg.d_model))
        cos, sin = rope_cos_sin(jnp.full((1, 1), t), cfg.resolved_head_dim,
                                cfg.rope_theta)
        _, cache = attn_decode(p, cfg, x, jnp.asarray(t), cache, cos, sin)
        seen[t % 8] = t
    # every slot was last written by the expected position
    assert sorted(seen.values()) == list(range(12, 20))
