"""Numerical parity: train-mode forward vs parallel prefill vs sequential
decode — the serving path must produce the training distribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

# one representative per mixer family
ARCHS = ["qwen3-14b", "jamba-1.5-large-398b", "xlstm-350m",
         "deepseek-moe-16b", "whisper-medium", "qwen2-vl-7b"]
B, S = 2, 16


def _batch(cfg):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.is_encdec:
        batch["audio_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model))
    # NOTE: no vision_embed here — the sequential-prefill oracle embeds
    # token-by-token and cannot inject patch embeddings; the vision path is
    # covered by test_models_smoke (parallel prefill + decode).
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_parallel_vs_sequential(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)  # no drops → exact parity
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    st0 = m.init_decode_state(B, 2 * S)
    lg_p, st_p = m.prefill(params, batch, st0)
    lg_s, st_s = m.prefill_sequential(params, batch, st0)
    np.testing.assert_allclose(
        np.asarray(lg_p, np.float32), np.asarray(lg_s, np.float32),
        rtol=2e-4, atol=2e-4)

    sb = {"token": jnp.zeros((B, 1), jnp.int32),
          "pos": jnp.asarray(S, jnp.int32)}
    if cfg.mrope:
        sb["positions"] = jnp.full((3, B, 1), S, jnp.int32)
    d_p, _ = m.decode_step(params, st_p, sb)
    d_s, _ = m.decode_step(params, st_s, sb)
    np.testing.assert_allclose(
        np.asarray(d_p, np.float32), np.asarray(d_s, np.float32),
        rtol=2e-4, atol=2e-4)


def test_swa_ring_cache_matches_window_attention():
    """Sliding-window decode with a ring cache == full attention restricted
    to the window."""
    cfg = get_config("mistral-nemo-12b").reduced()   # window 64
    cfg = cfg.replace(sliding_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    total = 24                                       # > window → wraps
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, total), 0, cfg.vocab)

    # sequential decode through the ring cache
    state = m.init_decode_state(1, cfg.sliding_window)
    outs = []
    for t in range(total):
        sb = {"token": toks[:, t:t + 1], "pos": jnp.asarray(t, jnp.int32)}
        lg, state = m.decode_step(params, state, sb)
        outs.append(lg)
    ring_last = np.asarray(outs[-1], np.float32)

    # oracle: full prefill with the window mask
    st0 = m.init_decode_state(1, total)
    lg_full, _ = m.prefill(params, {"tokens": toks}, st0)
    np.testing.assert_allclose(ring_last, np.asarray(lg_full, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_blocked_attention_matches_full():
    """Flash-style blocked attention (attention.ATTN_BLOCK) is exact vs the
    materialized-score path, causal and sliding-window."""
    import jax
    from repro.models import attention as A
    from repro.models.layers import rope_cos_sin

    cfg = get_config("qwen3-14b").reduced()
    p = A.init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model))
    cos, sin = rope_cos_sin(jnp.arange(256)[None], cfg.resolved_head_dim,
                            cfg.rope_theta)
    try:
        for window in (None, 64):
            cfgw = cfg.replace(sliding_window=window)
            A.set_attn_block(None)
            y_full = A.attn_train(p, cfgw, x, cos, sin)
            A.set_attn_block(32)
            y_blk = A.attn_train(p, cfgw, x, cos, sin)
            np.testing.assert_allclose(
                np.asarray(y_full, np.float32), np.asarray(y_blk, np.float32),
                rtol=1e-4, atol=1e-5)
    finally:
        A.set_attn_block(None)
