"""2-bit wire format: roundtrip + size properties (§3.3, Eq. 8)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.packing import (PACK_FACTOR, pack2bit, pack_tree,
                                packed_size, unpack2bit, unpack_tree)


@given(st.lists(st.integers(-1, 1), min_size=1, max_size=300))
@settings(max_examples=100, deadline=None)
def test_roundtrip(codes):
    t = jnp.asarray(codes, jnp.int8)
    packed = pack2bit(t)
    assert packed.dtype == jnp.uint8
    assert packed.shape[0] == packed_size(len(codes))
    out = unpack2bit(packed, len(codes))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(t))


def test_compression_ratio():
    """4 codes per byte → 16× less than fp32 (the Eq. 8 constant)."""
    n = 4096
    assert packed_size(n) == n // PACK_FACTOR
    assert (n * 4) / packed_size(n) == 16.0


def test_tree_roundtrip():
    tree = {
        "w": jnp.asarray(np.random.default_rng(0).integers(-1, 2, (17, 5)),
                         jnp.int8),
        "b": jnp.asarray([1, -1, 0], jnp.int8),
    }
    packed, layout = pack_tree(tree)
    out = unpack_tree(packed, layout)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_is_opaque_without_layout():
    """Sanity for the privacy argument: the packed buffer alone has no
    structure information (only byte count)."""
    t = jnp.asarray([1, 0, -1, 1, 0, 0, 1, -1], jnp.int8)
    packed = pack2bit(t)
    assert packed.ndim == 1
    assert packed.size * PACK_FACTOR >= t.size
