"""§4.2 information-flow discipline and worker defences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.privacy import (LeakageError, LeakageLedger, dp_noise_tree,
                                gradient_inversion_hardness, should_evade)


def test_ledger_blocks_non_pilot_weight_upload():
    led = LeakageLedger()
    led.record(0, 1, "cost", False)
    led.record(0, 1, "pilot_params", True)
    with pytest.raises(LeakageError):
        led.record(1, 1, "pilot_params", False)
    with pytest.raises(LeakageError):
        led.record(1, 1, "raw_gradients", False)


def test_pilot_streak_detection():
    led = LeakageLedger()
    for t in (1, 2, 3, 5):
        led.record(0, t, "pilot_params", True)
    assert led.consecutive_pilot_streak(0) == 3
    assert should_evade(3, max_streak=3)
    assert not should_evade(2, max_streak=3)


def test_dp_noise_preserves_structure():
    params = {"w": jnp.ones((8, 4)), "b": jnp.zeros(4)}
    noisy = dp_noise_tree(params, jax.random.PRNGKey(0), sigma=0.1)
    assert jax.tree_util.tree_structure(noisy) == \
        jax.tree_util.tree_structure(params)
    assert not np.allclose(np.asarray(noisy["w"]), 1.0)
    # zero sigma = identity
    clean = dp_noise_tree(params, jax.random.PRNGKey(0), sigma=0.0)
    np.testing.assert_array_equal(np.asarray(clean["w"]), 1.0)


def test_inversion_underdetermined():
    """Thm 2: unknowns (n gradients + private lr) exceed the one equation
    per observed epoch pair."""
    h = gradient_inversion_hardness(n_batches=10, known_lr=False)
    assert h["underdetermined"]


def test_simulator_ledger_integration():
    """The simulator must never register a non-pilot weight upload."""
    from repro.data.pipeline import BatchIterator
    from repro.fed.simulator import FedSimulator
    from repro.fed.worker import Worker, make_worker_configs
    from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad

    rng = np.random.default_rng(0)
    x = rng.normal(size=(60, 8)).astype(np.float32)
    y = rng.integers(0, 3, 60).astype(np.int32)
    splits = [np.arange(0, 20), np.arange(20, 40), np.arange(40, 60)]
    cfgs = make_worker_configs(3, [20, 20, 20], seed=1, batch_menu=(10,))
    workers = [
        Worker(cfg=cfgs[k],
               loader=BatchIterator((x[s], y[s]), 10, seed=k),
               loss_and_grad=mlp_loss_and_grad)
        for k, s in enumerate(splits)
    ]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 8, 3, hidden=(16,))
    sim = FedSimulator(workers, params)
    sim.run_fedpc(rounds=4)
    kinds = {k for (_, _, k, _) in sim.ledger.events}
    assert kinds <= {"cost", "pilot_params", "packed_ternary"}
    # exactly one pilot upload per round
    pilots = [r for (r, w, k, p) in sim.ledger.events if k == "pilot_params"]
    assert sorted(pilots) == [1, 2, 3, 4]
