import os
import sys

# Tests run on the single host CPU device (the dry-run sets its own device
# count in a separate process — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
