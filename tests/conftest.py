import os
import sys

# Tests run on the single host CPU device (the dry-run sets its own device
# count in a separate process — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# `hypothesis` is a declared test dependency (pyproject.toml), but hermetic
# containers without network can't install it; fall back to the deterministic
# shim so the property tests still run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    _hypothesis_fallback.install()
