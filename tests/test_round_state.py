"""The pure round core (repro.fed.rounds): RoundState/round_step/scan_rounds.

Covers the device-resident refactor's contract:
  * round_step with an all-ones mask + uniform beta_k is BITWISE equal to
    the plain full-participation path (property test over rounds/sizes);
  * scan-driven rounds equal a Python-loop driver bitwise over >= 5 rounds;
  * the scanned program is exactly 2 pallas launches (uplink + master) and
    contains zero host-sync primitives — pilot selection stays traced;
  * partial participation: pilot always sampled, masked workers contribute
    zero weight and keep their previous cost;
  * per-worker beta_k matches the pytree oracle end-to-end;
  * RoundState round-trips through repro.checkpoint bitwise (mid-federation
    resume).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import flat as fl
from repro.core.ternary import ternarize_tree, ternarize_tree_round1
from repro.core.update import master_update_tree
from repro.fed import rounds as rd
from repro.utils import HOST_SYNC_PRIMITIVES, jaxpr_primitive_counts

N = 5
ROWS = 64


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (41, 23)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (23,))}


def _fixture(seed=0, n=N):
    tree = _tree(seed)
    layout = fl.layout_of(tree)
    state = rd.init_round_state(tree, n, layout)
    key = jax.random.PRNGKey(seed + 77)
    deltas = 0.05 * jax.random.normal(key, (n,) + state.buf_p1.shape)
    sizes = jnp.linspace(20.0, 80.0, n)
    return tree, layout, state, deltas, sizes


def _worker_fn(deltas, n=N):
    def fn(wc, buf, t):
        bufs_q = buf[None] + deltas * (1.0 + 0.1 * t.astype(jnp.float32))
        costs = 1.0 / (t.astype(jnp.float32)
                       + jnp.arange(n, dtype=jnp.float32) + 1.0)
        return wc, bufs_q, costs
    return fn


# ---------------------------------------------------------------------------
# Identity property: all-ones mask + uniform beta_k == plain path, bitwise
# ---------------------------------------------------------------------------

@given(st.integers(0, 4), st.sampled_from([2, 5, 9]))
@settings(max_examples=8, deadline=None)
def test_ones_mask_uniform_betas_bitwise_identity(seed, n):
    wire = rd.WirePath(rd.WireConfig())
    tree, layout, state, deltas, sizes = _fixture(seed, n)
    worker = _worker_fn(deltas, n)
    _, bufs_q, costs = worker(0, state.buf_p1, state.round)

    plain, plain_buf, plain_info = wire.round_step(
        state, bufs_q, costs, sizes)
    dressed, dressed_buf, _ = wire.round_step(
        state, bufs_q, costs, sizes,
        betas=jnp.full((n,), wire.cfg.beta), mask=jnp.ones((n,)))
    np.testing.assert_array_equal(np.asarray(plain_buf),
                                  np.asarray(dressed_buf))
    for a, b in zip(plain, dressed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_step_matches_engine_path():
    """round_step == the RoundEngine wrapper on the same inputs (the
    engine is now a thin shell over the pure core)."""
    wire = rd.WirePath(rd.WireConfig())
    tree, layout, state, deltas, sizes = _fixture(3)
    _, bufs_q, costs = _worker_fn(deltas)(0, state.buf_p1, state.round)

    _, new_buf, info = wire.round_step(state, bufs_q, costs, sizes)
    engine = rd.RoundEngine(tree, wire.cfg)
    p_shares = sizes / jnp.sum(sizes)
    engine.run_round(bufs_q, info["k_star"], p_shares, 1)
    np.testing.assert_array_equal(np.asarray(engine.buf_p1),
                                  np.asarray(new_buf))


# ---------------------------------------------------------------------------
# scan == Python loop, bitwise, >= 5 rounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", ["plain", "masked+betas"])
def test_scan_matches_python_loop_bitwise(scenario):
    wire = rd.WirePath(rd.WireConfig())
    tree, layout, state0, deltas, sizes = _fixture(1)
    worker = _worker_fn(deltas)
    n_rounds = 6
    if scenario == "plain":
        betas, masks = None, None
    else:
        betas = jnp.linspace(0.1, 0.3, N)
        masks = rd.participation_masks(jax.random.PRNGKey(5), n_rounds,
                                       N, 0.6)

    st_scan, _, infos = jax.jit(lambda s: rd.scan_rounds(
        wire, s, worker, 0, n_rounds, sizes, betas=betas, masks=masks))(
        state0)

    # The Python-loop driver jits the same round body (local models + one
    # round_step) and re-dispatches it every round — what scan_rounds rolls
    # into a single program.
    def body(s, mask_row):
        _, bufs_q, costs = worker(0, s.buf_p1, s.round)
        return wire.round_step(s, bufs_q, costs, sizes, betas=betas,
                               mask=mask_row)

    body_jit = jax.jit(body)
    body_jit_nomask = jax.jit(lambda s: body(s, None))
    st = state0
    ks = []
    for r in range(n_rounds):
        if masks is None:
            st, _, info = body_jit_nomask(st)
        else:
            st, _, info = body_jit(st, masks[r])
        ks.append(int(info["k_star"]))

    for a, b in zip(st, st_scan):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(infos["k_star"]),
                                  np.asarray(ks))


# ---------------------------------------------------------------------------
# Structure: 2 launches per round, zero host syncs, traced pilot
# ---------------------------------------------------------------------------

def test_round_step_two_launches_no_host_sync():
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    _, _, state, deltas, sizes = _fixture(0)
    bufs = jnp.zeros((N,) + state.buf_p1.shape)
    costs = jnp.ones((N,))
    counts = jaxpr_primitive_counts(
        lambda s, b, c: wire.round_step(s, b, c, sizes), state, bufs, costs)
    assert counts.get("pallas_call") == 2, counts
    assert sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES) == 0, counts


def test_scan_program_two_launches_total_no_host_sync():
    """The whole multi-round program holds exactly two pallas_call eqns —
    the scan body is traced once regardless of trip count — and no
    host-sync primitives: zero per-round device→host transfers by
    construction."""
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    _, _, state, deltas, sizes = _fixture(0)
    worker = _worker_fn(deltas)
    counts = jaxpr_primitive_counts(
        lambda s: rd.scan_rounds(wire, s, worker, 0, 7, sizes), state)
    assert counts.get("pallas_call") == 2, counts
    assert counts.get("scan", 0) >= 1, counts
    assert sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES) == 0, counts


def test_round_step_pilot_stays_on_device():
    wire = rd.WirePath(rd.WireConfig())
    _, _, state, deltas, sizes = _fixture(2)
    _, bufs_q, costs = _worker_fn(deltas)(0, state.buf_p1, state.round)
    _, _, info = jax.jit(
        lambda s, b, c: wire.round_step(s, b, c, sizes))(
        state, bufs_q, costs)
    assert isinstance(info["k_star"], jax.Array)
    assert info["k_star"].shape == ()


# ---------------------------------------------------------------------------
# Partial participation semantics
# ---------------------------------------------------------------------------

def test_participation_mask_properties():
    for frac, want in ((0.6, 3), (0.2, 1), (1.0, 5)):
        m = rd.participation_mask(jax.random.PRNGKey(0), N, frac)
        assert set(np.asarray(m).tolist()) <= {0.0, 1.0}
        assert int(np.asarray(m).sum()) == want
    masks = rd.participation_masks(jax.random.PRNGKey(1), 8, N, 0.4)
    assert masks.shape == (8, N)
    assert np.all(np.asarray(masks).sum(axis=1) == 2)


def test_first_time_participant_scores_round1_rule():
    """A worker first sampled after round 1 still carries prev_cost=+inf;
    it must score by the round-1 rule S_k/C_k, not inf (which would hijack
    pilot selection by index)."""
    from repro.core.goodness import goodness
    sizes = jnp.array([10.0, 20.0, 30.0])
    costs = jnp.array([1.0, 2.0, 3.0])
    prev = jnp.array([jnp.inf, 1.5, jnp.inf])
    g = np.asarray(goodness(costs, prev, sizes, 3))
    assert np.isfinite(g).all()
    np.testing.assert_allclose(g[0], 10.0)
    np.testing.assert_allclose(g[1], 20.0 * (1.5 - 2.0))
    np.testing.assert_allclose(g[2], 10.0)


def test_masked_workers_excluded():
    """Non-participants: never pilot, zero Eq. (3) weight, previous cost
    carried forward."""
    wire = rd.WirePath(rd.WireConfig())
    _, _, state, deltas, sizes = _fixture(4)
    state = state._replace(prev_costs=jnp.linspace(1.0, 2.0, N),
                           round=jnp.asarray(3, jnp.int32))
    worker = _worker_fn(deltas)
    _, bufs_q, costs = worker(0, state.buf_p1, state.round)
    mask = jnp.asarray([0.0, 1.0, 0.0, 1.0, 1.0])

    new_state, _, info = wire.round_step(state, bufs_q, costs, sizes,
                                         mask=mask)
    k = int(info["k_star"])
    assert mask[k] == 1.0
    w = wire.weights(sizes / sizes.sum(), k, 3, mask=mask)
    np.testing.assert_array_equal(np.asarray(w[np.asarray(mask) == 0]), 0.0)
    pc = np.asarray(new_state.prev_costs)
    np.testing.assert_array_equal(pc[0], np.asarray(state.prev_costs)[0])
    np.testing.assert_array_equal(pc[2], np.asarray(state.prev_costs)[2])
    np.testing.assert_array_equal(pc[1], np.asarray(costs)[1])


# ---------------------------------------------------------------------------
# Heterogeneous beta_k vs the pytree oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [1, 3])
def test_betas_vector_matches_tree_oracle(t):
    wire = rd.WirePath(rd.WireConfig())
    tree, layout, state, deltas, sizes = _fixture(6)
    if t > 1:
        p2t = jax.tree_util.tree_map(lambda x: 0.9 * x, tree)
        state = state._replace(buf_p2=fl.flatten_tree(p2t, layout),
                               prev_costs=jnp.ones((N,)),
                               round=jnp.asarray(t, jnp.int32))
    else:
        p2t = jax.tree_util.tree_map(jnp.zeros_like, tree)
    betas = jnp.asarray([0.1, 0.15, 0.2, 0.25, 0.3])
    worker = _worker_fn(deltas)
    _, bufs_q, costs = worker(0, state.buf_p1, state.round)
    _, new_buf, info = wire.round_step(state, bufs_q, costs, sizes,
                                       betas=betas)
    got = fl.unflatten_tree(new_buf, layout)

    locals_ = [fl.unflatten_tree(bufs_q[k], layout) for k in range(N)]
    k_star = int(info["k_star"])
    terns = ([ternarize_tree_round1(l, tree, wire.cfg.alpha1)
              for l in locals_] if t == 1 else
             [ternarize_tree(l, tree, p2t, float(betas[k]))
              for k, l in enumerate(locals_)])
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *terns)
    p_shares = sizes / jnp.sum(sizes)
    want = master_update_tree(locals_[k_star], stacked, p_shares, betas,
                              k_star, tree, p2t, t, wire.cfg.alpha0)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Checkpoint round-trip: resume mid-federation, bitwise
# ---------------------------------------------------------------------------

def test_round_state_checkpoint_resume_bitwise(tmp_path):
    wire = rd.WirePath(rd.WireConfig())
    tree, layout, state0, deltas, sizes = _fixture(8)
    worker = _worker_fn(deltas)
    run = jax.jit(lambda s, n: rd.scan_rounds(wire, s, worker, 0, n, sizes),
                  static_argnums=1)

    st_full, _, _ = run(state0, 6)

    st_half, _, _ = run(state0, 3)
    rd.save_round_state(str(tmp_path), st_half, metadata={"algo": "fedpc"})
    like = rd.init_round_state(tree, N, layout)
    st_loaded, manifest = rd.load_round_state(str(tmp_path), like)
    assert manifest["metadata"]["kind"] == "fedpc_round_state"
    assert manifest["step"] == 4                      # 3 rounds done, next=4
    for a, b in zip(st_loaded, st_half):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    st_resumed, _, _ = run(st_loaded, 3)
    for a, b in zip(st_resumed, st_full):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
