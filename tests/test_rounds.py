"""Round engine (repro.fed.rounds): parity with the per-worker wire path it
replaced, with the pytree-level numerics oracle, and launch accounting for
the batched uplink (the simulator's N-worker uplink must be ONE kernel)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat as fl
from repro.core.ternary import ternarize_tree, ternarize_tree_round1
from repro.core.update import masked_weights, master_update_tree
from repro.fed import rounds as rd
from repro.kernels import ops

# A §3.3 wire byte whose four 2-bit fields all decode to code 0 — what the
# pre-engine simulator used to fill the pilot's masked row with.
ZERO_CODES_BYTE = 0b01010101


def _param_tree(key):
    ks = jax.random.split(key, 4)
    return {
        "w0": jax.random.normal(ks[0], (33, 17)),
        "b0": jax.random.normal(ks[1], (17,)),
        "w1": jax.random.normal(ks[2], (17, 5)),
        "scalar": jax.random.normal(ks[3], ()),
    }


def _round_fixture(n_workers, t, seed=0):
    key = jax.random.PRNGKey(seed)
    tree = _param_tree(key)
    p1t = tree
    p2t = (jax.tree_util.tree_map(jnp.zeros_like, tree) if t == 1
           else jax.tree_util.tree_map(lambda x: 0.9 * x, tree))
    locals_ = [jax.tree_util.tree_map(
        lambda x, i=i: x + 0.02 * (i + 1) * jnp.sign(x), tree)
        for i in range(n_workers)]
    p_shares = jnp.linspace(0.5, 1.5, n_workers)
    p_shares = p_shares / p_shares.sum()
    return tree, p1t, p2t, locals_, p_shares


@pytest.mark.parametrize("n_workers", [2, 8])
@pytest.mark.parametrize("t", [1, 3])
def test_engine_round_bitwise_matches_per_worker_path(n_workers, t):
    """simulator-via-engine == the pre-engine simulator path, bit for bit.

    The old path packed each non-pilot worker with its own kernel launch,
    zero-filled the pilot's packed row, and ran the fused master update.
    The engine packs all N rows (pilot masked by w instead) in one launch —
    the global params must not move by a single ULP.
    """
    tree, p1t, p2t, locals_, p_shares = _round_fixture(n_workers, t)
    cfg = rd.WireConfig(alpha0=0.01, beta=0.2, alpha1=0.01)
    k_star = n_workers // 2

    # --- engine path -------------------------------------------------------
    engine = rd.RoundEngine(tree, cfg)
    engine.buf_p1 = fl.flatten_tree(p1t, engine.layout)
    engine.buf_p2 = fl.flatten_tree(p2t, engine.layout)
    got = engine.run_round(engine.flatten_locals(locals_), k_star,
                           p_shares, t)

    # --- the old per-worker path, inline -----------------------------------
    layout = fl.layout_of(tree)
    buf_p1 = fl.flatten_tree(p1t, layout)
    buf_p2 = fl.flatten_tree(p2t, layout)
    pilot_fill = jnp.full((layout.packed_rows, fl.LANES),
                          ZERO_CODES_BYTE, jnp.uint8)
    buf_pilot, packed = None, []
    for k in range(n_workers):
        buf_q = fl.flatten_tree(locals_[k], layout)
        if k == k_star:
            buf_pilot = buf_q
            packed.append(pilot_fill)
        else:
            packed.append(ops.flat_ternary_pack(
                buf_q, buf_p1, buf_p2, t=t, beta=cfg.beta,
                alpha1=cfg.alpha1))
    betas = (jnp.ones((n_workers,)) if t == 1
             else jnp.full((n_workers,), cfg.beta))
    w = masked_weights(p_shares, betas, k_star)
    new_buf = ops.flat_master_update(
        buf_pilot, jnp.stack(packed), w, buf_p1, buf_p2,
        t=t, alpha0=cfg.alpha0)
    want = fl.unflatten_tree(new_buf, layout)

    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("t", [1, 3])
def test_engine_round_matches_tree_oracle(t):
    """Engine output vs core.update.master_update_tree on the same codes."""
    n_workers = 6
    tree, p1t, p2t, locals_, p_shares = _round_fixture(n_workers, t, seed=4)
    cfg = rd.WireConfig()
    k_star = 2

    engine = rd.RoundEngine(tree, cfg)
    engine.buf_p1 = fl.flatten_tree(p1t, engine.layout)
    engine.buf_p2 = fl.flatten_tree(p2t, engine.layout)
    got = engine.run_round(engine.flatten_locals(locals_), k_star,
                           p_shares, t)

    terns = ([ternarize_tree_round1(l, p1t, cfg.alpha1) for l in locals_]
             if t == 1 else
             [ternarize_tree(l, p1t, p2t, cfg.beta) for l in locals_])
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *terns)
    want = master_update_tree(
        locals_[k_star], stacked, p_shares,
        jnp.full((n_workers,), cfg.beta), k_star, p1t, p2t, t, cfg.alpha0)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_engine_history_rotation():
    """P^{t-1}/P^{t-2} rotate exactly as Algorithm 1 prescribes."""
    tree, p1t, p2t, locals_, p_shares = _round_fixture(3, 1)
    engine = rd.RoundEngine(tree, rd.WireConfig())
    p1_before = engine.buf_p1
    new_params = engine.run_round(engine.flatten_locals(locals_), 0,
                                  p_shares, 1)
    np.testing.assert_array_equal(np.asarray(engine.buf_p2),
                                  np.asarray(p1_before))
    np.testing.assert_array_equal(
        np.asarray(engine.buf_p1),
        np.asarray(fl.flatten_tree(new_params, engine.layout)))


def test_wire_weights_match_masked_weights():
    p_shares = jnp.array([0.1, 0.4, 0.3, 0.2])
    wire = rd.WirePath(rd.WireConfig(beta=0.2))
    for k_star in range(4):
        np.testing.assert_allclose(
            np.asarray(wire.weights(p_shares, k_star, 1)),
            np.asarray(masked_weights(p_shares, jnp.ones((4,)), k_star)))
        np.testing.assert_allclose(
            np.asarray(wire.weights(p_shares, k_star, 5)),
            np.asarray(masked_weights(p_shares, jnp.full((4,), 0.2),
                                      k_star)))


# ---------------------------------------------------------------------------
# Launch accounting (the acceptance criterion: the N-worker uplink is ONE
# batched pallas_call, the whole round exactly two)
# ---------------------------------------------------------------------------

def _count_launches(fn, *args):
    from repro.utils import jaxpr_primitive_counts
    return jaxpr_primitive_counts(fn, *args).get("pallas_call", 0)


def test_batched_uplink_single_launch():
    n_workers, rows = 8, 64
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    bufs = jnp.zeros((n_workers, rows, fl.LANES))
    hist = jnp.zeros((rows, fl.LANES))
    for t in (1, 3):
        n = _count_launches(
            functools.partial(wire.uplink_stacked, t=t), bufs, hist, hist)
        assert n == 1, f"t={t}: expected 1 batched launch, got {n}"


def test_engine_round_two_launches():
    n_workers, rows = 8, 64
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    bufs = jnp.zeros((n_workers, rows, fl.LANES))
    hist = jnp.zeros((rows, fl.LANES))
    w = jnp.full((n_workers,), 0.02)

    def whole_round(bufs, hist1, hist2, w):
        new_buf, _ = wire.round_from_stacked(bufs, 3, w, hist1, hist2, t=3)
        return new_buf

    n = _count_launches(whole_round, bufs, hist, hist, w)
    assert n == 2, f"expected uplink+master = 2 launches, got {n}"
