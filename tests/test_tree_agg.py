"""Hierarchical tree aggregation: tree == flat BITWISE at every fanout.

The contract under test (ISSUE 8 tentpole):

* the plain tree rides the integer wire (fixed-point weights, uint32
  words) and is bitwise equal to the flat integer comparator for every
  fanout, ragged last sibling groups included — modular accumulation is
  order-free, so tree shape can never change bits;
* the masked tree (sibling-scoped leaf masks + per-level node masks from
  the level-salted stream) produces bitwise the same round output as the
  flat masked path at BOTH moduli, with and without participation, under
  ``lax.scan``, and composed with ``renorm_shares``;
* a fully-dropped subtree contributes an exactly-zero partial;
* launches grow with tree depth (``levels + 2``), not with N, and the
  round program stays free of host syncs;
* the §4.2 audits still hold: the tree round program passes, and a
  de-masked (signed-int) partial crossing a fed collective below the
  root raises :class:`LeakageError`;
* the Eq. (8) tree byte model: the link into the root carries w_L ≤
  fanout buffers, per-level bytes shrink ~fanout× as the tree ascends.

Mesh parity (tree butterfly reduce vs flat psum on (4,2)/(2,4) meshes)
runs in a subprocess with 8 host devices, like tests/test_fed_sharded*.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as proto
from repro.core.privacy import LeakageError
from repro.core.tree import TreeSpec
from repro.fed import rounds as rd
from repro.kernels import ops, tune
from repro.privacy import audit as pv_audit
from repro.privacy import masking as pvm
from repro.privacy.spec import PrivacySpec
from repro.utils import HOST_SYNC_PRIMITIVES, jaxpr_primitive_counts

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ROWS = 32


def _mk(n, seed=0):
    k = jax.random.PRNGKey(seed)
    bufs_q = jax.random.normal(k, (n, ROWS, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (ROWS, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (ROWS, 128))
    costs = jax.random.uniform(jax.random.fold_in(k, 3), (n,))
    sizes = jnp.arange(1.0, n + 1.0)
    return bufs_q, p1, p2, costs, sizes


def _state(n, p1, t=3):
    return rd.RoundState(buf_p1=p1, buf_p2=jnp.zeros_like(p1),
                         prev_costs=jnp.full((n,), jnp.inf),
                         round=jnp.asarray(t, jnp.int32))


# ---------------------------------------------------------------------------
# TreeSpec shape algebra
# ---------------------------------------------------------------------------

def test_treespec_levels_and_widths():
    ts = TreeSpec(fanout=2)
    assert ts.level_widths(8) == [8, 4, 2]
    assert ts.n_levels(8) == 2
    assert ts.level_widths(5) == [5, 3, 2]          # ragged groups
    assert TreeSpec(fanout=4).level_widths(16) == [16, 4]
    assert TreeSpec(fanout=4).level_widths(7) == [7, 2]
    assert TreeSpec(fanout=8).n_levels(64) == 1     # 8 partials → root
    assert TreeSpec(fanout=8).n_levels(65) == 2
    # pinned depth overrides auto-derivation
    assert TreeSpec(fanout=2, levels=3).level_widths(8) == [8, 4, 2, 1]
    assert TreeSpec(fanout=2).launches(16) == 3 + 2   # L=3
    assert TreeSpec(fanout=4).launches(16) == 1 + 2   # L=1
    # last level's sibling group spans all remaining nodes
    assert TreeSpec(fanout=4).sibling_size(1, 7) == 2
    assert TreeSpec(fanout=2).sibling_size(1, 8) == 2
    assert TreeSpec(fanout=2).sibling_size(2, 8) == 2


def test_treespec_validation():
    with pytest.raises(ValueError):
        TreeSpec(fanout=1)
    with pytest.raises(ValueError):
        TreeSpec(fanout=2, levels=0)


# ---------------------------------------------------------------------------
# Plain tree: bitwise == the flat integer comparator, every fanout
# ---------------------------------------------------------------------------

def _flat_integer_round(bufs_q, k_star, w, p1, p2, t):
    """The flat comparator on the SAME integer wire the plain tree rides:
    unmasked uint32 words, fb=24 fixed-point weights, one modular master."""
    n = bufs_q.shape[0]
    wq = pvm.quantize_weights(w, rd.TREE_PLAIN_FIXPOINT_BITS)
    y = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=t, beta=0.2, alpha1=0.01, wq=wq,
        pair_keys=jnp.zeros((n, n), jnp.uint32),
        pair_signs=jnp.zeros((n, n), jnp.int32),
        rr_keys=jnp.zeros((n,), jnp.uint32),
        word_bits=rd.TREE_PLAIN_WORD_BITS, use_masks=False)
    return ops.flat_masked_master_update(
        jnp.take(bufs_q, k_star, axis=0), y, jnp.sum(wq), p1, p2, t=t,
        alpha0=0.01, scale_mult=2.0 ** -rd.TREE_PLAIN_FIXPOINT_BITS)


@pytest.mark.parametrize("fanout", [2, 4, 8])
@pytest.mark.parametrize("n", [5, 8, 9])
def test_plain_tree_bitwise_equals_flat(fanout, n):
    bufs_q, p1, p2, costs, sizes = _mk(n)
    wire = rd.WirePath(tree=TreeSpec(fanout=fanout))
    t = jnp.asarray(3, jnp.int32)
    k_star = jnp.asarray(1, jnp.int32)
    w = wire.weights(sizes / sizes.sum(), k_star, t)
    out_tree, _ = wire.round_from_stacked(bufs_q, k_star, w, p1, p2, t=t)
    out_flat = _flat_integer_round(bufs_q, k_star, w, p1, p2, t)
    assert np.array_equal(np.asarray(out_tree), np.asarray(out_flat))


def test_plain_tree_round1_branch():
    bufs_q, p1, p2, costs, sizes = _mk(6)
    wire = rd.WirePath(tree=TreeSpec(fanout=2))
    t = jnp.asarray(1, jnp.int32)
    k_star = jnp.asarray(0, jnp.int32)
    w = wire.weights(sizes / sizes.sum(), k_star, t)
    out_tree, _ = wire.round_from_stacked(bufs_q, k_star, w, p1, p2, t=t)
    out_flat = _flat_integer_round(bufs_q, k_star, w, p1, p2, t)
    assert np.array_equal(np.asarray(out_tree), np.asarray(out_flat))


# ---------------------------------------------------------------------------
# Masked tree: bitwise == the flat masked round, both moduli
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("modulus_bits", [16, 32])
@pytest.mark.parametrize("fanout,n", [(2, 8), (4, 8), (2, 7)])
def test_masked_tree_bitwise_equals_flat(modulus_bits, fanout, n):
    bufs_q, p1, p2, costs, sizes = _mk(n)
    spec = PrivacySpec(secure_agg=True, modulus_bits=modulus_bits)
    flat = rd.WirePath(privacy=spec)
    tree = rd.WirePath(privacy=spec, tree=TreeSpec(fanout=fanout))
    _, out_f, _ = flat.round_step(_state(n, p1), bufs_q, costs, sizes)
    _, out_t, _ = tree.round_step(_state(n, p1), bufs_q, costs, sizes)
    assert np.array_equal(np.asarray(out_f), np.asarray(out_t))


@pytest.mark.parametrize("modulus_bits", [16, 32])
def test_masked_tree_parity_under_participation(modulus_bits):
    n = 8
    bufs_q, p1, p2, costs, sizes = _mk(n)
    spec = PrivacySpec(secure_agg=True, modulus_bits=modulus_bits)
    mask = jnp.array([1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)
    for renorm in (False, True):
        flat = rd.WirePath(privacy=spec, renorm_shares=renorm)
        tree = rd.WirePath(privacy=spec, renorm_shares=renorm,
                           tree=TreeSpec(fanout=2))
        _, out_f, _ = flat.round_step(_state(n, p1), bufs_q, costs, sizes,
                                      mask=mask)
        _, out_t, _ = tree.round_step(_state(n, p1), bufs_q, costs, sizes,
                                      mask=mask)
        assert np.array_equal(np.asarray(out_f), np.asarray(out_t)), renorm


def test_masked_tree_parity_under_scan():
    n = 8
    bufs_q, p1, p2, costs, sizes = _mk(n)
    spec = PrivacySpec(secure_agg=True, modulus_bits=16)
    # Per-round inputs vary by integer gather only: float math on the
    # carry inside the body would let XLA's FMA contraction fuse the two
    # programs differently and shift the INPUTS by 1 ulp — the wire
    # itself is bitwise invariant.
    per_round = jnp.stack([bufs_q, bufs_q * 1.5, bufs_q - 0.25])

    def worker_fn(wc, gbuf, t):
        return wc, jnp.take(per_round, (t - 1) % 3, axis=0), costs

    outs = {}
    for name, wire in (("flat", rd.WirePath(privacy=spec)),
                       ("tree", rd.WirePath(privacy=spec,
                                            tree=TreeSpec(fanout=2)))):
        st, _, _ = rd.scan_rounds(
            wire, _state(n, p1, t=1), worker_fn, None, 3, sizes,
            participation=0.75, participation_key=jax.random.PRNGKey(9))
        outs[name] = np.asarray(st.buf_p1)
    assert np.array_equal(outs["flat"], outs["tree"])


def test_dropped_subtree_partial_is_exactly_zero():
    """Satellite 1 regression: when every leaf under a subtree is dropped,
    that subtree's partial is exactly 0 — no mask residue (its nodes pair
    with no active sibling), no field residue (zero weights)."""
    n = 8
    bufs_q, p1, p2, costs, sizes = _mk(n)
    mask = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    for modulus_bits in (16, 32):
        spec = PrivacySpec(secure_agg=True, modulus_bits=modulus_bits)
        tree = rd.WirePath(privacy=spec, tree=TreeSpec(fanout=2))
        t = jnp.asarray(3, jnp.int32)
        w = tree.weights(sizes / sizes.sum(), 0, t, mask=mask)
        y, _ = tree.uplink_masked(bufs_q, p1, p2, t=t, w=w, pmask=mask)
        top = tree._tree_fold_masked(y, t=t, pmask=mask)
        # last level has 2 nodes; node 1 spans dropped leaves 4..7
        assert top.shape[0] == 2
        assert not np.asarray(top[1]).any()
        assert np.asarray(top[0]).any()


def test_tree_activity_folds_up():
    mask = jnp.array([1, 0, 0, 0, 0, 0, 1, 1], jnp.float32)
    a1 = pvm.tree_activity(mask, 2)
    assert np.array_equal(np.asarray(a1), [1, 0, 0, 1])
    a2 = pvm.tree_activity(a1, 2)
    assert np.array_equal(np.asarray(a2), [1, 1])
    # ragged fold pads with inactive leaves
    assert np.array_equal(
        np.asarray(pvm.tree_activity(jnp.array([1.0, 0.0, 1.0]), 2)),
        [1, 1])


# ---------------------------------------------------------------------------
# Structure: launches grow with depth, not N; zero host syncs
# ---------------------------------------------------------------------------

def _round_counts(n, tree, privacy=None):
    bufs_q, p1, p2, costs, sizes = _mk(n)
    wire = rd.WirePath(privacy=privacy, tree=tree)
    return jaxpr_primitive_counts(
        lambda s, b, c, z: wire.round_step(s, b, c, z),
        _state(n, p1), bufs_q, costs, sizes)


@pytest.mark.parametrize("privacy", [None,
                                     PrivacySpec(secure_agg=True)])
def test_launches_scale_with_depth_not_n(privacy):
    ts = TreeSpec(fanout=8)
    # N=8 and N=64 share depth L=1 → identical launch count (levels + 2)
    c8 = _round_counts(8, ts, privacy)
    c64 = _round_counts(64, ts, privacy)
    assert c8.get("pallas_call") == ts.launches(8) == 3
    assert c64.get("pallas_call") == ts.launches(64) == 3
    # deeper tree at the same N adds exactly one launch per level
    c_deep = _round_counts(64, TreeSpec(fanout=2), privacy)
    assert c_deep.get("pallas_call") == TreeSpec(fanout=2).launches(64) == 7
    for c in (c8, c64, c_deep):
        assert not HOST_SYNC_PRIMITIVES & set(c), c


def test_flat_round_is_two_launches_still():
    c = _round_counts(8, None)
    assert c.get("pallas_call") == 2


# ---------------------------------------------------------------------------
# §4.2 audits on the tree path
# ---------------------------------------------------------------------------

def test_audit_passes_on_masked_tree_round():
    # n != rows//4 — the float-stacked rule keys on shape[0] == n_workers,
    # so an (8, 512) history slab at n=8 would collide coincidentally
    n = 6
    bufs_q, p1, p2, costs, sizes = _mk(n)
    spec = PrivacySpec(secure_agg=True)
    wire = rd.WirePath(privacy=spec, tree=TreeSpec(fanout=2))
    report = pv_audit.check_round_program(
        wire.round_step, _state(n, p1), bufs_q, costs, sizes,
        n_workers=n, masked=True)
    assert report["n_launches"] == TreeSpec(fanout=2).launches(n)


def test_demasked_partial_below_root_raises():
    """A signed-int (= de-masked, de-biased) buffer crossing a fed
    collective is the LeakageError the extended audit exists to catch."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("f",))

    def leaky(x):
        body = lambda v: jax.lax.psum(
            jax.lax.bitcast_convert_type(v, jnp.int32), "f")
        sm = jax.shard_map if hasattr(jax, "shard_map") else None
        if sm is not None:
            from jax.sharding import PartitionSpec as P
            return sm(body, mesh=mesh, in_specs=P(), out_specs=P(),
                      axis_names=frozenset({"f"}), check_vma=False)(x)
        from jax.experimental.shard_map import shard_map as _sm
        from jax.sharding import PartitionSpec as P
        return _sm(body, mesh=mesh, in_specs=P(), out_specs=P(),
                   check_rep=False)(x)

    words = jnp.zeros((8, 512), jnp.uint32)
    with pytest.raises(LeakageError, match="below the root"):
        pv_audit.check_fed_collectives(leaky, words, n_fed=4, masked=True)
    # unmasked runtimes still move signed payloads legitimately
    pv_audit.check_fed_collectives(leaky, words, n_fed=4, masked=False)


# ---------------------------------------------------------------------------
# Byte model: per-level fanout× reduction, root link O(fanout)
# ---------------------------------------------------------------------------

def test_tree_bytes_model():
    V, n = 1000.0, 64
    flat = proto.fedpc_masked_bytes_per_round(V, n, word_bits=16)
    tree = proto.fedpc_tree_bytes_per_round(V, n, 8, word_bits=16)
    # the tree adds interior-edge bytes on top of the same leaf uplinks…
    widths = TreeSpec(fanout=8).level_widths(n)
    expect = V * (n + 1) + V * (n - 1) * 16 / 32
    for w_l in widths[1:]:
        expect += V * w_l * 16 / 32
    assert tree == pytest.approx(expect)
    # …but the link INTO the root carries w_L ≤ fanout partials, not N-1
    assert widths[-1] <= 8
    # per-level payload shrinks fanout× exactly while groups stay full
    assert widths[1] == n // 8
    # plaintext tree: 2-bit leaves, word-wide (uint32) interior partials
    plain = proto.fedpc_tree_bytes_per_round(V, n, 8)
    expect_p = V * (n + 1) + V * (n - 1) * 2 / 32
    for w_l in widths[1:]:
        expect_p += V * w_l * 32 / 32
    assert plain == pytest.approx(expect_p)
    assert proto.fedpc_bytes_per_round(V, n) < plain < flat


# ---------------------------------------------------------------------------
# Tuner: partial_sum kinds resolve, fallback chain is reported once
# ---------------------------------------------------------------------------

def test_partial_sum_fallback_logged_once(capsys):
    tune._FALLBACK_LOGGED.discard(
        ("partial_sum_masked16", 4096, 2, "cpu-interpret"))
    tune.lookup("partial_sum_masked16", 4096, 2, interpret=True)
    out1 = capsys.readouterr().out
    assert "fell back" in out1
    assert "partial_sum_masked16 -> partial_sum_masked -> partial_sum" in out1
    tune.lookup("partial_sum_masked16", 4096, 2, interpret=True)
    assert "fell back" not in capsys.readouterr().out


def test_partial_sum_plans_never_change_bits():
    n, fanout = 8, 2
    bufs_q, p1, p2, _, sizes = _mk(n)
    packed = ops.flat_ternary_pack_stacked(bufs_q, p1, p2, t=3, beta=0.2,
                                           alpha1=0.01)
    wq = pvm.quantize_weights(sizes / sizes.sum(), 24)
    ref = ops.flat_partial_sum(packed, wq, fanout=fanout)
    for br, bg in ((ROWS // 4, 4), (2, 1), (4, 2)):
        out = ops.flat_partial_sum(packed, wq, fanout=fanout,
                                   block_rows=br, block_groups=bg)
        assert np.array_equal(np.asarray(ref), np.asarray(out)), (br, bg)


# ---------------------------------------------------------------------------
# Mesh: tree butterfly reduce == flat psum, (4,2) and (2,4)
# ---------------------------------------------------------------------------

MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.tree import TreeSpec
from repro.fed.distributed import build_fed_sync, fed_state_init
from repro.privacy import PrivacySpec

k = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(k, (300, 40)),
          "b": jax.random.normal(jax.random.fold_in(k, 5), (40,))}
out = {}

def tree_max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

for fed, model in ((4, 2), (2, 4)):
    devs = np.array(jax.devices()[: fed * model]).reshape(fed, model)
    mesh = Mesh(devs, ("data", "model"))
    F = fed
    sizes = jnp.linspace(50.0, 200.0, F)
    costs = jnp.linspace(0.9, 0.5, F)
    params_F = jax.tree_util.tree_map(
        lambda x: jnp.stack([x + 0.05 * (i + 1) for i in range(F)]),
        params)
    mask = (jnp.arange(F) != 1).astype(jnp.float32)
    state = fed_state_init(params, F)
    state["round"] = jnp.asarray(3, jnp.int32)
    state["params_prev"] = jax.tree_util.tree_map(lambda x: x + 0.01,
                                                  params)
    state["prev_costs"] = jnp.ones((F,))
    wb = 16 if fed == 4 else 32
    spec = PrivacySpec(modulus_bits=wb)
    with mesh:
        s_tree = build_fed_sync(None, mesh, "data", "fedpc",
                                shard_wire=True, privacy=spec,
                                tree=TreeSpec(fanout=2))
        s_flat = build_fed_sync(None, mesh, "data", "fedpc",
                                shard_wire=True, privacy=spec)
        for tag, m in (("full", None), ("part", mask)):
            a, _ = jax.jit(s_tree)(params_F, costs, sizes, state, m)
            b, _ = jax.jit(s_flat)(params_F, costs, sizes, state, m)
            out[f"{fed}x{model}_wb{wb}_{tag}"] = tree_max_diff(a, b)

# validation: the mesh tree needs the masked wire and power-of-two shapes
devs = np.array(jax.devices()[:4]).reshape(4, 1)
mesh = Mesh(devs, ("data", "model"))
for kwargs, tag in ((dict(), "plain"),
                    (dict(privacy=PrivacySpec(),
                          tree_fanout=3), "fanout3")):
    try:
        fo = kwargs.pop("tree_fanout", 2)
        build_fed_sync(None, mesh, "data", "fedpc",
                       tree=TreeSpec(fanout=fo), **kwargs)
        out["reject_" + tag] = False
    except ValueError:
        out["reject_" + tag] = True

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_mesh_tree_reduce_bitwise_equals_flat(mesh_results):
    keys = [k for k in mesh_results if "_wb" in k]
    assert len(keys) == 4
    for k in keys:
        assert mesh_results[k] == 0.0, f"{k}: {mesh_results[k]}"


def test_mesh_tree_requires_masked_power_of_two(mesh_results):
    assert mesh_results["reject_plain"] is True
    assert mesh_results["reject_fanout3"] is True
