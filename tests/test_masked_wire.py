"""Secure-aggregation wire kernels: the masked uplink, the sum-then-unmask
master, and the privacy autotuner kinds.

The contract under test:
  * both masked kernels are BITWISE equal to the jnp oracles
    (``repro.privacy.ref``, jitted with traced scalars) for every
    (block_rows, block_workers) plan, n in {1, 8, 33}, both round
    branches, RR on and off — the wire is integer end-to-end, so parity
    is exact, never allclose;
  * pairwise masks cancel EXACTLY: a masked aggregate is bit-identical to
    the zero-mask aggregate (mod 2**32 cancellation), and the net masks
    sum to zero — including under partial participation;
  * with DP off the masked round differs from the plain float wire only
    by the fixed-point weight rounding (<= 2**-(bits+1) per weight);
  * the RR mechanism flips at the configured rate and unbiasing makes the
    EXPECTED master update equal the noiseless one;
  * either masked kernel is exactly ONE pallas launch under every plan;
  * the tuner knows the masked kinds and falls back to the unmasked
    kind's tuned plan when a masked entry is missing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, tune
from repro.privacy import (PrivacySpec, masking, net_masks, quantize_weights,
                           rr_bits, rr_fields)
from repro.privacy import ref as pref
from repro.utils import jaxpr_primitive_counts

FIX_BITS = 24


def _fixture(n, rows_flat, seed=0):
    k = jax.random.PRNGKey(seed)
    bufs_q = jax.random.normal(k, (n, rows_flat, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows_flat, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows_flat, 128))
    w = jnp.linspace(0.01, 0.05, n)
    if n > 2:
        w = w.at[n // 2].set(0.0)           # the pilot
    return bufs_q, p1, p2, w


def _plans(r4, n):
    cands = [(r4, n), (r4, 1), (None, None)]
    for br in {max(1, r4 // 2), 3 if r4 % 3 == 0 else 1}:
        if r4 % br == 0:
            cands.append((br, 1))
    for bw in (3, 11, 2, 4):
        if n % bw == 0 and bw < n:
            cands.append((r4, bw))
    return cands


# ---------------------------------------------------------------------------
# Bitwise kernel-vs-oracle parity, every plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 8, 33])
@pytest.mark.parametrize("t", [1, 3])
@pytest.mark.parametrize("thr", [0, 3277])          # RR off / p = 0.05
def test_masked_uplink_bitwise_every_plan(n, t, thr):
    rows_flat = 96
    r4 = rows_flat // 4
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=10 * n + t)
    betas = jnp.linspace(0.1, 0.3, n)
    wq = quantize_weights(w, FIX_BITS)
    masks = net_masks(0, n, t, (r4, 512))
    bits = rr_bits(1, t, (n, r4, 512))

    oracle = jax.jit(lambda q, a, b, m, bt, tt: pref.masked_codes_ref(
        q.reshape(n, r4, 512), a.reshape(r4, 512), b.reshape(r4, 512),
        tt, betas, 0.01, wq, m, bt, thr))
    want = np.asarray(oracle(bufs_q, p1, p2, masks, bits, jnp.float32(t)))
    for br, bw in _plans(r4, n):
        got = ops.flat_ternary_pack_masked(
            bufs_q, p1, p2, t=t, beta=betas, alpha1=0.01, wq=wq,
            masks=masks, rr_bits=bits, rr_threshold=thr, interpret=True,
            block_rows=br, block_workers=bw)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"plan ({br}, {bw})")


@pytest.mark.parametrize("n", [1, 8, 33])
@pytest.mark.parametrize("t", [1, 3])
def test_masked_master_bitwise_every_plan(n, t):
    rows_flat = 96
    r4 = rows_flat // 4
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=5 * n + t)
    wq = quantize_weights(w, FIX_BITS)
    masks = net_masks(0, n, t, (r4, 512))
    y = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=t, beta=0.2, alpha1=0.01, wq=wq, masks=masks,
        rr_bits=masks, rr_threshold=0, interpret=True)
    q = jax.random.normal(jax.random.PRNGKey(99), (rows_flat, 128))
    sm = 2.0 ** -FIX_BITS

    # Traced scalars in the jitted oracle — the kernel gets them as runtime
    # operands, and constant-baking flips XLA:CPU's FMA choice (see
    # privacy/ref.py docstring).
    oracle = jax.jit(lambda qq, yy, a, b, tt, ss: pref.masked_master_ref(
        qq.reshape(r4, 512), yy, jnp.sum(wq), a.reshape(r4, 512),
        b.reshape(r4, 512), tt, 0.01, ss))
    want = np.asarray(oracle(q, y, p1, p2, jnp.float32(t),
                             jnp.float32(sm))).reshape(rows_flat, 128)
    for br, bw in _plans(r4, n):
        got = ops.flat_masked_master_update(
            q, y, jnp.sum(wq), p1, p2, t=t, alpha0=0.01, scale_mult=sm,
            interpret=True, block_rows=br, block_workers=bw)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"plan ({br}, {bw})")


# ---------------------------------------------------------------------------
# Mask cancellation: exact, in the integer domain
# ---------------------------------------------------------------------------

def test_net_masks_sum_to_zero():
    for n in (2, 5, 8):
        m = net_masks(7, n, 3, (6, 512))
        total = jnp.sum(m, axis=0, dtype=jnp.uint32)
        assert int(jnp.count_nonzero(total)) == 0
    # partial participation: active pairs cancel over the sampled set
    pm = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    m = net_masks(7, 5, 3, (6, 512), participation=pm)
    total = jnp.sum(m * pm[:, None, None].astype(jnp.uint32), axis=0,
                    dtype=jnp.uint32)
    assert int(jnp.count_nonzero(total)) == 0
    # non-participants carry a zero mask
    assert int(jnp.count_nonzero(m[1])) == 0
    assert int(jnp.count_nonzero(m[4])) == 0


def test_masked_aggregate_bitwise_equals_unmasked():
    """The whole point: with masks on, the master's output is bit-identical
    to the zero-mask run — cancellation is exact, any residue would show."""
    n, rows_flat = 6, 96
    r4 = rows_flat // 4
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=3)
    wq = quantize_weights(w, FIX_BITS)
    masks = net_masks(11, n, 5, (r4, 512))
    zeros = jnp.zeros_like(masks)
    q = bufs_q[0]
    outs = []
    for m in (masks, zeros):
        y = ops.flat_ternary_pack_masked(
            bufs_q, p1, p2, t=5, beta=0.2, alpha1=0.01, wq=wq, masks=m,
            rr_bits=m, rr_threshold=0, interpret=True)
        outs.append(ops.flat_masked_master_update(
            q, y, jnp.sum(wq), p1, p2, t=5, alpha0=0.01,
            scale_mult=2.0 ** -FIX_BITS, interpret=True))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    # and a masked word stream looks nothing like the unmasked one
    y_m = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=5, beta=0.2, alpha1=0.01, wq=wq, masks=masks,
        rr_bits=masks, rr_threshold=0, interpret=True)
    y_u = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=5, beta=0.2, alpha1=0.01, wq=wq, masks=zeros,
        rr_bits=zeros, rr_threshold=0, interpret=True)
    frac_equal = float(jnp.mean((y_m == y_u).astype(jnp.float32)))
    assert frac_equal < 0.01, frac_equal


def test_masked_vs_plain_float_wire_quantization_bound():
    """DP off: the only masked-vs-plain difference is the fixed-point
    weight rounding — bounded by sum_k |W_k/2^bits - w_k| * max|mult|."""
    n, rows_flat = 8, 256
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=4)
    wq = quantize_weights(w, FIX_BITS)
    masks = net_masks(0, n, 3, (rows_flat // 4, 512))
    y = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, wq=wq, masks=masks,
        rr_bits=masks, rr_threshold=0, interpret=True)
    got = ops.flat_masked_master_update(
        bufs_q[0], y, jnp.sum(wq), p1, p2, t=3, alpha0=0.01,
        scale_mult=2.0 ** -FIX_BITS, interpret=True)
    packed = ops.flat_ternary_pack_stacked(
        bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, interpret=True)
    want = ops.flat_master_update(bufs_q[0], packed, w, p1, p2, t=3,
                                  alpha0=0.01, interpret=True)
    step_max = float(jnp.max(jnp.abs(p1 - p2)))
    bound = n * 2.0 ** -(FIX_BITS + 1) * 2 * step_max + 1e-6
    assert float(jnp.max(jnp.abs(got - want))) <= bound


# ---------------------------------------------------------------------------
# Randomized response: rate and unbiasedness
# ---------------------------------------------------------------------------

def test_rr_flip_rate_matches_epsilon():
    spec = PrivacySpec(dp_epsilon=2.0)
    p = spec.flip_prob
    fields = jnp.ones((1 << 16,), jnp.uint32)          # all codes = 0
    bits = jax.random.bits(jax.random.PRNGKey(0), fields.shape, jnp.uint32)
    out = rr_fields(fields, bits, spec.rr_threshold)
    changed = float(jnp.mean((out != fields).astype(jnp.float32)))
    # P(output != input) = p * 2/3
    assert abs(changed - p * 2.0 / 3.0) < 0.01
    # epsilon bookkeeping is self-consistent
    assert abs(spec.eps_round - np.log((3 - 2 * p) / p)) < 1e-9
    # identity at threshold 0
    np.testing.assert_array_equal(np.asarray(rr_fields(fields, bits, 0)),
                                  np.asarray(fields))


def test_rr_unbiasing_recovers_noiseless_update():
    """E[masked master update] over the RR randomness == the noiseless
    masked update (statistical, fixed seeds)."""
    n, rows_flat, draws = 6, 32, 192
    r4 = rows_flat // 4
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=6)
    spec = PrivacySpec(dp_epsilon=2.0)     # flip_prob ~ 0.318
    wq = quantize_weights(w, FIX_BITS)
    zeros = jnp.zeros((n, r4, 512), jnp.uint32)
    sm_dp = spec.scale_mult
    q = bufs_q[0].reshape(r4, 512)
    p1r, p2r = p1.reshape(r4, 512), p2.reshape(r4, 512)

    def one(seed):
        bits = jax.random.bits(jax.random.PRNGKey(seed),
                               (n, r4, 512), jnp.uint32)
        y = pref.masked_codes_ref(bufs_q.reshape(n, r4, 512), p1r, p2r, 3,
                                  0.2, 0.01, wq, zeros, bits,
                                  spec.rr_threshold)
        return pref.masked_master_ref(q, y, jnp.sum(wq), p1r, p2r, 3,
                                      0.01, sm_dp)

    outs = jax.vmap(one)(jnp.arange(draws))
    noiseless = pref.masked_master_ref(
        q, pref.masked_codes_ref(bufs_q.reshape(n, r4, 512), p1r, p2r, 3,
                                 0.2, 0.01, wq, zeros, zeros, 0),
        jnp.sum(wq), p1r, p2r, 3, 0.01, 2.0 ** -FIX_BITS)
    # Mean |error| of the AVERAGED update concentrates as 1/sqrt(draws) of
    # a single draw's mean |error| iff the mechanism is unbiased; a
    # residual bias (e.g. a wrong 1/(1-p) factor) would not shrink.
    mean_err = float(jnp.mean(jnp.abs(jnp.mean(outs, axis=0) - noiseless)))
    single_err = float(jnp.mean(jnp.abs(outs[0] - noiseless)))
    assert single_err > 10 * mean_err      # noise is real ...
    assert mean_err < 3.0 * single_err / np.sqrt(draws) + 1e-5


# ---------------------------------------------------------------------------
# Launch structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan", [(None, None), (8, 1), (24, 4)])
def test_masked_kernels_single_launch_every_plan(plan):
    n, rows_flat = 8, 96
    r4 = rows_flat // 4
    br, bw = plan
    bufs_q, p1, p2, w = _fixture(n, rows_flat)
    wq = quantize_weights(w, FIX_BITS)
    masks = jnp.zeros((n, r4, 512), jnp.uint32)
    counts = jaxpr_primitive_counts(
        lambda a, b, c, m: ops.flat_ternary_pack_masked(
            a, b, c, t=3, beta=0.2, alpha1=0.01, wq=wq, masks=m,
            rr_bits=m, rr_threshold=0, interpret=True, block_rows=br,
            block_workers=bw),
        bufs_q, p1, p2, masks)
    assert counts.get("pallas_call") == 1, counts
    y = jnp.zeros((n, r4, 512), jnp.uint32)
    counts = jaxpr_primitive_counts(
        lambda q, yy: ops.flat_masked_master_update(
            q, yy, jnp.sum(wq), q, q, t=3, alpha0=0.01,
            scale_mult=2.0 ** -FIX_BITS, interpret=True, block_rows=br,
            block_workers=bw),
        bufs_q[0], y)
    assert counts.get("pallas_call") == 1, counts


# ---------------------------------------------------------------------------
# Tuner: masked kinds + fallback
# ---------------------------------------------------------------------------

def test_masked_kinds_registered():
    assert "uplink_masked" in tune.KINDS
    assert "master_masked" in tune.KINDS
    assert tune.MASKED_FALLBACK == {"uplink_masked": "uplink_stacked",
                                    "master_masked": "master"}


def test_lookup_falls_back_to_unmasked_plan():
    r4, n = 48, 6
    keys = [(k, r4, n, "cpu-interpret")
            for k in ("uplink_stacked", "master", "uplink_masked",
                      "master_masked")]
    try:
        tune.set_plan("uplink_stacked", r4, n,
                      {"block_rows": 24, "block_workers": 2},
                      backend="cpu-interpret")
        tune.set_plan("master", r4, n,
                      {"block_rows": 16, "block_workers": 3},
                      backend="cpu-interpret")
        # untuned masked kinds borrow the unmasked plans ...
        assert tune.lookup("uplink_masked", r4, n, interpret=True) == (24, 2)
        assert tune.lookup("master_masked", r4, n, interpret=True) == (16, 3)
        # ... until a masked entry exists, which then wins
        tune.set_plan("uplink_masked", r4, n,
                      {"block_rows": 48, "block_workers": 1},
                      backend="cpu-interpret")
        assert tune.lookup("uplink_masked", r4, n, interpret=True) == (48, 1)
    finally:
        for key in keys:
            tune._TABLE.pop(key, None)


def test_autotune_masked_sweeps_store_winners():
    r4, n = 16, 4
    keys = [("uplink_masked", r4, n, "cpu-interpret"),
            ("master_masked", r4, n, "cpu-interpret")]
    try:
        rec = tune.autotune_masked_uplink(r4, n, interpret=True, reps=1)
        assert rec["timings"] and all(r["us"] > 0 for r in rec["timings"])
        assert keys[0] in tune._TABLE
        rec_m = tune.autotune_masked_master(r4, n, interpret=True, reps=1)
        assert keys[1] in tune._TABLE
        assert rec_m["best"]["block_rows"] <= r4
    finally:
        for key in keys:
            tune._TABLE.pop(key, None)


def test_privacy_spec_validation():
    from repro.privacy.spec import MAX_DP_EPSILON, MIN_DP_EPSILON
    with pytest.raises(ValueError, match="dp_epsilon"):
        PrivacySpec(dp_epsilon=1e-5)      # p rounds to 1: unbias undefined
    with pytest.raises(ValueError, match="dp_epsilon"):
        PrivacySpec(dp_epsilon=99.0)      # threshold rounds to 0: no-op RR
    with pytest.raises(ValueError, match="fixpoint_bits"):
        PrivacySpec(fixpoint_bits=30)
    for eps in (MIN_DP_EPSILON, MAX_DP_EPSILON):   # boundaries construct
        spec = PrivacySpec(dp_epsilon=eps)
        assert 1 <= spec.rr_threshold <= (1 << 16) - 1
        assert np.isfinite(spec.scale_mult)


def test_quantize_weights_bounds():
    w = jnp.asarray([0.0, 0.25, 1.0 / 3.0, 0.5])
    wq = quantize_weights(w, FIX_BITS)
    back = np.asarray(wq, np.float64) / (1 << FIX_BITS)
    assert np.max(np.abs(back - np.asarray(w, np.float64))) \
        <= 2.0 ** -(FIX_BITS + 1)
    # pair structure sanity
    c, i_idx, j_idx = masking.pair_incidence(5)
    assert c.shape == (5, 10)
    np.testing.assert_array_equal(c.sum(axis=0), 0)
