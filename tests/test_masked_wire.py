"""Secure-aggregation wire kernels: the masked uplink, the sum-then-unmask
master, and the privacy autotuner kinds.

The contract under test:
  * both masked kernels are BITWISE equal to the jnp oracles
    (``repro.privacy.ref``, jitted with traced scalars) for every
    (block_rows, block_workers) plan, n in {1, 8, 33}, both round
    branches, RR on and off, and BOTH wire moduli — the wire is integer
    end-to-end, so parity is exact, never allclose. The kernels generate
    their mask/RR streams in-register from counter keys while the oracles
    consume the host-materialized ``net_masks``/``rr_bits`` expansions, so
    parity also proves the in-kernel PRNG reproduces the reference
    streams bit-for-bit;
  * pairwise masks cancel EXACTLY: a masked aggregate is bit-identical to
    the unmasked (``use_masks=False``) aggregate — mod 2**modulus_bits
    cancellation — and the net masks sum to zero, including under partial
    participation;
  * with DP off the masked round differs from the plain float wire only
    by the fixed-point weight rounding (<= 2**-(bits+1) per weight);
  * the RR mechanism flips at the configured rate and unbiasing makes the
    EXPECTED master update equal the noiseless one;
  * either masked kernel is exactly ONE pallas launch under every plan,
    and the uplink launch consumes NO mask-shaped tensor operand (the
    in-kernel PRNG removed the HBM mask planes) and no threefry PRNG;
  * the tuner knows the masked kinds and chains fallbacks
    ``*_masked16`` -> ``*_masked`` -> unmasked down to the heuristic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, tune
from repro.privacy import (PrivacySpec, masking, net_masks, pair_signs,
                           pair_stream_keys, quantize_weights, rr_bits,
                           rr_fields, rr_stream_keys)
from repro.privacy import ref as pref
from repro.utils import jaxpr_primitive_counts

FIX_BITS = {16: 14, 32: 24}


def _fixture(n, rows_flat, seed=0):
    k = jax.random.PRNGKey(seed)
    bufs_q = jax.random.normal(k, (n, rows_flat, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows_flat, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows_flat, 128))
    w = jnp.linspace(0.01, 0.05, n)
    if n > 2:
        w = w.at[n // 2].set(0.0)           # the pilot
    return bufs_q, p1, p2, w


def _keys(n, t, mask_seed=0, dp_seed=1):
    return (pair_stream_keys(mask_seed, n, t), pair_signs(n),
            rr_stream_keys(dp_seed, t, n))


def _plans(r4, n):
    cands = [(r4, n), (r4, 1), (None, None)]
    for br in {max(1, r4 // 2), 3 if r4 % 3 == 0 else 1}:
        if r4 % br == 0:
            cands.append((br, 1))
    for bw in (3, 11, 2, 4):
        if n % bw == 0 and bw < n:
            cands.append((r4, bw))
    return cands


# ---------------------------------------------------------------------------
# Bitwise kernel-vs-oracle parity, every plan, both moduli
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wb", [16, 32])
@pytest.mark.parametrize("n", [1, 8, 33])
@pytest.mark.parametrize("t", [1, 3])
@pytest.mark.parametrize("thr", [0, 3277])          # RR off / p = 0.05
def test_masked_uplink_bitwise_every_plan(wb, n, t, thr):
    rows_flat = 96
    r4 = rows_flat // 4
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=10 * n + t)
    betas = jnp.linspace(0.1, 0.3, n)
    wq = quantize_weights(w, FIX_BITS[wb])
    keys, signs, rrk = _keys(n, t)
    masks = net_masks(0, n, t, (r4, 512), word_bits=wb)
    bits = rr_bits(1, t, n, (r4, 512))

    oracle = jax.jit(lambda q, a, b, m, bt, tt: pref.masked_codes_ref(
        q.reshape(n, r4, 512), a.reshape(r4, 512), b.reshape(r4, 512),
        tt, betas, 0.01, wq, m, bt, thr))
    want = np.asarray(oracle(bufs_q, p1, p2, masks, bits, jnp.float32(t)))
    for br, bw in _plans(r4, n):
        got = ops.flat_ternary_pack_masked(
            bufs_q, p1, p2, t=t, beta=betas, alpha1=0.01, wq=wq,
            pair_keys=keys, pair_signs=signs, rr_keys=rrk,
            rr_threshold=thr, word_bits=wb, interpret=True,
            block_rows=br, block_workers=bw)
        assert got.dtype == (jnp.uint16 if wb == 16 else jnp.uint32)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"plan ({br}, {bw})")


@pytest.mark.parametrize("wb", [16, 32])
@pytest.mark.parametrize("n", [1, 8, 33])
@pytest.mark.parametrize("t", [1, 3])
def test_masked_master_bitwise_every_plan(wb, n, t):
    rows_flat = 96
    r4 = rows_flat // 4
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=5 * n + t)
    wq = quantize_weights(w, FIX_BITS[wb])
    keys, signs, rrk = _keys(n, t)
    y = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=t, beta=0.2, alpha1=0.01, wq=wq, pair_keys=keys,
        pair_signs=signs, rr_keys=rrk, rr_threshold=0, word_bits=wb,
        interpret=True)
    q = jax.random.normal(jax.random.PRNGKey(99), (rows_flat, 128))
    sm = 2.0 ** -FIX_BITS[wb]

    # Traced scalars in the jitted oracle — the kernel gets them as runtime
    # operands, and constant-baking flips XLA:CPU's FMA choice (see
    # privacy/ref.py docstring).
    oracle = jax.jit(lambda qq, yy, a, b, tt, ss: pref.masked_master_ref(
        qq.reshape(r4, 512), yy, jnp.sum(wq), a.reshape(r4, 512),
        b.reshape(r4, 512), tt, 0.01, ss))
    want = np.asarray(oracle(q, y, p1, p2, jnp.float32(t),
                             jnp.float32(sm))).reshape(rows_flat, 128)
    for br, bw in _plans(r4, n):
        got = ops.flat_masked_master_update(
            q, y, jnp.sum(wq), p1, p2, t=t, alpha0=0.01, scale_mult=sm,
            interpret=True, block_rows=br, block_workers=bw)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"plan ({br}, {bw})")


# ---------------------------------------------------------------------------
# Mask cancellation: exact, in the integer domain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wb", [16, 32])
def test_net_masks_sum_to_zero(wb):
    for n in (2, 5, 8):
        m = net_masks(7, n, 3, (6, 512), word_bits=wb)
        total = jnp.sum(m, axis=0, dtype=m.dtype)
        assert int(jnp.count_nonzero(total)) == 0
    # partial participation: active pairs cancel over the sampled set
    pm = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    m = net_masks(7, 5, 3, (6, 512), word_bits=wb, participation=pm)
    total = jnp.sum(m * pm[:, None, None].astype(m.dtype), axis=0,
                    dtype=m.dtype)
    assert int(jnp.count_nonzero(total)) == 0
    # non-participants carry a zero mask
    assert int(jnp.count_nonzero(m[1])) == 0
    assert int(jnp.count_nonzero(m[4])) == 0


@pytest.mark.parametrize("wb", [16, 32])
def test_masked_aggregate_bitwise_equals_unmasked(wb):
    """The whole point: with masks on, the master's output is bit-identical
    to the unmasked run — cancellation is exact, any residue would show."""
    n, rows_flat = 6, 96
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=3)
    wq = quantize_weights(w, FIX_BITS[wb])
    keys, signs, rrk = _keys(n, 5, mask_seed=11)
    q = bufs_q[0]
    outs = []
    for use_masks in (True, False):
        y = ops.flat_ternary_pack_masked(
            bufs_q, p1, p2, t=5, beta=0.2, alpha1=0.01, wq=wq,
            pair_keys=keys, pair_signs=signs, rr_keys=rrk,
            rr_threshold=0, word_bits=wb, use_masks=use_masks,
            interpret=True)
        outs.append(ops.flat_masked_master_update(
            q, y, jnp.sum(wq), p1, p2, t=5, alpha0=0.01,
            scale_mult=2.0 ** -FIX_BITS[wb], interpret=True))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    # and a masked word stream looks nothing like the unmasked one
    y_pair = [ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=5, beta=0.2, alpha1=0.01, wq=wq, pair_keys=keys,
        pair_signs=signs, rr_keys=rrk, rr_threshold=0, word_bits=wb,
        use_masks=um, interpret=True) for um in (True, False)]
    frac_equal = float(jnp.mean((y_pair[0] == y_pair[1]).astype(jnp.float32)))
    assert frac_equal < 0.01, frac_equal


@pytest.mark.parametrize("wb", [16, 32])
def test_masked_vs_plain_float_wire_quantization_bound(wb):
    """DP off: the only masked-vs-plain difference is the fixed-point
    weight rounding — bounded by sum_k |W_k/2^bits - w_k| * max|mult|."""
    n, rows_flat = 8, 256
    fb = FIX_BITS[wb]
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=4)
    wq = quantize_weights(w, fb)
    keys, signs, rrk = _keys(n, 3)
    y = ops.flat_ternary_pack_masked(
        bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, wq=wq, pair_keys=keys,
        pair_signs=signs, rr_keys=rrk, rr_threshold=0, word_bits=wb,
        interpret=True)
    got = ops.flat_masked_master_update(
        bufs_q[0], y, jnp.sum(wq), p1, p2, t=3, alpha0=0.01,
        scale_mult=2.0 ** -fb, interpret=True)
    packed = ops.flat_ternary_pack_stacked(
        bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, interpret=True)
    want = ops.flat_master_update(bufs_q[0], packed, w, p1, p2, t=3,
                                  alpha0=0.01, interpret=True)
    step_max = float(jnp.max(jnp.abs(p1 - p2)))
    bound = n * 2.0 ** -(fb + 1) * 2 * step_max + 1e-6
    assert float(jnp.max(jnp.abs(got - want))) <= bound


def test_fixpoint_sum_never_wraps_headroom():
    """The documented bound: sum_k W_k <= 2**fb + N/2 stays inside the
    signed half of the modulus for any cohort up to
    ``wrap_headroom_workers()``."""
    for mb in (16, 32):
        spec = PrivacySpec(modulus_bits=mb)
        n_max = spec.wrap_headroom_workers()
        assert (1 << spec.fixpoint_bits) + n_max // 2 < 1 << (mb - 1)
        # and the default headroom admits any realistic cohort
        assert n_max >= 1000


# ---------------------------------------------------------------------------
# Randomized response: rate and unbiasedness
# ---------------------------------------------------------------------------

def test_rr_flip_rate_matches_epsilon():
    spec = PrivacySpec(dp_epsilon=2.0)
    p = spec.flip_prob
    fields = jnp.ones((1 << 16,), jnp.uint32)          # all codes = 0
    bits = jax.random.bits(jax.random.PRNGKey(0), fields.shape, jnp.uint32)
    out = rr_fields(fields, bits, spec.rr_threshold)
    changed = float(jnp.mean((out != fields).astype(jnp.float32)))
    # P(output != input) = p * 2/3
    assert abs(changed - p * 2.0 / 3.0) < 0.01
    # epsilon bookkeeping is self-consistent
    assert abs(spec.eps_round - np.log((3 - 2 * p) / p)) < 1e-9
    # identity at threshold 0
    np.testing.assert_array_equal(np.asarray(rr_fields(fields, bits, 0)),
                                  np.asarray(fields))


def test_rr_unbiasing_recovers_noiseless_update():
    """E[masked master update] over the RR randomness == the noiseless
    masked update (statistical, fixed seeds; 32-bit oracle modulus)."""
    n, rows_flat, draws = 6, 32, 192
    r4 = rows_flat // 4
    fb = FIX_BITS[32]
    bufs_q, p1, p2, w = _fixture(n, rows_flat, seed=6)
    spec = PrivacySpec(dp_epsilon=2.0, modulus_bits=32)
    wq = quantize_weights(w, fb)
    zeros = jnp.zeros((n, r4, 512), jnp.uint32)
    sm_dp = spec.scale_mult
    q = bufs_q[0].reshape(r4, 512)
    p1r, p2r = p1.reshape(r4, 512), p2.reshape(r4, 512)

    def one(seed):
        bits = jax.random.bits(jax.random.PRNGKey(seed),
                               (n, r4, 512), jnp.uint32)
        y = pref.masked_codes_ref(bufs_q.reshape(n, r4, 512), p1r, p2r, 3,
                                  0.2, 0.01, wq, zeros, bits,
                                  spec.rr_threshold)
        return pref.masked_master_ref(q, y, jnp.sum(wq), p1r, p2r, 3,
                                      0.01, sm_dp)

    outs = jax.vmap(one)(jnp.arange(draws))
    noiseless = pref.masked_master_ref(
        q, pref.masked_codes_ref(bufs_q.reshape(n, r4, 512), p1r, p2r, 3,
                                 0.2, 0.01, wq, zeros, zeros, 0),
        jnp.sum(wq), p1r, p2r, 3, 0.01, 2.0 ** -fb)
    # Mean |error| of the AVERAGED update concentrates as 1/sqrt(draws) of
    # a single draw's mean |error| iff the mechanism is unbiased; a
    # residual bias (e.g. a wrong 1/(1-p) factor) would not shrink.
    mean_err = float(jnp.mean(jnp.abs(jnp.mean(outs, axis=0) - noiseless)))
    single_err = float(jnp.mean(jnp.abs(outs[0] - noiseless)))
    assert single_err > 10 * mean_err      # noise is real ...
    assert mean_err < 3.0 * single_err / np.sqrt(draws) + 1e-5


# ---------------------------------------------------------------------------
# Launch structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wb", [16, 32])
@pytest.mark.parametrize("plan", [(None, None), (8, 1), (24, 4)])
def test_masked_kernels_single_launch_every_plan(wb, plan):
    n, rows_flat = 8, 96
    r4 = rows_flat // 4
    br, bw = plan
    bufs_q, p1, p2, w = _fixture(n, rows_flat)
    wq = quantize_weights(w, FIX_BITS[wb])
    keys, signs, rrk = _keys(n, 3)
    counts = jaxpr_primitive_counts(
        lambda a, b, c, kk, ss, rr: ops.flat_ternary_pack_masked(
            a, b, c, t=3, beta=0.2, alpha1=0.01, wq=wq, pair_keys=kk,
            pair_signs=ss, rr_keys=rr, rr_threshold=0, word_bits=wb,
            interpret=True, block_rows=br, block_workers=bw),
        bufs_q, p1, p2, keys, signs, rrk)
    assert counts.get("pallas_call") == 1, counts
    # the in-kernel counter PRNG is pure integer arithmetic: the launch
    # needs no threefry (jax.random) primitives anywhere in its program
    assert not any("threefry" in k for k in counts), counts
    word = jnp.uint16 if wb == 16 else jnp.uint32
    y = jnp.zeros((n, r4, 512), word)
    counts = jaxpr_primitive_counts(
        lambda q, yy: ops.flat_masked_master_update(
            q, yy, jnp.sum(wq), q, q, t=3, alpha0=0.01,
            scale_mult=2.0 ** -FIX_BITS[wb], interpret=True, block_rows=br,
            block_workers=bw),
        bufs_q[0], y)
    assert counts.get("pallas_call") == 1, counts


def test_masked_uplink_consumes_no_mask_tensor():
    """The in-kernel PRNG contract, stated on the jaxpr: the uplink
    launch's operands contain nothing mask-shaped — the largest unsigned
    operand is the (N, N) key matrix."""
    n, rows_flat = 8, 96
    bufs_q, p1, p2, w = _fixture(n, rows_flat)
    wq = quantize_weights(w, FIX_BITS[16])
    keys, signs, rrk = _keys(n, 3)
    jaxpr = jax.make_jaxpr(
        lambda a, b, c, kk, ss, rr: ops.flat_ternary_pack_masked(
            a, b, c, t=3, beta=0.2, alpha1=0.01, wq=wq, pair_keys=kk,
            pair_signs=ss, rr_keys=rr, rr_threshold=3277, word_bits=16,
            interpret=True))(bufs_q, p1, p2, keys, signs, rrk)
    from repro.utils import iter_jaxpr_eqns
    launches = [e for e in iter_jaxpr_eqns(jaxpr.jaxpr, into_pallas=False)
                if e.primitive.name == "pallas_call"]
    assert len(launches) == 1
    for v in launches[0].invars:
        aval = v.aval
        if jnp.issubdtype(aval.dtype, jnp.unsignedinteger):
            assert int(np.prod(aval.shape)) <= n * n, (
                f"mask-sized unsigned operand {aval.shape} {aval.dtype}")


# ---------------------------------------------------------------------------
# Tuner: masked kinds + fallback chain
# ---------------------------------------------------------------------------

def test_masked_kinds_registered():
    for kind in ("uplink_masked", "master_masked", "uplink_masked16",
                 "master_masked16"):
        assert kind in tune.KINDS
    assert tune.MASKED_FALLBACK == {
        "uplink_masked16": "uplink_masked",
        "master_masked16": "master_masked",
        "uplink_masked": "uplink_stacked",
        "master_masked": "master",
        "partial_sum_masked16": "partial_sum_masked",
        "partial_sum_masked": "partial_sum",
        "mask_repair16": "mask_repair",
        "mask_repair": "uplink"}


def test_lookup_falls_back_to_unmasked_plan():
    r4, n = 48, 6
    keys = [(k, r4, n, "cpu-interpret")
            for k in ("uplink_stacked", "master", "uplink_masked",
                      "master_masked", "uplink_masked16",
                      "master_masked16")]
    try:
        tune.set_plan("uplink_stacked", r4, n,
                      {"block_rows": 24, "block_workers": 2},
                      backend="cpu-interpret")
        tune.set_plan("master", r4, n,
                      {"block_rows": 16, "block_workers": 3},
                      backend="cpu-interpret")
        # a table with ONLY unmasked entries resolves every masked kind
        # through the chain *_masked16 -> *_masked -> unmasked
        for kind in ("uplink_masked", "uplink_masked16"):
            assert tune.lookup(kind, r4, n, interpret=True) == (24, 2)
        for kind in ("master_masked", "master_masked16"):
            assert tune.lookup(kind, r4, n, interpret=True) == (16, 3)
        # a mid-chain entry wins over the chain tail ...
        tune.set_plan("uplink_masked", r4, n,
                      {"block_rows": 48, "block_workers": 1},
                      backend="cpu-interpret")
        assert tune.lookup("uplink_masked16", r4, n, interpret=True) == (48, 1)
        # ... and an exact 16-bit entry beats everything
        tune.set_plan("uplink_masked16", r4, n,
                      {"block_rows": 12, "block_workers": 6},
                      backend="cpu-interpret")
        assert tune.lookup("uplink_masked16", r4, n, interpret=True) == (12, 6)
    finally:
        for key in keys:
            tune._TABLE.pop(key, None)


def test_lookup_resolves_every_kind_on_empty_table():
    """Regression: with NO tuned entries at all, every registered kind
    still resolves (heuristic tail of the fallback chain)."""
    r4, n = 32, 4
    for kind in tune.KINDS:
        br, bw = tune.lookup(kind, r4, n, interpret=True)
        if kind.startswith("partial_sum"):
            # block_workers means output GROUPS per grid step for the tree
            # sub-aggregate kinds and may be the all-groups sentinel — the
            # ops wrappers clamp it to the level width
            bw = tune.fit_block_workers(n, bw)
        assert r4 % br == 0 and n % bw == 0, (kind, br, bw)


@pytest.mark.parametrize("wb", [16, 32])
def test_autotune_masked_sweeps_store_winners(wb):
    r4, n = 16, 4
    suffix = "16" if wb == 16 else ""
    keys = [(f"uplink_masked{suffix}", r4, n, "cpu-interpret"),
            (f"master_masked{suffix}", r4, n, "cpu-interpret")]
    try:
        rec = tune.autotune_masked_uplink(r4, n, interpret=True, reps=1,
                                          word_bits=wb)
        assert rec["kind"] == keys[0][0]
        assert rec["timings"] and all(r["us"] > 0 for r in rec["timings"])
        assert keys[0] in tune._TABLE
        rec_m = tune.autotune_masked_master(r4, n, interpret=True, reps=1,
                                            word_bits=wb)
        assert keys[1] in tune._TABLE
        assert rec_m["best"]["block_rows"] <= r4
    finally:
        for key in keys:
            tune._TABLE.pop(key, None)


def test_privacy_spec_validation():
    from repro.privacy.spec import MAX_DP_EPSILON, MIN_DP_EPSILON
    with pytest.raises(ValueError, match="dp_epsilon"):
        PrivacySpec(dp_epsilon=1e-5)      # p rounds to 1: unbias undefined
    with pytest.raises(ValueError, match="dp_epsilon"):
        PrivacySpec(dp_epsilon=99.0)      # threshold rounds to 0: no-op RR
    with pytest.raises(ValueError, match="fixpoint_bits"):
        PrivacySpec(fixpoint_bits=30, modulus_bits=32)
    with pytest.raises(ValueError, match="fixpoint_bits"):
        PrivacySpec(fixpoint_bits=24)     # 16-bit default can't hold 2**24
    with pytest.raises(ValueError, match="modulus_bits"):
        PrivacySpec(modulus_bits=8)
    for eps in (MIN_DP_EPSILON, MAX_DP_EPSILON):   # boundaries construct
        spec = PrivacySpec(dp_epsilon=eps)
        assert 1 <= spec.rr_threshold <= (1 << 16) - 1
        assert np.isfinite(spec.scale_mult)
    # the modulus picks the coupled defaults and the wire dtype
    assert PrivacySpec().fixpoint_bits == 14
    assert PrivacySpec().word_dtype == jnp.uint16
    assert PrivacySpec(modulus_bits=32).fixpoint_bits == 24
    assert PrivacySpec(modulus_bits=32).word_dtype == jnp.uint32


def test_quantize_weights_bounds():
    fb = FIX_BITS[32]
    w = jnp.asarray([0.0, 0.25, 1.0 / 3.0, 0.5])
    wq = quantize_weights(w, fb)
    back = np.asarray(wq, np.float64) / (1 << fb)
    assert np.max(np.abs(back - np.asarray(w, np.float64))) \
        <= 2.0 ** -(fb + 1)
    # pair structure sanity
    c, i_idx, j_idx = masking.pair_incidence(5)
    assert c.shape == (5, 10)
    np.testing.assert_array_equal(c.sum(axis=0), 0)
    # signs are antisymmetric with a zero diagonal
    s = np.asarray(pair_signs(5))
    np.testing.assert_array_equal(s, -s.T)
    np.testing.assert_array_equal(np.diag(s), 0)
