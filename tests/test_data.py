"""Federated data splits (Figs 2/3/5) + pipeline."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import BatchIterator, federated_loaders
from repro.data.synthetic import (SyntheticClassification, SyntheticLM,
                                  dirichlet_split, random_share_split,
                                  sequence_split)


def _labels(n=1000, c=10, seed=0):
    return np.random.default_rng(seed).integers(0, c, n).astype(np.int64)


def test_random_share_split_partition():
    y = _labels()
    splits = random_share_split(y, 5, seed=1)
    allidx = np.concatenate(splits)
    assert len(np.unique(allidx)) == len(allidx)          # disjoint
    assert len(allidx) <= len(y)
    # stratification: per-worker class histogram roughly proportional
    for s in splits:
        counts = np.bincount(y[s], minlength=10)
        assert counts.min() > 0                            # every class present


def test_random_share_split_imbalanced_sizes():
    y = _labels(2000)
    splits = random_share_split(y, 8, seed=3)
    sizes = np.array([len(s) for s in splits])
    assert sizes.std() > 0                                 # heterogeneous
    assert sizes.min() > 0.3 / 8 * 2000 * 0.5              # bounded imbalance


def test_dirichlet_split_nontrivial_skew():
    y = _labels(3000)
    iid = random_share_split(y, 6, seed=0)
    noniid = dirichlet_split(y, 6, alpha=0.3, seed=0)
    def skew(splits):
        fracs = []
        for s in splits:
            h = np.bincount(y[s], minlength=10).astype(float)
            h = h / max(h.sum(), 1)
            fracs.append(h.std())
        return np.mean(fracs)
    assert skew(noniid) > skew(iid)                        # Table 4 setting
    for s in noniid:
        assert len(s) >= 2                                 # trainable


@given(st.integers(2, 10), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_sequence_split_covers(n_workers, seed):
    splits = sequence_split(200, n_workers, seed=seed)
    assert len(splits) == n_workers
    assert all(len(s) >= 1 for s in splits)
    allidx = np.concatenate(splits)
    assert len(np.unique(allidx)) == len(allidx)


def test_batch_iterator_epoch():
    x = np.arange(25)
    it = BatchIterator((x,), batch_size=10, seed=0)
    seen = np.concatenate([b[0] for b in it.epoch()])
    assert sorted(seen.tolist()) == list(range(25))
    assert it.steps_per_epoch() == 3


def test_federated_loaders_private_batches():
    x = np.arange(400).reshape(400, 1).astype(np.float32)
    y = _labels(400)
    splits = random_share_split(y, 4, seed=2)
    loaders = federated_loaders((x, y), splits, seed=5)
    assert len(loaders) == 4
    assert {l.batch_size for l in loaders} <= {128, 64, 32, *{l.n for l in loaders}}


def test_synthetic_tasks_learnable_shapes():
    x, y = SyntheticClassification(n_samples=128, n_features=8,
                                   n_classes=4).generate()
    assert x.shape == (128, 8) and y.shape == (128,)
    assert set(np.unique(y)) <= set(range(4))
    toks = SyntheticLM(n_sequences=4, seq_len=16, vocab=32).generate()
    assert toks.shape == (4, 16)
    assert toks.min() >= 0 and toks.max() < 32
