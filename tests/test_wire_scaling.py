"""Worker-scalable wire kernels: the grid-accumulated master, the
rows-major stacked uplink, and the block-size autotuner.

The contract under test:
  * the accumulating master is BITWISE equal to the order-exact oracle
    (``ref.packed_master_accum_ref`` under jit) for every
    (block_rows, block_workers) plan — including odd block sizes,
    non-divisible worker counts (N = 33), and masked / beta_k-weighted
    ``w`` — so autotuning can never change results;
  * master VMEM per grid step is independent of N (the old kernel's was
    linear in N);
  * the stacked uplink's grid is rows-major (worker axis minor) so the
    shared history block index is constant across consecutive steps, and
    every plan packs bitwise like the per-worker loop;
  * either kernel is exactly ONE pallas launch under every plan;
  * the tuner: backend heuristics, explicit-plan snapping, table
    save/load, and that the ``ops`` wrappers consult a pinned plan.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tune
from repro.utils import iter_jaxpr_eqns, jaxpr_primitive_counts


def _wire_fixture(n, rows_flat, seed=0):
    k = jax.random.PRNGKey(seed)
    bufs_q = jax.random.normal(k, (n, rows_flat, 128))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (rows_flat, 128))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (rows_flat, 128))
    return bufs_q, p1, p2


def _plans(r4, n):
    """Every structurally distinct plan family: one-shot, worker grid,
    multi-row grid, odd row blocks, worker sub-blocks (incl. the divisors
    of a non-divisible N like 33 → 3, 11)."""
    cands = [(r4, n), (r4, 1), (None, None)]
    for br in {max(1, r4 // 2), 3 if r4 % 3 == 0 else 1}:
        if r4 % br == 0:
            cands.append((br, 1))
    for bw in (3, 11, 2, 4):
        if n % bw == 0 and bw < n:
            cands.append((r4, bw))
    return cands


# ---------------------------------------------------------------------------
# Accumulating master: bitwise vs the order-exact oracle, every plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 8, 33])
@pytest.mark.parametrize("t", [1, 3])
def test_master_accum_bitwise_every_plan(n, t):
    rows_flat = 96                       # r4 = 24: odd block 3 divides it
    r4 = rows_flat // 4
    bufs_q, p1, p2 = _wire_fixture(n, rows_flat, seed=10 * n + t)
    betas = jnp.linspace(0.1, 0.3, n)
    packed = ops.flat_ternary_pack_stacked(
        bufs_q, p1, p2, t=t, beta=betas, alpha1=0.01, interpret=True)
    q = jax.random.normal(jax.random.PRNGKey(99), (rows_flat, 128))
    # masked + beta_k-weighted w: pilot zeroed, two workers masked out
    w = jnp.linspace(0.01, 0.05, n) * betas
    w = jnp.where(jnp.arange(n) == n // 2, 0.0, w)
    if n > 2:
        w = w.at[1].set(0.0)

    oracle = jax.jit(partial(ref.packed_master_accum_ref, t=t, alpha0=0.01))
    want = np.asarray(oracle(q.reshape(-1), packed.reshape(n, -1), w,
                             p1.reshape(-1), p2.reshape(-1)))
    for br, bw in _plans(r4, n):
        got = ops.flat_master_update(
            q, packed, w, p1, p2, t=t, alpha0=0.01, interpret=True,
            block_rows=br, block_workers=bw)
        np.testing.assert_array_equal(np.asarray(got).reshape(-1), want,
                                      err_msg=f"plan ({br}, {bw})")


def test_master_accum_agrees_with_einsum_oracle():
    """The sequential accumulation is the same math as the einsum oracle
    (allclose — reduction order differs)."""
    n, rows_flat = 8, 256
    bufs_q, p1, p2 = _wire_fixture(n, rows_flat, seed=3)
    packed = ops.flat_ternary_pack_stacked(
        bufs_q, p1, p2, t=3, beta=0.2, alpha1=0.01, interpret=True)
    q = jax.random.normal(jax.random.PRNGKey(4), (rows_flat, 128))
    w = jnp.full((n,), 0.02).at[2].set(0.0)
    got = ops.flat_master_update(q, packed, w, p1, p2, t=3, alpha0=0.01,
                                 interpret=True)
    want = ref.packed_master_update_ref(
        q.reshape(-1), packed.reshape(n, -1), w, p1.reshape(-1),
        p2.reshape(-1), 3, 0.01)
    np.testing.assert_allclose(np.asarray(got).reshape(-1), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("plan", [(None, None), (8, 1), (24, 4)])
def test_master_single_launch_every_plan(plan):
    n, rows_flat = 8, 96
    br, bw = plan
    q = jnp.zeros((rows_flat, 128))
    packed = jnp.zeros((n, rows_flat // 4, 128), jnp.uint8)
    w = jnp.full((n,), 0.02)
    counts = jaxpr_primitive_counts(
        lambda a, b, c: ops.flat_master_update(
            a, b, c, q, q, t=3, alpha0=0.01, interpret=True,
            block_rows=br, block_workers=bw),
        q, packed, w)
    assert counts.get("pallas_call") == 1, counts


# ---------------------------------------------------------------------------
# Stacked uplink: bitwise vs per-worker loop, rows-major grid structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 8, 33])
def test_stacked_uplink_bitwise_every_plan(n):
    rows_flat = 96
    r4 = rows_flat // 4
    bufs_q, p1, p2 = _wire_fixture(n, rows_flat, seed=n)
    betas = jnp.linspace(0.1, 0.3, n)
    for t in (1, 3):
        want = jnp.stack([ops.flat_ternary_pack_traced(
            bufs_q[i], p1, p2, t=t, beta=betas[i], alpha1=0.01,
            interpret=True) for i in range(n)]).reshape(n, r4, 128)
        for br, bw in _plans(r4, n):
            got = ops.flat_ternary_pack_stacked(
                bufs_q, p1, p2, t=t, beta=betas, alpha1=0.01,
                interpret=True, block_rows=br, block_workers=bw)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"t={t} plan ({br}, {bw})")


def _pallas_eqn(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    for eqn in iter_jaxpr_eqns(jaxpr.jaxpr, into_pallas=False):
        if eqn.primitive.name == "pallas_call":
            return eqn
    raise AssertionError("no pallas_call in jaxpr")


def test_stacked_uplink_grid_is_rows_major_worker_minor():
    """The multi-step plan's grid must iterate (row blocks, worker blocks)
    with workers MINOR, and the history operands' block index must not
    depend on the worker axis — that is what lets consecutive steps reuse
    the fetched history block instead of re-reading it N times."""
    n, rows_flat = 4, 256
    r4 = rows_flat // 4
    bufs_q, p1, p2 = _wire_fixture(n, rows_flat)
    eqn = _pallas_eqn(
        lambda a, b, c: ops.flat_ternary_pack_stacked(
            a, b, c, t=3, beta=0.2, alpha1=0.01, interpret=True,
            block_rows=r4 // 2, block_workers=1),
        bufs_q, p1, p2)
    gm = eqn.params["grid_mapping"]
    assert gm.grid == (2, n)             # (row blocks, worker blocks)
    # history block mappings (operands 1 and 2) ignore the worker index
    hist_maps = [bm for bm in gm.block_mappings
                 if bm.block_shape == (r4 // 2, 512)][:2]
    assert len(hist_maps) == 2
    for bm in hist_maps:
        idx = jax.core.jaxpr_as_fun(bm.index_map_jaxpr)
        i0 = idx(jnp.int32(0), jnp.int32(0))
        for k in range(1, n):            # worker step changes nothing
            np.testing.assert_array_equal(
                np.asarray(idx(jnp.int32(0), jnp.int32(k))),
                np.asarray(i0))
        assert int(idx(jnp.int32(1), jnp.int32(0))[0]) != int(i0[0])


def test_stacked_uplink_single_launch_and_no_int8():
    n, rows_flat = 8, 256
    bufs_q, p1, p2 = _wire_fixture(n, rows_flat)
    counts = jaxpr_primitive_counts(
        lambda a, b, c: ops.flat_ternary_pack_stacked(
            a, b, c, t=3, beta=0.2, alpha1=0.01, interpret=True),
        bufs_q, p1, p2)
    assert counts.get("pallas_call") == 1, counts


# ---------------------------------------------------------------------------
# Master VMEM model: O(block), independent of N
# ---------------------------------------------------------------------------

def test_master_vmem_independent_of_workers():
    br = 64
    base = tune.master_vmem_tile_bytes(br, 1)
    for n in (8, 32, 64, 256):
        assert tune.master_vmem_tile_bytes(br, 1) == base
        old = tune.master_vmem_tile_bytes_preaccum(br, n)
        assert old - base == (n - 1) * br * 128   # old model: linear in N


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

def test_tune_heuristics():
    # cpu-interpret: fewest steps (whole-operand one-shot)
    assert tune.default_plan("master", 256, 8, "cpu-interpret") == {
        "block_rows": 256, "block_workers": 8}
    # compiled backends: VMEM tile, one worker per step
    plan = tune.default_plan("master", 256, 8, "tpu")
    assert plan == {"block_rows": 64, "block_workers": 1}
    assert tune.fit_block_workers(33, 8) == 3
    assert tune.fit_block_workers(33, 11) == 11
    assert tune.fit_block_workers(1, 4) == 1
    assert tune.fit_block_rows(24, 64) == 24
    assert tune.fit_block_rows(8400 // 4, 64) in range(1, 65)


def test_ops_wrappers_consult_pinned_plan():
    """set_plan() must steer the wrappers' grid (observable in the jaxpr)."""
    n, rows_flat = 4, 256
    r4 = rows_flat // 4
    bufs_q, p1, p2 = _wire_fixture(n, rows_flat)
    key = ("uplink_stacked", r4, n, "cpu-interpret")
    try:
        tune.set_plan("uplink_stacked", r4, n,
                      {"block_rows": r4 // 2, "block_workers": 2},
                      backend="cpu-interpret")
        eqn = _pallas_eqn(
            lambda a, b, c: ops.flat_ternary_pack_stacked(
                a, b, c, t=3, beta=0.2, alpha1=0.01, interpret=True),
            bufs_q, p1, p2)
        assert eqn.params["grid_mapping"].grid == (2, 2)
    finally:
        tune._TABLE.pop(key, None)


def test_autotune_sweep_stores_winner_and_roundtrips(tmp_path):
    r4, n = 16, 4
    rec = tune.autotune_stacked(r4, n, interpret=True, reps=1)
    assert rec["timings"] and all(t["us"] > 0 for t in rec["timings"])
    key = ("uplink_stacked", r4, n, "cpu-interpret")
    try:
        assert key in tune._TABLE
        assert tune._TABLE[key] == rec["best"]
        rec_m = tune.autotune_master(r4, n, interpret=True, reps=1)
        assert ("master", r4, n, "cpu-interpret") in tune._TABLE
        assert rec_m["best"]["block_rows"] <= r4

        path = str(tmp_path / "tuned.json")
        tune.save_table(path)
        saved = dict(tune._TABLE)
        tune.clear_table()
        assert tune.load_table(path) == len(saved)
        assert tune._TABLE == saved
    finally:
        tune._TABLE.pop(key, None)
        tune._TABLE.pop(("master", r4, n, "cpu-interpret"), None)
