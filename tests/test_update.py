"""Eq. (3) master update properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.fedpc import FedPCConfig, init_state, master_round
from repro.core.update import master_update, master_update_round1


def test_zero_ternary_is_identity():
    q = jnp.asarray(np.random.default_rng(0).normal(size=100), jnp.float32)
    tern = jnp.zeros((4, 100), jnp.int8)
    w = jnp.full((4,), 0.25)
    betas = jnp.full((4,), 0.2)
    p1 = jnp.ones(100)
    p2 = jnp.zeros(100)
    out = master_update(q, tern, w, betas, k_star=0, p_prev=p1, p_prev2=p2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(q), rtol=1e-6)


def test_pilot_row_masked():
    """The pilot's own ternary codes must not contribute."""
    q = jnp.zeros(10)
    tern = jnp.stack([jnp.ones(10, jnp.int8), jnp.zeros(10, jnp.int8)])
    w = jnp.array([0.7, 0.3])
    betas = jnp.array([0.2, 0.2])
    p1, p2 = jnp.ones(10), jnp.zeros(10)
    out = master_update(q, tern, w, betas, k_star=0, p_prev=p1, p_prev2=p2)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_round1_rule():
    q = jnp.zeros(5)
    tern = jnp.stack([jnp.full(5, -1, jnp.int8), jnp.ones(5, jnp.int8)])
    shares = jnp.array([0.5, 0.5])
    out = master_update_round1(q, tern, shares, k_star=0, alpha0=0.01)
    # only worker 1 contributes: -alpha0 * 0.5 * (+1)
    np.testing.assert_allclose(np.asarray(out), -0.005, rtol=1e-5)


def test_update_direction_against_history():
    """A +1 code (same direction as history step) pushes the parameter
    further along the step; -1 pushes back (Fig. A.8)."""
    q = jnp.zeros(2)
    tern = jnp.stack([jnp.zeros(2, jnp.int8),
                      jnp.asarray([1, -1], jnp.int8)])
    w = jnp.array([0.5, 0.5])
    betas = jnp.array([0.2, 0.2])
    p1 = jnp.asarray([1.0, 1.0])
    p2 = jnp.zeros(2)                    # step +1 in both dims
    out = master_update(q, tern, w, betas, 0, p1, p2)
    assert float(out[0]) < 0             # P = Q - w*T*step = -0.1
    assert float(out[1]) > 0


@given(st.integers(2, 6), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_master_round_consistency(n, seed):
    """Full Alg.1 round: if every worker reports the same params equal to
    the global model, the new global model equals it too (fixed point)."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    cfg = FedPCConfig(n_workers=n)
    state = init_state(params, n)
    # advance past round 1 so Eq.(5) thresholds apply with params_prev=params
    state = state._replace(round=jnp.asarray(3),
                           params_prev=params)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * n), params)
    costs = jnp.asarray(rng.uniform(0.1, 1.0, n), jnp.float32)
    sizes = jnp.asarray(rng.integers(10, 100, n), jnp.float32)
    new_state, aux = master_round(cfg, state, stacked, costs, sizes)
    for a, b in zip(jax.tree_util.tree_leaves(new_state.params),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
