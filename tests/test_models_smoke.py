"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED variant of the same
family (≤2 pattern periods, d_model ≤ 512, ≤4 experts) and run one forward +
one train step + one decode step on CPU, asserting output shapes and no
NaNs. The FULL configs are exercised only via launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, list_configs
from repro.models import build_model

B, S = 2, 32


def _batch(cfg, key=1):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.is_encdec:
        batch["audio_embed"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.n_frames, cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["vision_embed"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.n_units * len(cfg.pattern) + cfg.first_k_dense <= \
        2 * len(cfg.pattern) + cfg.first_k_dense


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, aux = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    opt_state = m.optimizer.init(params)
    p2, o2, metrics = jax.jit(m.train_step)(params, opt_state, batch,
                                            jnp.float32(0.01))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved, structure preserved
    assert jax.tree_util.tree_structure(p2) == \
        jax.tree_util.tree_structure(params)
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(p2),
                        jax.tree_util.tree_leaves(params)))
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_and_decode(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    state = m.init_decode_state(B, 2 * S)
    logits, state = jax.jit(m.prefill)(params, batch, state)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    sb = {"token": jnp.argmax(logits, -1).astype(jnp.int32),
          "pos": jnp.asarray(S, jnp.int32)}
    if cfg.mrope:
        sb["positions"] = jnp.full((3, B, 1), S, jnp.int32)
    logits2, state = jax.jit(m.decode_step)(params, state, sb)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_registry_complete():
    names = list_configs()
    for a in ASSIGNED:
        assert a in names
    assert "fedpc-paper" in names
