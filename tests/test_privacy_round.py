"""The privacy wire through the round core and the simulator drivers.

Covers:
  * masked round_step keeps the 2-launch / 0-host-sync structure;
  * mask-seed invariance under scan (cancellation survives lax.scan);
  * the PrivacyAccountant composes through scan_rounds and round-trips
    through checkpoint/resume;
  * jaxpr-level §4.2 enforcement: no plaintext code tensor materializes on
    the masked path, the master launch consumes no worker-stacked float
    operand, and the audit REJECTS the plaintext wire when asked to hold
    it to the masked policy;
  * in-scan participation sampling (stateless per-round keys) is
    bit-identical to the precomputed schedule, including on resume;
  * the renormalized-share Eq. (3) variant behind WirePath.renorm_shares;
  * simulator integration: run_fedpc == run_fedpc_scan bitwise with the
    masked wire on, ledger audits recorded, masked byte accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat as fl
from repro.core.privacy import LeakageError
from repro.fed import rounds as rd
from repro.privacy import PrivacySpec, check_round_program
from repro.utils import HOST_SYNC_PRIMITIVES, jaxpr_primitive_counts

N = 5


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (41, 23)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (23,))}


def _fixture(seed=0, n=N, privacy=None):
    tree = _tree(seed)
    layout = fl.layout_of(tree)
    state = rd.init_round_state(tree, n, layout, privacy=privacy)
    key = jax.random.PRNGKey(seed + 77)
    deltas = 0.05 * jax.random.normal(key, (n,) + state.buf_p1.shape)
    sizes = jnp.linspace(20.0, 80.0, n)
    return tree, layout, state, deltas, sizes


def _worker_fn(deltas, n=N):
    def fn(wc, buf, t):
        bufs_q = buf[None] + deltas * (1.0 + 0.1 * t.astype(jnp.float32))
        costs = 1.0 / (t.astype(jnp.float32)
                       + jnp.arange(n, dtype=jnp.float32) + 1.0)
        return wc, bufs_q, costs
    return fn


# ---------------------------------------------------------------------------
# Structure: still two launches, still zero host syncs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [PrivacySpec(),
                                  PrivacySpec(dp_epsilon=2.0)])
def test_masked_round_two_launches_no_host_sync(spec):
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    _, _, state, deltas, sizes = _fixture(0, privacy=spec)
    bufs = jnp.zeros((N,) + state.buf_p1.shape)
    costs = jnp.ones((N,))
    counts = jaxpr_primitive_counts(
        lambda s, b, c: wire.round_step(s, b, c, sizes), state, bufs, costs)
    assert counts.get("pallas_call") == 2, counts
    assert sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES) == 0, counts


def test_masked_scan_program_two_launches_no_host_sync():
    spec = PrivacySpec(dp_epsilon=2.0)
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    _, _, state, deltas, sizes = _fixture(0, privacy=spec)
    counts = jaxpr_primitive_counts(
        lambda s: rd.scan_rounds(wire, s, _worker_fn(deltas), 0, 7, sizes),
        state)
    assert counts.get("pallas_call") == 2, counts
    assert sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES) == 0, counts


# ---------------------------------------------------------------------------
# Mask cancellation through the scan; DP-off closeness to the float wire
# ---------------------------------------------------------------------------

def test_scan_bitwise_invariant_to_masking():
    tree, layout, state, deltas, sizes = _fixture(1)
    worker = _worker_fn(deltas)
    outs = {}
    for tag, seed in (("on", 0), ("other", 123), ("off", None)):
        spec = PrivacySpec(mask_seed=seed, dp_epsilon=2.0)
        wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
        st = rd.init_round_state(tree, N, layout, privacy=spec)
        st, _, _ = jax.jit(lambda s, w=wire: rd.scan_rounds(
            w, s, worker, 0, 5, sizes))(st)
        outs[tag] = np.asarray(st.buf_p1)
    np.testing.assert_array_equal(outs["on"], outs["off"])
    np.testing.assert_array_equal(outs["other"], outs["off"])


@pytest.mark.parametrize("mb,rtol,atol", [(32, 1e-5, 1e-6),
                                          (16, 1e-3, 2e-3)])
def test_masked_scan_close_to_plain_wire(mb, rtol, atol):
    """DP off: masked differs from the plain float wire only by the
    fixed-point weight rounding — 2**-25 per weight at the 32-bit modulus
    (fixpoint 24), 2**-15 at 16-bit (fixpoint 14), compounding over the
    5-round scan; the tolerances scale accordingly."""
    tree, layout, state, deltas, sizes = _fixture(2)
    worker = _worker_fn(deltas)
    spec = PrivacySpec(modulus_bits=mb)       # secure agg, DP off
    st_m = rd.init_round_state(tree, N, layout, privacy=spec)
    wire_m = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    st_m, _, _ = jax.jit(lambda s: rd.scan_rounds(
        wire_m, s, worker, 0, 5, sizes))(st_m)
    wire_p = rd.WirePath(rd.WireConfig(), interpret=True)
    st_p, _, _ = jax.jit(lambda s: rd.scan_rounds(
        wire_p, s, worker, 0, 5, sizes))(state)
    np.testing.assert_allclose(np.asarray(st_m.buf_p1),
                               np.asarray(st_p.buf_p1),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Accountant: composition through scan + checkpoint/resume
# ---------------------------------------------------------------------------

def test_accountant_composes_and_survives_resume(tmp_path):
    spec = PrivacySpec(dp_epsilon=1.5)
    tree, layout, state0, deltas, sizes = _fixture(8, privacy=spec)
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    worker = _worker_fn(deltas)
    run = jax.jit(lambda s, n: rd.scan_rounds(wire, s, worker, 0, n, sizes),
                  static_argnums=1)

    st_full, _, _ = run(state0, 6)
    acc = st_full.accountant
    assert int(acc.spent_rounds) == 6
    np.testing.assert_allclose(float(acc.eps_sum), 6 * spec.eps_round,
                               rtol=1e-6)
    np.testing.assert_allclose(float(acc.epsilon()), 6 * spec.eps_round,
                               rtol=1e-6)
    adv = float(acc.epsilon(spec.delta))
    want_adv = (np.sqrt(2 * np.log(1 / spec.delta) * 6 * spec.eps_round ** 2)
                + 6 * spec.eps_round * (np.exp(spec.eps_round) - 1))
    np.testing.assert_allclose(adv, want_adv, rtol=1e-5)

    st_half, _, _ = run(state0, 3)
    rd.save_round_state(str(tmp_path), st_half)
    like = rd.init_round_state(tree, N, layout, privacy=spec)
    st_loaded, _ = rd.load_round_state(str(tmp_path), like)
    for a, b in zip(st_loaded.accountant, st_half.accountant):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st_resumed, _, _ = run(st_loaded, 3)
    for a, b in zip(st_resumed, st_full):
        if a is None or b is None:
            assert a is b
            continue
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert int(st_resumed.accountant.spent_rounds) == 6


def test_accountant_untouched_without_dp():
    spec = PrivacySpec()                      # secure agg only
    tree, layout, state, deltas, sizes = _fixture(3, privacy=spec)
    assert state.accountant is None           # no DP, no accountant
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    st, _, _ = jax.jit(lambda s: rd.scan_rounds(
        wire, s, _worker_fn(deltas), 0, 4, sizes))(state)
    assert st.accountant is None


# ---------------------------------------------------------------------------
# §4.2 audits at jaxpr level
# ---------------------------------------------------------------------------

def _audit_args(state, sizes):
    bufs = jax.ShapeDtypeStruct((N,) + state.buf_p1.shape, jnp.float32)
    costs = jax.ShapeDtypeStruct((N,), jnp.float32)
    return state, bufs, costs, sizes


def test_audit_masked_round_program_passes():
    spec = PrivacySpec(dp_epsilon=2.0)
    _, _, state, _, sizes = _fixture(0, privacy=spec)
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    report = check_round_program(
        lambda s, b, c: wire.round_step(s, b, c, sizes),
        *(_audit_args(state, sizes)[:3]),
        n_workers=N, masked=True)
    assert report["n_launches"] == 2


def test_audit_rejects_plaintext_wire_under_masked_policy():
    """The plaintext path materializes the packed uint8 code buffer — the
    masked policy must catch exactly that."""
    _, _, state, _, sizes = _fixture(0)
    wire = rd.WirePath(rd.WireConfig(), interpret=True)   # no privacy
    with pytest.raises(LeakageError, match="plaintext"):
        check_round_program(
            lambda s, b, c: wire.round_step(s, b, c, sizes),
            *(_audit_args(state, sizes)[:3]),
            n_workers=N, masked=True)
    # without the masked policy the plaintext wire is §4.2-legal (codes
    # only, no stacked float into the master)
    report = check_round_program(
        lambda s, b, c: wire.round_step(s, b, c, sizes),
        *(_audit_args(state, sizes)[:3]),
        n_workers=N, masked=False)
    assert report["n_launches"] == 2


def test_audit_rejects_materialized_mask_tensor_in_uplink():
    """Deliberate regression to the pre-in-kernel-PRNG wire: an 'uplink'
    launch that consumes an HBM-materialized (N, rows, 512) mask tensor
    must be flagged by the masked policy — mask streams belong in
    registers, generated from counter keys."""
    from jax.experimental import pallas as pl

    def leaky_masked_round(bufs_q, masks, p1):
        def uplink(q_ref, m_ref, o_ref):
            o_ref[...] = q_ref[...].astype(jnp.uint32) + m_ref[...]

        y = pl.pallas_call(
            uplink,
            out_shape=jax.ShapeDtypeStruct(masks.shape, jnp.uint32),
            interpret=True)(bufs_q, masks)

        def master(y_ref, p_ref, o_ref):
            s = jnp.sum(y_ref[...], axis=0)
            o_ref[...] = p_ref[...] - s.astype(jnp.float32)

        return pl.pallas_call(
            master,
            out_shape=jax.ShapeDtypeStruct(p1.shape, jnp.float32),
            interpret=True)(y, p1)

    _, _, state, _, sizes = _fixture(0)
    buf = jax.ShapeDtypeStruct(state.buf_p1.shape, jnp.float32)
    bufs = jax.ShapeDtypeStruct((N,) + state.buf_p1.shape, jnp.float32)
    masks = jax.ShapeDtypeStruct((N,) + state.buf_p1.shape, jnp.uint32)
    with pytest.raises(LeakageError, match="materialized mask"):
        check_round_program(leaky_masked_round, bufs, masks, buf,
                            n_workers=N, masked=True)
    # the unmasked policy has no opinion about integer operands
    report = check_round_program(leaky_masked_round, bufs, masks, buf,
                                 n_workers=N, masked=False)
    assert report["n_launches"] == 2


def test_audit_rejects_stacked_float_into_master():
    """A deliberately leaky 'master' launch whose operand list carries the
    worker-stacked full-precision buffers must be flagged."""
    from jax.experimental import pallas as pl

    def leaky(bufs_q, p1, p2):
        def k(q_ref, o_ref):
            o_ref[...] = jnp.sum(q_ref[...], axis=0)

        return pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct(p1.shape, jnp.float32),
            interpret=True)(bufs_q)

    _, _, state, _, sizes = _fixture(0)
    buf = jax.ShapeDtypeStruct(state.buf_p1.shape, jnp.float32)
    bufs = jax.ShapeDtypeStruct((N,) + state.buf_p1.shape, jnp.float32)
    with pytest.raises(LeakageError, match="worker axis"):
        check_round_program(leaky, bufs, buf, buf,
                            n_workers=N, masked=False)


# ---------------------------------------------------------------------------
# In-scan participation sampling (stateless per-round keys)
# ---------------------------------------------------------------------------

def test_in_scan_participation_matches_precomputed_schedule():
    tree, layout, state, deltas, sizes = _fixture(4)
    worker = _worker_fn(deltas)
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    key = jax.random.PRNGKey(5)
    masks = rd.participation_masks(key, 6, N, 0.6)
    st_a, _, inf_a = jax.jit(lambda s: rd.scan_rounds(
        wire, s, worker, 0, 6, sizes, masks=masks))(state)
    st_b, _, inf_b = jax.jit(lambda s: rd.scan_rounds(
        wire, s, worker, 0, 6, sizes, participation=0.6,
        participation_key=key))(state)
    for a, b in zip(jax.tree_util.tree_leaves(st_a),
                    jax.tree_util.tree_leaves(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(inf_a["mask"]),
                                  np.asarray(inf_b["mask"]))


def test_in_scan_participation_resume_reproduces_schedule():
    """Keyed by ABSOLUTE round: 3+3 resumed rounds == 6 uninterrupted."""
    tree, layout, state, deltas, sizes = _fixture(5)
    worker = _worker_fn(deltas)
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    key = jax.random.PRNGKey(6)
    run = jax.jit(lambda s, n: rd.scan_rounds(
        wire, s, worker, 0, n, sizes, participation=0.6,
        participation_key=key), static_argnums=1)
    st_full, _, _ = run(state, 6)
    st_half, _, _ = run(state, 3)
    st_resumed, _, _ = run(st_half, 3)
    np.testing.assert_array_equal(np.asarray(st_resumed.buf_p1),
                                  np.asarray(st_full.buf_p1))


def test_in_scan_participation_validation():
    tree, layout, state, deltas, sizes = _fixture(0)
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    worker = _worker_fn(deltas)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="not both"):
        rd.scan_rounds(wire, state, worker, 0, 2, sizes,
                       masks=jnp.ones((2, N)), participation=0.5,
                       participation_key=key)
    with pytest.raises(ValueError, match="participation_key"):
        rd.scan_rounds(wire, state, worker, 0, 2, sizes, participation=0.5)
    with pytest.raises(ValueError, match="in \\(0, 1\\]"):
        rd.scan_rounds(wire, state, worker, 0, 2, sizes, participation=1.5,
                       participation_key=key)


# ---------------------------------------------------------------------------
# Renormalized-share Eq. (3) variant
# ---------------------------------------------------------------------------

def test_renorm_shares_default_off_is_bitwise_unchanged():
    _, _, state, deltas, sizes = _fixture(6)
    worker = _worker_fn(deltas)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    _, bufs_q, costs = worker(0, state.buf_p1, state.round)
    plain = rd.WirePath(rd.WireConfig())
    flagged = rd.WirePath(rd.WireConfig(), renorm_shares=False)
    _, a, _ = plain.round_step(state, bufs_q, costs, sizes, mask=mask)
    _, b, _ = flagged.round_step(state, bufs_q, costs, sizes, mask=mask)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_renorm_shares_weights_oracle():
    wire = rd.WirePath(rd.WireConfig(), renorm_shares=True)
    sizes = jnp.asarray([10.0, 20.0, 30.0, 40.0, 50.0])
    p = sizes / sizes.sum()
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    k_star = 2
    w = wire.weights(p, k_star, 3, mask=mask)
    pm = np.asarray(p) * np.asarray(mask)
    want = pm / pm.sum() * wire.cfg.beta
    want[k_star] = 0.0
    np.testing.assert_allclose(np.asarray(w), want, rtol=1e-6)
    # full participation: renorm is a no-op up to the fp division by ~1.0
    w_full = wire.weights(p, k_star, 3, mask=jnp.ones((5,)))
    w_plain = rd.WirePath(rd.WireConfig()).weights(
        p, k_star, 3, mask=jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(w_full), np.asarray(w_plain),
                               rtol=1e-6)


def test_renorm_shares_round_magnitude_invariant():
    """With renorm, the sum of Eq. (3) weights over the sampled set equals
    beta * (1 - p_pilot_renormalized) regardless of how few reported —
    the FedAvg-style constant-magnitude convention."""
    wire = rd.WirePath(rd.WireConfig(), renorm_shares=True)
    sizes = jnp.ones((N,))
    p = sizes / sizes.sum()
    for mask in (jnp.asarray([1, 1, 1, 0, 0.0]),
                 jnp.asarray([1, 1, 1, 1, 1.0])):
        k_star = 0
        w = wire.weights(p, k_star, 3, mask=mask)
        m = int(mask.sum())
        np.testing.assert_allclose(float(jnp.sum(w)),
                                   wire.cfg.beta * (m - 1) / m, rtol=1e-5)


# ---------------------------------------------------------------------------
# Simulator integration
# ---------------------------------------------------------------------------

def _make_sim(privacy=None, n=3, renorm=False):
    from repro.core.fedpc import FedPCConfig
    from repro.data.pipeline import BatchIterator
    from repro.fed.worker import Worker, make_worker_configs
    from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad

    rng = np.random.default_rng(0)
    x = rng.normal(size=(60, 8)).astype(np.float32)
    y = rng.integers(0, 3, 60).astype(np.int32)
    splits = [np.arange(0, 20), np.arange(20, 40), np.arange(40, 60)]
    cfgs = make_worker_configs(n, [20, 20, 20], seed=1, batch_menu=(10,))
    workers = [
        Worker(cfg=cfgs[k],
               loader=BatchIterator((x[s], y[s]), 10, seed=k),
               loss_and_grad=mlp_loss_and_grad)
        for k, s in enumerate(splits)
    ]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 8, 3, hidden=(16,))
    cfg = FedPCConfig(n_workers=n, privacy=privacy, renorm_shares=renorm)
    from repro.fed.simulator import FedSimulator as FS
    return FS(workers, params, cfg), params


def test_simulator_masked_drivers_bitwise_equal_and_audited():
    spec = PrivacySpec(dp_epsilon=2.0)
    sim_a, _ = _make_sim(privacy=spec)
    res_a = sim_a.run_fedpc(rounds=4)
    sim_b, _ = _make_sim(privacy=spec)
    res_b = sim_b.run_fedpc_scan(rounds=4)
    for a, b in zip(jax.tree_util.tree_leaves(res_a.params),
                    jax.tree_util.tree_leaves(res_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res_a.pilot_history == res_b.pilot_history
    # enforcement hook ran in BOTH runtimes and recorded the audit
    assert [a["runtime"] for a in sim_a.ledger.audits] == ["run_fedpc"]
    assert [a["runtime"] for a in sim_b.ledger.audits] == ["run_fedpc_scan"]
    assert all(a["masked"] for a in sim_a.ledger.audits)
    # the DP accountant rode along
    acc = res_a.round_state.accountant
    assert int(acc.spent_rounds) == 4
    # masked uplinks record only the allowed §4.2 fields — and the code
    # kind is the masked-wire one (the master never saw plaintext codes)
    kinds = {k for (_, _, k, _) in sim_a.ledger.events}
    assert kinds == {"cost", "pilot_params", "masked_words"}


def test_simulator_privacy_with_partial_participation():
    """The shipped secure-agg-ldp regime: privacy enforcement + C-fraction
    sampling must coexist (the audit's mask spec must trace correctly) and
    both drivers must still agree bitwise."""
    spec = PrivacySpec(dp_epsilon=4.0)
    outs = []
    for driver in ("run_fedpc", "run_fedpc_scan"):
        sim, _ = _make_sim(privacy=spec)
        res = getattr(sim, driver)(4, participation=0.67)
        assert len(sim.ledger.audits) == 1
        outs.append(res)
    for a, b in zip(jax.tree_util.tree_leaves(outs[0].params),
                    jax.tree_util.tree_leaves(outs[1].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert outs[0].pilot_history == outs[1].pilot_history


def test_fed_sync_rejects_privacy_with_fedavg():
    """fedavg psums full-precision params — combining it with an active
    PrivacySpec must fail loudly, not silently run a plaintext wire."""
    from jax.sharding import Mesh
    from repro.fed.distributed import build_fed_sync
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    with pytest.raises(ValueError, match="fedavg"):
        build_fed_sync(None, mesh, "data", "fedavg",
                       privacy=PrivacySpec())


def test_simulator_masked_byte_accounting():
    from repro.core import protocol as proto
    from repro.utils import tree_size
    spec = PrivacySpec()                       # 16-bit modulus default
    sim, params = _make_sim(privacy=spec)
    res = sim.run_fedpc(rounds=2)
    v = tree_size(params) * 4
    want = proto.fedpc_masked_bytes_per_round(v, 3,
                                              word_bits=spec.modulus_bits)
    assert res.bytes_per_round[0] == want
    assert want > proto.fedpc_bytes_per_round(v, 3)   # secure agg costs ...

    spec32 = PrivacySpec(modulus_bits=32)
    sim32, _ = _make_sim(privacy=spec32)
    res32 = sim32.run_fedpc(rounds=2)
    want32 = proto.fedpc_masked_bytes_per_round(v, 3, word_bits=32)
    assert res32.bytes_per_round[0] == want32
    assert want < want32                       # ... half as much at 16-bit

    sim_p, _ = _make_sim()
    res_p = sim_p.run_fedpc(rounds=2)
    assert res_p.bytes_per_round[0] == proto.fedpc_bytes_per_round(v, 3)
