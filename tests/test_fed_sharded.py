"""Model-sharded wire path: sharded-vs-replicated sync parity over several
(fed, model) mesh shapes, both round branches — runs in a subprocess with 8
host devices so the main pytest process keeps its single-device view."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.fed.distributed import build_fed_sync, fed_state_init

k = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(k, (300, 40)),
          "b": jax.random.normal(jax.random.fold_in(k, 5), (40,)),
          "s": jax.random.normal(jax.random.fold_in(k, 6), ())}
out = {}

def tree_max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

for fed, model in ((4, 2), (2, 4), (2, 2), (8, 1)):
    devs = np.array(jax.devices()[: fed * model]).reshape(fed, model)
    mesh = Mesh(devs, ("data", "model"))
    F = fed
    sizes = jnp.linspace(50.0, 200.0, F)
    costs = jnp.linspace(0.9, 0.5, F)
    params_F = jax.tree_util.tree_map(
        lambda x: jnp.stack([x + 0.05 * (i + 1) for i in range(F)]), params)

    # heterogeneous per-worker beta_k + a partial-participation mask (at
    # least one worker dropped, pilot guaranteed in the sampled set)
    betas = jnp.linspace(0.1, 0.35, F)
    mask = (jnp.arange(F) != 1).astype(jnp.float32)

    for t in (1, 3):
        state = fed_state_init(params, F)
        if t > 1:
            state["round"] = jnp.asarray(t, jnp.int32)
            state["params_prev"] = jax.tree_util.tree_map(
                lambda x: x + 0.01, params)
            state["prev_costs"] = jnp.ones((F,))
        with mesh:
            for strat in ("fedpc", "fedpc_packed", "fedpc_reduce"):
                res, res_het = {}, {}
                for shard in (True, False):
                    sync = build_fed_sync(None, mesh, "data", strat,
                                          shard_wire=shard)
                    new_params, aux = jax.jit(sync)(
                        params_F, costs, sizes, state)
                    res[shard] = new_params
                    sync_h = build_fed_sync(None, mesh, "data", strat,
                                            shard_wire=shard, betas=betas)
                    new_h, aux_h = jax.jit(sync_h)(
                        params_F, costs, sizes, state, mask)
                    res_het[shard] = new_h
                key = f"{fed}x{model}_t{t}_{strat}"
                out[key] = tree_max_diff(res[True], res[False])
                out["het_" + key] = tree_max_diff(res_het[True],
                                                  res_het[False])
                out["het_vs_plain_" + key] = tree_max_diff(res_het[True],
                                                           res[True])
                out["het_kstar_" + key] = int(aux_h["k_star"])

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_covers_all_mesh_shapes(results):
    plain = [k for k in results if not k.startswith("het")]
    assert len(plain) == 4 * 2 * 3            # meshes × rounds × strategies


def test_sharded_bitwise_equals_replicated_exact_modes(results):
    """gather / packed move exact int8/uint8 codes — slab math must be
    bitwise identical to the replicated buffer, in the uniform AND the
    heterogeneous-beta_k + partial-participation regimes."""
    for key, diff in results.items():
        if key.startswith("het_vs_plain") or key.startswith("het_kstar"):
            continue
        if key.endswith("fedpc") or key.endswith("fedpc_packed"):
            assert diff == 0.0, f"{key}: {diff}"


def test_sharded_reduce_close_to_replicated(results):
    """fedpc_reduce sums f16 on the wire; psum_scatter+all_gather may order
    the sum differently than a fused psum — bounded, tiny."""
    for key, diff in results.items():
        if key.startswith("het_vs_plain") or key.startswith("het_kstar"):
            continue
        if key.endswith("fedpc_reduce"):
            assert diff < 2e-2, f"{key}: {diff}"


def test_heterogeneous_round_differs_and_avoids_masked_pilot(results):
    """betas+mask actually change the update (not a silent no-op), and the
    masked worker (index 1) is never selected as pilot."""
    assert any(d > 0.0 for k, d in results.items()
               if k.startswith("het_vs_plain"))
    for k, v in results.items():
        if k.startswith("het_kstar"):
            assert v != 1, f"{k}: masked worker won pilot selection"
