"""Checkpoint save/restore roundtrip + strictness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import latest_step


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones(4, jnp.bfloat16)},
        "round": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), tree, step=3, metadata={"algo": "fedpc"})
    restored, manifest = load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 3
    assert manifest["metadata"]["algo"] == "fedpc"
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_selection(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), tree, step=1)
    save_checkpoint(str(tmp_path), tree, step=5)
    assert latest_step(str(tmp_path)) == 5
    _, manifest = load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 5


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), _tree(), step=0)
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), bad)


def test_missing_key_rejected(tmp_path):
    save_checkpoint(str(tmp_path), _tree(), step=0)
    bad = _tree()
    bad["extra"] = jnp.zeros(3)
    with pytest.raises(KeyError):
        load_checkpoint(str(tmp_path), bad)
