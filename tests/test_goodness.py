"""Eq. (1) goodness + pilot selection properties."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.goodness import goodness, rotation_entropy, select_pilot


def test_round1_inverse_cost_per_sample():
    costs = jnp.array([1.0, 0.5])
    sizes = jnp.array([100.0, 100.0])
    g = goodness(costs, jnp.full((2,), jnp.inf), sizes, t=1)
    assert g[1] > g[0]          # lower cost wins at equal size
    k, _ = select_pilot(costs, jnp.full((2,), jnp.inf), sizes, 1)
    assert int(k) == 1


def test_later_rounds_reward_cost_reduction():
    prev = jnp.array([1.0, 1.0, 1.0])
    costs = jnp.array([0.9, 0.5, 1.1])   # worker 2 got worse
    sizes = jnp.array([100.0, 100.0, 100.0])
    g = goodness(costs, prev, sizes, t=2)
    assert int(jnp.argmax(g)) == 1
    assert float(g[2]) < 0               # regression → negative goodness


def test_size_weighting():
    """Same reduction, more data → higher goodness (paper's rationale)."""
    prev = jnp.array([1.0, 1.0])
    costs = jnp.array([0.8, 0.8])
    sizes = jnp.array([1000.0, 10.0])
    g = goodness(costs, prev, sizes, t=3)
    assert g[0] > g[1]


@given(st.integers(2, 8), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_pilot_in_range(n, seed):
    rng = np.random.default_rng(seed)
    costs = jnp.asarray(rng.uniform(0.1, 2.0, n), jnp.float32)
    prev = jnp.asarray(rng.uniform(0.1, 2.0, n), jnp.float32)
    sizes = jnp.asarray(rng.integers(1, 1000, n), jnp.float32)
    k, scores = select_pilot(costs, prev, sizes, 2)
    assert 0 <= int(k) < n
    assert float(scores[int(k)]) == float(jnp.max(scores))


def test_rotation_entropy():
    flat = jnp.asarray([0, 1, 2, 3] * 5)
    stuck = jnp.zeros(20, jnp.int32)
    assert float(rotation_entropy(flat, 4)) > float(rotation_entropy(stuck, 4))
