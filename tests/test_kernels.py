"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(128,), (1000,), (8, 128), (64, 37), (3, 5, 7), (4096,), (2048, 2)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ternary_encode_matches_ref(shape, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, shape, dtype)
    p1 = jax.random.normal(k2, shape, dtype)
    p2 = jax.random.normal(k3, shape, dtype)
    out = ops.ternary_encode(q, p1, p2, 0.2, interpret=True)
    want = ref.ternary_encode_ref(q, p1, p2, 0.2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES)
def test_ternary_round1_matches_ref(shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    q = jax.random.normal(k1, shape)
    p0 = jax.random.normal(k2, shape)
    out = ops.ternary_encode_round1(q, p0, 0.01, interpret=True)
    want = ref.ternary_encode_round1_ref(q, p0, 0.01)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("n", [4, 16, 128, 1000, 4096, 9999])
def test_pack_unpack_matches_ref(n):
    t = jnp.asarray(
        np.random.default_rng(n).integers(-1, 2, n), jnp.int8)
    packed = ops.pack2bit(t, interpret=True)
    pad = (-n) % 4
    want = ref.pack2bit_ref(jnp.concatenate(
        [t, jnp.zeros((pad,), jnp.int8)]) if pad else t)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(want))
    out = ops.unpack2bit(packed, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(t))


@pytest.mark.parametrize("n_workers", [2, 4, 8, 16])
@pytest.mark.parametrize("m", [128, 1000, 5000])
def test_master_update_matches_ref(n_workers, m):
    rng = np.random.default_rng(n_workers * m)
    q = jnp.asarray(rng.normal(size=m), jnp.float32)
    p1 = jnp.asarray(rng.normal(size=m), jnp.float32)
    p2 = jnp.asarray(rng.normal(size=m), jnp.float32)
    tern = jnp.asarray(rng.integers(-1, 2, (n_workers, m)), jnp.int8)
    w = jnp.asarray(rng.uniform(0, 0.2, n_workers), jnp.float32)
    out = ops.master_update(q, tern, w, p1, p2, interpret=True)
    want = ref.master_update_ref(q, tern, w, p1, p2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_kernel_consistency_with_core():
    """Kernel path == core (pytree) path on a realistic parameter tree."""
    from repro.core.ternary import ternarize
    k = jax.random.PRNGKey(7)
    q = jax.random.normal(k, (333, 17))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (333, 17))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (333, 17))
    np.testing.assert_array_equal(
        np.asarray(ops.ternary_encode(q, p1, p2, 0.2, interpret=True)),
        np.asarray(ternarize(q, p1, p2, 0.2)))
