"""Parameter/batch/cache sharding rules (no devices needed — specs only)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import batch_spec, cache_specs, param_specs


class FakeMesh:
    """Duck-typed mesh: specs.py only touches .axis_names and .shape."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_col_row_rules():
    params = {
        "units": {"b0": {
            "mixer": {"wq": _sds((2, 5120, 4096)), "wo": _sds((2, 4096, 5120))},
            "ffn": {"w_up": _sds((2, 5120, 14336)),
                    "w_down": _sds((2, 14336, 5120))},
            "norm1": _sds((2, 5120)),
        }},
        "embed": _sds((131072, 5120)),
        "lm_head": _sds((5120, 131072)),
    }
    specs = param_specs(params, MESH)
    b0 = specs["units"]["b0"]
    assert b0["mixer"]["wq"] == P(None, "data", "model")
    assert b0["mixer"]["wo"] == P(None, "model", "data")
    assert b0["ffn"]["w_down"] == P(None, "model", "data")
    assert b0["norm1"] == P(None, None)                # replicated
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")


def test_expert_rules_divisible_vs_not():
    # 64 experts: expert-parallel over model
    p64 = {"units": {"b0": {"ffn": {
        "experts_gate": _sds((2, 64, 2048, 1408)),
        "experts_down": _sds((2, 64, 1408, 2048)),
    }}}}
    s = param_specs(p64, MESH)["units"]["b0"]["ffn"]
    assert s["experts_gate"][1] == "model"
    # 8 experts: tensor-parallel inside each expert — the FSDP shard rides
    # on the F dim together with 'model' (contraction dims stay unsharded;
    # EXPERIMENTS.md §Perf 0)
    p8 = {"units": {"b0": {"ffn": {
        "experts_gate": _sds((2, 8, 6144, 32768)),
        "experts_down": _sds((2, 8, 32768, 6144)),
    }}}}
    s8 = param_specs(p8, MESH)["units"]["b0"]["ffn"]
    assert s8["experts_gate"][1] is None
    assert s8["experts_gate"][2] is None        # contraction dim unsharded
    assert s8["experts_gate"][3] == ("model", "data")
    assert s8["experts_down"][2] == ("model", "data")


def test_non_divisible_falls_back_to_replication():
    params = {"units": {"b0": {"mixer": {"wq": _sds((2, 37, 53))}}}}
    spec = param_specs(params, MESH)["units"]["b0"]["mixer"]["wq"]
    assert spec == P(None, None, None)


def test_batch_spec():
    assert batch_spec(MESH, 256) == P("data", None)
    assert batch_spec(MESH_MP, 256) == P(("pod", "data"), None)
    assert batch_spec(MESH, 1) == P(None, None)        # long_500k B=1


def test_cache_specs_kv_and_ssm():
    cache = {
        "kv": {"k": _sds((128, 32768, 8, 128), jnp.bfloat16)},
        "ssm": {"h": _sds((128, 16384, 16))},
        "b1": {"k": _sds((1, 524288, 8, 128), jnp.bfloat16)},
    }
    specs = cache_specs(cache, MESH, 128)
    assert specs["kv"]["k"][0] == "data"            # batch sharded
    assert specs["ssm"]["h"][1] == "model"             # channels sharded
    # B=1: sequence dim takes the data axes
    assert specs["b1"]["k"][0] is None
    assert specs["b1"]["k"][1] == "data"


def test_multipod_param_sharding():
    params = {"units": {"b0": {"ffn": {"w_up": _sds((2, 8192, 24576))}}}}
    spec = param_specs(params, MESH_MP)["units"]["b0"]["ffn"]["w_up"]
    assert spec == P(None, ("pod", "data"), "model")
