"""Simulator drivers: run_fedpc (Python loop) vs run_fedpc_scan (lax.scan).

The device-resident refactor's simulator-facing contract:
  * the two drivers are bitwise-identical over >= 5 rounds, in the uniform
    AND the partial-participation + heterogeneous-beta_k regimes;
  * neither driver syncs device→host per round (host conversions counted by
    instrumenting the simulator module, as in test_worker_transfers.py —
    the count must not grow with the number of rounds);
  * continuation through the returned RoundState is bitwise equal to an
    uninterrupted run;
  * ledger/byte accounting respect participation (only sampled workers
    upload; the pilot is always sampled).
"""
import jax
import numpy as np
import pytest

import repro.fed.simulator as sim_mod
from repro.data.pipeline import federated_loaders
from repro.data.synthetic import SyntheticClassification
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad

N = 4
SAMPLES = 384            # 96 per worker, divisible by the 32-batch menu

_REAL_FLOAT = float
_REAL_INT = int


def _make_sim(seed=0):
    t = SyntheticClassification(n_samples=SAMPLES, n_features=16,
                                n_classes=5, seed=0)
    x, y = t.generate()
    per = SAMPLES // N
    splits = [np.arange(i * per, (i + 1) * per) for i in range(N)]
    loaders = federated_loaders((x, y), splits, seed=seed, batch_menu=(32,))
    cfgs = make_worker_configs(N, [per] * N, seed=seed, batch_menu=(32,))
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(N)]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 16, 5, hidden=(32,))
    return FedSimulator(workers, params)


def _assert_same_result(r1, r2):
    assert r1.pilot_history == r2.pilot_history
    assert r1.costs == r2.costs
    assert r1.bytes_per_round == r2.bytes_per_round
    for a, b in zip(jax.tree_util.tree_leaves(r1.params),
                    jax.tree_util.tree_leaves(r2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Driver parity (bitwise, >= 5 rounds)
# ---------------------------------------------------------------------------

def test_scan_driver_bitwise_equals_python_driver():
    r1 = _make_sim().run_fedpc(6)
    r2 = _make_sim().run_fedpc_scan(6)
    _assert_same_result(r1, r2)


def test_scan_driver_parity_partial_participation_and_betas():
    kw = dict(participation=0.5, betas=[0.1, 0.2, 0.3, 0.25],
              participation_seed=3)
    r1 = _make_sim().run_fedpc(6, **kw)
    r2 = _make_sim().run_fedpc_scan(6, **kw)
    _assert_same_result(r1, r2)


# ---------------------------------------------------------------------------
# Zero per-round host syncs (both drivers)
# ---------------------------------------------------------------------------

@pytest.fixture
def host_sync_counter(monkeypatch):
    """Counts float(<jax.Array>) / int(<jax.Array>) conversions inside the
    simulator module — each is a blocking device→host read."""
    calls = {"n": 0}

    def counting_float(x=0.0):
        if isinstance(x, jax.Array):
            calls["n"] += 1
        return _REAL_FLOAT(x)

    def counting_int(x=0, *a):
        if isinstance(x, jax.Array):
            calls["n"] += 1
        return _REAL_INT(x, *a) if a else _REAL_INT(x)

    monkeypatch.setattr(sim_mod, "float", counting_float, raising=False)
    monkeypatch.setattr(sim_mod, "int", counting_int, raising=False)
    return calls


@pytest.mark.parametrize("driver", ["run_fedpc", "run_fedpc_scan"])
def test_host_sync_count_independent_of_rounds(driver, host_sync_counter):
    """The per-round loop performs ZERO device→host conversions: the total
    count is the same for 2 rounds and for 5 (setup + the single post-run
    fetch only)."""
    counts = {}
    for rounds in (2, 5):
        sim = _make_sim()
        host_sync_counter["n"] = 0
        getattr(sim, driver)(rounds)
        counts[rounds] = host_sync_counter["n"]
    assert counts[2] == counts[5], (
        f"{driver}: host syncs grew with rounds: {counts}")


# ---------------------------------------------------------------------------
# Continuation through RoundState
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("participation", [None, 0.5])
@pytest.mark.parametrize("driver", ["run_fedpc", "run_fedpc_scan"])
def test_continuation_bitwise(driver, participation):
    """3 rounds + 3 resumed rounds == 6 uninterrupted rounds, bitwise (the
    returned RoundState is the full inter-round protocol state; under
    sampling, masks are keyed by absolute round so the resumed segment
    draws the schedule the uninterrupted run would have)."""
    kw = {} if participation is None else {"participation": participation}
    full = getattr(_make_sim(), driver)(6, **kw)

    sim = _make_sim()
    half = getattr(sim, driver)(3, **kw)
    cont = getattr(sim, driver)(3, state=half.round_state, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(cont.params),
                    jax.tree_util.tree_leaves(full.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert half.pilot_history + cont.pilot_history == full.pilot_history


# ---------------------------------------------------------------------------
# Participation accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", ["run_fedpc", "run_fedpc_scan"])
def test_partial_participation_ledger_and_bytes(driver):
    sim = _make_sim()
    res = getattr(sim, driver)(5, participation=0.5, participation_seed=1)
    # ledger: per round, only sampled workers appear; pilot among them
    by_round = {}
    for (r, w, kind, is_pilot) in sim.ledger.events:
        by_round.setdefault(r, set()).add((w, kind))
    masks = np.asarray(sim_mod.rd.participation_masks(
        jax.random.PRNGKey(1), 5, N, 0.5))
    for i in range(5):
        row = masks[i]
        uploaders = {w for (w, kind) in by_round[i + 1]}
        assert uploaders == set(np.flatnonzero(row > 0).tolist())
        assert row[res.pilot_history[i]] > 0
    # Eq. (8) bytes follow the per-round participant count (2 of 4 here)
    from repro.core import protocol as proto
    mb = proto.model_size_bytes(sim.init_params)
    assert res.bytes_per_round == [proto.fedpc_bytes_per_round(mb, 2)] * 5


def test_worker_beta_menu_reaches_the_wire():
    """Workers drawing private beta_k via make_worker_configs(beta_menu=...)
    change the aggregate (vs the uniform default), and both drivers agree
    on it bitwise."""
    def make_het(seed=0):
        sim = _make_sim(seed)
        for k, w in enumerate(sim.workers):
            w.cfg.beta = (0.1, 0.2, 0.3, 0.25)[k]
        return sim

    r_uni = _make_sim().run_fedpc(4)
    r_het = make_het().run_fedpc(4)
    r_het_scan = make_het().run_fedpc_scan(4)
    _assert_same_result(r_het, r_het_scan)
    diffs = [np.max(np.abs(np.asarray(a) - np.asarray(b)))
             for a, b in zip(jax.tree_util.tree_leaves(r_uni.params),
                             jax.tree_util.tree_leaves(r_het.params))]
    assert max(diffs) > 0.0


def test_federation_scenario_presets():
    """The named regimes of repro.configs.federation drive the simulator."""
    from repro.configs import get_scenario, list_scenarios
    assert {"paper-uniform", "hetero-beta", "cross-device",
            "cross-device-hetero"} <= set(list_scenarios())
    sc = get_scenario("cross-device-hetero")
    betas = sc.betas_for(N, seed=0)
    assert len(betas) == N and all(b in sc.beta_menu for b in betas)
    res = _make_sim().run_fedpc_scan(3, participation=sc.participation,
                                     betas=betas)
    assert len(res.pilot_history) == 3
    assert get_scenario("paper-uniform").betas_for(N) is None


def test_fedavg_mask_renormalizes_over_participants():
    """build_fed_sync('fedavg') with a participation mask averages the
    sampled workers only, shares renormalized (the fedavg branch has no
    collectives, so a 1x1 mesh suffices)."""
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.fed.distributed import build_fed_sync, fed_state_init

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    params = {"w": jnp.arange(8.0)}
    F = 4
    params_F = {"w": jnp.stack([params["w"] + i for i in range(F)])}
    sizes = jnp.array([10.0, 20.0, 30.0, 40.0])
    costs = jnp.linspace(0.9, 0.6, F)
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    state = fed_state_init(params, F)
    sync = build_fed_sync(None, mesh, "data", "fedavg")
    got, _ = sync(params_F, costs, sizes, state, mask)
    w = np.array([10.0, 0.0, 30.0, 0.0]) / 40.0
    want = (np.asarray(params_F["w"]) * w[:, None]).sum(0)
    np.testing.assert_allclose(np.asarray(got["w"]), want, rtol=1e-6)


def test_scan_driver_rejects_ragged_shards():
    sim = _make_sim()
    sim.workers[0].loader.batch_size = 28     # 96 % 28 != 0
    with pytest.raises(ValueError, match="ragged"):
        sim.run_fedpc_scan(2)


def test_scan_driver_rejects_evasion():
    sim = _make_sim()
    sim.evade_streak = 2
    with pytest.raises(ValueError, match="evade"):
        sim.run_fedpc_scan(2)
