"""Federated simulator: FedPC vs FedAvg vs Phong vs centralized on the
synthetic classification task (the paper's Tables 1–3 behaviour, scaled)."""
import jax
import numpy as np
import pytest

from repro.data.pipeline import BatchIterator, federated_loaders
from repro.data.synthetic import SyntheticClassification, random_share_split
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, \
    mlp_loss_and_grad


@pytest.fixture(scope="module")
def task():
    t = SyntheticClassification(n_samples=1200, n_features=16,
                                n_classes=5, seed=0)
    x, y = t.generate()
    return x[:1000], y[:1000], x[1000:], y[1000:]


def _make_sim(task, n=4, seed=0):
    xtr, ytr, xte, yte = task
    splits = random_share_split(ytr, n, seed=seed)
    loaders = federated_loaders((xtr, ytr), splits, seed=seed,
                                batch_menu=(64, 32))
    cfgs = make_worker_configs(n, [len(s) for s in splits], seed=seed,
                               batch_menu=(64, 32))
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(n)]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 16, 5, hidden=(32,))
    return FedSimulator(workers, params,
                        eval_fn=lambda p: mlp_accuracy(p, xte, yte)), params


def test_fedpc_cost_decreases(task):
    sim, _ = _make_sim(task)
    res = sim.run_fedpc(rounds=12)
    assert res.costs[-1] < res.costs[0]
    # Fig. 4 behaviour: late rounds stable-ish (non-strict check)
    assert res.costs[-1] < np.mean(res.costs[:3])


def test_fedpc_approximates_centralized(task):
    """Table 2 structure: FedPC within a few points of centralized."""
    xtr, ytr, xte, yte = task
    sim, params = _make_sim(task)
    res_pc = sim.run_fedpc(rounds=15, eval_every=15)
    cfg = sim.workers[0].cfg
    central = Worker(cfg=cfg, loader=BatchIterator((xtr, ytr), 64, seed=9),
                     loss_and_grad=mlp_loss_and_grad)
    res_c = sim.run_centralized(15, central, eval_every=15)
    acc_pc = res_pc.eval_history[-1][1]
    acc_c = res_c.eval_history[-1][1]
    assert acc_pc > 0.4                      # actually learned
    assert acc_c - acc_pc < 0.25             # approximation gap bounded


def test_pilot_rotation(task):
    """Goodness-driven rotation (privacy discussion §4.2): not always the
    same pilot across rounds."""
    sim, _ = _make_sim(task, n=5, seed=3)
    res = sim.run_fedpc(rounds=10)
    assert len(set(res.pilot_history)) >= 2


def test_comm_ordering_matches_eq8(task):
    sim, _ = _make_sim(task)
    r_pc = sim.run_fedpc(rounds=2)
    r_avg = sim.run_fedavg(rounds=2)
    r_ph = sim.run_phong(rounds=2)
    assert r_pc.bytes_per_round[0] < r_avg.bytes_per_round[0]
    assert r_avg.bytes_per_round[0] == r_ph.bytes_per_round[0]


def test_phong_and_fedavg_learn(task):
    sim, _ = _make_sim(task)
    r_avg = sim.run_fedavg(rounds=8, eval_every=8)
    r_ph = sim.run_phong(rounds=8, eval_every=8)
    assert r_avg.costs[-1] < r_avg.costs[0]
    assert r_ph.costs[-1] < r_ph.costs[0]
    assert r_avg.eval_history[-1][1] > 0.3
    assert r_ph.eval_history[-1][1] > 0.3


def test_evasion_defence_rotates_pilot(task):
    sim, _ = _make_sim(task, n=3, seed=7)
    sim.evade_streak = 2
    res = sim.run_fedpc(rounds=8)
    # with the defence on, no worker can be pilot for many consecutive rounds
    longest = 1
    cur = 1
    for a, b in zip(res.pilot_history, res.pilot_history[1:]):
        cur = cur + 1 if a == b else 1
        longest = max(longest, cur)
    assert longest <= 4
