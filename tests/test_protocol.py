"""Eq. (8) communication model — including the paper's headline numbers."""
import pytest

from repro.core.protocol import (CommLedger, fedavg_bytes_per_round,
                                 fedpc_bytes_per_round, phong_bytes_per_round,
                                 reduction_vs_fedavg)


def test_eq8_formula():
    V, N = 35e6, 10     # ResNet50-FIXUP instance size used in the paper
    d = fedpc_bytes_per_round(V, N)
    assert d == V * (N + 1) + V * (N - 1) / 16


def test_paper_reduction_endpoints():
    """§5.2: 'at least 31.25%' (N→3) and 'up to 42.20%' (N=10)."""
    assert reduction_vs_fedavg(35e6, 10) == pytest.approx(0.422, abs=2e-3)
    assert reduction_vs_fedavg(35e6, 3) == pytest.approx(0.3125, abs=0.021)
    # monotone in N
    reds = [reduction_vs_fedavg(1.0, n) for n in range(3, 11)]
    assert all(b > a for a, b in zip(reds, reds[1:]))


def test_fedavg_phong_equal():
    assert fedavg_bytes_per_round(1e6, 7) == phong_bytes_per_round(1e6, 7)
    assert fedavg_bytes_per_round(1e6, 7) == 2 * 1e6 * 7


def test_ledger_accounting():
    led = CommLedger()
    rec = led.record_round(model_bytes=1000 * 4, n_workers=5, n_params=1000)
    assert rec["downlink"] == 4000 * 5
    assert rec["uplink_model"] == 4000
    assert rec["uplink_ternary"] == 250 * 4     # 1000 codes → 250 B × 4 peers
    assert led.total() == rec["total"]
