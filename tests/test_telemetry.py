"""Observability layer: device-resident round records, JSONL traces,
byte cross-checks against the ``core.protocol`` models.

Contracts pinned here:
  * both simulator drivers export bitwise-identical telemetry (the scan
    stacks the same device records the Python loop fetches);
  * telemetry riding the carry adds NO kernel launches and NO host syncs
    to the round program (jaxpr-counted, scan included);
  * checkpoint/resume continues the telemetry carry and record stream
    exactly where the interrupted run stopped;
  * the JSONL schema round-trips and rejects malformed events;
  * every exported round's bytes equal an independent in-test
    re-derivation through ``core.protocol`` — flat, tree, masked-16/32
    and faulty-round runs (the SimResult byte views are the same data);
  * tuner sweeps emit one plan event per timed candidate;
  * the fault-code constants mirrored into ``telemetry.record`` (to
    avoid an import cycle) stay identical to ``repro.fed.faults``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat as fl
from repro.core import protocol as proto
from repro.core.fedpc import FedPCConfig
from repro.core.tree import TreeSpec
from repro.data.pipeline import federated_loaders
from repro.data.synthetic import SyntheticClassification
from repro.fed import faults as ft
from repro.fed import rounds as rd
from repro.fed.faults import FaultPlan
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.kernels import tune
from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad
from repro.privacy.spec import PrivacySpec
from repro.telemetry import record as tmr
from repro.telemetry import trace as tmt
from repro.utils import HOST_SYNC_PRIMITIVES, jaxpr_primitive_counts

N = 6
PER = 60


def _make_sim(cfg, seed=0):
    task = SyntheticClassification(n_samples=N * PER, n_features=12,
                                   n_classes=4, seed=0)
    x, y = task.generate()
    splits = [np.arange(k * PER, (k + 1) * PER) for k in range(N)]
    loaders = federated_loaders((x, y), splits, seed=seed, batch_menu=(30,))
    cfgs = make_worker_configs(N, [PER] * N, seed=seed, batch_menu=(30,))
    workers = [Worker(cfg=cfgs[k], loader=loaders[k],
                      loss_and_grad=mlp_loss_and_grad) for k in range(N)]
    params = init_mlp_classifier(jax.random.PRNGKey(0), 12, 4, hidden=(16,))
    return FedSimulator(workers, params, fed_cfg=cfg)


def _faulty_cfg(fanout=3, mb=16):
    return FedPCConfig(
        n_workers=N,
        privacy=PrivacySpec(mask_seed=5, modulus_bits=mb,
                            recovery_threshold=2),
        tree=TreeSpec(fanout=fanout),
        faults=FaultPlan(seed=3, drop_before_uplink=0.1,
                         drop_after_uplink=0.25))


# ---------------------------------------------------------------------------
# Mirrored constants (import-cycle avoidance must not drift)
# ---------------------------------------------------------------------------

def test_fault_constants_pinned_to_faults_module():
    assert tmr.FAULT_NONE == ft.FAULT_NONE
    assert tmr.DROP_BEFORE == ft.DROP_BEFORE


# ---------------------------------------------------------------------------
# Driver parity: scan and Python loop export identical telemetry
# ---------------------------------------------------------------------------

def test_driver_trace_parity_bitwise():
    r1 = _make_sim(_faulty_cfg()).run_fedpc(rounds=3)
    r2 = _make_sim(_faulty_cfg()).run_fedpc_scan(rounds=3)
    assert r1.telemetry is not None and r2.telemetry is not None
    assert r1.telemetry.meta["driver"] == "run_fedpc"
    assert r2.telemetry.meta["driver"] == "run_fedpc_scan"
    # event streams are identical (ints exact; device costs computed by
    # the same float32 program are bitwise equal across drivers)
    assert r1.telemetry.rounds == r2.telemetry.rounds
    assert r1.telemetry.workers == r2.telemetry.workers
    assert r1.telemetry.edges == r2.telemetry.edges
    # cumulative carry totals agree too
    t1, t2 = r1.round_state.telemetry, r2.round_state.telemetry
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(t1.rounds) == 3
    assert int(t1.sampled) == sum(r["n_sampled"]
                                  for r in r1.telemetry.rounds)


# ---------------------------------------------------------------------------
# Structure: telemetry adds no launches, no host syncs
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (41, 23)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (23,))}


def _fixture(seed=0, privacy=None, telemetry=True):
    tree = _tree(seed)
    layout = fl.layout_of(tree)
    state = rd.init_round_state(tree, N, layout, privacy=privacy,
                                telemetry=telemetry)
    key = jax.random.PRNGKey(seed + 77)
    deltas = 0.05 * jax.random.normal(key, (N,) + state.buf_p1.shape)
    sizes = jnp.linspace(20.0, 80.0, N)
    return tree, layout, state, deltas, sizes


def _worker_fn(deltas):
    def fn(wc, buf, t):
        bufs_q = buf[None] + deltas * (1.0 + 0.1 * t.astype(jnp.float32))
        costs = 1.0 / (t.astype(jnp.float32)
                       + jnp.arange(N, dtype=jnp.float32) + 1.0)
        return wc, bufs_q, costs
    return fn


@pytest.mark.parametrize("spec", [None, PrivacySpec(),
                                  PrivacySpec(dp_epsilon=2.0)])
def test_round_step_with_telemetry_two_launches_no_host_sync(spec):
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    _, _, state, _, sizes = _fixture(0, privacy=spec)
    assert state.telemetry is not None
    bufs = jnp.zeros((N,) + state.buf_p1.shape)
    costs = jnp.ones((N,))
    counts = jaxpr_primitive_counts(
        lambda s, b, c: wire.round_step(s, b, c, sizes), state, bufs, costs)
    assert counts.get("pallas_call") == 2, counts
    assert sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES) == 0, counts


def test_scan_with_telemetry_two_launches_no_host_sync():
    spec = PrivacySpec(dp_epsilon=2.0)
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    _, _, state, deltas, sizes = _fixture(0, privacy=spec)
    counts = jaxpr_primitive_counts(
        lambda s: rd.scan_rounds(wire, s, _worker_fn(deltas), 0, 7, sizes),
        state)
    assert counts.get("pallas_call") == 2, counts
    assert sum(counts.get(p, 0) for p in HOST_SYNC_PRIMITIVES) == 0, counts


def test_telemetry_off_still_runs():
    wire = rd.WirePath(rd.WireConfig(), interpret=True)
    _, _, state, deltas, sizes = _fixture(0, telemetry=False)
    assert state.telemetry is None
    st, _, infos = jax.jit(lambda s: rd.scan_rounds(
        wire, s, _worker_fn(deltas), 0, 3, sizes))(state)
    assert st.telemetry is None
    assert infos["telemetry"].n_sampled.shape == (3,)


# ---------------------------------------------------------------------------
# Checkpoint/resume: carry totals and record stream continue exactly
# ---------------------------------------------------------------------------

def test_checkpoint_resume_trace_continuity(tmp_path):
    spec = PrivacySpec(dp_epsilon=2.0)
    tree, layout, state0, deltas, sizes = _fixture(3, privacy=spec)
    wire = rd.WirePath(rd.WireConfig(), interpret=True, privacy=spec)
    worker = _worker_fn(deltas)

    def run(st, n):
        return jax.jit(lambda s: rd.scan_rounds(
            wire, s, worker, 0, n, sizes))(st)

    st_full, _, infos_full = run(state0, 4)
    st_half, _, infos_a = run(state0, 2)
    rd.save_round_state(str(tmp_path), st_half)
    like = rd.init_round_state(tree, N, layout, privacy=spec)
    st_loaded, _ = rd.load_round_state(str(tmp_path), like)
    for a, b in zip(jax.tree_util.tree_leaves(st_loaded.telemetry),
                    jax.tree_util.tree_leaves(st_half.telemetry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st_resumed, _, infos_b = run(st_loaded, 2)
    # carry totals: resumed == uninterrupted, bitwise
    for a, b in zip(jax.tree_util.tree_leaves(st_resumed.telemetry),
                    jax.tree_util.tree_leaves(st_full.telemetry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(st_resumed.telemetry.rounds) == 4
    # record stream: segment A ++ segment B == the 4-round run's records
    cat = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([jnp.atleast_1d(a),
                                      jnp.atleast_1d(b)]),
        infos_a["telemetry"], infos_b["telemetry"])
    for a, b in zip(jax.tree_util.tree_leaves(cat),
                    jax.tree_util.tree_leaves(infos_full["telemetry"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# JSONL schema: round-trip + rejection of malformed events
# ---------------------------------------------------------------------------

def test_trace_jsonl_roundtrip(tmp_path):
    res = _make_sim(_faulty_cfg()).run_fedpc_scan(rounds=2)
    path = str(tmp_path / "trace.jsonl")
    n = res.telemetry.write(path)
    events = tmt.read_trace(path)
    assert len(events) == n
    summary = tmt.summarize(events)
    assert summary.bytes_per_round == res.telemetry.bytes_per_round
    assert (summary.recovery_bytes_per_round
            == res.telemetry.recovery_bytes_per_round)
    assert summary.pilots == res.telemetry.pilots
    assert summary.meta == res.telemetry.meta


def test_schema_rejects_malformed_events():
    meta = {"ev": "meta", "schema": tmt.SCHEMA_VERSION, "source": "t"}
    ok_round = {"ev": "round", "t": 1, "pilot": 0, "n_sampled": 4,
                "n_used": 4, "n_dead": 0, "n_pre_uplink": 0,
                "n_recovered": 0, "n_degraded": 0, "cost": 1.0,
                "wire_bytes": 10.0, "recovery_bytes": 0.0}
    tmt.validate_trace([meta, ok_round])
    with pytest.raises(ValueError, match="unknown trace event kind"):
        tmt.validate_event({"ev": "nope"})
    with pytest.raises(ValueError, match="missing field"):
        tmt.validate_event({k: v for k, v in ok_round.items()
                            if k != "pilot"})
    with pytest.raises(ValueError, match="unknown fields"):
        tmt.validate_event({**ok_round, "extra": 1})
    with pytest.raises(ValueError, match="bool"):
        tmt.validate_event({**ok_round, "n_dead": True})
    with pytest.raises(ValueError, match="must start with a meta"):
        tmt.validate_trace([ok_round])
    with pytest.raises(ValueError, match="schema"):
        tmt.validate_trace([{**meta, "schema": 99}])
    with pytest.raises(ValueError, match="empty trace"):
        tmt.validate_trace([])
    with pytest.raises(ValueError, match="sent"):
        tmt.validate_event({"ev": "worker", "t": 1, "worker": 0,
                            "sampled": True, "fault": 0, "pilot": False,
                            "sent": "gradients"})


def test_summarize_rejects_tampered_bytes(tmp_path):
    res = _make_sim(_faulty_cfg()).run_fedpc_scan(rounds=2)
    events = res.telemetry.events()
    bad = [dict(e) for e in events]
    for e in bad:
        if e["ev"] == "round":
            e["wire_bytes"] += 1.0
            break
    with pytest.raises(tmt.TelemetryMismatch, match="stored wire bytes"):
        tmt.summarize(bad)


# ---------------------------------------------------------------------------
# Byte model matrix: trace bytes == core/protocol, re-derived in-test
# ---------------------------------------------------------------------------

def _expected_bytes(meta, r):
    """An independent re-derivation of one round's bytes straight from the
    protocol functions (not via telemetry.round_bytes)."""
    mb, n = meta["model_bytes"], r["n_sampled"]
    masked = meta["wire"] == "masked"
    if meta["fanout"]:
        wire = proto.fedpc_tree_bytes_per_round(
            mb, n, meta["fanout"],
            word_bits=meta["modulus_bits"] if masked else None)
    elif masked:
        wire = proto.fedpc_masked_bytes_per_round(
            mb, n, word_bits=meta["modulus_bits"])
    else:
        wire = proto.fedpc_bytes_per_round(mb, n)
    rec_b = 0.0
    if meta["faults_active"]:
        leaf_bits = meta["modulus_bits"] if masked else 2.0
        wire -= mb * r["n_pre_uplink"] * leaf_bits / 32.0
        if meta["masking"] and meta["recovery_threshold"]:
            g = meta["fanout"] or None
            rec_b = (proto.recovery_dealing_bytes_per_round(
                         meta["n_workers"], g)
                     + proto.recovery_reconstruction_bytes(
                         r["n_recovered"], meta["recovery_threshold"], g,
                         n_workers=meta["n_workers"]))
    return float(wire), float(rec_b)


_MATRIX = {
    "flat": FedPCConfig(n_workers=N),
    "tree": FedPCConfig(n_workers=N, tree=TreeSpec(fanout=3)),
    "masked16": FedPCConfig(n_workers=N,
                            privacy=PrivacySpec(mask_seed=5,
                                                modulus_bits=16)),
    "masked32": FedPCConfig(n_workers=N,
                            privacy=PrivacySpec(mask_seed=5,
                                                modulus_bits=32)),
    "faulty": _faulty_cfg(),
}


@pytest.mark.parametrize("name", sorted(_MATRIX))
def test_trace_bytes_match_protocol_models(name):
    res = _make_sim(_MATRIX[name]).run_fedpc_scan(rounds=2)
    summary = res.telemetry
    assert summary is not None and len(summary.rounds) == 2
    for r in summary.rounds:
        wire, rec_b = _expected_bytes(summary.meta, r)
        assert r["wire_bytes"] == wire
        assert r["recovery_bytes"] == rec_b


@pytest.mark.parametrize("name", ["flat", "faulty"])
def test_simresult_views_are_telemetry_rollup(name):
    """Satellite 1 regression pin: the old hand-built SimResult byte lists
    and the telemetry rollup are the same numbers (build_trace would have
    raised on any divergence; this pins the VIEW wiring too)."""
    res = _make_sim(_MATRIX[name]).run_fedpc(rounds=2)
    assert res.bytes_per_round == res.telemetry.bytes_per_round
    assert (res.recovery_bytes_per_round
            == res.telemetry.recovery_bytes_per_round)
    assert res.total_bytes == pytest.approx(
        np.sum(res.bytes_per_round) + np.sum(res.recovery_bytes_per_round))
    assert res.total_bytes == pytest.approx(res.telemetry.total_bytes)


def test_fedavg_baseline_keeps_backing_lists():
    res = _make_sim(FedPCConfig(n_workers=N)).run_fedavg(rounds=2)
    assert res.telemetry is None
    assert len(res.bytes_per_round) == 2
    mb = None
    for b in res.bytes_per_round:
        mb = b if mb is None else mb
        assert b == mb                      # constant 2VN per round
    assert res.total_bytes == pytest.approx(np.sum(res.bytes_per_round))


# ---------------------------------------------------------------------------
# Tuner sweeps emit plan events through the same trace schema
# ---------------------------------------------------------------------------

def test_tune_sweeps_emit_plan_events():
    events = []

    def sink(event):
        tmt.validate_event(event)
        events.append(event)

    tune.set_trace_writer(tmt.plan_emitter(sink))
    try:
        out1 = tune.autotune_stacked(32, 4, interpret=True, reps=1)
        out2 = tune.autotune_mask_repair(32, 4, interpret=True, reps=1)
        out3 = tune.autotune_partial_sum(32, 2, 4, interpret=True, reps=1)
    finally:
        tune.set_trace_writer(None)
    assert len(events) == (len(out1["timings"]) + len(out2["timings"])
                           + len(out3["timings"]))
    for out in (out1, out2, out3):
        kind_evs = [e for e in events if e["kind"] == out["kind"]]
        bests = [e for e in kind_evs if e["best"]]
        assert len(bests) == 1
        assert bests[0]["block_rows"] == out["best"]["block_rows"]
        assert {(e["block_rows"], e["block_workers"]) for e in kind_evs} \
            == {(t["block_rows"], t["block_workers"])
                for t in out["timings"]}
    # hook cleared: further sweeps emit nothing
    n = len(events)
    tune.autotune_stacked(32, 4, interpret=True, reps=1)
    assert len(events) == n


def test_plan_trace_writer_roundtrip(tmp_path):
    path = str(tmp_path / "plans.jsonl")
    with tmt.TraceWriter(path, source="test_bench") as w:
        tune.set_trace_writer(tmt.plan_emitter(w.emit))
        try:
            tune.autotune_mask_repair(32, 4, interpret=True, reps=1)
        finally:
            tune.set_trace_writer(None)
    events = tmt.read_trace(path)
    assert events[0]["source"] == "test_bench"
    summary = tmt.summarize(events)
    assert summary.plans and not summary.rounds
    assert sum(e["best"] for e in summary.plans) == 1
