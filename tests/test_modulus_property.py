"""Property tests for the fixed-point / modulus contract of the masked wire.

The secure-aggregation wire carries ``W_k * field`` words mod
``2**modulus_bits`` with ``W_k = round(w_k * 2**fixpoint_bits)`` and
``field = code + 1 in {0, 1, 2}``. The whole scheme rests on one
arithmetic contract, which these tests check for RANDOM weight vectors
(``sum_k w_k <= 1`` — the Eq. (3) convexity invariant), RANDOM
participation subsets and BOTH moduli:

* the unmasked cohort sum never wraps the modulus, and the signed
  de-bias value ``sum_k W_k * code_k`` fits the signed range — so the
  master's ``bitcast(sum - sum_wq)`` is EXACT integer arithmetic;
* descaling by ``2**-fixpoint_bits`` round-trips to the real-weighted
  ternary sum within the documented ``n * 2**-(fixpoint_bits+1)``
  per-word rounding bound (each weight rounds by at most half an lsb,
  and ``|code| <= 1``);
* the analytic ``PrivacySpec.wrap_headroom_workers`` bound covers every
  cohort size these examples draw.

Runs under real hypothesis when installed, else the deterministic
``tests/_hypothesis_fallback`` shim.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.privacy import PrivacySpec, quantize_weights

WORDS = 64


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=12),
       st.integers(min_value=0, max_value=2**32 - 1),
       st.sampled_from([16, 32]))
def test_cohort_sum_never_wraps_and_descale_roundtrips(n, seed, mb):
    rng = np.random.default_rng(seed)
    w = rng.random(n)
    if w.sum() > 1.0:
        w = w / w.sum()                      # sum_k w_k <= 1
    part = rng.random(n) < 0.7               # random participation subset
    if not part.any():
        part[int(rng.integers(n))] = True
    w = np.where(part, w, 0.0).astype(np.float32)

    spec = PrivacySpec(modulus_bits=mb)
    fb = spec.fixpoint_bits
    assert n <= spec.wrap_headroom_workers()
    wq = np.asarray(quantize_weights(jnp.asarray(w), fb), np.uint64)

    # analytic no-wrap: max field sum (every code +1) inside the modulus,
    # max |de-bias| inside the signed half
    total = int(wq.sum())
    assert 2 * total < 2 ** mb
    assert total < 2 ** (mb - 1)

    # empirical exactness over random ternary codes
    codes = rng.integers(-1, 2, size=(n, WORDS))
    fields = (codes + 1).astype(np.uint64)
    mask = np.uint64(2 ** mb - 1)
    s = (wq[:, None] * fields).sum(axis=0) & mask
    sumw = np.uint64(total) & mask
    ci = (s - sumw) & mask                   # the master's modular de-bias
    ci = ci.astype(np.int64)
    ci = np.where(ci >= 2 ** (mb - 1), ci - 2 ** mb, ci)
    exact = (wq.astype(np.int64)[:, None] * codes).sum(axis=0)
    np.testing.assert_array_equal(ci, exact)

    # descale round-trip within the documented rounding bound
    descale = ci.astype(np.float64) * 2.0 ** -fb
    true = (w.astype(np.float64)[:, None] * codes).sum(axis=0)
    bound = n * 2.0 ** -(fb + 1) + 1e-9
    assert np.max(np.abs(descale - true)) <= bound
