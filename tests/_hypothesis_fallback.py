"""Minimal deterministic stand-in for `hypothesis` when it is not installed.

The real dependency is declared in pyproject.toml; this fallback exists so the
tier-1 suite still *runs* the property tests (with seeded random examples and
boundary-value bias) in hermetic containers without network access. Only the
surface the test-suite actually uses is implemented: ``given``, ``settings``
and the strategies ``integers``, ``floats``, ``booleans``, ``lists``,
``sampled_from``, ``just`` and ``tuples``.

``install()`` registers the shim as ``hypothesis`` / ``hypothesis.strategies``
in ``sys.modules``; conftest only calls it after a real import fails, so an
installed hypothesis always wins.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A strategy draws one example per call; ``i`` is the example index so
    the first draws can hit boundary values deterministically."""

    def example(self, rng: random.Random, i: int):  # pragma: no cover
        raise NotImplementedError

    def map(self, fn):
        return _Mapped(self, fn)


class _Mapped(Strategy):
    def __init__(self, inner, fn):
        self.inner, self.fn = inner, fn

    def example(self, rng, i):
        return self.fn(self.inner.example(rng, i))


class _Integers(Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = min_value, max_value

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(Strategy):
    def __init__(self, min_value, max_value, **_kw):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Booleans(Strategy):
    def example(self, rng, i):
        return bool(i % 2) if i < 2 else rng.random() < 0.5


class _SampledFrom(Strategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def example(self, rng, i):
        if i < len(self.seq):
            return self.seq[i]
        return rng.choice(self.seq)


class _Just(Strategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng, i):
        return self.value


class _Lists(Strategy):
    def __init__(self, elements, min_size=0, max_size=10, **_kw):
        self.el, self.lo = elements, min_size
        self.hi = self.lo + 10 if max_size is None else max_size

    def example(self, rng, i):
        size = self.lo if i == 0 else (
            self.hi if i == 1 else rng.randint(self.lo, self.hi))
        return [self.el.example(rng, i + 2) for _ in range(size)]


class _Tuples(Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng, i):
        return tuple(s.example(rng, i) for s in self.strategies)


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", None) or getattr(
                fn, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                vals = [s.example(rng, i) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {vals!r}") from e

        wrapper._hypothesis_given = True
        # Strategy-filled parameters must not look like pytest fixtures.
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_kw):
    # Works whether it decorates the raw test (given applied after) or the
    # given-wrapper (given applied first): both read `_max_examples`.
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def install() -> None:
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda min_value=0, max_value=2**16: _Integers(
        min_value, max_value)
    st.floats = _Floats
    st.booleans = _Booleans
    st.lists = _Lists
    st.sampled_from = _SampledFrom
    st.just = _Just
    st.tuples = _Tuples
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                            data_too_large="data_too_large")
    hyp.__is_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
