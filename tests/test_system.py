"""End-to-end system tests: FedPC trains a real (reduced) transformer on
synthetic LM data, checkpoints, and resumes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import SyntheticLM, sequence_split
from repro.fed.simulator import FedSimulator
from repro.fed.worker import Worker, make_worker_configs
from repro.models import build_model


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("fedpc-paper")
    m = build_model(cfg)
    toks = SyntheticLM(n_sequences=96, seq_len=32, vocab=cfg.vocab,
                       seed=0).generate()
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p, batch: m.loss(p, {"tokens": jnp.asarray(batch[0])}),
        has_aux=True))
    return cfg, m, toks, loss_fn


def _workers(toks, loss_fn, n=3, seed=0):
    splits = sequence_split(len(toks), n, seed=seed)
    cfgs = make_worker_configs(n, [len(s) for s in splits], seed=seed,
                               batch_menu=(16, 8))
    return [
        Worker(cfg=cfgs[k],
               loader=BatchIterator((toks[splits[k]],), cfgs[k].batch_size,
                                    seed=seed + k),
               loss_and_grad=loss_fn)
        for k in range(n)
    ]


def test_fedpc_trains_transformer(lm_setup):
    cfg, m, toks, loss_fn = lm_setup
    workers = _workers(toks, loss_fn)
    params = m.init(jax.random.PRNGKey(0))
    sim = FedSimulator(workers, params)
    res = sim.run_fedpc(rounds=6)
    assert res.costs[-1] < res.costs[0]
    for leaf in jax.tree_util.tree_leaves(res.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_fedpc_beats_comm_budget_of_fedavg(lm_setup):
    cfg, m, toks, loss_fn = lm_setup
    workers = _workers(toks, loss_fn, seed=1)
    params = m.init(jax.random.PRNGKey(0))
    sim = FedSimulator(workers, params)
    r_pc = sim.run_fedpc(rounds=3)
    r_avg = sim.run_fedavg(rounds=3)
    assert r_pc.total_bytes < r_avg.total_bytes
    # Eq. (8) exact ratio at N=3, fp32
    want = (3 + 1 + (3 - 1) / 16.0) / (2 * 3)
    assert r_pc.total_bytes / r_avg.total_bytes == pytest.approx(want,
                                                                 rel=1e-6)


def test_checkpoint_resume(lm_setup, tmp_path):
    cfg, m, toks, loss_fn = lm_setup
    workers = _workers(toks, loss_fn, seed=2)
    params = m.init(jax.random.PRNGKey(0))
    sim = FedSimulator(workers, params)
    res = sim.run_fedpc(rounds=2)
    save_checkpoint(str(tmp_path), res.params, step=2)
    restored, manifest = load_checkpoint(str(tmp_path), res.params)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # resume training from the checkpoint
    sim2 = FedSimulator(_workers(toks, loss_fn, seed=3), restored)
    res2 = sim2.run_fedpc(rounds=2)
    assert np.isfinite(res2.costs[-1])
