"""Secure-aggregation wire on the mesh: masked sharded sync parity.

Subprocess with 8 host devices (like tests/test_fed_sharded.py). The
secure-agg contract under test:
  * with DP off, the masked sharded sync is BITWISE identical to the
    unmasked (mask_seed=None) replicated reference — masks cancel exactly
    in the integer domain, and modular addition is order-free, so the
    psum_scatter+all_gather reduction can never reorder its way out of
    parity — across multiple (fed, model) meshes and both round branches;
  * masked == unmasked holds sharded-vs-sharded and replicated-vs-
    replicated too (mask values can never reach the output);
  * the masked wire is allclose to the plain float wire (fixed-point
    weight rounding only);
  * DP on actually changes the update, and still cancels masks bitwise;
  * the collective-payload audit: nothing float crosses the fed axis
    stacked per worker, no plaintext int8/uint8 code payload crosses on
    the masked wire, and the audit hook records into the ledger.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.privacy import LeakageLedger
from repro.fed.distributed import build_fed_sync, fed_state_init
from repro.privacy import PrivacySpec

k = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(k, (300, 40)),
          "b": jax.random.normal(jax.random.fold_in(k, 5), (40,)),
          "s": jax.random.normal(jax.random.fold_in(k, 6), ())}
out = {"audits": 0, "audit_payload_dtypes": []}

def tree_max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

SPECS = {
    "m": PrivacySpec(),                        # secure agg, masks on
    "u": PrivacySpec(mask_seed=None),          # same wire, masks off
    "dp": PrivacySpec(dp_epsilon=2.0),         # + randomized response
}

for fed, model in ((4, 2), (2, 4), (8, 1)):
    devs = np.array(jax.devices()[: fed * model]).reshape(fed, model)
    mesh = Mesh(devs, ("data", "model"))
    F = fed
    sizes = jnp.linspace(50.0, 200.0, F)
    costs = jnp.linspace(0.9, 0.5, F)
    params_F = jax.tree_util.tree_map(
        lambda x: jnp.stack([x + 0.05 * (i + 1) for i in range(F)]), params)
    betas = jnp.linspace(0.1, 0.35, F)
    mask = (jnp.arange(F) != 1).astype(jnp.float32)

    for t in (1, 3):
        state = fed_state_init(params, F)
        if t > 1:
            state["round"] = jnp.asarray(t, jnp.int32)
            state["params_prev"] = jax.tree_util.tree_map(
                lambda x: x + 0.01, params)
            state["prev_costs"] = jnp.ones((F,))
        res = {}
        with mesh:
            led = LeakageLedger()
            for shard in (True, False):
                for tag, spec in SPECS.items():
                    sync = build_fed_sync(None, mesh, "data", "fedpc",
                                          shard_wire=shard, privacy=spec,
                                          betas=betas, ledger=led)
                    new_params, aux = jax.jit(sync)(
                        params_F, costs, sizes, state, mask)
                    res[(shard, tag)] = new_params
                sync_p = build_fed_sync(None, mesh, "data", "fedpc",
                                        shard_wire=shard, betas=betas)
                res[(shard, "plain")], _ = jax.jit(sync_p)(
                    params_F, costs, sizes, state, mask)
            out["audits"] += len(led.audits)

        key = f"{fed}x{model}_t{t}"
        # DP off: masked sharded == unmasked replicated (the acceptance
        # comparison) and every other mask/shard combination
        out[key + "_msh_vs_urep"] = tree_max_diff(res[(True, "m")],
                                                  res[(False, "u")])
        out[key + "_msh_vs_mrep"] = tree_max_diff(res[(True, "m")],
                                                  res[(False, "m")])
        out[key + "_ush_vs_urep"] = tree_max_diff(res[(True, "u")],
                                                  res[(False, "u")])
        out[key + "_m_vs_plain"] = tree_max_diff(res[(True, "m")],
                                                 res[(True, "plain")])
        # DP on: masks still cancel (dp-sharded vs dp-sharded is trivial;
        # the real check is dp with masks == dp without masks, same mesh)
        sync_dpu = build_fed_sync(None, mesh, "data", "fedpc",
                                  shard_wire=True,
                                  privacy=PrivacySpec(mask_seed=None,
                                                      dp_epsilon=2.0),
                                  betas=betas)
        with mesh:
            dpu, _ = jax.jit(sync_dpu)(params_F, costs, sizes, state, mask)
        out[key + "_dp_masked_vs_unmasked"] = tree_max_diff(
            res[(True, "dp")], dpu)
        out[key + "_dp_vs_m"] = tree_max_diff(res[(True, "dp")],
                                              res[(True, "m")])

# collective payload audit detail (one mesh is enough)
from repro.privacy import collective_payloads
from repro.core import flat as fl
devs = np.array(jax.devices()).reshape(4, 2)
mesh = Mesh(devs, ("data", "model"))
F = 4
sizes = jnp.linspace(50.0, 200.0, F)
costs = jnp.linspace(0.9, 0.5, F)
params_F = jax.tree_util.tree_map(
    lambda x: jnp.stack([x + 0.05 * (i + 1) for i in range(F)]), params)
state = fed_state_init(params, F)
with mesh:
    sync = build_fed_sync(None, mesh, "data", "fedpc", shard_wire=True,
                          privacy=PrivacySpec())
    payloads = collective_payloads(sync, params_F, costs, sizes, state)
out["audit_payload_dtypes"] = sorted({p["dtype"] for p in payloads})
out["stacked_float_payloads"] = sum(
    1 for p in payloads
    if p["dtype"].startswith("float") and p["shape"][:1] == (F,))
out["code_payloads"] = sum(
    1 for p in payloads if p["dtype"] in ("int8", "uint8"))

# 32-bit conservative modulus on one mesh: bitwise mask cancellation,
# tight parity vs the plain float wire (fb=24), uint32 words on the fed
# axis (the full modulus sweep is per-kernel in tests/test_masked_wire.py)
state = fed_state_init(params, F)
state["round"] = jnp.asarray(3, jnp.int32)
state["params_prev"] = jax.tree_util.tree_map(lambda x: x + 0.01, params)
state["prev_costs"] = jnp.ones((F,))
with mesh:
    s32 = build_fed_sync(None, mesh, "data", "fedpc", shard_wire=True,
                         privacy=PrivacySpec(modulus_bits=32))
    m32, _ = jax.jit(s32)(params_F, costs, sizes, state)
    s32u = build_fed_sync(None, mesh, "data", "fedpc", shard_wire=True,
                          privacy=PrivacySpec(modulus_bits=32,
                                              mask_seed=None))
    u32, _ = jax.jit(s32u)(params_F, costs, sizes, state)
    sp = build_fed_sync(None, mesh, "data", "fedpc", shard_wire=True)
    pl32, _ = jax.jit(sp)(params_F, costs, sizes, state)
    payloads32 = collective_payloads(s32, params_F, costs, sizes, state)
out["m32_vs_u32"] = tree_max_diff(m32, u32)
out["m32_vs_plain"] = tree_max_diff(m32, pl32)
out["audit_payload_dtypes_32"] = sorted({p["dtype"] for p in payloads32})

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_masked_sharded_bitwise_equals_unmasked_replicated(results):
    """Acceptance: DP off -> masked sharded sync bit-identical to the
    unmasked replicated reference, >= 2 meshes x both round branches."""
    keys = [k for k in results if k.endswith("_msh_vs_urep")]
    assert len(keys) == 3 * 2                 # meshes x round branches
    for k in keys:
        assert results[k] == 0.0, f"{k}: {results[k]}"


def test_mask_and_shard_combinations_all_bitwise(results):
    for suffix in ("_msh_vs_mrep", "_ush_vs_urep"):
        for k in (k for k in results if k.endswith(suffix)):
            assert results[k] == 0.0, f"{k}: {results[k]}"


def test_masked_allclose_to_plain_float_wire(results):
    # default wire is the 16-bit modulus: fixpoint_bits=14 weight rounding
    # is the only divergence from the float wire, so the bound is coarser
    # than the 32-bit path's
    for k in (k for k in results if k.endswith("_m_vs_plain")):
        assert 0.0 <= results[k] < 2e-3, f"{k}: {results[k]}"


def test_conservative_32bit_modulus_path(results):
    """modulus_bits=32 on the mesh: masks cancel bitwise, fb=24 rounding
    keeps the tight plain-wire bound, uint32 words cross the fed axis."""
    assert results["m32_vs_u32"] == 0.0
    assert 0.0 <= results["m32_vs_plain"] < 1e-5
    assert "uint32" in results["audit_payload_dtypes_32"]


def test_dp_cancels_masks_and_changes_update(results):
    for k in (k for k in results if k.endswith("_dp_masked_vs_unmasked")):
        assert results[k] == 0.0, f"{k}: {results[k]}"
    assert any(results[k] > 0.0
               for k in results if k.endswith("_dp_vs_m"))


def test_fed_collective_payload_policy(results):
    """What actually crosses the fed axis on the masked wire: uint16
    masked words (the 16-bit default modulus — half the 32-bit path's
    bytes) and the f32 pilot/goodness scalars — never a worker-stacked
    float buffer, never plaintext int8/uint8 codes."""
    assert results["stacked_float_payloads"] == 0
    assert results["code_payloads"] == 0
    assert "uint16" in results["audit_payload_dtypes"]
    # enforcement hook recorded audits (one per first-call masked build)
    assert results["audits"] > 0
