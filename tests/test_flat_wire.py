"""Fused flat wire path: parity with the reference composition, flat
round-trip identity, and launch/intermediate accounting (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import flat as fl
from repro.core.ternary import ternarize_tree, ternarize_tree_round1
from repro.core.update import masked_weights, master_update_tree
from repro.kernels import ops, ref

SHAPES = [(128,), (1000,), (8, 128), (64, 37), (3, 5, 7), (4096,), (2048, 2)]


# ---------------------------------------------------------------------------
# Uplink: ternary_pack == pack2bit(ternary_encode(...)), both round branches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
def test_ternary_pack_matches_composition(shape):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, shape)
    p1 = jax.random.normal(k2, shape)
    p2 = jax.random.normal(k3, shape)
    fused = ops.ternary_pack(q, p1, p2, 0.2, interpret=True)
    comp = ops.pack2bit(ops.ternary_encode(q, p1, p2, 0.2, interpret=True),
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(comp))


@pytest.mark.parametrize("shape", SHAPES)
def test_ternary_pack_round1_matches_composition(shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    q = jax.random.normal(k1, shape)
    p0 = jax.random.normal(k2, shape)
    fused = ops.ternary_pack_round1(q, p0, 0.01, interpret=True)
    comp = ops.pack2bit(ops.ternary_encode_round1(q, p0, 0.01,
                                                  interpret=True),
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(comp))


def test_ternary_pack_ragged_tail_bytes():
    """Tail codes beyond n must pack exactly like the zero-padded ref."""
    n = 999                                  # 3 codes in the last byte
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=n), jnp.float32)
    p1 = jnp.asarray(rng.normal(size=n), jnp.float32)
    p2 = jnp.asarray(rng.normal(size=n), jnp.float32)
    fused = ops.ternary_pack(q, p1, p2, 0.2, interpret=True)
    assert fused.shape[0] == -(-n // 4)
    pad = (-n) % 4
    codes = jnp.concatenate([ref.ternary_encode_ref(q, p1, p2, 0.2),
                             jnp.zeros((pad,), jnp.int8)])
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(ref.pack2bit_ref(codes)))


# ---------------------------------------------------------------------------
# Master: packed_master_update == master_update_tree on the same wire codes
# ---------------------------------------------------------------------------

def _param_tree(key):
    ks = jax.random.split(key, 4)
    return {
        "w0": jax.random.normal(ks[0], (33, 17)),
        "b0": jax.random.normal(ks[1], (17,)),
        "w1": jax.random.normal(ks[2], (17, 5)),
        "scalar": jax.random.normal(ks[3], ()),
    }


@pytest.mark.parametrize("n_workers", [2, 8, 16])
@pytest.mark.parametrize("t", [1, 3])
def test_flat_master_update_matches_tree_reference(n_workers, t):
    key = jax.random.PRNGKey(10 * n_workers + t)
    tree = _param_tree(key)
    layout = fl.layout_of(tree)
    p1t = tree
    p2t = (jax.tree_util.tree_map(jnp.zeros_like, tree) if t == 1
           else jax.tree_util.tree_map(lambda x: 0.9 * x, tree))
    locals_ = [jax.tree_util.tree_map(
        lambda x, i=i: x + 0.02 * (i + 1) * jnp.sign(x), tree)
        for i in range(n_workers)]
    k_star = n_workers // 2
    p_shares = jnp.linspace(0.5, 1.5, n_workers)
    p_shares = p_shares / p_shares.sum()
    beta, alpha0, alpha1 = 0.2, 0.01, 0.01

    buf_p1 = fl.flatten_tree(p1t, layout)
    buf_p2 = fl.flatten_tree(p2t, layout)
    packed = []
    for k in range(n_workers):
        buf_q = fl.flatten_tree(locals_[k], layout)
        packed.append(ops.flat_ternary_pack(
            buf_q, buf_p1, buf_p2, t=t, beta=beta, alpha1=alpha1,
            interpret=True))
    betas = jnp.ones((n_workers,)) if t == 1 else jnp.full((n_workers,), beta)
    w = masked_weights(p_shares, betas, k_star)
    new_buf = ops.flat_master_update(
        fl.flatten_tree(locals_[k_star], layout), jnp.stack(packed), w,
        buf_p1, buf_p2, t=t, alpha0=alpha0, interpret=True)
    got = fl.unflatten_tree(new_buf, layout)

    if t == 1:
        terns = [ternarize_tree_round1(l, p1t, alpha1) for l in locals_]
    else:
        terns = [ternarize_tree(l, p1t, p2t, beta) for l in locals_]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *terns)
    want = master_update_tree(
        locals_[k_star], stacked, p_shares,
        jnp.full((n_workers,), beta), k_star, p1t, p2t, t, alpha0)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_packed_master_update_ref_agrees():
    """The flat kernel also matches the byte-level oracle in ref.py."""
    rng = np.random.default_rng(3)
    n, m = 4, 2048
    q = jnp.asarray(rng.normal(size=m), jnp.float32)
    p1 = jnp.asarray(rng.normal(size=m), jnp.float32)
    p2 = jnp.asarray(rng.normal(size=m), jnp.float32)
    codes = jnp.asarray(rng.integers(-1, 2, (n, m)), jnp.int8)
    packed = jnp.stack([ops.pack2bit(codes[k], interpret=True)
                        for k in range(n)])
    w = jnp.asarray(rng.uniform(0, 0.2, n), jnp.float32)
    want = ref.packed_master_update_ref(q, packed, w, p1, p2, 3, 0.01)
    rows = m // 128
    got = ops.flat_master_update(
        q.reshape(rows, 128), packed.reshape(n, rows // 4, 128), w,
        p1.reshape(rows, 128), p2.reshape(rows, 128), t=3, alpha0=0.01,
        interpret=True)
    np.testing.assert_allclose(np.asarray(got.reshape(-1)), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# FlatParams round-trip is the identity
# ---------------------------------------------------------------------------

@given(st.integers(1, 300), st.integers(1, 40), st.integers(1, 12),
       st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_flat_roundtrip_identity(n1, n2, n3, seed):
    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.normal(size=n1), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n2, n3)), jnp.float32),
        "c": jnp.asarray(rng.normal(), jnp.float32),
        "h": jnp.asarray(rng.normal(size=n3), jnp.bfloat16),
    }
    fp = fl.FlatParams.from_tree(tree)
    assert fp.buf.shape == (fp.layout.rows, fl.LANES)
    assert fp.layout.rows % fl.ROW_MULTIPLE == 0
    out = fp.to_tree()
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_layout_is_cached():
    tree = _param_tree(jax.random.PRNGKey(0))
    assert fl.layout_of(tree) is fl.layout_of(
        jax.tree_util.tree_map(lambda x: x + 1, tree))


def test_layout_cache_is_bounded_lru():
    """The layout cache must not grow without limit in long-lived
    multi-model processes, and must evict least-recently-used first."""
    fl._layout_cache.clear()
    trees = [{"x": jnp.zeros((8, i + 1))}
             for i in range(fl.LAYOUT_CACHE_MAX + 10)]
    for t in trees:
        fl.layout_of(t)
    assert len(fl._layout_cache) == fl.LAYOUT_CACHE_MAX
    # oldest entries were evicted → recomputed (new object); newest retained
    newest = fl.layout_of(trees[-1])
    assert fl.layout_of(trees[-1]) is newest
    # touching an old-but-retained entry protects it from the next eviction
    protected = fl.layout_of(trees[11])           # refresh its recency
    fl.layout_of({"x": jnp.zeros((16, 999))})     # force one eviction
    assert fl.layout_of(trees[11]) is protected


def test_layout_shards_align_slabs():
    tree = _param_tree(jax.random.PRNGKey(2))
    for m in (1, 2, 4, 8):
        lay = fl.layout_of(tree, shards=m)
        assert lay.shards == m
        assert lay.rows % (fl.ROW_MULTIPLE * m) == 0
        assert lay.shard_rows * m == lay.rows
        assert lay.packed_shard_rows * fl.PACK == lay.shard_rows
        # flatten/unflatten round-trips under any shard padding
        fp = fl.FlatParams.from_tree(tree, lay)
        for a, b in zip(jax.tree_util.tree_leaves(fp.to_tree()),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Launch accounting: the fused uplink is ONE pallas_call with no int8
# intermediate; the old composition is two with a full-size int8 tensor
# (the CPU-interpret analogue of the ≥1.5× HBM-traffic win on TPU).
# ---------------------------------------------------------------------------

def _count(fn, *args):
    """(pallas launch count, HBM int8 intermediate sizes) — kernel
    internals are excluded (in-register values don't touch HBM)."""
    from repro.utils import iter_jaxpr_eqns
    jaxpr = jax.make_jaxpr(fn)(*args)
    launches, int8_sizes = 0, []
    for eqn in iter_jaxpr_eqns(jaxpr.jaxpr, into_pallas=False):
        if eqn.primitive.name == "pallas_call":
            launches += 1
            continue
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if (aval is not None and getattr(aval, "dtype", None) is not None
                    and aval.dtype == jnp.int8):
                int8_sizes.append(int(np.prod(aval.shape)))
    return launches, int8_sizes


def test_fused_uplink_single_launch_no_int8_intermediate():
    n = 1 << 16
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (n,))
    p1 = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    p2 = jax.random.normal(jax.random.fold_in(k, 2), (n,))

    launches, int8_sizes = _count(
        lambda a, b, c: ops.ternary_pack(a, b, c, 0.2, interpret=True),
        q, p1, p2)
    assert launches == 1
    assert not any(s >= n for s in int8_sizes), int8_sizes

    launches, int8_sizes = _count(
        lambda a, b, c: ops.pack2bit(
            ops.ternary_encode(a, b, c, 0.2, interpret=True),
            interpret=True),
        q, p1, p2)
    assert launches == 2
    assert any(s >= n for s in int8_sizes)   # the 4×-wire-size intermediate


def test_fused_master_single_launch():
    n_workers, rows = 8, 256
    q = jnp.zeros((rows, 128))
    packed = jnp.zeros((n_workers, rows // 4, 128), jnp.uint8)
    w = jnp.full((n_workers,), 0.02)
    launches, int8_sizes = _count(
        lambda a, b, c: ops.flat_master_update(
            a, b, c, q, q, t=3, alpha0=0.01, interpret=True),
        q, packed, w)
    assert launches == 1
    assert not any(s >= rows * 128 for s in int8_sizes)
