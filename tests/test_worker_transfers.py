"""Regression: Worker.train_round must not sync device→host per batch.

The old loop did ``float(loss)`` on every batch — one blocking transfer per
step, serializing the round on transfer latency. The fix accumulates the
loss on-device. Host transfers are counted by instrumenting ``float`` over
jax arrays (on the CPU backend device→host reads are zero-copy, so jax's
transfer guard cannot see them): ``train_round_device`` must perform ZERO
conversions, the public ``train_round`` wrapper exactly ONE per round.

The instrumentation shadows ``float`` in the *worker module's* namespace
(and this test module's, for the sanity check) rather than in builtins —
patching builtins breaks jax's own ``isinstance(x, float)`` checks."""
import jax
import numpy as np
import pytest

import repro.fed.worker as worker_mod
from repro.data.pipeline import BatchIterator
from repro.fed.worker import Worker, WorkerConfig
from repro.models.mlp import init_mlp_classifier, mlp_loss_and_grad

N_SAMPLES, BATCH, EPOCHS = 96, 32, 2
BATCHES_PER_ROUND = (N_SAMPLES // BATCH) * EPOCHS       # 6

_REAL_FLOAT = float          # captured before any fixture patches the name


def _make_worker(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(N_SAMPLES, 8)).astype(np.float32)
    y = rng.integers(0, 3, size=(N_SAMPLES,)).astype(np.int32)
    cfg = WorkerConfig(worker_id=0, batch_size=BATCH, local_epochs=EPOCHS)
    w = Worker(cfg=cfg, loader=BatchIterator((x, y), BATCH, seed=seed),
               loss_and_grad=mlp_loss_and_grad)
    params = init_mlp_classifier(jax.random.PRNGKey(0), 8, 3, hidden=(16,))
    return w, params


@pytest.fixture
def float_counter(monkeypatch):
    """Counts float(<jax.Array>) conversions — each is a host sync."""
    calls = {"n": 0}

    def counting_float(x=0.0):
        if isinstance(x, jax.Array):
            calls["n"] += 1
        return _REAL_FLOAT(x)

    monkeypatch.setattr(worker_mod, "float", counting_float, raising=False)
    monkeypatch.setitem(globals(), "float", counting_float)
    return calls


def test_train_round_single_host_sync(float_counter):
    w, params = _make_worker()
    w.train_round(params)                       # warm-up / jit compile
    float_counter["n"] = 0
    _, cost = w.train_round(params)
    assert float_counter["n"] == 1, (
        f"train_round synced {float_counter['n']} times for "
        f"{BATCHES_PER_ROUND} batches; must be exactly 1 per round")
    assert np.isfinite(cost)


def test_train_round_device_zero_host_syncs(float_counter):
    w, params = _make_worker()
    w.train_round(params)
    float_counter["n"] = 0
    _, cost = w.train_round_device(params)
    assert float_counter["n"] == 0
    assert isinstance(cost, jax.Array)          # still on device
    assert np.isfinite(float(cost))


def test_counter_sees_per_batch_syncs(float_counter):
    """Sanity: the counter detects the old per-batch pattern it guards
    against."""
    w, params = _make_worker()
    float_counter["n"] = 0
    for batch in w.loader.epoch():
        (loss, _), _ = w.loss_and_grad(params, batch)
        float(loss)                             # the old per-batch host sync
    assert float_counter["n"] == N_SAMPLES // BATCH


def test_train_round_cost_matches_device_path():
    w1, params = _make_worker(seed=3)
    w2, _ = _make_worker(seed=3)
    p1, c1 = w1.train_round(params)
    p2, c2 = w2.train_round_device(params)
    assert c1 == pytest.approx(float(c2), rel=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
