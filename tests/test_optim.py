"""Optimizers + schedules: convergence on a quadratic, momentum/adam math."""
import jax
import jax.numpy as jnp
import pytest

from repro.optim.optimizers import adam, apply_updates, get, momentum, sgd
from repro.optim.schedules import constant, cosine_decay, step_decay, \
    warmup_cosine


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam"])
def test_quadratic_convergence(name):
    opt = get(name)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    grad_fn = jax.grad(lambda p: jnp.sum(jnp.square(p["x"])))
    lr = 0.1 if name != "adam" else 0.3
    for _ in range(200):
        g = grad_fn(params)
        upd, state = opt.update(g, state, params, lr)
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_momentum_accumulates():
    opt = momentum(decay=0.9)
    params = {"x": jnp.zeros(1)}
    state = opt.init(params)
    g = {"x": jnp.ones(1)}
    upd1, state = opt.update(g, state, params, 1.0)
    upd2, state = opt.update(g, state, params, 1.0)
    assert float(upd2["x"][0]) == pytest.approx(-1.9)     # 1 + 0.9


def test_adam_bias_correction_first_step():
    opt = adam()
    params = {"x": jnp.zeros(1)}
    state = opt.init(params)
    upd, state = opt.update({"x": jnp.full(1, 0.5)}, state, params, 1e-3)
    # first step ≈ -lr * sign(g)
    assert float(upd["x"][0]) == pytest.approx(-1e-3, rel=1e-3)


def test_schedules():
    assert float(constant(0.1)(100)) == pytest.approx(0.1)
    sd = step_decay(0.01, 0.5, every=10)
    assert float(sd(0)) == pytest.approx(0.01)
    assert float(sd(10)) == pytest.approx(0.005)
    assert float(sd(25)) == pytest.approx(0.0025)
    cd = cosine_decay(1.0, 100)
    assert float(cd(0)) == pytest.approx(1.0)
    assert float(cd(100)) == pytest.approx(0.0, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(5)) == pytest.approx(0.5)
    assert float(wc(10)) == pytest.approx(1.0)
