"""Distributed (shard_map) fed runtime — runs in a subprocess with 8 host
devices so the main pytest process keeps its single-device view."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.fed.distributed import build_fed_step, build_fed_sync, fed_state_init
from repro.core.update import master_update_tree
from repro.core.ternary import ternarize_tree, ternarize_tree_round1

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("fedpc-paper")
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
F = 4
sizes = jnp.array([100.0, 200.0, 150.0, 50.0])
out = {}

try:
    mesh_ctx = jax.set_mesh(mesh)        # jax >= 0.5
except AttributeError:
    mesh_ctx = mesh                       # Mesh is a context manager on 0.4
with mesh_ctx:
    # --- strategies agree with each other and with the reference math ----
    state = fed_state_init(params, F)
    state["round"] = jnp.asarray(3, jnp.int32)       # exercise Eq.(5) branch
    state["params_prev"] = jax.tree_util.tree_map(
        lambda x: x + 0.01, params)
    state["prev_costs"] = jnp.array([1.0, 1.0, 1.0, 1.0])
    params_F = jax.tree_util.tree_map(
        lambda x: jnp.stack([x + 0.05 * (i + 1) for i in range(F)]), params)
    costs = jnp.array([0.9, 0.5, 0.8, 0.95])

    results = {}
    for strat in ("fedpc", "fedpc_packed", "fedpc_reduce"):
        sync = build_fed_sync(m, mesh, "data", strat)
        new_params, aux = jax.jit(sync)(params_F, costs, sizes, state)
        results[strat] = new_params
    reduce_diff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(results["fedpc"]),
                        jax.tree_util.tree_leaves(results["fedpc_reduce"])))
    out["reduce_vs_gather_max_diff"] = reduce_diff
    diff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(results["fedpc"]),
                        jax.tree_util.tree_leaves(results["fedpc_packed"])))
    out["packed_vs_plain_max_diff"] = diff

    # --- reference: core master_update_tree on the same inputs ----------
    from repro.core.goodness import select_pilot
    k_star, _ = select_pilot(costs, state["prev_costs"], sizes, 3)
    tern = jax.vmap(lambda q: ternarize_tree(
        q, state["params"], state["params_prev"], 0.2))(params_F)
    p_shares = sizes / jnp.sum(sizes)
    betas = jnp.full((F,), 0.2)
    q_pilot = jax.tree_util.tree_map(lambda x: x[k_star], params_F)
    want = master_update_tree(q_pilot, tern, p_shares, betas, k_star,
                              state["params"], state["params_prev"], 3, 0.01)
    ref_diff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(results["fedpc"]),
                        jax.tree_util.tree_leaves(want)))
    out["vs_reference_max_diff"] = ref_diff

    # --- round-1 branch: Eq. (4) codes + p_k-only weights ---------------
    state1 = fed_state_init(params, F)
    sync1 = build_fed_sync(m, mesh, "data", "fedpc")
    got1, _ = jax.jit(sync1)(params_F, costs, sizes, state1)
    k1, _ = select_pilot(costs, state1["prev_costs"], sizes, 1)
    tern1 = jax.vmap(lambda q: ternarize_tree_round1(
        q, state1["params"], 0.01))(params_F)
    q_pilot1 = jax.tree_util.tree_map(lambda x: x[k1], params_F)
    want1 = master_update_tree(q_pilot1, tern1, p_shares, betas, k1,
                               state1["params"], state1["params_prev"],
                               1, 0.01)
    out["round1_vs_reference_max_diff"] = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(got1),
                        jax.tree_util.tree_leaves(want1)))

    # --- full fed step runs and improves cost over rounds ---------------
    fs = build_fed_step(m, mesh, "data", "fedpc_packed", lr=0.05)
    st = fed_state_init(params, F)
    opt_F = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * F), m.optimizer.init(params))
    batch_F = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (F, 2, 2, 16), 0, cfg.vocab)}
    costs_hist = []
    step = jax.jit(fs)
    for _ in range(4):
        st, opt_F, metrics = step(st, opt_F, batch_F, sizes)
        costs_hist.append(float(metrics["cost_mean"]))
    out["costs"] = costs_hist

    # --- fedavg equals weighted average ----------------------------------
    sync_avg = build_fed_sync(m, mesh, "data", "fedavg")
    new_avg, _ = jax.jit(sync_avg)(params_F, costs, sizes, state)
    w = (sizes / jnp.sum(sizes)).reshape(-1, 1, 1)
    leaf = jax.tree_util.tree_leaves(params_F)[0]
    want0 = jnp.sum(leaf.astype(jnp.float32) *
                    w.reshape((-1,) + (1,) * (leaf.ndim - 1)), axis=0)
    got0 = jax.tree_util.tree_leaves(new_avg)[0]
    out["fedavg_max_diff"] = float(jnp.max(jnp.abs(
        got0.astype(jnp.float32) - want0)))

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_packed_equals_plain(results):
    assert results["packed_vs_plain_max_diff"] < 1e-6


def test_matches_core_reference(results):
    assert results["vs_reference_max_diff"] < 1e-5


def test_round1_matches_core_reference(results):
    """Round 1 must use p_k-only weights (Eq. (3) alpha0 rule), not
    beta-scaled ones — regression test for the round-1 divergence."""
    assert results["round1_vs_reference_max_diff"] < 1e-5


def test_fed_step_cost_improves(results):
    assert results["costs"][-1] < results["costs"][0]


def test_fedavg_weighted_average(results):
    assert results["fedavg_max_diff"] < 1e-5


def test_reduce_strategy_close_to_gather(results):
    # fedpc_reduce sums w_k·T_k in f16 on the wire — small quantization
    # error vs the exact int8 gather is expected and bounded
    assert results["reduce_vs_gather_max_diff"] < 2e-2
